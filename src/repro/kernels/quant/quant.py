"""Pallas TPU kernel: fixed-point fake quantization (paper §4 on TPU).

Elementwise round-and-saturate to a signed Q(i).(f) format. The widths are
RUNTIME scalars (held in SMEM), because the deployed equalizer adapts its
precision per layer from the learned QAT widths — reloading weights, not
recompiling, mirrors the FPGA's runtime-flexible datapath.

Blocked over the last dimension; VPU-elementwise, memory-bound by design —
it exists to be FUSED into consumers (see kernels/cnn_eq quantized variant)
and standalone mainly for validation and QAT experiments.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _quant_kernel(bits_ref, x_ref, o_ref):
    i_bits = bits_ref[0]
    f_bits = bits_ref[1]
    scale = jnp.exp2(f_bits)
    hi = jnp.exp2(i_bits) - 1.0 / scale
    lo = -jnp.exp2(i_bits)
    xq = jnp.round(x_ref[...].astype(jnp.float32) * scale) / scale
    o_ref[...] = jnp.clip(xq, lo, hi).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fixed_point_quantize(x: jnp.ndarray, int_bits: jnp.ndarray | float,
                         frac_bits: jnp.ndarray | float, block: int = 1024,
                         interpret: bool | None = None) -> jnp.ndarray:
    """Quantize an arbitrary-shape array to Q(int_bits).(frac_bits)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    block = min(block, n)
    n_blocks = pl.cdiv(n, block)
    if n_blocks * block != n:
        flat = jnp.pad(flat, (0, n_blocks * block - n))
    bits = jnp.stack([jnp.asarray(int_bits, jnp.float32),
                      jnp.asarray(frac_bits, jnp.float32)])

    out = pl.pallas_call(
        _quant_kernel,
        grid=(n_blocks,),
        in_specs=[
            # per-layer widths are runtime scalars → SMEM
            pl.BlockSpec((2,), lambda i: (0,), memory_space=pltpu.SMEM),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_blocks * block,), x.dtype),
        interpret=interpret,
    )(bits, flat)
    return out[:n].reshape(shape)
