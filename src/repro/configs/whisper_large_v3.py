"""whisper-large-v3 — encoder–decoder audio backbone [arXiv:2212.04356].

32+32L · d_model 1280 · 20 heads (MHA) · d_ff 5120 · vocab 51866 (padded to
51968 for the 128-lane boundary) · enc_len 1500. The mel/conv frontend is a
STUB: `input_specs()` provides precomputed frame embeddings. GELU MLP,
sinusoidal positions (rope disabled). TP note: 20 heads pad to 32 with full
KV expansion (DESIGN.md §5).
"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, enc_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab=51866, enc_len=1500,
    rope_theta=0.0, mlp_act="gelu",
    tp=16, train_accum=4,
)

REDUCED = ModelConfig(
    name="whisper-reduced", family="encdec",
    n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=500, enc_len=30,
    rope_theta=0.0, mlp_act="gelu", dtype="float32",
)
