"""Fig. 12 + §7.1 — timing-model validation: λ_sym and T_net vs ℓ_inst for
N_i ∈ {16, 32, 64}, model vs SIMULATED measurement (a cycle-accurate-at-the-
granularity-of-the-model event simulation of the SSM/MSM tree), plus the
paper's headline numbers (64 instances → 102.4 GSa/s, ℓ_inst 7320 →
17.5 µs)."""
from __future__ import annotations

import math

from repro.configs import equalizer_ht as HT
from repro.core import seqlen_opt, timing_model as tm
from repro.core.stream_partition import actual_overlap

from .common import Bench


def simulate_stream(cfg, hw, n_inst: int, l_inst: int, l_in: int):
    """Discrete-event walk of the split tree (the 'measurement' the paper
    compares its closed-form model against)."""
    o_act = actual_overlap(cfg, n_inst)
    l_ol = l_inst + 2 * o_act
    # t_init: each SSM level halves the stream width; writing to the second
    # output starts after ℓ_ol/(2·V_p) cycles per level
    levels = int(math.log2(n_inst)) if n_inst > 1 else 0
    f_clk = hw.sym_rate_per_inst / cfg.v_parallel
    t_init = levels * (l_ol / (2 * cfg.v_parallel)) / f_clk
    # processing: n_seq sequences of ℓ_ol, one per instance slot
    n_seq = l_in / (l_inst * n_inst)
    t_p = n_seq * l_ol / (cfg.v_parallel * f_clk)
    return t_init, l_in / t_p


def run() -> dict:
    bench = Bench("timing_model", "Fig. 12 / §6.1 / §7.1")
    cfg = HT.CNN
    hw = tm.fpga_profile(cfg, f_clk=HT.F_CLK)

    curves = {}
    max_err_lat, max_err_tp = 0.0, 0.0
    for n_inst in (16, 32, 64):
        pts = []
        for l_inst in (1024, 2048, 4096, 8192, 16384, 32768):
            lam = tm.symbol_latency(cfg, hw, n_inst, l_inst)
            tnet = tm.net_throughput(cfg, hw, n_inst, l_inst)
            lam_sim, tnet_sim = simulate_stream(cfg, hw, n_inst, l_inst,
                                                l_in=l_inst * n_inst * 8)
            pts.append({"l_inst": l_inst, "lat_model_us": lam * 1e6,
                        "lat_sim_us": lam_sim * 1e6,
                        "tput_model_gsyms": tnet / 1e9,
                        "tput_sim_gsyms": tnet_sim / 1e9})
            if lam_sim:
                max_err_lat = max(max_err_lat, abs(lam - lam_sim) / lam_sim)
            max_err_tp = max(max_err_tp, abs(tnet - tnet_sim) / tnet_sim)
        curves[f"n_inst_{n_inst}"] = {
            "t_max_gsyms": tm.max_throughput(hw, n_inst) / 1e9,
            "points": pts,
        }
    bench.record("curves", curves)
    bench.record("model_vs_sim_max_err",
                 {"latency": max_err_lat, "throughput": max_err_tp})

    # §7.1/7.2 headline numbers
    t_max64 = tm.max_throughput(hw, 64)
    l_pick = seqlen_opt.optimal_l_inst(cfg, hw, 64, HT.T_REQ_SAMPLES)
    lam_pick = tm.symbol_latency(cfg, hw, 64, l_pick)
    # 64 is the MINIMAL instance count reaching 80 GSa/s
    n_min = next(n for n in (16, 32, 64, 128)
                 if tm.max_throughput(hw, n) > HT.T_REQ_SAMPLES)
    bench.record("headline", {
        "t_max_64_gsyms": t_max64 / 1e9,          # paper: 102.4
        "n_instances_min": n_min,                  # paper: 64
        "l_inst_selected": l_pick,                 # paper: 7320
        "latency_at_selected_us": lam_pick * 1e6,  # paper: 17.5 µs
        "paper_l_inst": HT.L_INST,
        "t_net_at_selected_gsyms":
            tm.net_throughput(cfg, hw, 64, l_pick) / 1e9,
    })
    print(f"[bench_timing] T_max(64)={t_max64/1e9:.1f} GSa/s, "
          f"ℓ_inst={l_pick} (paper 7320), λ={lam_pick*1e6:.2f} µs "
          f"(paper 17.5), model-vs-sim err: lat {max_err_lat:.1%}, "
          f"tput {max_err_tp:.2%}")
    return bench.finish()


if __name__ == "__main__":
    run()
