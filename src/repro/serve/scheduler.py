"""Dynamic micro-batching — many tenant streams, one fused-kernel launch.

The paper's FPGA hits its throughput target by instantiating N_i parallel
CNN instances and streaming one link through each; the GPU baseline it beats
by three orders of magnitude loses exactly because small per-link calls
cannot fill the device. The TPU serving answer is the same shape as the
FPGA's: keep the datapath full by running MANY links per launch — here by
stacking the pending chunks of all tenants that share a `group_key()`
(topology + backend + static kernel config) into one batched fused kernel
with per-row tenant weights (`core.engine.stacked_engine_fn`).

Coalescing policy (the classic dynamic-batching trade-off):
  * max_batch   — launch as soon as this many tenant chunks are pending
                  in a group (throughput knob);
  * max_wait_s  — … or as soon as the OLDEST pending chunk has waited this
                  long (tail-latency knob);
  * `drain()`   — launch everything now (end of stream / shutdown).

A launch is split into three phases so an async front-end can pipeline
them (see `runtime.AsyncServeRuntime`):

  take_ready()  policy check + pop + ASSEMBLE: build the padded stacked
                input and look up the memoized per-group launch fn — pure
                host work (numpy, dict lookups);
  execute()     the device phase: dispatch the fused kernel and block
                until the stacked output is ready;
  descatter()   host work again: slice each tenant's rows out, append to
                its session, resolve its future, record latency/traffic.

The synchronous `pump()`/`drain()`/`flush_session()` drivers run all three
phases inline on the caller's thread (deterministic, single-threaded — the
tier-1 parity surface); `AsyncServeRuntime` runs execute() on a dedicated
launcher thread so the host phases of launch k+1 overlap the device phase
of launch k.

Every request carries submit/launch/done timestamps; `latency_stats()`
reports p50/p99 queueing and total latency plus batch-occupancy history —
the numbers `benchmarks/bench_serve.py` publishes. Per tune-key
`TrafficStats` (batch-occupancy and launch-width histograms) additionally
feed the serve-aware autotune re-tune (`runtime.py`).
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import threading
import time
from collections import Counter, deque
from typing import (Callable, Deque, Dict, List, Optional, Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from ..core.engine import stacked_engine_fn
from ..obs import Observability
from .chunker import ChunkPlan
from .recovery import CorruptOutput, output_ok
from .session import Session

_CONSUMED = np.zeros((0,), np.float32)     # placeholder for launched inputs


@dataclasses.dataclass(frozen=True)
class BatchPolicy:
    """Micro-batching policy knobs (all per `MicroBatcher`, i.e. runtime-wide).

    max_batch:    maximum tenant chunks coalesced into one stacked launch
                  (count; default 8). A group launches as soon as this many
                  chunks are pending — the throughput knob. Must be ≥ 1;
                  1 disables coalescing (one launch per chunk).
    max_wait_s:   maximum queueing age of the oldest pending chunk before
                  its group launches anyway (seconds; default 2 ms) — the
                  tail-latency knob. Only honoured when something calls
                  `pump()` (the sync runtime pumps inside submit;
                  `AsyncServeRuntime` runs it from a timer thread). Set
                  very large (e.g. 1e9) to batch purely on max_batch.
    width_bucket: row-padding quantum for stacked launches (samples;
                  default 0 = auto → one kernel tile, tile_m·V_p·N_os).
                  Bounds the set of compiled launch shapes. Values that are
                  not a multiple of the tile quantum are rounded UP to it —
                  a sub-tile bucket would break the chunker's bitwise
                  contract (see `_bucket_width`), so it cannot be expressed.
    retune_after: serve-aware autotune warm-up threshold (launches per
                  `EqualizerEngine.tune_key()`; default 64; 0 disables).
                  Once a tune-key has this many recorded launches, tenants
                  opened with tile_m="auto" get their tile re-tuned against
                  the OBSERVED batch-occupancy/width histograms instead of
                  the single-stream autotune default. Already-open sessions
                  keep their tile — a mid-stream tile change would break
                  the chunker's tile-alignment (bitwise) invariant.
    """
    max_batch: int = 8
    max_wait_s: float = 2e-3
    width_bucket: int = 0
    retune_after: int = 64


@dataclasses.dataclass
class Request:
    """One tenant chunk queued for a batched launch.

    `future` (a `concurrent.futures.Future`) is set by the async runtime at
    enqueue time and resolved with this request's emitted symbols at
    descatter — the per-chunk awaitable handle. The sync runtime leaves it
    None and callers read `symbols` directly after pump/drain.
    """
    session: Session
    plan: ChunkPlan
    t_submit: float
    t_launch: float = 0.0
    t_done: float = 0.0
    batch_size: int = 0
    symbols: Optional[np.ndarray] = None
    future: Optional[concurrent.futures.Future] = None

    @property
    def done(self) -> bool:
        return self.symbols is not None

    @property
    def wait_s(self) -> float:
        return self.t_launch - self.t_submit

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit


@dataclasses.dataclass
class LaunchBatch:
    """One assembled stacked launch: everything execute() needs, no more.

    Assembly snapshots the padded input `x` and the memoized launch fn so
    the device phase touches NO scheduler state — the async launcher thread
    runs execute() without holding the runtime lock.
    """
    key: Tuple                      # the group_key the requests share
    reqs: List[Request]
    x: np.ndarray                   # (B, W) padded stacked input
    fn: Callable[[jnp.ndarray], jnp.ndarray]


class TrafficStats:
    """Live per-tune-key traffic histograms for serve-aware autotune.

    Counts are per LAUNCH (not per request): `occupancy` histograms the
    stacked batch size B, `widths` the padded launch width W in samples
    (post width-bucket rounding, so the support is small). Bounded by
    construction — distinct (B, W) pairs are few because the bucketing
    quantizes widths.

    Thread-safe: fleet worker launchers record launches concurrently with
    the controller reading the histograms for placement/autotune (PR 6
    assumed one launcher thread). Mutation and snapshotting go through an
    internal lock; the derived statistics (`mode_occupancy`,
    `median_width`, `as_dict`) compute from a locked snapshot so a racing
    `record` can never half-update what they see.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self.launches = 0
        self.occupancy: Counter = Counter()
        self.widths: Counter = Counter()

    def record(self, batch_size: int, width_samples: int) -> None:
        with self._lock:
            self.launches += 1
            self.occupancy[int(batch_size)] += 1
            self.widths[int(width_samples)] += 1

    def _snapshot(self) -> Tuple[int, Counter, Counter]:
        with self._lock:
            return self.launches, Counter(self.occupancy), \
                Counter(self.widths)

    def mode_occupancy(self) -> int:
        """The most common stacked batch size (0 if no traffic yet)."""
        _, occupancy, _ = self._snapshot()
        if not occupancy:
            return 0
        return max(sorted(occupancy), key=occupancy.get)

    def median_width(self) -> int:
        """Median padded launch width in samples (0 if no traffic yet)."""
        _, _, widths = self._snapshot()
        if not widths:
            return 0
        flat = sorted(w for w, c in widths.items() for _ in range(c))
        return flat[len(flat) // 2]

    def as_dict(self) -> Dict:
        launches, occupancy, widths = self._snapshot()
        flat = sorted(w for w, c in widths.items() for _ in range(c))
        return {"launches": launches,
                "occupancy": dict(sorted(occupancy.items())),
                "widths": dict(sorted(widths.items())),
                "mode_occupancy": (max(sorted(occupancy),
                                       key=occupancy.get)
                                   if occupancy else 0),
                "median_width": flat[len(flat) // 2] if flat else 0}


class MicroBatcher:
    """Groups pending requests by engine `group_key()` and launches them as
    stacked fused calls under the max-batch / max-wait policy."""

    # stacked-fn cache bound: steady-state traffic cycles through few
    # distinct (ordered) tenant sets; 64 covers many groups without
    # pinning unbounded weight stacks
    FN_CACHE_MAX = 64
    # default latency-window bound; the live bound comes from
    # `Retention.latency_window` (same default) — a bounded window, not the
    # full history (unbounded streams would otherwise leak one Request,
    # with its symbols array, per chunk forever)
    COMPLETED_MAX = 8192

    def __init__(self, policy: Optional[BatchPolicy] = None,
                 clock: Callable[[], float] = time.perf_counter,
                 obs: Optional[Observability] = None,
                 obs_scope: str = "serve"):
        self.policy = policy or BatchPolicy()
        self.clock = clock
        # observability spine: runtimes pass their hub (fleet workers with
        # per-worker scopes like "fleet.worker0"); a standalone batcher
        # gets a private hub with tracing off, so every hook below is a
        # cheap guarded no-op by default
        self.obs = obs if obs is not None else Observability(clock=clock)
        self.tracer = self.obs.tracer
        window = self.obs.retention.latency_window
        scope = self.obs.scope(obs_scope)
        self._m_requests = scope.counter("requests_total")
        self._m_launches = scope.counter("launches_total")
        self._h_latency = scope.histogram("launch.latency_s", window)
        self._h_wait = scope.histogram("launch.wait_s", window)
        self._h_occupancy = scope.histogram("launch.occupancy", window)
        self._h_width = scope.histogram("launch.width_samples", window)
        self._h_device = scope.histogram("launch.device_s", window)
        self._h_descatter = scope.histogram("launch.descatter_s", window)
        scope.callback("pending", self.pending)
        scope.callback("latency", self.latency_stats)
        scope.callback("traffic", self.traffic_stats)
        self._groups: Dict[Tuple, List[Request]] = {}
        # (id(engine), …) → (engine refs, stacked fn). Holding the refs
        # keeps the ids valid; bounded FIFO so evicted engines can be GC'd.
        self._fn_cache: "Dict[Tuple, Tuple[list, Callable]]" = {}
        self.completed: Deque[Request] = deque(maxlen=window)
        self.batch_sizes: Deque[int] = deque(maxlen=window)
        # tune_key (group_key minus tile) → live width/occupancy histograms
        self.traffic: Dict[Tuple, TrafficStats] = {}
        self.total_requests = 0
        self.launches = 0
        # fault-tolerance hooks (serve/recovery.py): an optional
        # deterministic chaos schedule, and the output-sentinel bound
        # (None = no check). `exec_seq` numbers execute ATTEMPTS — the
        # index space FaultPlan launch faults are scheduled in; it only
        # ever advances on the launching thread (sync caller or the async
        # launcher), so a plain int is race-free.
        self.fault_plan = None
        self.sentinel_limit: Optional[float] = None
        self.exec_seq = 0
        # fleet identity (serve/fleet.py): set by FleetRuntime so device
        # fault kinds (FaultPlan.on_worker) can target THIS worker by
        # index; None outside a fleet. Because every fleet worker owns
        # its own batcher, `exec_seq` doubles as the per-worker execute
        # index the `Fault.after` schedule counts.
        self.worker_index: Optional[int] = None

    # -- queueing ----------------------------------------------------------

    def enqueue(self, session: Session) -> Optional[Request]:
        """Turn the session's pending stream samples into a queued request
        (None if the chunker has nothing emittable yet).

        The chunker commits here — at enqueue, not at launch — so a tenant
        can queue several requests back-to-back without double-planning the
        same positions. That is safe because a plan is a self-contained
        input snapshot: a failed launch re-queues its requests (see pump /
        flush_session) or retries in place (async launcher) and never needs
        the chunker rewound.
        """
        plan = session.chunker.plan()
        if plan is None:
            return None
        session.chunker.commit(plan)
        req = Request(session=session, plan=plan, t_submit=self.clock())
        span = self.tracer.begin(session.spec.tenant_id)
        if span is not None:                       # tracing on: the span
            span.stamp("submit", req.t_submit)     # rides the plan from
            plan.span = span                       # here to emit/seal
            # cross-wire propagation: contexts the net ingress queued for
            # this tenant (v2 DATA frames) become span events, so the
            # Chrome lane starts at the client's send timestamp
            while session.trace_ctx:
                tid_, t_client, t_ingress = session.trace_ctx.popleft()
                span.event("client_send", t_client, trace_id=tid_)
                span.event("net_ingress", t_ingress, trace_id=tid_)
        key = session.engine.group_key()
        self._groups.setdefault(key, []).append(req)
        return req

    def pending(self) -> int:
        return sum(len(v) for v in self._groups.values())

    # -- launch phases (assemble → execute → descatter) --------------------

    def take_ready(self, now: Optional[float] = None,
                   force: bool = False) -> List[LaunchBatch]:
        """Pop and ASSEMBLE every policy-ready batch (all, if force).

        Host-only phase: builds each batch's padded stacked input and
        launch fn, removes its requests from the queues. The caller owns
        the returned batches — it must execute+descatter each, or requeue()
        them (in reverse order) on failure, or no symbols are ever emitted.
        """
        if now is None:
            now = self.clock()
        out: List[LaunchBatch] = []
        for key in list(self._groups):
            reqs = self._groups[key]
            while reqs and (
                    force
                    or len(reqs) >= self.policy.max_batch
                    or now - reqs[0].t_submit >= self.policy.max_wait_s):
                take = reqs[:self.policy.max_batch]
                del reqs[:self.policy.max_batch]
                out.append(self.assemble(key, take))
            if not reqs:
                del self._groups[key]
        return out

    def take_session(self, session: Session) -> List[LaunchBatch]:
        """Pop and assemble ONLY this session's pending requests (tenant
        close/tail flush). Other tenants' partial batches stay queued so
        their max_batch/max_wait policy — and batch occupancy — is
        untouched."""
        out: List[LaunchBatch] = []
        for key in list(self._groups):
            reqs = self._groups[key]
            mine = [r for r in reqs if r.session is session]
            if not mine:
                continue
            rest = [r for r in reqs if r.session is not session]
            if rest:
                self._groups[key] = rest
            else:
                del self._groups[key]
            for i in range(0, len(mine), self.policy.max_batch):
                out.append(self.assemble(key, mine[i:i + self.policy.max_batch]))
        return out

    def requeue(self, batch: LaunchBatch) -> None:
        """Put an un-executed batch's requests back at the head of their
        group (launch failure; plans are self-contained input snapshots so
        this is always safe). When several batches failed, requeue them in
        REVERSE take order so stream order per session is preserved."""
        if self.tracer.enabled:
            t = self.clock()
            for r in batch.reqs:
                if r.plan.span is not None:
                    r.plan.span.event("requeue", t)
        self._groups.setdefault(batch.key, [])[:0] = batch.reqs

    def adopt_requests(self, reqs: List[Request]) -> None:
        """Admit EXISTING Request objects into this batcher's queues (the
        fleet migration path: a dead worker's un-landed requests, plans
        and futures intact, move to a surviving worker's batcher). The
        caller must already have re-pointed each `Request.session` at a
        session rebuilt against THIS worker's pool — the group key is
        recomputed from that session's engine, so adopted requests stack
        with the new worker's traffic. Input order is preserved, which is
        what keeps per-session replay FIFO."""
        for r in reqs:
            key = r.session.engine.group_key()
            self._groups.setdefault(key, []).append(r)

    def evict_all(self) -> List[Request]:
        """Pop EVERY pending request, preserving per-group enqueue order
        (fleet worker death: never-assembled requests migrate too)."""
        out: List[Request] = []
        for key in list(self._groups):
            out.extend(self._groups.pop(key))
        return out

    def assemble(self, key: Tuple, reqs: List[Request]) -> LaunchBatch:
        """Host phase 1: pad the requests' plans to one width bucket, stack
        them into the (B, W) launch input, bind the memoized group fn."""
        if self.tracer.enabled:
            t = self.clock()
            for r in reqs:
                if r.plan.span is not None:
                    r.plan.span.stamp("assemble", t)
        engines = [r.session.engine for r in reqs]
        fn = self._group_fn(engines)
        width = self._bucket_width(reqs)
        x = np.zeros((len(reqs), width), np.float32)
        for i, r in enumerate(reqs):
            x[i, :r.plan.width] = r.plan.data      # right zero-pad = offline
        return LaunchBatch(key=key, reqs=reqs, x=x, fn=fn)

    def execute(self, batch: LaunchBatch) -> np.ndarray:
        """Device phase: ONE stacked fused-kernel launch, blocking until
        the (B, S) output is on host. Touches no scheduler state beyond
        the attempt counter — safe to run off-thread without the runtime
        lock. Each call consumes one `exec_seq` index; an installed
        `FaultPlan` may raise/delay before the dispatch or corrupt the
        landed output at its scheduled indices (retries and failover
        replays consume FRESH indices, so an injected fault fires once)."""
        idx, self.exec_seq = self.exec_seq, self.exec_seq + 1
        if self.fault_plan is not None:
            if self.worker_index is not None:
                self.fault_plan.on_worker(self.worker_index, idx)
            self.fault_plan.on_execute(idx)
        t_launch = self.clock()
        if self.tracer.enabled:          # stamp AFTER the fault hooks so a
            for r in batch.reqs:         # raised injection never stamps —
                if r.plan.span is not None:   # the retry's stamps describe
                    r.plan.span.stamp("launch", t_launch)  # the real launch
        y = batch.fn(jnp.asarray(batch.x))
        y = np.asarray(jax.block_until_ready(y))
        if self.fault_plan is not None:
            y = self.fault_plan.on_output(idx, y)
        t_landed = self.clock()
        self._h_device.observe(t_landed - t_launch)
        if self.tracer.enabled:
            for r in batch.reqs:
                if r.plan.span is not None:
                    r.plan.span.stamp("execute", t_landed)
        for r in batch.reqs:
            r.t_launch = t_launch
        return y

    def descatter(self, batch: LaunchBatch, y: np.ndarray) -> None:
        """Host phase 2: slice each tenant's emitted rows out of the
        stacked output, append to its session in stream order, resolve its
        future, record latency + traffic stats.

        The output sentinel runs FIRST, before any row is emitted: a
        rejected batch raises `CorruptOutput` with the batch state fully
        intact (inputs unconsumed, futures pending, nothing appended), so
        the caller can requeue or replay it exactly like a failed launch —
        quarantine instead of emitting garbage."""
        if self.sentinel_limit is not None and not output_ok(
                y, self.sentinel_limit):
            raise CorruptOutput(
                f"stacked output rejected by sentinel (|y| ≤ "
                f"{self.sentinel_limit:g} violated or non-finite) for "
                f"batch of {len(batch.reqs)}")
        t_done = self.clock()
        reqs = batch.reqs
        for i, r in enumerate(reqs):
            vp = r.session.v_parallel
            syms = y[i, r.plan.skip * vp:(r.plan.skip + r.plan.n_emit) * vp]
            r.symbols = syms
            r.t_done, r.batch_size = t_done, len(reqs)
            r.session.append_output(syms)
            if r.session.tap is not None:
                # adaptation tap: the REAL input samples behind the emitted
                # positions (skip/context sliced off) + the symbols they
                # produced — the (rx, decision) pairs repro.adapt collects
                ts = r.session.chunker.ts
                lo = r.plan.skip * ts
                r.session.tap(r.plan.data[lo:lo + r.plan.n_emit * ts], syms)
            span = r.plan.span
            if span is not None:
                span.stamp("descatter", t_done)
                span.n_emit = r.plan.n_emit
                span.width = r.plan.width
            r.plan.data = _CONSUMED        # release the input buffer; the
            self.completed.append(r)       # record keeps only timing+syms
            # a caller may legally cancel() a pending chunk future; the
            # symbols still join the stream (cancel abandons the
            # notification, not the data) — set_result on a cancelled
            # future would raise and poison the whole batch
            if r.future is not None and not r.future.done():
                r.future.set_result(syms)
            if span is not None:           # emitted ⇒ sealed exactly once
                span.stamp("emit", self.clock())
                self.tracer.seal(span)
            self._h_latency.observe(r.latency_s)
            self._h_wait.observe(r.wait_s)
        skey = reqs[0].session.engine.tune_key()
        self.traffic.setdefault(skey, TrafficStats()).record(
            len(reqs), batch.x.shape[1])
        self.total_requests += len(reqs)
        self.batch_sizes.append(len(reqs))
        self.launches += 1
        self._m_requests.inc(len(reqs))
        self._m_launches.inc()
        self._h_occupancy.observe(len(reqs))
        self._h_width.observe(batch.x.shape[1])
        self._h_descatter.observe(self.clock() - t_done)

    def fail(self, batch: LaunchBatch, exc: BaseException) -> None:
        """Terminal launch failure (async path, after retries): fail every
        request's future and poison its session so a later output()/close()
        raises instead of silently returning a stream with a hole.
        Idempotent per request — futures already resolved (e.g. a failure
        mid-descatter) are left alone."""
        self.fail_requests(batch.reqs, exc)

    def fail_requests(self, reqs: List[Request], exc: BaseException) -> None:
        """Poison a SUBSET of a failed batch's requests (the failover path
        partitions a batch into replayable and over-budget requests — only
        the latter die). Same semantics as `fail`, per request."""
        t = self.clock() if self.tracer.enabled else 0.0
        for r in reqs:
            r.session.failed = exc
            if r.future is not None and not r.future.done():
                r.future.set_exception(exc)
            span = r.plan.span
            if span is not None:           # poisoned chunks seal "failed":
                span.event("poisoned", t, error=repr(exc))   # never counted
                self.tracer.seal(span, status="failed")      # as emitted


    # -- synchronous drivers ----------------------------------------------

    def _run(self, batches: List[LaunchBatch]) -> int:
        """Execute+descatter assembled batches inline; on failure requeue
        every un-executed batch (reverse order) and surface the error —
        transient device failures are retryable via the next pump."""
        n = 0
        try:
            for b in batches:
                y = self.execute(b)
                self.descatter(b, y)
                n += 1
        except Exception:
            for b in reversed(batches[n:]):
                self.requeue(b)
            raise
        return n

    def pump(self, force: bool = False) -> int:
        """Launch every group that meets the policy (or all, if force).
        Returns the number of launches performed."""
        return self._run(self.take_ready(self.clock(), force=force))

    def drain(self) -> int:
        return self.pump(force=True)

    def flush_session(self, session: Session) -> int:
        """Synchronously launch ONLY this session's pending requests."""
        return self._run(self.take_session(session))

    # -- assembly helpers --------------------------------------------------

    def _bucket_width(self, reqs: List[Request]) -> int:
        e = reqs[0].session.engine
        tile_q = e.resolved_tile_m() * e.total_stride
        q = self.policy.width_bucket
        # the bucket MUST be a whole number of tiles: a sub-tile-width row
        # would shrink the kernel's effective tile (n_pos < tile_m) and
        # void the chunker's tile-alignment ⇒ bitwise-offline invariant,
        # so a user quantum is rounded up to the tile quantum
        q = tile_q if q <= 0 else (-(-q // tile_q) * tile_q)
        w = max(r.plan.width for r in reqs)
        return -(-w // q) * q                      # ceil to bucket quantum

    def _group_fn(self, engines) -> Callable:
        """Memoized stacked launch fn: steady-state round-robin traffic
        re-batches the SAME engines in the SAME order every round, so the
        per-launch weight re-stack (and its host→device transfer) is paid
        once per tenant set, not once per launch."""
        key = tuple(id(e) for e in engines)
        hit = self._fn_cache.get(key)
        if hit is not None:
            return hit[1]
        fn = stacked_engine_fn(engines)
        self._fn_cache[key] = (list(engines), fn)
        while len(self._fn_cache) > self.FN_CACHE_MAX:
            self._fn_cache.pop(next(iter(self._fn_cache)))
        return fn

    # -- accounting --------------------------------------------------------

    def traffic_stats(self) -> Dict[str, Dict]:
        """Live serve-aware histograms, one entry per tune-key (keys are
        stringified for JSON-ability — `cfg layers/backend` summary)."""
        out = {}
        for key, st in self.traffic.items():
            cfg, backend = key[0], key[1]
            out[f"L{cfg.layers}_K{cfg.kernel}_{backend}"] = st.as_dict()
        return out

    def latency_stats(self) -> Dict[str, float]:
        """Percentiles over the last `Retention.latency_window` requests
        (full history for any run shorter than the window, e.g. the
        benches)."""
        if not self.completed:
            return {"requests": 0}
        lat = np.array([r.latency_s for r in self.completed])
        wait = np.array([r.wait_s for r in self.completed])
        occ = np.array(self.batch_sizes, np.float64)
        return {
            "requests": self.total_requests,
            "launches": self.launches,
            "mean_batch": float(occ.mean()),
            "p50_latency_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_latency_ms": float(np.percentile(lat, 99) * 1e3),
            "p50_wait_ms": float(np.percentile(wait, 50) * 1e3),
            "p99_wait_ms": float(np.percentile(wait, 99) * 1e3),
        }
