"""Streaming link-quality estimation from served symbols (signal health).

The system metrics (PR 8) see launches, latencies, and retries — never the
SIGNAL: a tenant whose channel drifts keeps serving fast, traced, and
silently garbage. The real-time equalizer demonstrators report live
EVM/BER as THE operational metric and retrain when it degrades; this
module is that signal plane for the serving stack.

`LinkMonitor` hangs off the `Session.tap` seam (the same descatter hook
the PR 7 `SampleCollector` uses — `Session.add_tap` fans the two out) and
incrementally estimates, per tenant, from every emitted chunk:

  * EVM   — decision-directed error-vector magnitude: the RMS distance of
            the soft symbols to their nearest constellation points, over
            the RMS of the decided points:  sqrt(E|y - ŷ|² / E|ŷ|²).
  * SNR   — the matching decision-directed SNR estimate,
            10·log10(E|ŷ|² / E|y - ŷ|²) dB. At operating SNRs almost all
            decisions are correct, so the residual IS noise+ISI and the
            estimate tracks the true channel SNR ramp (bench_link gates
            on exactly that).
  * SER proxy — the predicted nearest-constellation-point disagreement
            rate: the probability that a decision differs from the
            transmitted symbol under the Gaussian residual model,
            2·(1−1/M)·Q(d_min/2σ) for M-PAM with measured residual σ —
            a live BER-shaped health number with no pilots needed.
  * confidence — a histogram of per-symbol decision margins,
            (d₂ − d₁)/d_min ∈ [0, 1] (distance to the runner-up point
            minus distance to the decided point, in units of the
            half-grid): mass near 0 means symbols sitting on decision
            boundaries — degradation visible before errors are.

Everything is windowed (last `window` symbols, the live view) AND
lifetime (stream totals), registered as ``link.<tenant>.*`` gauges /
histograms in the hub's `MetricsRegistry` (tenant ids sanitized with the
same `safe_segment` the adapt metrics use).

Contract #11 (extended): estimation is pure host-side numpy over symbols
that were ALREADY emitted — it never touches launch order, launch inputs,
or the device, so serving with link telemetry on stays bitwise-equal to
offline. `benchmarks/bench_link.py` gates on that.

An attached `SloEngine` is stepped after every segment (for that tenant
only), so SLO edges fire with segment granularity without any polling
thread.
"""
from __future__ import annotations

import dataclasses
import math
import threading
from collections import deque
from typing import Deque, Dict, Optional

import numpy as np

from .hub import Observability
from .metrics import DEFAULT_WINDOW, safe_segment
from .slo import SloEngine


def pam_amplitudes(levels: int) -> np.ndarray:
    """Unit-power M-PAM constellation (numpy twin of channels.common and
    adapt.collector — kept local so obs stays dependency-free)."""
    pts = 2.0 * np.arange(levels, dtype=np.float32) - (levels - 1)
    return pts / np.sqrt(np.mean(pts**2))


def q_function(x: float) -> float:
    """Gaussian tail probability Q(x) = P(N(0,1) > x)."""
    return 0.5 * math.erfc(x / math.sqrt(2.0))


def pam_ser(snr_linear: float, levels: int) -> float:
    """Analytic M-PAM symbol-error rate at a given SNR (Es/N0, linear) —
    the closed form the SER proxy inverts; exposed for estimator tests."""
    m = levels
    if m < 2:
        return 0.0
    # unit-power constellation: d_min/2 = sqrt(3/(M²−1)) · sqrt(Es)
    arg = math.sqrt(3.0 / (m * m - 1.0) * snr_linear)
    return 2.0 * (1.0 - 1.0 / m) * q_function(arg)


@dataclasses.dataclass(frozen=True)
class LinkEstimate:
    """One tenant's link-quality readout (windowed + lifetime)."""
    tenant_id: str
    syms: int                   # lifetime symbols observed
    evm: float                  # windowed
    snr_db: float
    ser_proxy: float
    evm_lifetime: float
    snr_db_lifetime: float
    ser_proxy_lifetime: float


class _TenantLink:
    """Per-tenant accumulator: bounded window + lifetime sums."""

    __slots__ = ("err2", "sig2", "err2_life", "sig2_life", "syms",
                 "g_evm", "g_snr", "g_ser", "g_evm_l", "g_snr_l", "g_ser_l",
                 "c_syms", "c_segs", "h_conf")

    def __init__(self, window: int, scope) -> None:
        self.err2: Deque[float] = deque(maxlen=window)
        self.sig2: Deque[float] = deque(maxlen=window)
        self.err2_life = 0.0
        self.sig2_life = 0.0
        self.syms = 0
        self.g_evm = scope.gauge("evm")
        self.g_snr = scope.gauge("snr_db")
        self.g_ser = scope.gauge("ser_proxy")
        self.g_evm_l = scope.gauge("lifetime.evm")
        self.g_snr_l = scope.gauge("lifetime.snr_db")
        self.g_ser_l = scope.gauge("lifetime.ser_proxy")
        self.c_syms = scope.counter("syms")
        self.c_segs = scope.counter("segments")
        self.h_conf = scope.histogram("confidence")


class LinkMonitor:
    """Per-tenant streaming EVM/SNR/SER estimation over the tap seam.

    obs:    the runtime's `Observability` hub (gauges land in its registry,
            names ``<scope>.<tenant>.*``, scope default "link").
    window: symbols in the live window (default `DEFAULT_WINDOW`).
    slo:    optional `SloEngine` — watched per tenant at attach and stepped
            after every segment, the event-driven alternative to polling.

    `attach(session)` wires the monitor into a live session via
    `Session.add_tap`, composing with any collector tap already installed;
    the PAM order comes from the session's own `CNNEqConfig.levels`.
    `observe(tenant, soft)` is the raw entry point for tests and for
    callers without a session object (call `watch` first).
    """

    def __init__(self, obs: Observability, window: int = DEFAULT_WINDOW,
                 slo: Optional[SloEngine] = None,
                 scope: str = "link") -> None:
        if window < 1:
            raise ValueError("LinkMonitor window must be >= 1")
        self.obs = obs
        self.window = window
        self.slo = slo
        self._scope = obs.scope(scope)
        self._lock = threading.Lock()
        self._tenants: Dict[str, _TenantLink] = {}
        self._amps: Dict[str, np.ndarray] = {}
        self._dmin: Dict[str, float] = {}

    # -- wiring ----------------------------------------------------------------

    def watch(self, tenant_id: str, levels: int) -> None:
        """Register a tenant (idempotent): create its accumulator, its
        ``link.<tenant>.*`` instruments, and its constellation grid."""
        if levels < 2:
            raise ValueError("LinkMonitor needs a PAM order >= 2")
        with self._lock:
            if tenant_id in self._tenants:
                return
            seg = safe_segment(tenant_id)
            self._tenants[tenant_id] = _TenantLink(
                self.window, self._scope.scope(seg))
            amps = np.sort(pam_amplitudes(levels))
            self._amps[tenant_id] = amps
            self._dmin[tenant_id] = float(np.min(np.diff(amps)))
        if self.slo is not None:
            self.slo.watch(tenant_id)

    def attach(self, session) -> None:
        """Wire this monitor into a live session's descatter tap (fans out
        with any existing tap, e.g. an adaptation collector)."""
        tid = session.spec.tenant_id
        self.watch(tid, session.spec.cfg.levels)

        def _tap(rx, soft, _tid=tid):
            self.observe(_tid, soft)

        session.add_tap(_tap)

    @property
    def tenants(self):
        with self._lock:
            return tuple(self._tenants)

    # -- estimation --------------------------------------------------------------

    def observe(self, tenant_id: str, soft_syms) -> None:
        """Fold one emitted chunk's soft symbols into the tenant's
        estimators and publish the gauges. Host-side numpy only; copies
        nothing it keeps beyond scalar sums (contract #11)."""
        y = np.asarray(soft_syms, np.float64).reshape(-1)
        if y.size == 0:
            return
        with self._lock:
            st = self._tenants.get(tenant_id)
            amps = self._amps.get(tenant_id)
            d_min = self._dmin.get(tenant_id, 0.0)
        if st is None:
            raise KeyError(f"tenant {tenant_id!r} not watched "
                           f"(call watch/attach first)")
        d = np.abs(y[:, None] - amps[None, :])         # (n, M), M small
        near = np.argmin(d, axis=1)
        decided = amps[near]
        err2 = (y - decided) ** 2
        sig2 = decided.astype(np.float64) ** 2
        if amps.size > 1:
            dp = np.partition(d, 1, axis=1)
            conf = np.clip((dp[:, 1] - dp[:, 0]) / d_min, 0.0, 1.0)
        else:
            conf = np.ones_like(err2)
        m = int(amps.size)
        with self._lock:
            st.err2.extend(err2.tolist())
            st.sig2.extend(sig2.tolist())
            st.err2_life += float(err2.sum())
            st.sig2_life += float(sig2.sum())
            st.syms += int(y.size)
            e_w = math.fsum(st.err2) / len(st.err2)
            s_w = math.fsum(st.sig2) / len(st.sig2)
            e_l = st.err2_life / st.syms
            s_l = st.sig2_life / st.syms
        st.h_conf.observe_many(conf)
        st.c_syms.inc(int(y.size))
        st.c_segs.inc()
        st.g_evm.set(self._evm(e_w, s_w))
        st.g_snr.set(self._snr_db(e_w, s_w))
        st.g_ser.set(self._ser(e_w, s_w, d_min, m))
        st.g_evm_l.set(self._evm(e_l, s_l))
        st.g_snr_l.set(self._snr_db(e_l, s_l))
        st.g_ser_l.set(self._ser(e_l, s_l, d_min, m))
        if self.slo is not None:
            self.slo.step(tenant_id)

    # the decided points carry the constellation's power; a dead stream
    # (all-zero symbols decided to the innermost points) still has s > 0
    # for every unit-power M-PAM with even M, and the guards below keep
    # odd/degenerate grids from dividing by zero

    SNR_CAP_DB = 99.0          # reported when the residual is exactly zero

    @staticmethod
    def _evm(e: float, s: float) -> float:
        return math.sqrt(e / s) if s > 0 else float("inf")

    @classmethod
    def _snr_db(cls, e: float, s: float) -> float:
        if s <= 0:
            return -cls.SNR_CAP_DB
        if e <= 0:
            return cls.SNR_CAP_DB
        return min(cls.SNR_CAP_DB, 10.0 * math.log10(s / e))

    @staticmethod
    def _ser(e: float, s: float, d_min: float, m: int) -> float:
        if m < 2 or d_min <= 0:
            return 0.0
        sigma = math.sqrt(max(e, 1e-300))
        return 2.0 * (1.0 - 1.0 / m) * q_function(d_min / (2.0 * sigma))

    # -- readout -----------------------------------------------------------------

    def estimate(self, tenant_id: str) -> LinkEstimate:
        with self._lock:
            st = self._tenants[tenant_id]
            d_min = self._dmin[tenant_id]
            m = int(self._amps[tenant_id].size)
            if st.syms == 0:
                return LinkEstimate(tenant_id, 0, *(float("nan"),) * 6)
            e_w = math.fsum(st.err2) / len(st.err2)
            s_w = math.fsum(st.sig2) / len(st.sig2)
            e_l = st.err2_life / st.syms
            s_l = st.sig2_life / st.syms
            syms = st.syms
        return LinkEstimate(
            tenant_id, syms,
            evm=self._evm(e_w, s_w),
            snr_db=self._snr_db(e_w, s_w),
            ser_proxy=self._ser(e_w, s_w, d_min, m),
            evm_lifetime=self._evm(e_l, s_l),
            snr_db_lifetime=self._snr_db(e_l, s_l),
            ser_proxy_lifetime=self._ser(e_l, s_l, d_min, m))
