"""Error-feedback gradient compression for slow interconnects.

At multi-pod scale the cross-pod (DCN) gradient all-reduce is the slowest
collective. Standard mitigation: 8-bit compression with ERROR FEEDBACK
(Seide et al. / EF-SGD) — quantization error is carried to the next step,
so the compressed-SGD fixed point matches full-precision SGD:

    c_t   = Q(g_t + e_t)           # int8 + per-tensor scale
    e_t+1 = (g_t + e_t) − D(c_t)   # residual stays local
    step uses D(AllReduce(c_t))

`compressed_psum` composes with `shard_map` over the pod axis so only the
int8 payload crosses pods (4× fewer DCN bytes than f32, 2× fewer than
bf16); intra-pod reduction stays full precision.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    bits: int = 8
    stochastic: bool = False     # stochastic rounding of the quantizer


def _qmax(bits: int) -> int:
    return (1 << (bits - 1)) - 1


def compress(g: jnp.ndarray, cfg: CompressionConfig = CompressionConfig(),
             key=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """g → (int8 payload, f32 scale)."""
    qm = _qmax(cfg.bits)
    scale = jnp.max(jnp.abs(g)).astype(jnp.float32) / qm
    scale = jnp.maximum(scale, 1e-12)
    x = g.astype(jnp.float32) / scale
    if cfg.stochastic and key is not None:
        x = jnp.floor(x + jax.random.uniform(key, x.shape))
    else:
        x = jnp.round(x)
    return jnp.clip(x, -qm, qm).astype(jnp.int8), scale


def decompress(payload: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return payload.astype(jnp.float32) * scale


def ef_compress_tree(grads: Any, error: Any,
                     cfg: CompressionConfig = CompressionConfig()):
    """Error-feedback compression over a gradient pytree.

    Returns (payloads, scales, new_error): decompress(payloads)·scales is
    what the collective carries; new_error stays on-worker.
    """
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        payload, scale = compress(corrected, cfg)
        back = decompress(payload, scale)
        return payload, scale, corrected - back

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_flatten(error)[0]
    ps, ss, es = [], [], []
    for g, e in zip(flat_g, flat_e):
        p, s, ne = one(g, e)
        ps.append(p)
        ss.append(s)
        es.append(ne)
    unf = lambda xs: jax.tree_util.tree_unflatten(treedef, xs)
    return unf(ps), unf(ss), unf(es)


def init_error(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(grads: Any, error: Any, axis_name: str,
                    cfg: CompressionConfig = CompressionConfig()):
    """All-reduce a gradient tree with int8 payloads over `axis_name`.

    For use INSIDE shard_map over the pod axis: the int8 payloads are
    all-gathered (sum of int8 overflows), decompressed, and averaged
    locally. Returns (mean_grads, new_error).
    """
    n = jax.lax.psum(1, axis_name)
    payloads, scales, new_error = ef_compress_tree(grads, error, cfg)

    def reduce_one(p, s):
        # gather the payloads+scales of all pods, decompress, average
        ps = jax.lax.all_gather(p, axis_name)          # (n, …) int8
        ss = jax.lax.all_gather(s, axis_name)          # (n,)  f32
        return jnp.tensordot(ss, ps.astype(jnp.float32),
                             axes=((0,), (0,))) / n

    mean = jax.tree.map(reduce_one, payloads, scales)
    return mean, new_error
