"""§Perf — the hillclimb driver: re-lowers selected cells with the
optimizations enabled and records before (baseline JSON from the paper-
faithful sweep) vs after, per roofline term.

Must run in a FRESH process (it imports repro.launch.dryrun, which pins
XLA_FLAGS to 512 host devices):

    PYTHONPATH=src python -m benchmarks.bench_perf [--cells ...]
"""
from __future__ import annotations

import argparse
import json
import pathlib

REPORT = pathlib.Path(__file__).resolve().parent.parent / "reports"

# (arch, shape, overrides, which §Perf iterations they carry)
CELLS = [
    # hillclimb cell 1 — worst roofline fraction: xlstm train
    ("xlstm-125m", "train_4k", {}, "it.4 in-scan mLSTM chunks"),
    # hillclimb cell 2 — most collective-bound: mixtral prefill
    ("mixtral-8x22b", "prefill_32k",
     {"fused_attention": True, "serve_int8_weights": True},
     "it.3 flash-attn + it.5 int8 gathers"),
    # hillclimb cell 3 — paper-technique representative: mixtral long_500k
    # (bounded-receptive-field ring decode)
    ("mixtral-8x22b", "long_500k", {"serve_int8_weights": True},
     "it.5 int8 gathers"),
    # beyond the required three — the generalizing wins:
    ("internlm2-1.8b", "train_4k", {"fused_attention": True},
     "it.3 flash-attn (train fwd+remat)"),
    ("deepseek-7b", "prefill_32k", {"fused_attention": True},
     "it.3 flash-attn"),
    ("whisper-large-v3", "prefill_32k", {"fused_attention": True},
     "it.3 flash-attn"),
    ("zamba2-1.2b", "prefill_32k", {"fused_attention": True},
     "it.4 in-scan SSD + it.3 flash-attn"),
    ("zamba2-1.2b", "train_4k", {}, "it.4 in-scan SSD chunks"),
    ("mixtral-8x22b", "train_4k", {"fused_attention": True},
     "it.3 flash-attn"),
    ("llava-next-34b", "train_4k", {"fused_attention": True},
     "it.3 flash-attn"),
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", nargs="*", default=None,
                    help="arch:shape filters")
    args = ap.parse_args(argv)

    from repro.launch.dryrun import run_cell    # pins XLA_FLAGS on import

    out_dir = REPORT / "perf"
    out_dir.mkdir(parents=True, exist_ok=True)
    rows = []
    for arch, shape, overrides, note in CELLS:
        if args.cells and f"{arch}:{shape}" not in args.cells:
            continue
        base_f = REPORT / "dryrun" / f"{arch}_{shape}_sp.json"
        base = json.loads(base_f.read_text()) if base_f.exists() else None
        res = run_cell(arch, shape, multi_pod=False, cfg_overrides=overrides)
        (out_dir / f"{arch}_{shape}_opt.json").write_text(
            json.dumps(res, indent=2))
        if res.get("status") != "ok":
            print(f"[perf] {arch}×{shape}: FAILED {res.get('error')}")
            rows.append({"cell": f"{arch}×{shape}", "note": note,
                         "status": res.get("error")})
            continue
        row = {"cell": f"{arch}×{shape}", "note": note, "status": "ok"}
        for term in ("t_compute_s", "t_memory_s", "t_collective_s",
                     "t_step_s", "mfu_at_roofline"):
            after = res["roofline"][term]
            before = (base["roofline"][term]
                      if base and base.get("status") == "ok" else None)
            row[term] = {"before": before, "after": after}
        rows.append(row)
        b = row["t_step_s"]["before"]
        a = row["t_step_s"]["after"]
        if b:
            print(f"[perf] {arch}×{shape} ({note}): t_step "
                  f"{b*1e3:.0f}→{a*1e3:.0f} ms ({b/a:.2f}×), MFU "
                  f"{row['mfu_at_roofline']['before']*100:.1f}→"
                  f"{row['mfu_at_roofline']['after']*100:.1f}%")
    (out_dir / "summary.json").write_text(json.dumps(rows, indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
