"""Fleet serving over a device mesh (repro.serve.fleet) — the ISSUE-7
acceptance surface.

  * device-set selection: `worker_devices` cycles real devices as
    interpret-mode stand-ins; `best_mesh` (folded in from runtime/elastic,
    which now delegates here) validates and shapes the (data, model) mesh;
  * placement: tenants shard onto the least-loaded healthy worker with
    group-key affinity as the tie-break;
  * the chaos acceptance sweep: a `FaultPlan` kills one worker of a
    2-worker fleet MID-STREAM — every in-flight stream migrates (rebuilt
    from `TenantSpec` + carry snapshot, retained plans replayed FIFO) and
    finishes BITWISE-equal to offline with every chunk emitted exactly
    once, zero sessions poisoned, and the migration visible in the
    per-worker `RecoveryStats` ledgers (contract #10);
  * health: `device_slow` injection feeds the launch-latency heartbeat
    without killing the worker; consecutive terminal launch failures
    cross `RecoveryPolicy.device_lost_after` and declare the device lost;
  * budgets: only sessions exhausting `max_session_recoveries` are
    poisoned; a fleet with no surviving worker poisons and refuses opens.

All tests carry the `chaos` marker (deselect with -m "not chaos").
"""
import jax
import numpy as np
import pytest

from repro.core import equalizer as eq
from repro.runtime import best_mesh as runtime_best_mesh
from repro.serve import (BatchPolicy, Fault, FaultPlan, FleetRuntime,
                         RecoveryPolicy, TenantSpec, best_mesh, chop,
                         worker_devices)

pytestmark = pytest.mark.chaos

CFG = eq.CNNEqConfig()
INT8_FMT = tuple((2, 5, 3, 4) for _ in range(CFG.layers))


def _weights(seed, cfg=CFG):
    params = eq.init(jax.random.PRNGKey(seed), cfg)
    folded = eq.fold_bn(params, eq.init_bn_state(cfg), cfg)
    return eq.folded_weights(folded)


def _spec(tid, backend, seed, tile_m=32, priority=0):
    return TenantSpec(
        tid, CFG, weights=_weights(seed),
        formats=INT8_FMT if backend == "fused_int8" else None,
        backend=backend, tile_m=tile_m, priority=priority)


def _offline(spec, wave):
    import jax.numpy as jnp
    return np.asarray(spec.build_engine()(jnp.asarray(wave[None])))[0]


def _wave(seed, n_syms):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(n_syms * CFG.n_os).astype(np.float32)


def _policy():
    return BatchPolicy(max_batch=3, max_wait_s=1e9)


# ---------------------------------------------------------------------------
# device-set / mesh units (satellite: elastic.best_mesh folded into fleet)
# ---------------------------------------------------------------------------

def test_worker_devices_cycles_and_validates():
    devs = jax.devices()
    picked = worker_devices(3)
    assert len(picked) == 3
    assert picked == [devs[i % len(devs)] for i in range(3)]
    assert worker_devices(devices=devs) == devs
    with pytest.raises(ValueError, match="n_workers"):
        worker_devices(0)
    with pytest.raises(RuntimeError, match="no jax devices"):
        worker_devices(2, devices=[])


def test_best_mesh_shapes_and_runtime_reexport():
    d = jax.devices()[0]
    mesh = best_mesh(n_devices=4, model_parallel=4, devices=[d] * 4)
    assert mesh.devices.shape == (1, 4)
    assert mesh.axis_names == ("data", "model")
    # model_parallel that doesn't divide halves until it does
    mesh = best_mesh(n_devices=6, model_parallel=4, devices=[d] * 6)
    assert mesh.devices.shape == (3, 2)
    # the historical repro.runtime import path delegates here
    via_runtime = runtime_best_mesh(n_devices=2, model_parallel=2,
                                    devices=[d] * 2)
    assert via_runtime.devices.shape == (1, 2)
    with pytest.raises(ValueError, match="n_devices"):
        best_mesh(n_devices=9, devices=[d] * 4)
    with pytest.raises(RuntimeError, match="no jax devices"):
        best_mesh(devices=[])


# ---------------------------------------------------------------------------
# the ISSUE-7 acceptance sweep: kill a worker mid-stream, stay bitwise
# ---------------------------------------------------------------------------

def test_fleet_chaos_device_loss_migrates_bitwise_zero_loss():
    """Multi-tenant fp32+int8 sweep on a 2-worker fleet; the FaultPlan
    kills worker 0 after its 2nd launch. Every in-flight stream must
    complete bitwise-equal to offline (chunks exactly once, FIFO), zero
    sessions poisoned, and stats() must show the migration in the
    per-worker RecoveryStats ledgers."""
    fp = FaultPlan([Fault("device_lost", at=0, after=2)])
    specs = [_spec(f"t{i}", ("fused_fp32", "fused_int8")[i % 2],
                   seed=200 + i, priority=i) for i in range(4)]
    # streams must exceed one kernel tile — below that the offline
    # reference legally shrinks its tile and the contract is ~1 ULP
    waves = {s.tenant_id: _wave(300 + i, 280 + 16 * i)
             for i, s in enumerate(specs)}
    with FleetRuntime(n_workers=2, policy=_policy(), launch_retries=1,
                      fault_plan=fp) as rt:
        for s in specs:
            rt.open(s)
        # least-loaded + group-affinity placement shards the two
        # group keys across the two workers
        assert rt.stats()["placement"] == {"t0": 0, "t1": 1,
                                           "t2": 0, "t3": 1}
        streams = {t: iter(chop(w, 120 * CFG.n_os, seed=i, jitter=0.5))
                   for i, (t, w) in enumerate(sorted(waves.items()))}
        live = set(streams)
        while live:
            for t in sorted(live):
                c = next(streams[t], None)
                if c is None:
                    live.discard(t)
                    rt.finish(t)
                else:
                    rt.submit(t, c)
        rt.drain()
        outputs = {s.tenant_id: rt.output(s.tenant_id) for s in specs}
        st = rt.stats()

    for s in specs:
        want = _offline(s, waves[s.tenant_id])
        got = outputs[s.tenant_id]
        assert got.shape == want.shape             # exactly-once emission
        np.testing.assert_array_equal(got, want)   # bitwise == offline
    assert fp.fired == [("device_lost", 0)]
    assert st["migrations"] == 1
    agg = st["recovery"]
    assert agg["sessions_poisoned"] == 0
    assert agg["device_losses"] == 1
    w0, w1 = st["workers"]
    assert not w0["alive"] and "DeviceLost" in w0["reason"]
    assert w0["recovery"]["sessions_migrated_out"] == 2
    assert w1["alive"]
    assert w1["recovery"]["sessions_migrated_in"] == 2
    assert w1["recovery"]["engine_rebuilds"] >= 2
    # worker 0's tenants re-homed onto worker 1
    assert st["placement"] == {"t0": 1, "t1": 1, "t2": 1, "t3": 1}


def test_fleet_device_slow_fires_without_killing_worker():
    """`device_slow` injects latency into one launch of worker 0 — the
    latency feeds the health monitor but the worker survives and the
    stream stays bitwise."""
    fp = FaultPlan([Fault("device_slow", at=0, after=1, delay_s=0.05)])
    spec = _spec("slowpoke", "fused_fp32", seed=11)
    wave = _wave(13, 300)
    with FleetRuntime(n_workers=2, policy=_policy(), fault_plan=fp) as rt:
        rt.open(spec)
        for c in chop(wave, 100 * CFG.n_os, seed=1):
            rt.submit("slowpoke", c)
        got = rt.close("slowpoke")
        st = rt.stats()
    np.testing.assert_array_equal(got, _offline(spec, wave))
    assert fp.fired == [("device_slow", 0)]
    assert st["workers"][0]["alive"]
    assert st["recovery"]["device_losses"] == 0
    assert st["recovery"]["sessions_poisoned"] == 0


def test_fleet_consecutive_failures_declare_device_lost():
    """No injected DeviceLost — a plain launch fault turns TERMINAL
    (launch_retries=0) and crosses device_lost_after=1, so the fleet
    itself declares the device gone and migrates; the stream still
    finishes bitwise."""
    fp = FaultPlan([Fault("launch_error", 0)])
    pol = RecoveryPolicy(device_lost_after=1, backoff_base_s=1e-4,
                         backoff_max_s=1e-3)
    spec = _spec("flaky", "fused_fp32", seed=23)
    wave = _wave(29, 300)
    with FleetRuntime(n_workers=2, policy=_policy(), launch_retries=0,
                      recovery=pol, fault_plan=fp) as rt:
        rt.open(spec)
        for c in chop(wave, 100 * CFG.n_os, seed=2):
            rt.submit("flaky", c)
        got = rt.close("flaky")
        st = rt.stats()
    np.testing.assert_array_equal(got, _offline(spec, wave))
    w0 = st["workers"][0]
    assert not w0["alive"] and "consecutive terminal" in w0["reason"]
    assert st["migrations"] == 1
    assert st["recovery"]["sessions_poisoned"] == 0
    assert st["recovery"]["sessions_migrated_in"] == 1


def test_fleet_budget_exhaustion_poisons_only_the_over_budget_stream():
    """max_session_recoveries=0: the tenant on the dying worker has no
    migration budget and is poisoned; the tenant on the surviving worker
    is untouched."""
    fp = FaultPlan([Fault("device_lost", at=0, after=0)])
    pol = RecoveryPolicy(max_session_recoveries=0, backoff_base_s=1e-4,
                         backoff_max_s=1e-3)
    doomed = _spec("doomed", "fused_fp32", seed=31)
    lucky = _spec("lucky", "fused_fp32", seed=37)
    wave_d, wave_l = _wave(41, 300), _wave(43, 300)
    with FleetRuntime(n_workers=2, policy=_policy(), launch_retries=0,
                      recovery=pol, fault_plan=fp) as rt:
        rt.open(doomed)                            # → worker 0
        rt.open(lucky)                             # → worker 1
        assert rt.stats()["placement"] == {"doomed": 0, "lucky": 1}
        fut = rt.submit("doomed", wave_d)
        rt.submit("lucky", wave_l)
        rt.finish("doomed")
        rt.finish("lucky")
        rt.drain()
        with pytest.raises(Exception):
            fut.result(timeout=30)
        with pytest.raises(RuntimeError, match="lost a chunk"):
            rt.output("doomed")
        got = rt.output("lucky")
        st = rt.stats()
    np.testing.assert_array_equal(got, _offline(lucky, wave_l))
    assert st["workers"][0]["recovery"]["sessions_poisoned"] == 1
    assert st["recovery"]["sessions_migrated_in"] == 0


def test_fleet_no_survivors_poisons_and_rejects_opens():
    """A 1-worker fleet losing its only device has nowhere to migrate:
    the stream is poisoned, and admitting a new tenant raises."""
    fp = FaultPlan([Fault("device_lost", at=0, after=0)])
    with FleetRuntime(n_workers=1, policy=_policy(), launch_retries=0,
                      fault_plan=fp) as rt:
        rt.open(_spec("stranded", "fused_fp32", seed=47))
        rt.submit("stranded", _wave(53, 300))
        rt.finish("stranded")
        rt.drain()
        with pytest.raises(RuntimeError, match="lost a chunk"):
            rt.output("stranded")
        with pytest.raises(RuntimeError, match="no healthy workers"):
            rt.open(_spec("latecomer", "fused_fp32", seed=59))
        st = rt.stats()
    assert st["recovery"]["sessions_poisoned"] == 1
    assert st["recovery"]["device_losses"] == 1


def test_fleet_shutdown_is_idempotent_and_rejects_after():
    rt = FleetRuntime(n_workers=2, policy=_policy())
    rt.shutdown()
    rt.shutdown()
    with pytest.raises(RuntimeError, match="shut down"):
        rt.open(_spec("late", "fused_fp32", seed=61))
