"""Equalizer data pipeline: on-device channel simulation feeding training.

The channel simulators (channels/imdd.py, channels/proakis.py) are pure JAX,
so the "data loader" is a jitted function — frames are synthesized on-device
at full speed, exactly like the experimental capture replay of the paper but
without a disk in the loop.
"""
from __future__ import annotations

import functools
from typing import Callable, Iterator, Tuple

import jax
import jax.numpy as jnp

from ..channels import imdd, proakis


def channel_fn(kind: str, cfg=None) -> Callable:
    """Uniform (key, n_syms) → (rx_waveform, tx_symbols) interface."""
    if kind == "imdd":
        ccfg = cfg or imdd.IMDDConfig()
        return lambda key, n_syms: imdd.simulate(key, ccfg, n_syms)
    if kind == "proakis":
        ccfg = cfg or proakis.ProakisConfig()
        return lambda key, n_syms: proakis.simulate(key, ccfg, n_syms)
    raise ValueError(kind)


def frames(key: jax.Array, fn: Callable, batch: int, n_syms: int
           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(batch, n_syms·N_os) waveforms + (batch, n_syms) symbols."""
    keys = jax.random.split(key, batch)
    rx, syms = jax.vmap(lambda k: fn(key=k, n_syms=n_syms))(keys)
    return rx, syms


def stream(key: jax.Array, kind: str, batch: int, n_syms: int,
           cfg=None) -> Iterator[Tuple[jnp.ndarray, jnp.ndarray]]:
    fn = channel_fn(kind, cfg)
    while True:
        key, sub = jax.random.split(key)
        yield frames(sub, fn, batch, n_syms)
