"""xlstm-125m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

12 blocks · d_model 768 · 4 heads · vocab 50304 · d_ff 0 (xLSTM blocks
carry their own projections: mLSTM pre-up ×2, sLSTM post-up ×4/3).
sLSTM at blocks {3, 9} (paper-style mix), mLSTM elsewhere in
chunkwise-parallel form. Recurrent state ⇒ long_500k RUNS at O(1) memory.
"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    slstm_at=(3, 9), expand=2, d_conv=4,
    tp=16, train_accum=2, ssd_chunk=64,   # accum 2: fits 16 GiB HBM (§Perf it. 8)
)

REDUCED = ModelConfig(
    name="xlstm-reduced", family="ssm",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=512, slstm_at=(1,), expand=2,
    ssd_chunk=16, dtype="float32",
)
