"""Serving runtime (repro.serve) — the ISSUE-3 acceptance surface.

  * chunked-streaming equivalence: a property-style sweep over chunk sizes
    (including chunks smaller than the receptive field) asserting
    serve output == offline engine output per backend — BITWISE for the
    fused fp32/bf16/int8 datapaths; ≤2 ULP for "ref" (the pure-jnp oracle's
    dot widths depend on stream length, so XLA may contract differently);
  * engine-pool LRU eviction (rebuild-after-evict keeps streams correct);
  * micro-batching policy: max_batch and max_wait triggers, grouping by
    engine group_key, latency accounting;
  * chunker unit behaviour (carry bound, tile alignment, end-of-stream).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import equalizer as eq
from repro.core.engine import BACKENDS, EqualizerEngine
from repro.serve import (BatchPolicy, EnginePool, ServeRuntime,
                         StreamChunker, TenantSpec, chop)

CFG = eq.CNNEqConfig()
INT8_FMT = tuple((2, 5, 3, 4) for _ in range(CFG.layers))
KEY = jax.random.PRNGKey(0)
ULP_TOL = 5e-6


def _spec(tid, backend, seed, cfg=CFG, tile_m=32):
    params = eq.init(jax.random.PRNGKey(seed), cfg)
    folded = eq.fold_bn(params, eq.init_bn_state(cfg), cfg)
    return TenantSpec(
        tid, cfg, weights=eq.folded_weights(folded),
        formats=INT8_FMT if backend == "fused_int8" else None,
        backend=backend, tile_m=tile_m)


def _offline(spec, wave):
    return np.asarray(spec.build_engine()(jnp.asarray(wave[None])))[0]


def _replay_round_robin(rt, streams):
    ids = list(streams)
    iters = {t: iter(streams[t]) for t in ids}
    live = set(ids)
    while live:
        for t in list(live):
            c = next(iters[t], None)
            if c is None:
                live.discard(t)
                rt.finish(t)
            else:
                rt.submit(t, c)
    rt.drain()


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ---------------------------------------------------------------------------
# chunked-streaming equivalence sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("chunk_samples", [
    17,       # smaller than the receptive field (halo = 68 samples)
    160,      # a few positions per chunk, not stride-aligned
    10_000,   # whole stream in one chunk
])
def test_chunked_serve_equals_offline(backend, chunk_samples):
    n_tenants, n_syms = 2, 523                       # odd on purpose
    rt = ServeRuntime(BatchPolicy(max_batch=n_tenants, max_wait_s=1e9))
    specs = [_spec(f"t{i}", backend, seed=i) for i in range(n_tenants)]
    rng = np.random.default_rng(42)
    waves = [rng.standard_normal(n_syms * CFG.n_os).astype(np.float32)
             for _ in range(n_tenants)]
    for s in specs:
        rt.open(s)
    streams = {s.tenant_id: chop(w, chunk_samples, seed=i, jitter=0.5)
               for i, (s, w) in enumerate(zip(specs, waves))}
    _replay_round_robin(rt, streams)
    for s, w in zip(specs, waves):
        got = rt.output(s.tenant_id)
        want = _offline(s, w)
        assert got.shape == want.shape
        if backend == "ref":
            np.testing.assert_allclose(got, want, rtol=0, atol=ULP_TOL)
        else:
            # fused backends: BITWISE — the chunker keeps its carry tile-
            # aligned so every emitted position repeats the offline tile
            # computation exactly (int8 thereby also beats its ≤1-LSB bound)
            np.testing.assert_array_equal(got, want)


def test_chunked_serve_single_sample_trickle():
    """Degenerate arrival pattern: 1-sample chunks still reassemble the
    offline stream bitwise (fp32 fused)."""
    rt = ServeRuntime(BatchPolicy(max_batch=64, max_wait_s=1e9))
    spec = _spec("drip", "fused_fp32", seed=7)
    rt.open(spec)
    rng = np.random.default_rng(3)
    wave = rng.standard_normal(120 * CFG.n_os).astype(np.float32)
    for v in wave:
        rt.submit("drip", np.array([v], np.float32))
    got_stream = rt.close("drip")
    np.testing.assert_array_equal(got_stream, _offline(spec, wave))


def test_close_flushes_tail_and_matches_offline():
    rt = ServeRuntime(BatchPolicy(max_batch=4, max_wait_s=1e9))
    spec = _spec("solo", "fused_int8", seed=1)
    rt.open(spec)
    rng = np.random.default_rng(5)
    wave = rng.standard_normal(301 * CFG.n_os + 7).astype(np.float32)
    for c in chop(wave, 200, seed=1, jitter=0.3):
        rt.submit("solo", c)
    got = rt.close("solo")                 # finish + drain + release
    np.testing.assert_array_equal(got, _offline(spec, wave))
    assert "solo" not in rt.sessions


# ---------------------------------------------------------------------------
# engine pool / session manager
# ---------------------------------------------------------------------------

def test_engine_pool_lru_eviction():
    pool = EnginePool(max_engines=2)
    built = []

    def mk(name):
        def build():
            built.append(name)
            return f"engine-{name}"
        return build

    assert pool.get("a", mk("a")) == "engine-a"
    assert pool.get("b", mk("b")) == "engine-b"
    assert pool.get("a", mk("a")) == "engine-a"      # hit refreshes a
    assert pool.get("c", mk("c")) == "engine-c"      # evicts b (LRU)
    assert "b" not in pool and "a" in pool and "c" in pool
    assert pool.get("b", mk("b")) == "engine-b"      # rebuild, evicts a
    assert "a" not in pool
    assert built == ["a", "b", "c", "b"]
    st = pool.stats()
    assert st["evictions"] == 2 and st["hits"] == 1 and st["misses"] == 4
    assert len(pool) == 2


def test_streams_survive_engine_eviction():
    """More tenants than pool slots: engines are rebuilt on demand and the
    streams stay bitwise-correct (chunker state is session-owned)."""
    n_tenants = 4
    rt = ServeRuntime(BatchPolicy(max_batch=n_tenants, max_wait_s=1e9),
                      max_engines=2)                 # < n_tenants slots
    specs = [_spec(f"s{i}", "fused_fp32", seed=10 + i)
             for i in range(n_tenants)]
    rng = np.random.default_rng(11)
    waves = [rng.standard_normal(257 * CFG.n_os).astype(np.float32)
             for _ in range(n_tenants)]
    for s in specs:
        rt.open(s)
    streams = {s.tenant_id: chop(w, 300, seed=i)
               for i, (s, w) in enumerate(zip(specs, waves))}
    _replay_round_robin(rt, streams)
    assert rt.pool.stats()["evictions"] > 0          # pressure really hit
    for s, w in zip(specs, waves):
        np.testing.assert_array_equal(rt.output(s.tenant_id),
                                      _offline(s, w))


# ---------------------------------------------------------------------------
# micro-batching policy
# ---------------------------------------------------------------------------

def test_max_batch_triggers_immediate_coalesced_launch():
    clock = FakeClock()
    rt = ServeRuntime(BatchPolicy(max_batch=3, max_wait_s=1e9), clock=clock)
    specs = [_spec(f"m{i}", "fused_fp32", seed=20 + i) for i in range(3)]
    rng = np.random.default_rng(13)
    waves = [rng.standard_normal(128 * CFG.n_os).astype(np.float32)
             for _ in range(3)]
    for s in specs:
        rt.open(s)
    rt.submit("m0", waves[0])
    rt.submit("m1", waves[1])
    assert rt.batcher.launches == 0                  # below max_batch, no t
    rt.submit("m2", waves[2])                        # 3rd pending → launch
    assert rt.batcher.launches == 1
    assert list(rt.batcher.batch_sizes) == [3]       # ONE stacked call
    st = rt.stats()
    assert st["requests"] == 3 and st["mean_batch"] == 3.0
    assert st["p99_latency_ms"] >= 0.0


def test_max_wait_triggers_time_flush():
    clock = FakeClock()
    rt = ServeRuntime(BatchPolicy(max_batch=100, max_wait_s=0.5),
                      clock=clock)
    spec = _spec("w0", "fused_fp32", seed=31)
    rt.open(spec)
    rng = np.random.default_rng(17)
    wave = rng.standard_normal(128 * CFG.n_os).astype(np.float32)
    rt.submit("w0", wave)
    assert rt.batcher.launches == 0
    clock.advance(0.1)
    assert rt.pump() == 0                            # not old enough yet
    clock.advance(0.6)                               # oldest now > max_wait
    assert rt.pump() == 1
    assert rt.batcher.launches == 1
    np.testing.assert_array_equal(
        rt.output("w0"), _offline(spec, wave)[:len(rt.output("w0"))])


def test_close_does_not_drain_other_tenants():
    """Closing one tenant launches only ITS pending requests; another
    tenant's partial batch keeps waiting for its max_batch/max_wait."""
    clock = FakeClock()
    rt = ServeRuntime(BatchPolicy(max_batch=8, max_wait_s=1e9), clock=clock)
    a = _spec("closer", "fused_fp32", seed=60)
    b = _spec("waiter", "fused_fp32", seed=61)
    rng = np.random.default_rng(37)
    # ≥ one tile of positions (tile_m=32 → 512 syms) so the offline call
    # tiles exactly like serve (see chunker docstring boundary note)
    wa = rng.standard_normal(600 * CFG.n_os).astype(np.float32)
    wb = rng.standard_normal(600 * CFG.n_os).astype(np.float32)
    rt.open(a)
    rt.open(b)
    rt.submit("closer", wa)
    rt.submit("waiter", wb)
    got = rt.close("closer")                         # flushes only "closer"
    np.testing.assert_array_equal(got, _offline(a, wa))
    assert rt.batcher.pending() == 1                 # waiter still queued
    assert all(s <= 2 for s in rt.batcher.batch_sizes)
    rt.drain()
    assert rt.batcher.pending() == 0


def test_groups_split_by_backend():
    """Tenants on different backends never share a stacked launch."""
    clock = FakeClock()
    rt = ServeRuntime(BatchPolicy(max_batch=4, max_wait_s=1e9), clock=clock)
    specs = ([_spec(f"g32-{i}", "fused_fp32", seed=40 + i) for i in range(2)]
             + [_spec(f"g8-{i}", "fused_int8", seed=50 + i)
                for i in range(2)])
    rng = np.random.default_rng(23)
    for s in specs:
        rt.open(s)
        rt.submit(s.tenant_id,
                  rng.standard_normal(200 * CFG.n_os).astype(np.float32))
    assert rt.batcher.launches == 0
    rt.drain()
    assert sorted(rt.batcher.batch_sizes) == [2, 2]  # one per group


# ---------------------------------------------------------------------------
# chunker unit behaviour
# ---------------------------------------------------------------------------

def test_chunker_carry_is_bounded_and_tile_aligned():
    ch = StreamChunker(halo=68, total_stride=16, tile_m=8)
    rng = np.random.default_rng(29)
    for _ in range(50):
        ch.push(rng.standard_normal(130).astype(np.float32))
        plan = ch.plan()
        if plan is not None:
            ch.commit(plan)
            assert ch._o_pos % ch.tile_m == 0        # tile-aligned carry
    # carry never exceeds context + one tile + one pending stride round
    assert ch.carry_samples <= (ch._ctx_pos + ch.tile_m + 1) * ch.ts + 130


def test_chunker_rejects_push_after_finish():
    ch = StreamChunker(halo=4, total_stride=2, tile_m=4)
    ch.push(np.zeros(8, np.float32))
    ch.finish()
    with pytest.raises(RuntimeError, match="finished"):
        ch.push(np.zeros(2, np.float32))


def test_chunker_emits_exact_offline_position_count():
    ch = StreamChunker(halo=68, total_stride=16, tile_m=16)
    total = 0
    rng = np.random.default_rng(31)
    for n in (7, 100, 33, 501, 16, 3):
        ch.push(rng.standard_normal(n).astype(np.float32))
        total += n
    ch.finish()
    emitted = 0
    while True:
        p = ch.plan()
        if p is None:
            break
        ch.commit(p)
        emitted += p.n_emit
    assert emitted == total // 16                    # ⌊W/ts⌋, like offline
