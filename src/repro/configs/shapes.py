"""Assigned input shapes (the 4 columns of the 10×4 cell grid).

  train_4k     seq 4 096 × global batch 256   → lowers train_step
  prefill_32k  seq 32 768 × global batch 32   → lowers prefill
  decode_32k   seq 32 768 × global batch 128  → lowers serve_step (1 token,
                                                KV/SSM state of seq_len)
  long_500k    seq 524 288 × global batch 1   → serve_step; requires
                                                sub-quadratic attention

long_500k runnability (DESIGN.md §5): full-attention archs are SKIPPED
(receptive field = whole sequence ⇒ the paper's overlap partitioning
degenerates); mixtral (SWA), zamba2 (hybrid, windowed shared attn at decode),
xlstm (recurrent) RUN.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# archs whose attention is sub-quadratic (or state-bounded) at decode —
# the only ones that run long_500k
LONG_CONTEXT_ARCHS = ("mixtral-8x22b", "zamba2-1.2b", "xlstm-125m")


def long_500k_runnable(arch: str) -> bool:
    return arch in LONG_CONTEXT_ARCHS
