"""Substrate tests: checkpoint manager, fault loop, straggler monitor,
data pipeline determinism, gradient compression numerics, roofline parser."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import PipelineConfig, TokenSource
from repro.launch import roofline as rl
from repro.optim import AdamW, grad_comp
from repro.runtime import (FailureInjector, StragglerConfig,
                           StragglerMonitor, TrainLoopConfig, WorkerFailure,
                           run_with_restarts)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def _tree(x=1.0):
    return {"layers": {"w": jnp.full((4, 4), x), "b": jnp.zeros((4,))},
            "step_count": jnp.asarray(7, jnp.int32)}


def test_checkpoint_roundtrip(tmp_path):
    ck = CheckpointManager(str(tmp_path), keep_k=2)
    ck.save(10, _tree(3.0), extra={"loss": 1.5})
    out = ck.restore(_tree(0.0))
    np.testing.assert_array_equal(np.asarray(out["layers"]["w"]), 3.0)
    assert int(out["step_count"]) == 7
    assert ck.extra(10)["loss"] == 1.5


def test_checkpoint_keep_k_and_latest(tmp_path):
    ck = CheckpointManager(str(tmp_path), keep_k=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(float(s)))
    assert ck.steps() == [3, 4]
    assert ck.latest_step() == 4
    out = ck.restore(_tree(0.0))
    np.testing.assert_array_equal(np.asarray(out["layers"]["w"]), 4.0)


def test_checkpoint_atomicity_no_tmp_left(tmp_path):
    ck = CheckpointManager(str(tmp_path), keep_k=3)
    ck.save(1, _tree())
    assert not list(tmp_path.glob("*.tmp"))
    # a stale tmp dir from a crashed save is ignored and overwritten
    (tmp_path / "step_00000002.tmp").mkdir()
    ck.save(2, _tree(2.0))
    out = ck.restore(_tree(0.0), step=2)
    np.testing.assert_array_equal(np.asarray(out["layers"]["w"]), 2.0)


# ---------------------------------------------------------------------------
# fault loop
# ---------------------------------------------------------------------------

def test_run_with_restarts_recovers(tmp_path):
    ck = CheckpointManager(str(tmp_path), keep_k=3)
    calls = {"n": 0}

    def init_state():
        return jnp.zeros(()), jnp.zeros(())

    def train_step(p, o, batch):
        calls["n"] += 1
        return p + 1, o, {"loss": jnp.asarray(1.0) / (p + 1)}

    def batches(start):
        def gen():
            while True:
                yield {}
        return gen()

    inj = FailureInjector(fail_at=(7, 13))
    out = run_with_restarts(
        TrainLoopConfig(total_steps=20, checkpoint_every=5, log_every=5),
        ck, init_state, train_step, batches, injector=inj)
    assert out["steps"] == 20
    assert out["restarts"] == 2
    assert float(out["final"][0]) == 20.0        # params resumed, not reset


def test_run_with_restarts_gives_up(tmp_path):
    ck = CheckpointManager(str(tmp_path), keep_k=3)
    inj = FailureInjector(fail_at=(1,))
    inj._fired = set()          # always fire

    class AlwaysFail(FailureInjector):
        def check(self, step):
            if step == 1:
                raise WorkerFailure("persistent")

    with pytest.raises(WorkerFailure):
        run_with_restarts(
            TrainLoopConfig(total_steps=5, checkpoint_every=100,
                            max_restarts=2),
            ck, lambda: (jnp.zeros(()), jnp.zeros(())),
            lambda p, o, b: (p + 1, o, {"loss": jnp.zeros(())}),
            lambda s: iter(lambda: {}, None), injector=AlwaysFail())


# ---------------------------------------------------------------------------
# straggler monitor
# ---------------------------------------------------------------------------

def test_straggler_detection_and_mitigation():
    fired = []
    mon = StragglerMonitor(
        StragglerConfig(warmup_steps=3, patience=2, sigma_factor=3.0),
        on_straggler=lambda step, dt: fired.append(step))
    for s in range(20):
        mon.observe(s, 0.10 + 0.001 * (s % 3))
    assert not mon.flags
    # inject persistent 10× steps
    flagged = [mon.observe(100 + i, 1.0) for i in range(3)]
    assert all(flagged)
    assert fired, "mitigation callback not fired"
    assert mon.recommend_accum(8) == 4
    sm = mon.summary()
    assert sm["flagged"] >= 2 and sm["p50_s"] < 0.2


def test_straggler_stats_robust_to_outliers():
    mon = StragglerMonitor(StragglerConfig(warmup_steps=2, patience=100))
    for s in range(10):
        mon.observe(s, 0.1)
    mean_before = mon.mean
    mon.observe(10, 5.0)            # flagged → excluded from stats
    assert mon.mean == pytest.approx(mean_before)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_token_source_deterministic_and_elastic():
    cfg = PipelineConfig(seq_len=128, global_batch=8, seed=5)
    src = TokenSource(cfg, vocab=1000)
    a = src.block(step=3, row=2)
    b = src.block(step=3, row=2)
    np.testing.assert_array_equal(a, b)              # restart-stable
    c = src.block(step=3, row=3)
    assert not np.array_equal(a, c)                  # rows differ
    d = src.block(step=4, row=2)
    assert not np.array_equal(a, d)                  # steps differ
    assert a.min() >= 0 and a.max() < 1000


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_compress_roundtrip_error_bound():
    g = jax.random.normal(jax.random.PRNGKey(0), (512,))
    p, s = grad_comp.compress(g)
    back = grad_comp.decompress(p, s)
    assert p.dtype == jnp.int8
    assert float(jnp.max(jnp.abs(back - g))) <= float(s) / 2 + 1e-6


def test_error_feedback_mean_converges():
    """EF property: the RUNNING SUM of decompressed grads tracks the true
    sum (error never accumulates unboundedly)."""
    key = jax.random.PRNGKey(1)
    err = {"w": jnp.zeros((64,))}
    total_true = jnp.zeros((64,))
    total_sent = jnp.zeros((64,))
    for i in range(50):
        key, k = jax.random.split(key)
        g = {"w": jax.random.normal(k, (64,))}
        payload, scale, err = grad_comp.ef_compress_tree(g, err)
        total_sent += grad_comp.decompress(payload["w"], scale["w"])
        total_true += g["w"]
    # the residual is the CURRENT error buffer, bounded by one quant step
    resid = np.asarray(total_true - total_sent)
    np.testing.assert_allclose(resid, np.asarray(err["w"]), atol=1e-4)
    assert np.max(np.abs(resid)) < 0.05


# ---------------------------------------------------------------------------
# roofline HLO parser
# ---------------------------------------------------------------------------

_FAKE_HLO = """\
HloModule jit_step

%body (param: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %g = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %ag = f32[128,8]{1,0} all-gather(%g), channel_id=1, replica_groups=[16,16]<=[256]T(1,0), dimensions={0}
  %d = f32[8,8]{1,0} dot(%g, %g), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[8,8]) tuple(%g, %d)
}

%cond (param.1: (s32[], f32[8,8])) -> pred[] {
  %p1 = (s32[], f32[8,8]) parameter(0)
  ROOT %lt = pred[] constant(true)
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %init = (s32[], f32[8,8]) tuple(%a, %a)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  %ar = f32[8,8]{1,0} all-reduce(%a), channel_id=2, replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_roofline_parser_loops_and_collectives():
    an = rl.analyze_hlo(_FAKE_HLO)
    # dot inside while body: 2·8·8·8 flops × trip 10
    assert an.flops == pytest.approx(2 * 8 * 8 * 8 * 10)
    # all-gather operand 256B × 10 trips; all-reduce 256B × 1
    assert an.coll.op_bytes["all-gather"] == 256 * 10
    assert an.coll.op_bytes["all-reduce"] == 256
    assert an.coll.count["all-gather"] == 10
    # ring models: AG receives (n−1)·operand; AR moves 2·(n−1)/n·operand
    assert an.coll.ring_bytes["all-gather"] == pytest.approx(
        256 * 15 * 10)
    assert an.coll.ring_bytes["all-reduce"] == pytest.approx(
        2 * 256 * 3 / 4)


def test_roofline_terms_and_bottleneck():
    coll = rl.CollectiveStats({"all-reduce": 100}, {"all-reduce": 1e9}, {})
    r = rl.Roofline(flops=197e12, hbm_bytes=0.0, coll=coll, n_chips=4,
                    model_flops=4 * 197e12 * 0.5)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_collective == pytest.approx(1e9 / rl.ICI_BW)
    assert r.bottleneck == "compute"
    assert r.mfu == pytest.approx(0.5)


def test_straggler_warmup_never_flags_and_is_excluded_from_quantiles():
    """Warmup steps carry compile/first-touch time: they must neither
    flag (even when enormous) nor skew the summary quantiles."""
    mon = StragglerMonitor(StragglerConfig(warmup_steps=4, patience=1,
                                           sigma_factor=1.0))
    flagged = [mon.observe(s, 50.0) for s in range(4)]   # huge warmups
    assert not any(flagged) and not mon.flags and not mon.degraded
    for s in range(4, 14):
        mon.observe(s, 0.01)
    sm = mon.summary()
    assert sm["steps"] == 14 and sm["flagged"] == 0
    assert sm["p50_s"] <= 0.011 and sm["p99_s"] <= 0.011  # no 50s leak


def test_straggler_latch_edges_fire_callbacks_exactly_once():
    """patience=2 edge walk: the first flag does nothing, the second
    latches (on_straggler fires ONCE), further flags while degraded stay
    silent, and exactly `patience` consecutive clean steps un-latch
    (on_recovered fires once)."""
    events = []
    mon = StragglerMonitor(
        StragglerConfig(warmup_steps=2, patience=2, sigma_factor=3.0),
        on_straggler=lambda step, dt: events.append(("slow", step)),
        on_recovered=lambda step: events.append(("ok", step)))
    for s in range(8):                       # warmup + steady baseline
        mon.observe(s, 0.01)
    assert mon.observe(8, 1.0) and not mon.degraded      # flag 1 of 2
    assert events == []
    assert mon.observe(9, 1.0) and mon.degraded          # latch
    assert events == [("slow", 9)]
    assert mon.observe(10, 1.0) and mon.degraded         # no refire
    assert events == [("slow", 9)]
    mon.observe(11, 0.01)                    # clean 1 of 2: still latched
    assert mon.degraded
    mon.observe(12, 0.01)                    # clean 2: un-latch
    assert not mon.degraded
    assert events == [("slow", 9), ("ok", 12)]
    assert mon.recommend_accum(8) == 8       # mitigation lifted


def test_straggler_non_consecutive_flags_never_latch():
    mon = StragglerMonitor(StragglerConfig(warmup_steps=2, patience=2,
                                           sigma_factor=3.0))
    for s in range(6):
        mon.observe(s, 0.01)
    for i in range(5):                       # flag/clean alternation
        assert mon.observe(6 + 2 * i, 1.0)
        assert not mon.degraded
        assert not mon.observe(7 + 2 * i, 0.01)
    assert not mon.degraded and len(mon.flags) == 5
