from .fault import (FailureInjector, TrainLoopConfig, WorkerFailure,
                    run_with_restarts)
from .straggler import StragglerConfig, StragglerMonitor
from .elastic import ElasticRestore, best_mesh
