"""Pluggable datagram transports for the net layer.

Two implementations behind one duck-typed interface (``send(bytes)``,
``recv(timeout=0.0) -> Optional[bytes]``, ``close()``):

  * `loopback_pair` — an in-memory datagram pair whose two directions
    each run a deterministic seeded `WireSchedule` of impairments
    (bounded reordering, duplication, explicit drops). Every adversarial
    wire test and `benchmarks/bench_net.py` runs on this: the same seed
    always yields the same delivery order, so "bitwise under reordering"
    is a reproducible claim, not a flake.
  * `UdpTransport` — a real UDP socket (one peer per endpoint), so the
    same gateway/client code that passes the deterministic suite can be
    driven by actual datagrams.

The loopback reordering model: datagram i is assigned a delay
d ∈ [0, reorder_window] and released once `i + d` sends have happened
(or on demand when the receiver drains an otherwise-empty wire), which
bounds displacement by the window — the property `NetIngress` sizes its
reassembly buffer against.
"""
from __future__ import annotations

import heapq
import socket
import threading
from collections import deque
from typing import Optional

import numpy as np


class WireSchedule:
    """Deterministic seeded impairment plan for one loopback direction.

    reorder_window — max positions a datagram may be displaced (0: FIFO).
    dup_prob       — probability a datagram is delivered twice.
    drop_idx       — send indices (0-based, pre-duplication) to drop.
    drop_prob      — additional random drop probability.
    """

    def __init__(self, seed: int = 0, reorder_window: int = 0,
                 dup_prob: float = 0.0, drop_idx=(),
                 drop_prob: float = 0.0):
        self.seed = int(seed)
        self.reorder_window = int(reorder_window)
        self.dup_prob = float(dup_prob)
        self.drop_idx = frozenset(int(i) for i in drop_idx)
        self.drop_prob = float(drop_prob)

    def spawn_rng(self) -> np.random.Generator:
        return np.random.default_rng(self.seed)


class _Pipe:
    """One impaired direction: a release-ordered heap + a ready queue."""

    def __init__(self, schedule: Optional[WireSchedule]):
        self.schedule = schedule or WireSchedule()
        self.rng = self.schedule.spawn_rng()
        self.ready: deque = deque()
        self.held: list = []            # (release_at, tiebreak, datagram)
        self.sent = 0                   # send index (pre-duplication)
        self.tiebreak = 0
        self.dropped = 0
        self.duplicated = 0
        self.lock = threading.Lock()
        self.closed = False

    def put(self, data: bytes) -> None:
        with self.lock:
            if self.closed:
                raise OSError("transport closed")
            sch, idx = self.schedule, self.sent
            self.sent += 1
            copies = 1
            if idx in sch.drop_idx or (
                    sch.drop_prob and self.rng.random() < sch.drop_prob):
                self.dropped += 1
                copies = 0
            elif sch.dup_prob and self.rng.random() < sch.dup_prob:
                self.duplicated += 1
                copies = 2
            for _ in range(copies):
                delay = (int(self.rng.integers(0, sch.reorder_window + 1))
                         if sch.reorder_window else 0)
                heapq.heappush(self.held,
                               (idx + delay, self.tiebreak, bytes(data)))
                self.tiebreak += 1
            while self.held and self.held[0][0] <= idx:
                self.ready.append(heapq.heappop(self.held)[2])

    def get(self) -> Optional[bytes]:
        with self.lock:
            if self.ready:
                return self.ready.popleft()
            if self.held:           # wire idle: deliver the earliest held
                return heapq.heappop(self.held)[2]
            return None


class LoopbackTransport:
    """One endpoint of an in-memory datagram pair (see `loopback_pair`)."""

    def __init__(self, tx: _Pipe, rx: _Pipe):
        self._tx = tx
        self._rx = rx

    def send(self, data: bytes) -> None:
        self._tx.put(data)

    def recv(self, timeout: float = 0.0) -> Optional[bytes]:
        return self._rx.get()

    def close(self) -> None:
        self._tx.closed = True

    @property
    def stats(self) -> dict:
        """Impairment accounting for THIS endpoint's transmit direction."""
        return {"sent": self._tx.sent, "dropped": self._tx.dropped,
                "duplicated": self._tx.duplicated}


def loopback_pair(schedule_ab: Optional[WireSchedule] = None,
                  schedule_ba: Optional[WireSchedule] = None):
    """Two connected `LoopbackTransport` endpoints (a, b); datagrams a→b
    run `schedule_ab`, b→a run `schedule_ba` (None: a clean FIFO wire)."""
    ab, ba = _Pipe(schedule_ab), _Pipe(schedule_ba)
    return LoopbackTransport(ab, ba), LoopbackTransport(ba, ab)


class UdpTransport:
    """Real UDP datagram endpoint with the loopback's interface.

    One peer per endpoint: a client passes ``remote=`` at construction;
    a server learns its peer from the first datagram it receives (the
    net layer's NACK/credit/ack traffic then flows back to it). Sends
    before the peer is known are buffered (bounded) and flushed on the
    first receive — a server gateway can `open_wire` (initial CREDIT
    grant) before its client has said anything.
    """

    PRE_PEER_BUFFER = 256

    def __init__(self, bind=("127.0.0.1", 0), remote=None):
        self.sock = socket.socket(socket.AF_INET, socket.SOCK_DGRAM)
        self.sock.bind(bind)
        self.remote = tuple(remote) if remote else None
        self._pre_peer: list = []

    @property
    def address(self):
        return self.sock.getsockname()

    def send(self, data: bytes) -> None:
        if self.remote is None:
            if len(self._pre_peer) >= self.PRE_PEER_BUFFER:
                raise OSError("no peer yet and pre-peer buffer full")
            self._pre_peer.append(data)
            return
        self.sock.sendto(data, self.remote)

    def recv(self, timeout: float = 0.0) -> Optional[bytes]:
        self.sock.settimeout(timeout if timeout > 0 else 0.000_1)
        try:
            data, addr = self.sock.recvfrom(65535)
        except (socket.timeout, BlockingIOError):
            return None
        if self.remote is None:
            self.remote = addr
            for d in self._pre_peer:
                self.sock.sendto(d, self.remote)
            self._pre_peer.clear()
        return data

    def close(self) -> None:
        self.sock.close()
