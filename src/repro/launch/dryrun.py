import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ["REPRO_STUB_FLASH"] = "1"   # see models/attention._flash_sharded

"""Multi-pod dry-run: prove the distribution config is coherent without
real hardware.

For every (architecture × input shape × mesh) cell:

    with mesh:
        lowered  = jax.jit(step, in_shardings=…, out_shardings=…) \
                       .lower(**input_specs(arch))
        compiled = lowered.compile()
        print(compiled.memory_analysis())    # proves it fits
        print(compiled.cost_analysis())      # FLOPs/bytes for §Roofline

on the 16×16 single-pod mesh AND the 2×16×16 multi-pod mesh. Failures
(sharding mismatch, OOM at compile, unsupported collective) are bugs.

Usage:
    python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out reports/dryrun]

Results land as JSON per cell (roofline terms, bytes/device, collective
schedule) consumed by EXPERIMENTS.md §Dry-run/§Roofline.

(No `from __future__` import here: the XLA_FLAGS lines above must stay the
very first statements of the module.)
"""
import argparse
import json
import pathlib
import time
import traceback

import jax

from .. import configs
from ..configs.shapes import SHAPES
from ..models import registry
from ..parallel import sharding
from . import roofline as rl
from . import steps
from .mesh import make_production_mesh

HBM_PER_CHIP = 16 * 1024**3          # v5e-class: 16 GiB


def _tokens_for(shape) -> int:
    if shape.kind == "train":
        return shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return shape.global_batch * shape.seq_len
    return shape.global_batch        # decode: 1 token per sequence


def _kernel_flops(cfg, shape, n_chips: int) -> float:
    """Per-chip flops of flash-attention invocations (§Perf it. 3).

    A pallas custom-call is opaque to HLO cost analysis: its HBM traffic is
    visible (operands/results of the call), but its FLOPs must be added
    analytically. Invocations: train = 2·L (fwd + remat-fwd; the XLA
    backward is visible) per microbatch; prefill = L.
    """
    if not cfg.fused_attention or shape.kind == "decode":
        return 0.0
    from ..kernels.flash_attn import attention_costs
    from ..parallel.sharding import resolve_heads
    hq, _ = resolve_heads(cfg.n_heads, cfg.n_kv_heads, cfg.tp)
    if cfg.family == "hybrid":
        from ..models.zamba2 import attn_points
        layers = len(attn_points(cfg))
    else:
        layers = cfg.n_layers
    b = shape.global_batch
    s = shape.seq_len                       # VLM: prefix+text = backbone seq
    if shape.kind == "train":
        # fwd (1×) + remat-fwd (1×) + kernel bwd (dkv 4 matmuls + dq 3
        # matmuls over the 2-matmul fwd = 3.5×) per layer per microbatch
        factor = 5.5 * layers
    else:
        factor = 1.0 * layers
    per = attention_costs(b, s, s, hq, cfg.head_dim, causal=True,
                          window=cfg.window)
    return factor * per["flops"] / n_chips


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             reduced: bool = False, cfg_overrides: dict | None = None,
             verbose: bool = True) -> dict:
    shape = SHAPES[shape_name]
    ok, reason = steps.cell_supported(arch, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        with mesh:
            low = steps.make_lowerable(arch, shape, mesh, reduced=reduced,
                                       cfg_overrides=cfg_overrides)
            lowered = low.fn.lower(*low.args_sds)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            if isinstance(cost, list):
                cost = cost[0]
            hlo = compiled.as_text()

            cfg = low.cfg
            n_active = cfg.n_active_params()
            mfl = rl.model_flops(n_active, _tokens_for(shape), shape.kind)
            roof = rl.from_compiled(compiled, n_chips=mesh.size,
                                    model_fl=mfl, hlo_text=hlo)
            kf = _kernel_flops(cfg, shape, mesh.size)
            if kf:
                roof.flops += kf

            result = {
                "arch": arch, "shape": shape_name,
                "mesh": f"{dict(zip(mesh.axis_names, mesh.devices.shape))}",
                "chips": mesh.size,
                "status": "ok",
                "kind": shape.kind,
                "t_lower_s": round(t_lower, 1),
                "t_compile_s": round(t_compile, 1),
                "n_params": int(
                    sum(p.size for p in jax.tree.leaves(low.args_sds[0]))),
                "n_active_params": int(n_active),
                "roofline": roof.to_dict(),
            }
            if mem is not None:
                ba = getattr(mem, "temp_size_in_bytes", None)
                result["memory"] = {
                    "argument_bytes": getattr(mem, "argument_size_in_bytes",
                                              0),
                    "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                    "temp_bytes": ba or 0,
                    "generated_code_bytes": getattr(
                        mem, "generated_code_size_in_bytes", 0),
                }
                live = (result["memory"]["argument_bytes"]
                        + result["memory"]["temp_bytes"])
                result["memory"]["live_bytes_per_chip"] = live
                result["memory"]["fits_hbm"] = bool(live <= HBM_PER_CHIP)
            if verbose:
                r = result["roofline"]
                print(f"[{arch} × {shape_name} × {mesh.size}ch] OK  "
                      f"compile {t_compile:.0f}s  "
                      f"compute {r['t_compute_s']*1e3:.2f}ms  "
                      f"memory {r['t_memory_s']*1e3:.2f}ms  "
                      f"collective {r['t_collective_s']*1e3:.2f}ms  "
                      f"→ {r['bottleneck']}-bound, "
                      f"MFU@roofline {r['mfu_at_roofline']*100:.1f}%")
                if mem is not None:
                    print(f"    mem/chip: args "
                          f"{result['memory']['argument_bytes']/2**30:.2f} GiB"
                          f" + temps "
                          f"{result['memory']['temp_bytes']/2**30:.2f} GiB"
                          f" (fits 16 GiB: "
                          f"{result['memory'].get('fits_hbm')})")
            return result
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        if verbose:
            traceback.print_exc()
        return {"arch": arch, "shape": shape_name, "status": "failed",
                "error": f"{type(e).__name__}: {e}",
                "multi_pod": multi_pod}
    finally:
        sharding.set_mesh(None)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCHS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--out", default="reports/dryrun")
    args = ap.parse_args()

    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    cells = []
    if args.all:
        for a in configs.ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            res = run_cell(arch, shape, multi_pod=mp, reduced=args.reduced)
            tag = "mp" if mp else "sp"
            fname = out / f"{arch}_{shape}_{tag}.json"
            fname.write_text(json.dumps(res, indent=2))
            if res["status"] == "failed":
                failures += 1
                print(f"[{arch} × {shape} × {tag}] FAILED: {res['error']}")
            elif res["status"] == "skipped":
                print(f"[{arch} × {shape}] SKIPPED: {res['reason'][:60]}…")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
