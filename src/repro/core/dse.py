"""Design-space exploration framework (paper §3.4–3.5, Fig. 2).

Sweeps equalizer configurations, trains each `n_seeds` times, keeps the WORST
BER of the seeds (the paper's conservative choice), pairs it with MAC/symbol,
and extracts the Pareto frontier. A hardware-aware complexity ceiling prunes
infeasible models *before* implementation — the cross-layer trick:

  FPGA (paper):  MAC_sym,max = DSP_avail / T_req · f_clk · 1.2
  TPU (ours):    MAC_sym,max = chips · peak_FLOPs · util / (2 · T_req)
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, Sequence, Tuple

import jax

from .equalizer import CNNEqConfig
from .fir import FIRConfig
from .train_eq import EqTrainConfig, train_equalizer
from .volterra import VolterraConfig


@dataclasses.dataclass
class DSEEntry:
    kind: str
    cfg: object
    mac_per_sym: float
    ber: float
    feasible: bool


def mac_sym_max_fpga(dsp_avail: int = 12_288, t_req: float = 40e9,
                     f_clk: float = 200e6, lut_bonus: float = 1.2) -> float:
    """The paper's ceiling for the XCVU13P (12 288 DSPs, 200 MHz, 40 GBd)."""
    return dsp_avail / t_req * f_clk * lut_bonus


def mac_sym_max_tpu(chips: int = 1, peak_flops: float = 197e12,
                    util: float = 0.4, t_req: float = 40e9) -> float:
    """Roofline analogue: MACs/sym the chip budget supports at T_req."""
    return chips * peak_flops * util / (2.0 * t_req)


def cnn_grid(v_parallel=(1, 2, 4, 8, 16), layers=(3, 4, 5),
             kernel=(9, 15, 21), channels=(3, 4, 5), n_os=2):
    """The paper's 135-model CNN grid."""
    for vp, l, k, c in itertools.product(v_parallel, layers, kernel, channels):
        yield CNNEqConfig(layers=l, kernel=k, channels=c, v_parallel=vp,
                          n_os=n_os)


def fir_grid(taps=(3, 5, 9, 17, 25, 41, 57, 89, 121, 185, 249, 377, 505,
                   761, 1017), n_os=2):
    for m in taps:
        yield FIRConfig(taps=m, n_os=n_os)


def volterra_grid(m1=(3, 9, 15, 25, 35, 55, 75, 89, 121),
                  m2=(1, 3, 9, 15, 25, 30, 35), m3=(1, 3, 9, 15), n_os=2):
    # the paper sweeps each order; we pair orders diagonally to keep the
    # sweep affordable, covering the same complexity range
    for a, b, c in itertools.product(m1, m2, m3):
        yield VolterraConfig(m1=a, m2=b, m3=c, n_os=n_os)


def explore(key: jax.Array, entries: Sequence[Tuple[str, object]],
            channel_fn: Callable, train_cfg: EqTrainConfig,
            mac_ceiling: float, n_seeds: int = 3) -> List[DSEEntry]:
    """Train every (kind, cfg); keep the worst seed BER (paper §3.4)."""
    results: List[DSEEntry] = []
    for i, (kind, cfg) in enumerate(entries):
        macs = cfg.mac_per_symbol()
        bers = []
        for s in range(n_seeds):
            k = jax.random.fold_in(key, i * 97 + s)
            _, _, info = train_equalizer(k, kind, cfg, channel_fn, train_cfg)
            bers.append(info["ber"])
        results.append(DSEEntry(kind=kind, cfg=cfg, mac_per_sym=macs,
                                ber=max(bers), feasible=macs <= mac_ceiling))
    return results


def pareto_front(entries: Sequence[DSEEntry]) -> List[DSEEntry]:
    """Non-dominated set under (mac_per_sym ↓, ber ↓)."""
    srt = sorted(entries, key=lambda e: (e.mac_per_sym, e.ber))
    front, best = [], float("inf")
    for e in srt:
        if e.ber < best:
            front.append(e)
            best = e.ber
    return front


def select_operating_point(entries: Sequence[DSEEntry]) -> DSEEntry:
    """Paper §3.5: lowest BER among models meeting the throughput ceiling."""
    feas = [e for e in entries if e.feasible]
    if not feas:
        raise ValueError("no feasible model under the MAC ceiling")
    return min(feas, key=lambda e: e.ber)
