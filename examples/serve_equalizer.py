"""Multi-tenant streaming equalizer serving — the repro.serve runtime.

Opens a mixed tenant population on ONE runtime:

  * three "ht" tenants — the 40 GBd IM/DD optical operating point with
    8-bit QAT formats → the auto ladder deploys fused_int8;
  * three "lp" tenants — the Proakis-B magnetic-recording operating point
    with 12-bit QAT formats → deploys fused_bf16;

then streams each tenant's channel-simulated waveform in bursty chunks
(round-robin arrivals). Chunks from tenants sharing a backend coalesce into
ONE stacked fused-kernel launch with per-row tenant weights; the two
backends form separate batch groups. At the end each tenant's streamed
output is checked against the offline engine on its full waveform —
bitwise-identical for every fused backend.

`--driver async` (the default) runs the same workload through
`AsyncServeRuntime`: submits return per-chunk futures, the max_wait timer
fires from the runtime's own thread, and stacked-input assembly overlaps
device launches (double buffering). `--driver sync` uses the synchronous
`ServeRuntime`. The parity check is identical either way — only the
driving loop changes.

    PYTHONPATH=src python examples/serve_equalizer.py \
        [--tenants-per-op 3] [--driver async|sync]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.channels import imdd, proakis
from repro.configs import equalizer_ht as HT
from repro.configs import equalizer_lp as LP
from repro.core import equalizer as eq
from repro.serve import (AsyncServeRuntime, BatchPolicy, ServeRuntime,
                         TenantSpec, chop, replay)

FORMATS = {
    "ht": {"w_int": 2, "w_frac": 5, "a_int": 3, "a_frac": 4},   # → int8
    "lp": {"w_int": 3, "w_frac": 8, "a_int": 3, "a_frac": 8},   # → bf16
}


def make_tenant(op: str, idx: int, n_syms: int):
    cfg = HT.CNN if op == "ht" else LP.CNN
    key = jax.random.PRNGKey(100 * idx + (0 if op == "ht" else 1))
    params = eq.init(key, cfg)
    params["qat"] = {
        f"layer{i}": {k: jnp.asarray(float(v))
                      for k, v in FORMATS[op].items()}
        for i in range(cfg.layers)}
    spec = TenantSpec(f"{op}-{idx}", cfg, params=params,
                      bn_state=eq.init_bn_state(cfg), backend="auto",
                      tile_m=16)
    if op == "ht":
        rx, _ = imdd.simulate(key, HT.CHANNEL, n_syms)
    else:
        rx, _ = proakis.simulate(key, LP.CHANNEL, n_syms)
    return spec, np.asarray(rx, np.float32)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants-per-op", type=int, default=3)
    ap.add_argument("--n-syms", type=int, default=2048)
    ap.add_argument("--chunk-syms", type=int, default=256)
    ap.add_argument("--driver", choices=("async", "sync"), default="async")
    args = ap.parse_args(argv)

    policy = BatchPolicy(max_batch=args.tenants_per_op, max_wait_s=1e9)
    rt = (AsyncServeRuntime(policy) if args.driver == "async"
          else ServeRuntime(policy))
    print(f"driver: {args.driver} ({type(rt).__name__})")
    tenants = [make_tenant(op, i, args.n_syms)
               for op in ("ht", "lp") for i in range(args.tenants_per_op)]
    for spec, _ in tenants:
        s = rt.open(spec)
        print(f"  open {spec.tenant_id}: backend={s.engine.backend}")

    streams = {spec.tenant_id: chop(w, args.chunk_syms * spec.cfg.n_os,
                                    seed=i, jitter=0.5)
               for i, (spec, w) in enumerate(tenants)}
    rep = replay(rt, streams)       # async: drain() waits for all landings

    worst = 0.0
    for spec, w in tenants:
        got = rt.output(spec.tenant_id)
        want = np.asarray(spec.build_engine()(jnp.asarray(w[None])))[0]
        assert got.shape == want.shape, \
            f"{spec.tenant_id}: streamed {got.shape} != offline {want.shape}"
        worst = max(worst, float(np.max(np.abs(got - want))))
        assert bool(np.all(got == want)), \
            f"{spec.tenant_id}: streamed != offline (max |Δ| {worst:.2e})"
    st = rt.stats()
    print(f"\n{len(tenants)} tenants, {rep['total_syms']} symbols streamed "
          f"in {rep['elapsed_s']:.2f}s "
          f"({rep['agg_syms_per_s']:,.0f} sym/s aggregate)")
    print(f"  launches={st['launches']} mean_batch={st['mean_batch']:.1f} "
          f"(int8 and bf16 tenants batch separately)")
    print(f"  latency p50={st['p50_latency_ms']:.1f} ms "
          f"p99={st['p99_latency_ms']:.1f} ms")
    print(f"  engine pool: {st['pool']}")
    print(f"  streamed output == offline engine: bitwise "
          f"(max |Δ| = {worst:.1e}) for all tenants")
    if args.driver == "async":
        rt.shutdown()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
