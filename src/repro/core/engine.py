"""EqualizerEngine — the single production inference path.

Everything downstream of training funnels through this object: stream
partitioning (`core.stream_partition.partitioned_apply`), halo-exchange
sharding (`parallel.halo.halo_apply`), the examples, and the equalizer
benchmarks all consume an engine instead of hand-rolled `apply_folded`
lambdas. The engine owns:

  * BN folding (done once, at construction — the FPGA deployment step),
  * backend selection:
      - "ref"        pure-jnp stream-semantics oracle (kernels.cnn_eq.ref),
      - "fused_fp32" the fused Pallas kernel — same math as "ref",
      - "fused_bf16" the fused Pallas kernel with bf16 tap dots and fp32
        accumulation — the native datapath for QAT formats in the 9–16-bit
        range (qat.deployment_dtype == "bfloat16"),
      - "fused_int8" the quantized fused Pallas kernel: int8 weights at
        QAT's learned per-layer scales, int8×int8 MXU dots with int32
        accumulation and fused requantization between layers,
      - "auto"       fused_int8 when trained QAT formats deploy to int8
        AND the BN-folded weights still fit the learned grid; else
        fused_bf16 when every layer's frozen format fits 16 bits; else
        fused_fp32,
  * tile_m selection: an explicit int, or "auto" → the cached autotune
    sweep (core.autotune) keyed on (topology, backend).

An engine is a plain callable `(W,) | (B, W) waveform → symbols`, so it
drops into every site that previously took an `apply_fn`. Engines that
share a `group_key()` (topology + backend + static kernel config) can be
fused into ONE batched launch with per-row weights via
`stacked_engine_fn` — the multi-tenant serving path (repro.serve): batch
row i is computed with engine i's weights, bitwise-identical to engine i
run alone.

All backends share STREAM semantics (one halo pad, VALID convs — see
kernels/cnn_eq/ref.py), so swapping backends never changes results beyond
floating-point fusion noise; the property tests in tests/test_engine.py
assert ≤2-ULP fp32 agreement with the oracle everywhere, bitwise bf16
agreement with the bf16 oracle, and ≤1-LSB int8 agreement with the QAT
fake-quant reference (observed: exact).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from . import autotune as autotune_lib
from . import qat as qat_lib
from .equalizer import (CNNEqConfig, fold_bn, folded_weights, init_bn_state,
                        layer_strides)

BACKENDS = ("ref", "fused_fp32", "fused_bf16", "fused_int8")

Format = Tuple[int, int, int, int]          # (w_int, w_frac, a_int, a_frac)


def _folded_fit_grid(weights, formats) -> bool:
    """True iff every BN-folded weight is representable on its layer's
    learned Q(w_int).(w_frac) grid without saturating. w_int/w_frac may be
    per-output-channel tuples (`qat.per_channel_formats`) — each channel is
    then checked against its own grid."""
    for (w, _), (wi, wf, _, _) in zip(weights, formats):
        wi_col = np.asarray(wi, np.float64).reshape(-1, 1, 1)
        wf_col = np.asarray(wf, np.float64).reshape(-1, 1, 1)
        hi = np.exp2(wi_col) - np.exp2(-wf_col)
        lo = -np.exp2(wi_col)
        wv = np.asarray(w, np.float64)
        if bool(np.any(wv > hi)) or bool(np.any(wv < lo)):
            return False
    return True


@dataclasses.dataclass
class EqualizerEngine:
    """Callable quantized/fused inference engine for the CNN equalizer.

    Build with `EqualizerEngine.from_params` (trained params + BN state,
    QAT formats picked up automatically) or directly from folded weights.
    """
    cfg: CNNEqConfig
    weights: Tuple[Tuple[jnp.ndarray, jnp.ndarray], ...]  # BN-folded, fp32
    backend: str = "fused_fp32"
    tile_m: int | str = "auto"
    formats: Optional[Tuple[Format, ...]] = None          # int8 backend only
    interpret: Optional[bool] = None

    def __post_init__(self):
        if self.backend == "auto":
            # int8 only when the FOLDED weights still fit the learned grid
            # (see from_params); a vetoed int8 or a 9–16-bit format deploys
            # bf16 — bf16's range covers any learned int width natively.
            if (self._int8_deployable()
                    and _folded_fit_grid(self.weights, self.formats)):
                self.backend = "fused_int8"
            elif self._bf16_deployable():
                self.backend = "fused_bf16"
            else:
                self.backend = "fused_fp32"
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"expected one of {BACKENDS + ('auto',)}")
        if self.backend == "fused_int8":
            if not self._int8_deployable():
                raise ValueError(
                    "fused_int8 needs per-layer formats that fit int8 "
                    "(qat.deployment_plan(...)['all_int8']); got "
                    f"{self.formats}")
            from ..kernels.cnn_eq.cnn_eq import quantize_weights_int8
            self._qweights = quantize_weights_int8(self.weights, self.formats)
        if self.backend == "fused_bf16":
            from ..kernels.cnn_eq.cnn_eq import cast_weights_bf16
            self._bweights = cast_weights_bf16(self.weights)
        self._strides = layer_strides(self.cfg)

    # -- construction ------------------------------------------------------

    @classmethod
    def from_params(cls, params: Dict[str, Any], bn_state: Optional[Dict],
                    cfg: CNNEqConfig, backend: str = "auto",
                    tile_m: int | str = "auto",
                    interpret: Optional[bool] = None,
                    per_channel: bool = False) -> "EqualizerEngine":
        """Deployment step: fold BN, derive quantized-deployment formats
        from learned QAT widths (`qat.deployment_plan`), pick the backend.

        QAT learns Q(w_int) on the UNfolded weights; folding multiplies by
        g = scale/√(var+ε), which can push weights past the learned grid.
        Silently saturating them would break the train→deploy accuracy
        contract, so auto-deployment only goes int8 when the FOLDED weights
        still fit each layer's grid; a vetoed int8 (and any learned format
        in the 9–16-bit range) deploys fused_bf16, whose exponent covers
        the overflow with no clipping; only >16-bit formats (or no QAT at
        all) fall back to fused_fp32.

        per_channel=True refines the learned per-layer weight formats to
        per-output-channel scales (`qat.per_channel_formats`) before the
        backend decision: same learned total width, finer grids on channels
        with small folded weights — no extra MXU cost (the requant is
        already per-row). This is a DEPLOYMENT refinement; the formats are
        derived deterministically from the folded weights, so engine
        rebuilds (e.g. after serve-pool eviction) reproduce them exactly.
        """
        folded = fold_bn(params, bn_state or init_bn_state(cfg), cfg)
        weights = folded_weights(folded)
        formats = None
        if "qat" in params:
            plan = qat_lib.deployment_plan(params["qat"])
            if qat_lib.plan_backend(plan) != "fused_fp32":
                formats = plan["formats"]
        if per_channel and formats is not None:
            formats = qat_lib.per_channel_formats(weights, formats)
        if (backend == "fused_int8" and formats is not None
                and not _folded_fit_grid(weights, formats)):
            raise ValueError(
                "explicit fused_int8 requested but the BN-folded weights "
                "overflow the learned Q(w_int) grids — deploying would "
                "silently saturate; use backend='auto' (deploys bf16) or "
                "retrain with folding-aware QAT")
        return cls(cfg=cfg, weights=weights, backend=backend,
                   tile_m=tile_m, formats=formats, interpret=interpret)

    @classmethod
    def from_folded(cls, folded: Dict[str, Any], cfg: CNNEqConfig,
                    **kw) -> "EqualizerEngine":
        return cls(cfg=cfg, weights=folded_weights(folded), **kw)

    # -- backend plumbing --------------------------------------------------

    def _int8_deployable(self) -> bool:
        return (self.formats is not None
                and all(qat_lib.format_max_bits(wi, wf) <= 8
                        and ai + af + 1 <= 8
                        for wi, wf, ai, af in self.formats))

    def _bf16_deployable(self) -> bool:
        return (self.formats is not None
                and all(max(qat_lib.format_max_bits(wi, wf), ai + af + 1)
                        <= 16
                        for wi, wf, ai, af in self.formats))

    def resolved_tile_m(self) -> int:
        """The tile width actually used (runs the autotune sweep if 'auto')."""
        if isinstance(self.tile_m, int):
            return self.tile_m
        if self.backend == "ref":
            return 64                              # ref has no tiling knob
        best = autotune_lib.best_tile_m(
            self.cfg, self.backend,
            lambda t: self._make_fn(t))
        self.tile_m = best
        return best

    def _make_fn(self, tile_m: int) -> Callable[[jnp.ndarray], jnp.ndarray]:
        if self.backend == "ref":
            from ..kernels.cnn_eq.ref import cnn_eq as ref_fn
            return functools.partial(ref_fn, weights=self.weights,
                                     strides=self._strides)
        if self.backend == "fused_fp32":
            from ..kernels.cnn_eq.cnn_eq import cnn_eq_fused
            return lambda x: cnn_eq_fused(x, self.weights, self._strides,
                                          tile_m=tile_m,
                                          interpret=self.interpret)
        if self.backend == "fused_bf16":
            from ..kernels.cnn_eq.cnn_eq import cnn_eq_fused_bf16
            return lambda x: cnn_eq_fused_bf16(x, self._bweights,
                                               self._strides, tile_m=tile_m,
                                               interpret=self.interpret)
        from ..kernels.cnn_eq.cnn_eq import cnn_eq_fused_int8
        return lambda x: cnn_eq_fused_int8(x, self._qweights, self._strides,
                                           self.formats, tile_m=tile_m,
                                           interpret=self.interpret)

    def _layer_weights(self):
        """The weight pytree the active backend's kernel consumes."""
        if self.backend == "fused_int8":
            return self._qweights
        if self.backend == "fused_bf16":
            return self._bweights
        return self.weights

    # -- the production path -----------------------------------------------

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        """(S·N_os,) or (B, S·N_os) waveform → (S,) or (B, S) soft symbols."""
        squeeze = x.ndim == 1
        if squeeze:
            x = x[None]
        y = self._make_fn(self.resolved_tile_m())(x)
        return y[0] if squeeze else y

    # -- multi-tenant serving surface --------------------------------------

    @property
    def total_stride(self) -> int:
        """Input samples consumed per network pass (V_p · N_os)."""
        n = 1
        for s in self._strides:
            n *= s
        return n

    @property
    def halo_samples(self) -> int:
        """Half a receptive field per side, in SAMPLES — the overlap a
        streaming chunker must carry between chunks."""
        from ..kernels.cnn_eq.ref import receptive_halo
        kernels = tuple(int(w.shape[-1]) for w, _ in self.weights)
        return receptive_halo(kernels, self._strides)

    def tune_key(self) -> Tuple:
        """Hashable (topology, backend, static kernel config) identity —
        the group key WITHOUT the tile width.

        This is the granularity at which the serving layer aggregates
        traffic statistics for serve-aware autotune (`repro.serve`):
        engines that differ only in tile_m share one live width/occupancy
        histogram, and a re-tune picks a new tile FOR this key. Never
        triggers an autotune sweep itself (unlike `group_key`, it does not
        resolve tile_m).
        """
        fmts = self.formats if self.backend == "fused_int8" else None
        return (self.cfg, self.backend, fmts, self.interpret)

    def group_key(self) -> Tuple:
        """Hashable key of everything a batched launch must share.

        Two engines with equal group keys can be stacked into one fused
        launch (`stacked_engine_fn`) — same topology, backend, static
        kernel config (int8 formats are baked into the kernel as requant
        scales) and tile width. Weights are NOT in the key: they ride in
        per-row stacked kernel operands. Structurally this is
        `tune_key() + (tile_m,)`; the serving scheduler relies on that to
        map launches back to their traffic-stats bucket.
        """
        return self.tune_key() + (self.resolved_tile_m(),)

    def describe(self) -> Dict[str, Any]:
        """Deployment summary (for logs / benchmark records)."""
        return {
            "backend": self.backend,
            "tile_m": self.tile_m if isinstance(self.tile_m, int) else "auto",
            "layers": self.cfg.layers,
            "formats": self.formats,
        }


def stacked_engine_fn(engines) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Fuse same-group engines into ONE batched launch with per-row weights.

    engines: a sequence of `EqualizerEngine`s whose `group_key()`s agree.
    Returns a callable (B, W) → (B, S) where batch row i runs through
    engine i's weights — bitwise-identical to `engines[i](x[i:i+1])` (same
    kernel body, same tile shapes; only the BlockSpec row index differs).
    This is the TPU analogue of the paper's DOP-parallel datapath serving
    many links at once: one kernel grid, many tenants.

    The "ref" backend has no batched-weights kernel; it falls back to a
    per-row loop (kept so every backend can be served and tested).
    """
    if not engines:
        raise ValueError("stacked_engine_fn needs at least one engine")
    e0 = engines[0]
    key = e0.group_key()
    for e in engines[1:]:
        if e.group_key() != key:
            raise ValueError(
                f"engines are not batch-compatible: {e.group_key()} != {key}")
    if len(engines) == 1:
        return lambda x: e0(x)
    if e0.backend == "ref":
        fns = [e._make_fn(e.resolved_tile_m()) for e in engines]
        return lambda x: jnp.concatenate(
            [fn(x[i:i + 1]) for i, fn in enumerate(fns)], axis=0)

    per = [e._layer_weights() for e in engines]
    stacked = tuple(
        (jnp.stack([p[layer][0] for p in per]),
         jnp.stack([p[layer][1] for p in per]))
        for layer in range(len(per[0])))
    tile_m = e0.resolved_tile_m()
    strides = e0._strides
    if e0.backend == "fused_fp32":
        from ..kernels.cnn_eq.cnn_eq import cnn_eq_fused
        return lambda x: cnn_eq_fused(x, stacked, strides, tile_m=tile_m,
                                      interpret=e0.interpret)
    if e0.backend == "fused_bf16":
        from ..kernels.cnn_eq.cnn_eq import cnn_eq_fused_bf16
        return lambda x: cnn_eq_fused_bf16(x, stacked, strides,
                                           tile_m=tile_m,
                                           interpret=e0.interpret)
    from ..kernels.cnn_eq.cnn_eq import cnn_eq_fused_int8
    return lambda x: cnn_eq_fused_int8(x, stacked, strides, e0.formats,
                                       tile_m=tile_m, interpret=e0.interpret)
