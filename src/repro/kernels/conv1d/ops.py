"""Jitted public wrapper for the conv1d Pallas kernel."""
from __future__ import annotations

import jax.numpy as jnp

from .conv1d import conv1d as conv1d_pallas
from .ref import conv1d as conv1d_ref


def conv1d_same_lower(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                      stride: int = 1, use_pallas: bool = True,
                      tile_w: int = 256) -> jnp.ndarray:
    """SAME_LOWER-padded strided conv used by the equalizer layers."""
    k = w.shape[-1]
    pad = (k // 2, k - 1 - k // 2)
    xp = jnp.pad(x, ((0, 0), (0, 0), pad))
    fn = conv1d_pallas if use_pallas else conv1d_ref
    if use_pallas:
        return fn(xp, w, b, stride, tile_w=tile_w)
    return fn(xp, w, b, stride)


__all__ = ["conv1d_pallas", "conv1d_ref", "conv1d_same_lower"]
