"""Learnable-bit-width quantization-aware training (paper §4).

The paper learns, per layer, a fixed-point format for weights and activations
by making the bit width differentiable:

  * integer width  i  and fraction width f are separate continuous parameters
    (this differs from BitPruning [20], which learns a scale; learning i and f
    directly means no rescaling is needed at deployment — values ARE their
    fixed-point representation),
  * quantization at non-integer width b interpolates between the two adjacent
    integer widths:  Q_b(x) = (1-α)·Q_⌊b⌋(x) + α·Q_⌈b⌉(x),  α = b - ⌊b⌋,
  * a straight-through estimator passes gradients through the rounding,
  * the loss gains  QLF · (B_p + B_a)/2  where B_p/B_a are the average
    parameter/activation widths.

Three-phase schedule (paper Fig. 5/6):
  1. full precision, 2. bit-width-aware (widths trained), 3. fine-tune with
  widths frozen to the next-highest integer.

TPU note (DESIGN.md §2): widths are *learned* exactly as on the FPGA; at
deployment the learned (i, f) map to the nearest MXU-native dtype (int8 /
bf16) — `deployment_dtype()` below.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class QATConfig:
    qlf: float = 5e-4             # quantization trade-off factor
    init_int_bits: float = 16.0   # phase-1 format: Q16.16
    init_frac_bits: float = 16.0
    min_bits: float = 1.0
    enabled: bool = True


# ---------------------------------------------------------------------------
# Fixed-point fake quantization
# ---------------------------------------------------------------------------

def _round_ste(x: jnp.ndarray) -> jnp.ndarray:
    """round() with a straight-through gradient."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def quantize_fixed(x: jnp.ndarray, int_bits: jnp.ndarray,
                   frac_bits: jnp.ndarray) -> jnp.ndarray:
    """Fixed-point quantization to signed Q(int_bits).(frac_bits).

    Integer widths only — see `quantize_interp` for the differentiable-width
    version. STE on the rounding; clipping is naturally differentiable at the
    boundaries (clip gradient).
    """
    scale = jnp.exp2(frac_bits)
    hi = jnp.exp2(int_bits) - 1.0 / scale
    lo = -jnp.exp2(int_bits)
    xq = _round_ste(x * scale) / scale
    return jnp.clip(xq, lo, hi)


def quantize_interp(x: jnp.ndarray, int_bits: jnp.ndarray,
                    frac_bits: jnp.ndarray) -> jnp.ndarray:
    """Differentiable-width quantization via floor/ceil interpolation.

    Differentiable w.r.t. BOTH int_bits and frac_bits (and x via STE), so the
    widths can be learned with backprop — the paper's core quantization trick.
    """
    f_lo, f_hi = jnp.floor(frac_bits), jnp.ceil(frac_bits)
    a_f = frac_bits - f_lo
    i_lo, i_hi = jnp.floor(int_bits), jnp.ceil(int_bits)
    a_i = int_bits - i_lo
    q_ll = quantize_fixed(x, i_lo, f_lo)
    q_lh = quantize_fixed(x, i_lo, f_hi)
    q_hl = quantize_fixed(x, i_hi, f_lo)
    q_hh = quantize_fixed(x, i_hi, f_hi)
    q_l = (1 - a_f) * q_ll + a_f * q_lh
    q_h = (1 - a_f) * q_hl + a_f * q_hh
    return (1 - a_i) * q_l + a_i * q_h


# ---------------------------------------------------------------------------
# Per-layer quantizer parameter handling
# ---------------------------------------------------------------------------

def init_qparams(layer_names, cfg: QATConfig) -> Dict[str, Any]:
    """One (w_int, w_frac, a_int, a_frac) quadruple per layer."""
    mk = lambda v: jnp.asarray(v, jnp.float32)
    return {
        name: {
            "w_int": mk(cfg.init_int_bits), "w_frac": mk(cfg.init_frac_bits),
            "a_int": mk(cfg.init_int_bits), "a_frac": mk(cfg.init_frac_bits),
        }
        for name in layer_names
    }


def clip_qparams(qparams: Dict[str, Any], cfg: QATConfig) -> Dict[str, Any]:
    """Project widths onto the feasible region after an optimizer step."""
    return jax.tree.map(lambda b: jnp.clip(b, cfg.min_bits, 16.0), qparams)


def freeze_qparams(qparams: Dict[str, Any]) -> Dict[str, Any]:
    """Phase-3: fix widths to the next-highest integer (paper §4 step 3)."""
    return jax.tree.map(jnp.ceil, qparams)


def apply_weight_quant(w: jnp.ndarray, q: Dict[str, jnp.ndarray],
                       enabled: bool = True) -> jnp.ndarray:
    if not enabled:
        return w
    return quantize_interp(w, q["w_int"], q["w_frac"])


def apply_act_quant(a: jnp.ndarray, q: Dict[str, jnp.ndarray],
                    enabled: bool = True) -> jnp.ndarray:
    if not enabled:
        return a
    return quantize_interp(a, q["a_int"], q["a_frac"])


def average_bits(qparams: Dict[str, Any]):
    """(B_p, B_a): average total width of params / activations (+sign bit)."""
    w = [q["w_int"] + q["w_frac"] + 1.0 for q in qparams.values()]
    a = [q["a_int"] + q["a_frac"] + 1.0 for q in qparams.values()]
    return sum(w) / len(w), sum(a) / len(a)


def quant_loss_term(qparams: Dict[str, Any], cfg: QATConfig) -> jnp.ndarray:
    """QLF · (B_p + B_a) / 2 — the paper's quantization-aware loss term."""
    bp, ba = average_bits(qparams)
    return cfg.qlf * (bp + ba) / 2.0


def deployment_dtype(q: Dict[str, jnp.ndarray]) -> str:
    """Map a learned fixed-point format to the nearest TPU-native dtype."""
    total = float(q["w_int"] + q["w_frac"]) + 1.0
    if total <= 8:
        return "int8"
    if total <= 16:
        return "bfloat16"   # 8-bit exponent covers the int range; 8-bit mantissa
    return "float32"


def frozen_format(q: Dict[str, jnp.ndarray]):
    """Learned widths → concrete integer (w_int, w_frac, a_int, a_frac).

    Rounds UP like phase-3 freezing (`freeze_qparams`), so the deployed grid
    always covers the trained one. This is the per-layer fixed-point format
    the int8 fused kernel bakes in as its scales and clip bounds.
    """
    return (int(jnp.ceil(q["w_int"])), int(jnp.ceil(q["w_frac"])),
            int(jnp.ceil(q["a_int"])), int(jnp.ceil(q["a_frac"])))


def per_channel_formats(weights, formats):
    """Refine per-layer weight formats to per-OUTPUT-CHANNEL scales.

    The paper (and `frozen_format`) learns ONE (w_int, w_frac) per layer; a
    single channel with a large BN-fold gain then forces the whole layer
    onto a coarse grid. Per-channel refinement keeps each layer's learned
    TOTAL weight width (w_int + w_frac — the trained accuracy/width
    trade-off) but redistributes it per output channel: a channel whose
    folded weights are small narrows its integer width and reclaims the
    bits as fraction width (a finer grid). This costs nothing on the MXU —
    the int8 dot is unchanged; only the (already per-row) requantization
    scale becomes a per-channel vector (`repro.kernels.cnn_eq`).

    weights: BN-folded ((w, b), …) — per-channel ranges come from the
             DEPLOYED weights, exactly what the int8 kernel will quantize.
    formats: per-layer (w_int, w_frac, a_int, a_frac) from
             `layer_formats`/`deployment_plan` (scalars).

    Returns formats where w_int/w_frac are length-C_out tuples of ints
    (activation formats stay scalar — activations are requantized between
    layers on a shared grid). Layers whose every channel already needs the
    full learned integer width are returned unchanged (scalar).
    """
    out = []
    for (w, _), (wi, wf, ai, af) in zip(weights, formats):
        total = int(wi) + int(wf)            # magnitude bits, sign excluded
        wabs = np.max(np.abs(np.asarray(w, np.float64)).reshape(
            w.shape[0], -1), axis=1)
        wi_c = np.ceil(np.log2(np.maximum(wabs, 1e-12))).astype(np.int64)
        # never widen past the learned grid, never narrow absurdly (an
        # all-zero channel would otherwise get a 2^-40 grid and overflow
        # float scale math downstream)
        wi_c = np.clip(wi_c, int(wi) - 8, int(wi))
        # guarantee fit: Q(i).(f) tops out at 2^i − 2^−f, so a max right at
        # the power of two needs one more integer bit
        for c in range(wi_c.shape[0]):
            f_c = total - int(wi_c[c])
            if wabs[c] > 2.0 ** int(wi_c[c]) - 2.0 ** -f_c:
                wi_c[c] = min(int(wi_c[c]) + 1, int(wi))
        if np.all(wi_c == int(wi)):
            out.append((wi, wf, ai, af))     # nothing to reclaim
            continue
        out.append((tuple(int(v) for v in wi_c),
                    tuple(total - int(v) for v in wi_c), ai, af))
    return tuple(out)


def format_max_bits(wi, wf) -> int:
    """Worst-case total width (+sign) of a scalar OR per-channel format."""
    return int(np.max(np.asarray(wi) + np.asarray(wf))) + 1


def _layer_order(qparams: Dict[str, Any]):
    """'layer0' … 'layerN' keys in layer order (robust to dict ordering)."""
    return sorted(qparams, key=lambda n: int("".join(filter(str.isdigit, n))
                                             or 0))


def layer_formats(qparams: Dict[str, Any]):
    """Ordered tuple of frozen per-layer formats for the whole stack."""
    return tuple(frozen_format(qparams[n]) for n in _layer_order(qparams))


def _format_dtype(total_bits: int) -> str:
    if total_bits <= 8:
        return "int8"
    if total_bits <= 16:
        return "bfloat16"
    return "float32"


def plan_backend(plan: Dict[str, Any]) -> str:
    """Map a deployment plan to the engine backend that serves it natively.

    all layers int8        → "fused_int8"   (int8 MXU dots, int32 accum)
    all layers ≤ 16 bits   → "fused_bf16"   (bf16 MXU dots, fp32 accum —
                              bf16's exponent covers any learned int width,
                              its 8-bit mantissa the 9–16-bit fractions)
    anything wider         → "fused_fp32"
    """
    dts = set(plan["dtypes"].values())
    if dts <= {"int8"}:
        return "fused_int8"
    if dts <= {"int8", "bfloat16"}:
        return "fused_bf16"
    return "fused_fp32"


def deployment_plan(qparams: Dict[str, Any]) -> Dict[str, Any]:
    """Summarize how a trained quantizer deploys on the TPU datapath.

    Returns {"formats": ((w_int, w_frac, a_int, a_frac), …),
             "dtypes": {layer: dtype}, "all_int8": bool}. Unlike
    `deployment_dtype` (weight-only, raw learned widths), the per-layer
    dtype here uses the FROZEN formats and the wider of the weight and
    activation requirement — the same criterion as `all_int8` — so the
    record can never say "int8" for a layer the engine refuses to deploy.
    """
    names = _layer_order(qparams)
    formats = tuple(frozen_format(qparams[n]) for n in names)
    dtypes = {n: _format_dtype(max(wi + wf, ai + af) + 1)
              for n, (wi, wf, ai, af) in zip(names, formats)}
    all_int8 = all(d == "int8" for d in dtypes.values())
    return {"formats": formats, "dtypes": dtypes, "all_int8": all_int8}
