from .manager import CheckpointManager
