"""Packetized wire tests: frame-codec fuzzing + adversarial data plane +
control plane (tests/test_net.py; select with `-m net`).

What must hold:

  * CODEC TOTALITY: decode of any corrupted datagram — truncated,
    bit-flipped (every single bit), bad magic, bad version, bad CRC —
    raises a typed `FrameError`, never a crash and never a
    silently-wrong payload; intact frames round-trip exactly (property
    tested under hypothesis when available, seeded sweeps otherwise).
  * WIRE TRANSPARENCY (contract #12): through NetIngress→runtime→
    NetEgress over a seeded reordering/duplicating loopback, every
    tenant's delivered symbols are bitwise-equal to offline
    equalization and every symbol arrives exactly once — fp32 AND int8
    wire (requant idempotence), sync AND async AND fleet runtimes.
  * LOSS IS LOUD: a dropped datagram surfaces as a per-tenant
    `stream_gap` error + NACK (window overflow or idle-stream sweep),
    never a silent hole; other tenants complete bitwise.
  * BACKPRESSURE ISOLATES: a credit-starved tenant blocks at ingress
    (bounded parking, overflow NACKed) without stalling other tenants.
  * CONTROL IS SAFE: register commands (open/swap/policy/stats/close)
    apply through the runtime APIs with per-command acks; hot-swap over
    the wire keeps the PR 5 bitwise-per-epoch splice; malformed or
    unknown-register commands draw an error ack and change nothing.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import equalizer as eq
from repro.net import (BadCRC, BadLength, BadMagic, BadVersion,
                       ControlAckError, FrameError, FrameType, NetClient,
                       NetGateway, Reassembler, UdpTransport, WireDtype,
                       WireSchedule, decode_frame, decode_samples,
                       encode_frame, encode_samples, loopback_pair,
                       wire_grid)
from repro.serve import (AsyncServeRuntime, BatchPolicy, FleetRuntime,
                         ServeRuntime, TenantSpec, chop, replay_wire)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

pytestmark = pytest.mark.net

CFG = eq.CNNEqConfig()
TILE_M = 32
INT8_FMT = tuple((2, 5, 3, 4) for _ in range(CFG.layers))
CHUNK = 60 * CFG.n_os


def _weights(seed: int):
    params = eq.init(jax.random.PRNGKey(seed), CFG)
    folded = eq.fold_bn(params, eq.init_bn_state(CFG), CFG)
    return eq.folded_weights(folded)


def _spec(tid: str, seed: int, backend: str = "fused_fp32") -> TenantSpec:
    return TenantSpec(tid, CFG, weights=_weights(seed),
                      formats=INT8_FMT if backend == "fused_int8" else None,
                      backend=backend, tile_m=TILE_M)


def _offline(spec: TenantSpec, wave: np.ndarray) -> np.ndarray:
    return np.asarray(spec.build_engine()(jnp.asarray(wave[None])))[0]


def _wave(seed: int, n_syms: int = 480) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.standard_normal(n_syms * CFG.n_os).astype(np.float32)


def _attach(rt, gw, client, spec: TenantSpec, credits=None):
    """Open a tenant on the runtime + both wire ends (data plane only)."""
    sess = rt.open(spec)
    gw.open_wire(spec.tenant_id, credits=credits)
    if spec.backend == "fused_int8":
        client.attach(spec.tenant_id, WireDtype.INT8,
                      grid=wire_grid(sess.engine))
    else:
        client.attach(spec.tenant_id, WireDtype.FP32)
    return sess


# ---------------------------------------------------------------------------
# frame codec: round-trip + corruption totality
# ---------------------------------------------------------------------------

def _assert_roundtrip(tenant, seq, ftype, payload, dtype, a_int, a_frac):
    data = encode_frame(ftype, tenant, seq, payload, dtype=dtype,
                        a_int=a_int, a_frac=a_frac)
    f = decode_frame(data)
    assert (f.ftype, f.tenant, f.seq, f.payload) == (ftype, tenant, seq,
                                                     bytes(payload))
    assert (f.dtype, f.a_int, f.a_frac) == (dtype, a_int, a_frac)


if HAVE_HYPOTHESIS:
    @settings(max_examples=80, deadline=None)
    @given(tenant=st.text(st.characters(min_codepoint=33,
                                        max_codepoint=0x2FF),
                          min_size=1, max_size=16),
           seq=st.integers(0, 2**32 - 1),
           ftype=st.sampled_from(list(FrameType)),
           payload=st.binary(max_size=256),
           grid=st.tuples(st.integers(0, 7), st.integers(0, 7)))
    def test_frame_roundtrip_property(tenant, seq, ftype, payload, grid):
        _assert_roundtrip(tenant, seq, ftype, payload, WireDtype.NONE,
                          *grid)

    @settings(max_examples=80, deadline=None)
    @given(data=st.binary(max_size=128))
    def test_frame_decode_total_on_garbage(data):
        try:
            decode_frame(data)
        except FrameError:
            pass                         # typed rejection is the contract
else:
    def test_frame_roundtrip_property():
        rng = np.random.default_rng(0)
        alphabet = "abcdefgh0123456789_-αβγδ"
        for _ in range(80):
            tenant = "".join(rng.choice(list(alphabet),
                                        size=rng.integers(1, 16)))
            _assert_roundtrip(
                tenant, int(rng.integers(0, 2**32)),
                FrameType(int(rng.integers(1, 7))),
                rng.bytes(int(rng.integers(0, 256))),
                WireDtype.NONE, int(rng.integers(0, 8)),
                int(rng.integers(0, 8)))

    def test_frame_decode_total_on_garbage():
        rng = np.random.default_rng(1)
        for _ in range(200):
            try:
                decode_frame(rng.bytes(int(rng.integers(0, 128))))
            except FrameError:
                pass


def test_truncation_always_typed():
    data = encode_frame(FrameType.DATA, "t0", 7,
                        encode_samples(np.arange(8.0), WireDtype.FP32),
                        dtype=WireDtype.FP32)
    for n in range(len(data)):           # every proper prefix
        with pytest.raises(FrameError):
            decode_frame(data[:n])
    with pytest.raises(BadLength):       # trailing garbage too
        decode_frame(data + b"x")


def test_every_single_bitflip_raises_typed():
    data = encode_frame(FrameType.DATA, "t", 3, b"\x01\x02\x03\x04")
    for byte in range(len(data)):
        for bit in range(8):
            corrupt = bytearray(data)
            corrupt[byte] ^= 1 << bit
            with pytest.raises(FrameError):
                decode_frame(bytes(corrupt))


def test_bad_magic_version_crc_are_distinct_types():
    data = bytearray(encode_frame(FrameType.DATA, "t", 0, b"abcd"))
    bad_magic = bytes(b"XX") + bytes(data[2:])
    with pytest.raises(BadMagic):
        decode_frame(bad_magic)
    bad_ver = bytearray(data)
    bad_ver[2] = 99
    with pytest.raises(BadVersion):
        decode_frame(bytes(bad_ver))
    bad_crc = bytearray(data)
    bad_crc[-1] ^= 0xFF
    with pytest.raises(BadCRC):
        decode_frame(bytes(bad_crc))
    assert all(issubclass(t, (FrameError, ValueError))
               for t in (BadMagic, BadVersion, BadCRC, BadLength))


def test_sample_codec_fp32_bf16_roundtrip():
    rng = np.random.default_rng(2)
    x = rng.standard_normal(257).astype(np.float32)
    assert np.array_equal(
        decode_samples(encode_samples(x, WireDtype.FP32), WireDtype.FP32), x)
    xb = decode_samples(encode_samples(x, WireDtype.BF16), WireDtype.BF16)
    # bf16 is lossy from fp32 but must be idempotent through the wire
    assert np.array_equal(
        decode_samples(encode_samples(xb, WireDtype.BF16), WireDtype.BF16),
        xb)


def test_int8_codec_matches_kernel_requant_and_is_idempotent():
    from repro.kernels.cnn_eq.cnn_eq import dequant_int8, requant_int8
    a_int, a_frac = 3, 4
    rng = np.random.default_rng(3)
    x = (rng.standard_normal(513) * 4).astype(np.float32)
    wire = encode_samples(x, WireDtype.INT8, a_int, a_frac)
    q_kernel = np.asarray(requant_int8(jnp.asarray(x), a_int, a_frac))
    assert np.array_equal(np.frombuffer(wire, np.int8), q_kernel)
    deq = decode_samples(wire, WireDtype.INT8, a_int, a_frac)
    assert np.array_equal(deq, np.asarray(dequant_int8(
        jnp.asarray(q_kernel), a_frac)).astype(np.float32))
    # requant ∘ dequant ∘ requant == requant: the wire is transparent
    assert encode_samples(deq, WireDtype.INT8, a_int, a_frac) == wire


# ---------------------------------------------------------------------------
# reassembler + loopback transport determinism
# ---------------------------------------------------------------------------

def test_reassembler_reorder_dup_gap():
    r = Reassembler(window=3)
    assert r.offer(0, "a") == ["a"]
    assert r.offer(2, "c") == []                   # held
    assert r.offer(2, "c") == [] and r.duplicates == 1
    assert r.offer(1, "b") == ["b", "c"]           # drains in order
    assert r.offer(0, "a") == [] and r.duplicates == 2
    assert r.gap is None
    assert r.offer(7, "z") == []                   # 7 - 3 > window
    assert r.gap == 3
    assert r.offer(3, "d") == []                   # latched: stream is dead


def test_loopback_schedule_is_deterministic(loopback_wire):
    def deliver(seed):
        a, b = loopback_wire(seed=seed, reorder_window=4, dup_prob=0.3,
                             drop_idx=(5,), impair_both=False)
        for i in range(20):
            a.send(bytes([i]))
        out = []
        while (d := b.recv()) is not None:
            out.append(d[0])
        return out, a.stats
    out1, stats1 = deliver(9)
    out2, _ = deliver(9)
    assert out1 == out2                    # same seed, same wire
    assert 5 not in out1                   # the scheduled drop happened
    assert len(out1) == 19 + stats1["duplicated"]
    assert set(out1) == set(range(20)) - {5}   # everything else delivered
    assert out1 != sorted(out1)            # ...and actually reordered


# ---------------------------------------------------------------------------
# adversarial data plane: bitwise exactly-once under impairment
# ---------------------------------------------------------------------------

def _run_wire(rt, cli_t, srv_t, specs, waves, burst=3, credits=None,
              **gw_kw):
    gw = NetGateway(rt, srv_t, **gw_kw)
    client = NetClient(cli_t)
    for s in specs:
        _attach(rt, gw, client, s,
                credits=(credits or {}).get(s.tenant_id))
    streams = {s.tenant_id: chop(waves[s.tenant_id], CHUNK, seed=i,
                                 jitter=0.5)
               for i, s in enumerate(specs)}
    acct = replay_wire(gw, client, streams, burst=burst)
    return gw, client, acct


def test_wire_bitwise_exactly_once_reorder_dup(loopback_wire):
    cli_t, srv_t = loopback_wire(seed=21, reorder_window=5, dup_prob=0.25)
    specs = [_spec("f32", 100), _spec("i8", 101, "fused_int8")]
    waves = {s.tenant_id: _wave(300 + i) for i, s in enumerate(specs)}
    rt = ServeRuntime(BatchPolicy(max_batch=2, max_wait_s=1e9))
    gw, client, acct = _run_wire(rt, cli_t, srv_t, specs, waves)
    assert not acct["errors"]
    net = rt.obs.snapshot()["net"]
    assert net["duplicates"] > 0, "impairment never fired: vacuous test"
    for s in specs:                        # bitwise AND exactly once
        got = client.symbols(s.tenant_id)
        np.testing.assert_array_equal(got, _offline(s, waves[s.tenant_id]))
    assert net["gaps"] == 0 and net["crc_errors"] == 0


def test_wire_micro_stream_lengths_bitwise(loopback_wire):
    """Stream lengths around (and under) one tile_m=32 tile — including
    240 syms = 30 positions, historically 1-2 ULP off offline until the
    offline path stopped shrinking its tile below the requested tile_m.
    The wire contract (#12) is now unconditional in stream length."""
    for i, n_syms in enumerate((240, 250, 264, 320)):
        cli_t, srv_t = loopback_wire(seed=40 + i, reorder_window=2,
                                     dup_prob=0.2)
        specs = [_spec("f32", 100), _spec("i8", 101, "fused_int8")]
        waves = {s.tenant_id: _wave(360 + i, n_syms=n_syms) for s in specs}
        rt = ServeRuntime(BatchPolicy(max_batch=2, max_wait_s=1e9))
        gw, client, acct = _run_wire(rt, cli_t, srv_t, specs, waves)
        assert not acct["errors"]
        for s in specs:
            np.testing.assert_array_equal(
                client.symbols(s.tenant_id),
                _offline(s, waves[s.tenant_id]),
                err_msg=f"n_syms={n_syms} backend={s.backend}")


def test_wire_trace_propagation_client_to_emit(loopback_wire):
    """v2 DATA frames carry (trace_id, t_client); the ingress queues the
    context on the session and the next chunk span starts at the client:
    every sealed span shows client_send/net_ingress events, the Chrome
    export gains a "wire" slice, and a version-1-only decoder rejects the
    extended frames loudly (total-decode contract)."""
    from repro.obs import Observability
    cli_t, srv_t = loopback_wire(seed=45, impair_both=False)
    spec = _spec("tr", 107)
    wave = _wave(370, n_syms=480)
    obs = Observability(tracing=True)
    rt = ServeRuntime(BatchPolicy(max_batch=1, max_wait_s=1e9), obs=obs)
    gw = NetGateway(rt, srv_t)
    client = NetClient(cli_t, tracing=True, clock=obs.clock)
    _attach(rt, gw, client, spec)
    acct = replay_wire(gw, client, {"tr": chop(wave, CHUNK, seed=0)},
                       burst=4)
    assert not acct["errors"]
    np.testing.assert_array_equal(client.symbols("tr"),
                                  _offline(spec, wave))
    spans = obs.tracer.sealed_spans("tr")
    assert spans, "tracing on but no sealed spans"
    names = [n for s in spans for n, _, _ in s.events]
    assert "client_send" in names and "net_ingress" in names
    # every client frame's context landed on exactly one span
    n_ctx = sum(1 for n in names if n == "client_send")
    assert n_ctx == client.streams["tr"].tx_seq - 1   # DATA frames only
    # each context's send precedes its span's submit → a "wire" slice
    chrome = obs.tracer.export_chrome("tr")["traceEvents"]
    assert any(e["name"] == "wire" and e["ph"] == "X" for e in chrome)
    # an old (v1-only) decoder must reject the extended frames LOUDLY
    data = encode_frame(FrameType.DATA, "tr", 0, b"abcd",
                        dtype=WireDtype.FP32, trace_id=9, t_client=0.5)
    with pytest.raises(BadVersion):
        decode_frame(data, versions=(1,))
    f = decode_frame(data)                  # current decoder: fine
    assert f.trace_id == 9 and f.t_client == 0.5


def test_wire_bf16_tenant_parity(loopback_wire):
    """bf16 wire is lossy vs the original wave — parity is defined vs
    offline on the DECODED (bf16-rounded) waveform, chunk-split exact."""
    cli_t, srv_t = loopback_wire(seed=23, reorder_window=3, dup_prob=0.2)
    spec = _spec("b16", 102)
    wave = _wave(310)
    dec = decode_samples(encode_samples(wave, WireDtype.BF16),
                         WireDtype.BF16)
    rt = ServeRuntime(BatchPolicy(max_batch=1, max_wait_s=1e9))
    gw = NetGateway(rt, srv_t)
    client = NetClient(cli_t)
    rt.open(spec)
    gw.open_wire("b16")
    client.attach("b16", WireDtype.BF16)
    acct = replay_wire(gw, client, {"b16": chop(wave, CHUNK, seed=0)},
                       burst=3)
    assert not acct["errors"]
    np.testing.assert_array_equal(client.symbols("b16"),
                                  _offline(spec, dec))


def test_drop_surfaces_stream_gap_not_silent_hole(loopback_wire):
    # datagram 3 of a single-tenant stream is dropped; ≥window later
    # frames overflow the reorder window → loud per-tenant stream_gap
    cli_t, srv_t = loopback_wire(seed=25, reorder_window=0, drop_idx=(3,),
                                 impair_both=False)
    spec = _spec("t0", 103)
    wave = _wave(320, n_syms=480)
    rt = ServeRuntime(BatchPolicy(max_batch=1, max_wait_s=1e9))
    gw = NetGateway(rt, srv_t, reorder_window=2)
    client = NetClient(cli_t)
    _attach(rt, gw, client, spec)
    acct = replay_wire(gw, client,
                       {"t0": chop(wave, CHUNK, seed=0)}, burst=8)
    assert "t0" in acct["errors"]
    assert "stream_gap" in acct["errors"]["t0"]
    assert gw.ingress.error("t0") is not None
    assert "stream_gap" in gw.ingress.error("t0")
    assert client.errors("t0"), "client never saw the NACK"
    assert rt.obs.snapshot()["net"]["gaps"] == 1


def test_idle_stream_gap_swept_at_end(loopback_wire):
    # the drop lands near the END of the stream — too few frames follow
    # to overflow the window, so only the idle sweep can flag it
    cli_t, srv_t = loopback_wire(seed=26, reorder_window=0, drop_idx=(4,),
                                 impair_both=False)
    spec = _spec("t0", 104)
    wave = _wave(321, n_syms=480)   # ~8 chunks: index 4 is a mid-stream
    # DATA frame (dropping EOS would be a sender fault, not a wire gap)
    rt = ServeRuntime(BatchPolicy(max_batch=1, max_wait_s=1e9))
    gw = NetGateway(rt, srv_t, reorder_window=32)
    client = NetClient(cli_t)
    _attach(rt, gw, client, spec)
    acct = replay_wire(gw, client,
                       {"t0": chop(wave, CHUNK, seed=0)}, burst=8)
    assert "t0" in acct["errors"] and "stream_gap" in acct["errors"]["t0"]
    assert "idle" in gw.ingress.error("t0")


def test_gap_tenant_does_not_poison_others(loopback_wire):
    # round-robin burst=1: datagrams alternate gap/ok — index 2 is gap's
    # second DATA frame; tenant "ok" must still finish bitwise
    cli_t, srv_t = loopback_wire(seed=27, reorder_window=0, drop_idx=(2,),
                                 impair_both=False)
    specs = [_spec("gap", 105), _spec("ok", 106, "fused_int8")]
    waves = {"gap": _wave(330, n_syms=480), "ok": _wave(331, n_syms=480)}
    rt = ServeRuntime(BatchPolicy(max_batch=2, max_wait_s=1e9))
    gw, client, acct = _run_wire(rt, cli_t, srv_t, specs, waves, burst=1,
                                 reorder_window=2)
    assert set(acct["errors"]) == {"gap"}
    np.testing.assert_array_equal(client.symbols("ok"),
                                  _offline(specs[1], waves["ok"]))


def test_credit_starved_tenant_blocks_without_stalling_others(
        loopback_wire):
    cli_t, srv_t = loopback_wire(seed=28, impair_both=False)
    specs = [_spec("tiny", 107), _spec("big", 108)]
    waves = {s.tenant_id: _wave(340 + i) for i, s in enumerate(specs)}
    rt = ServeRuntime(BatchPolicy(max_batch=2, max_wait_s=1e9))
    gw = NetGateway(rt, srv_t)
    client = NetClient(cli_t)
    _attach(rt, gw, client, specs[0], credits=1)   # starved
    _attach(rt, gw, client, specs[1])              # default window
    client.poll()                                  # learn initial grants
    chunks = {t: chop(waves[t], CHUNK, seed=0) for t in waves}
    for c in chunks["tiny"]:
        client.send_samples("tiny", c)
    for c in chunks["big"]:
        client.send_samples("big", c)
    # the starved tenant is credit-blocked with a client-side backlog;
    # the healthy tenant's whole stream is already on the wire
    assert client.credits("tiny") == 0 and client.backlog("tiny") > 0
    assert client.backlog("big") == 0
    client.finish("tiny")
    client.finish("big")
    acct = replay_wire(gw, client, {"tiny": [], "big": []})
    assert not acct["errors"]
    for s in specs:
        np.testing.assert_array_equal(client.symbols(s.tenant_id),
                                      _offline(s, waves[s.tenant_id]))


def test_rude_sender_parks_bounded_then_overflows_loud(loopback_wire):
    """A sender ignoring its credit window: in-order frames park (bounded)
    and drain correctly while within `park_max`; beyond it they drop with
    a credit_overflow NACK — the queue can never grow unbounded."""
    spec = _spec("rude", 109)
    wave = _wave(350, n_syms=480)
    chunks = chop(wave, CHUNK, seed=0)

    def rude_blast(park_max):
        cli_t, srv_t = loopback_wire(seed=29, impair_both=False)
        rt = ServeRuntime(BatchPolicy(max_batch=1, max_wait_s=1e9))
        gw = NetGateway(rt, srv_t, initial_credits=2, park_max=park_max)
        rt.open(_spec("rude", 109))
        gw.open_wire("rude")
        for seq, c in enumerate(chunks):   # no credit discipline at all
            cli_t.send(encode_frame(
                FrameType.DATA, "rude", seq,
                encode_samples(c, WireDtype.FP32), dtype=WireDtype.FP32))
        cli_t.send(encode_frame(FrameType.EOS, "rude", len(chunks)))
        gw.settle()
        client = NetClient(cli_t)
        client.attach("rude", WireDtype.FP32)
        client.poll(max_datagrams=256)
        return rt, client

    rt, client = rude_blast(park_max=len(chunks) + 1)
    net = rt.obs.snapshot()["net"]
    assert net["frames_parked"] > 0        # parking really happened
    np.testing.assert_array_equal(client.symbols("rude"),
                                  _offline(spec, wave))

    rt2, client2 = rude_blast(park_max=2)
    net2 = rt2.obs.snapshot()["net"]
    assert net2["frames_dropped"] > 0 and net2["nacks_sent"] > 0
    assert any("credit_overflow" in e for e in client2.errors("rude"))


def test_wire_async_runtime_bitwise(loopback_wire):
    cli_t, srv_t = loopback_wire(seed=31, reorder_window=4, dup_prob=0.2)
    specs = [_spec("a0", 110), _spec("a1", 111, "fused_int8")]
    waves = {s.tenant_id: _wave(360 + i) for i, s in enumerate(specs)}
    with AsyncServeRuntime(BatchPolicy(max_batch=2, max_wait_s=2e-3)) as rt:
        gw, client, acct = _run_wire(rt, cli_t, srv_t, specs, waves)
        assert not acct["errors"]
        for s in specs:
            np.testing.assert_array_equal(
                client.symbols(s.tenant_id),
                _offline(s, waves[s.tenant_id]))


def test_wire_fleet_runtime_bitwise(loopback_wire):
    cli_t, srv_t = loopback_wire(seed=32, reorder_window=4, dup_prob=0.2)
    specs = [_spec("w0", 112), _spec("w1", 113, "fused_int8")]
    waves = {s.tenant_id: _wave(370 + i) for i, s in enumerate(specs)}
    with FleetRuntime(n_workers=2,
                      policy=BatchPolicy(max_batch=2, max_wait_s=2e-3)) as rt:
        gw, client, acct = _run_wire(rt, cli_t, srv_t, specs, waves)
        assert not acct["errors"]
        for s in specs:
            np.testing.assert_array_equal(
                client.symbols(s.tenant_id),
                _offline(s, waves[s.tenant_id]))


# ---------------------------------------------------------------------------
# control plane
# ---------------------------------------------------------------------------

def test_control_open_swap_splice_close(loopback_wire):
    """Two tenants opened via wire OPEN; t_swap hot-swaps weights via a
    control frame mid-stream — the PR 5 bitwise-per-epoch splice must
    hold end-to-end through the wire; the other tenant is untouched."""
    cli_t, srv_t = loopback_wire(seed=41, reorder_window=3, dup_prob=0.15)
    rt = ServeRuntime(BatchPolicy(max_batch=2, max_wait_s=1e9))
    gw = NetGateway(rt, srv_t)
    client = NetClient(cli_t)
    w_old, w_new = _weights(120), _weights(121)
    ack0 = client.open("swp", CFG, w_old, backend="fused_fp32",
                       tile_m=TILE_M, pump=gw.step)
    ack1 = client.open("i8", CFG, _weights(122), formats=INT8_FMT,
                       backend="fused_int8", tile_m=TILE_M, pump=gw.step)
    assert ack0["ok"] and ack0["granted"] > 0
    assert ack1["backend"] == "fused_int8" and ack1["a_frac"] == INT8_FMT[0][3]

    waves = {"swp": _wave(380), "i8": _wave(381)}
    chunks = {t: chop(waves[t], CHUNK, seed=0) for t in waves}
    half = len(chunks["swp"]) // 2
    for t in waves:
        for c in chunks[t][:half]:
            client.send_samples(t, c)
    gw.settle()
    client.poll(max_datagrams=256)

    swap_ack = client.swap_weights("swp", w_new, pump=gw.step)
    assert swap_ack["epoch"] == 1

    for t in waves:
        for c in chunks[t][half:]:
            client.send_samples(t, c)
        client.finish(t)
    acct = replay_wire(gw, client, {"swp": [], "i8": []})
    assert not acct["errors"]

    sess = rt.sessions.get("swp")
    (_, p0), (_, p1) = sess.swap_log
    assert p0 == 0 and p1 > 0
    vp = CFG.v_parallel
    off_old = _offline(_spec("swp", 120), waves["swp"])
    off_new = _offline(dataclasses.replace(_spec("swp", 121),
                                           weights=w_new), waves["swp"])
    want = np.concatenate([off_old[: p1 * vp], off_new[p1 * vp:]])
    np.testing.assert_array_equal(client.symbols("swp"), want)
    np.testing.assert_array_equal(
        client.symbols("i8"),
        _offline(_spec("i8", 122, "fused_int8"), waves["i8"]))

    assert client.close("swp", pump=gw.step)["syms_emitted"] == want.shape[0]
    assert client.close("i8", pump=gw.step)["ok"]
    assert "swp" not in rt.sessions and "i8" not in rt.sessions


def test_control_rollback_over_wire(loopback_wire):
    cli_t, srv_t = loopback_wire(seed=42, impair_both=False)
    rt = ServeRuntime(BatchPolicy(max_batch=1, max_wait_s=1e9))
    gw = NetGateway(rt, srv_t)
    client = NetClient(cli_t)
    client.open("rb", CFG, _weights(130), backend="fused_fp32",
                tile_m=TILE_M, pump=gw.step)
    assert client.swap_weights("rb", _weights(131),
                               pump=gw.step)["epoch"] == 1
    assert client.rollback_weights("rb", pump=gw.step)["epoch"] == 2


def test_control_malformed_and_unknown_leave_sessions_untouched(
        loopback_wire):
    cli_t, srv_t = loopback_wire(seed=43, impair_both=False)
    rt = ServeRuntime(BatchPolicy(max_batch=1, max_wait_s=1e9))
    gw = NetGateway(rt, srv_t)
    client = NetClient(cli_t)
    spec = _spec("live", 140)
    wave = _wave(390)
    client.open("live", CFG, _weights(140), backend="fused_fp32",
                tile_m=TILE_M, pump=gw.step)
    before = rt.stats()["tenants"]

    with pytest.raises(ControlAckError, match="unknown register"):
        client.command("live", {"reg": 999}, pump=gw.step)
    with pytest.raises(ControlAckError):   # wrong field type
        client.command("live", {"reg": 5, "max_batch": "huge"},
                       pump=gw.step)
    # raw garbage in a CTRL frame: error ack, not a crash
    cli_t.send(encode_frame(FrameType.CTRL, "live", 7777, b"\x00garbage"))
    gw.step()
    client.poll()
    assert client._acks.pop(7777)["ok"] is False
    # swap for a tenant that does not exist: error ack, sessions intact
    with pytest.raises(ControlAckError):
        client.swap_weights("ghost", _weights(1), pump=gw.step)

    assert rt.stats()["tenants"] == before
    sess = rt.sessions.get("live")
    assert sess.weight_epoch == 0 and sess.swap_log == [(0, 0)]
    # ... and the session still serves, bitwise
    acct = replay_wire(gw, client, {"live": chop(wave, CHUNK, seed=0)})
    assert not acct["errors"]
    np.testing.assert_array_equal(client.symbols("live"),
                                  _offline(spec, wave))


def test_control_policy_and_stats(loopback_wire):
    cli_t, srv_t = loopback_wire(seed=44, impair_both=False)
    rt = ServeRuntime(BatchPolicy(max_batch=8, max_wait_s=1e9))
    gw = NetGateway(rt, srv_t)
    client = NetClient(cli_t)
    ack = client.set_policy(max_batch=2, pump=gw.step)
    assert ack["policy"]["max_batch"] == 2
    assert rt.batcher.policy.max_batch == 2
    assert rt.batcher.policy.max_wait_s == 1e9      # untouched knob
    stats = client.read_stats(pump=gw.step)["stats"]
    assert stats["tenants"] == 0


def test_close_while_symbols_in_flight_is_refused(loopback_wire):
    cli_t, srv_t = loopback_wire(seed=45, impair_both=False)
    rt = ServeRuntime(BatchPolicy(max_batch=1, max_wait_s=1e9))
    gw = NetGateway(rt, srv_t)
    client = NetClient(cli_t)
    client.open("c0", CFG, _weights(150), backend="fused_fp32",
                tile_m=TILE_M, pump=gw.step)
    client.send_samples("c0", _wave(400))
    with pytest.raises(ControlAckError, match="close before EOS"):
        client.close("c0", pump=gw.step)
    # the refusal changed nothing — the stream is still attached (close()
    # only detaches on success): finish cleanly and close for real
    assert "c0" in client.streams
    client.finish("c0")
    acct = replay_wire(gw, client, {"c0": []})
    assert not acct["errors"]
    assert client.close("c0", pump=gw.step)["ok"]


# ---------------------------------------------------------------------------
# UDP transport smoke
# ---------------------------------------------------------------------------

def test_udp_transport_end_to_end():
    try:
        srv_t = UdpTransport(bind=("127.0.0.1", 0))
        cli_t = UdpTransport(bind=("127.0.0.1", 0), remote=srv_t.address)
    except OSError as e:
        pytest.skip(f"no UDP sockets in this sandbox: {e}")
    try:
        spec = _spec("udp", 160)
        wave = _wave(410)
        rt = ServeRuntime(BatchPolicy(max_batch=1, max_wait_s=1e9))
        gw = NetGateway(rt, srv_t)
        client = NetClient(cli_t)
        # over real sockets the CLIENT must speak first (the server only
        # learns its peer from the first datagram) — so open over the
        # control plane, exactly as a remote deployment would
        ack = client.open("udp", CFG, _weights(160), backend="fused_fp32",
                          tile_m=TILE_M, pump=gw.step)
        assert ack["ok"]
        acct = replay_wire(gw, client, {"udp": chop(wave, CHUNK, seed=0)},
                           max_rounds=2_000)
        assert not acct["errors"]
        np.testing.assert_array_equal(client.symbols("udp"),
                                      _offline(spec, wave))
        assert client.close("udp", pump=gw.step)["ok"]
    finally:
        srv_t.close()
        cli_t.close()
