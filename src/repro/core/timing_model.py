"""Timing model of the parallel equalizer (paper §6.1) + TPU re-derivation.

FPGA form (verbatim from the paper):

    t_init  = log2(N_i) · ℓ_ol / (2 · V_p · f_clk)          (pipeline fill)
    λ_sym  ≈ t_init                                          (symbol latency)
    t_p     = ℓ_in / (N_i·V_p·f_clk) · (1 + 2·o_act/ℓ_inst)  (processing time)
    T_net   = N_i·V_p·f_clk / (1 + 2·o_act/ℓ_inst)           (net throughput)
    T_max   = N_i·V_p·f_clk                                  (ceiling)

TPU form: an "instance" is a chip; `f_clk·V_p` (symbols/s/instance) becomes the
roofline-limited symbol rate of the fused CNN kernel, and the SSM/MSM split
tree becomes halo exchange whose fill time is the ICI transfer of 2·o_act
boundary symbols plus per-hop latency. The structural trade-off (latency ∝
ℓ_inst, throughput saturating in ℓ_inst) is IDENTICAL — this is the paper's
insight carried over; only the constants change.
"""
from __future__ import annotations

import dataclasses
import math

from .equalizer import CNNEqConfig
from .stream_partition import actual_overlap


@dataclasses.dataclass(frozen=True)
class HWProfile:
    """Hardware constants for the timing model."""
    name: str
    sym_rate_per_inst: float     # symbols/s produced by one instance (V_p·f_clk)
    link_bw: float               # bytes/s for split/merge or halo traffic
    hop_latency: float           # seconds per tree level / ICI hop
    bytes_per_sym: float = 2.0   # bf16 waveform samples (N_os=2 × 1 B eq.)


def fpga_profile(cfg: CNNEqConfig, f_clk: float = 200e6) -> HWProfile:
    return HWProfile(name="fpga-xcvu13p",
                     sym_rate_per_inst=cfg.v_parallel * f_clk,
                     link_bw=float("inf"), hop_latency=0.0)


def tpu_profile(cfg: CNNEqConfig, peak_flops: float = 197e12,
                mxu_util: float = 0.4, ici_bw: float = 50e9,
                ici_hop_latency: float = 1e-6) -> HWProfile:
    """Roofline-limited symbol rate of the fused CNN equalizer on one chip."""
    macs_per_sym = cfg.mac_per_symbol()
    sym_rate = mxu_util * peak_flops / (2.0 * macs_per_sym)
    return HWProfile(name="tpu-v5e", sym_rate_per_inst=sym_rate,
                     link_bw=ici_bw, hop_latency=ici_hop_latency)


# ---------------------------------------------------------------------------
# Paper equations
# ---------------------------------------------------------------------------

def t_init(cfg: CNNEqConfig, hw: HWProfile, n_inst: int, l_inst: int) -> float:
    """Time until the last instance starts processing (pipeline fill)."""
    o_act = actual_overlap(cfg, n_inst)
    l_ol = l_inst + 2 * o_act
    if n_inst == 1:
        fill = 0.0
    else:
        fill = math.log2(n_inst) * l_ol / (2.0 * hw.sym_rate_per_inst)
    # TPU extension: halo bytes over ICI + per-hop latency (0 for FPGA profile)
    halo = 2 * o_act * hw.bytes_per_sym / hw.link_bw if math.isfinite(hw.link_bw) else 0.0
    hops = math.log2(n_inst) * hw.hop_latency if n_inst > 1 else 0.0
    return fill + halo + hops


def symbol_latency(cfg: CNNEqConfig, hw: HWProfile, n_inst: int,
                   l_inst: int) -> float:
    """λ_sym ≈ t_init (paper eq. 3)."""
    return t_init(cfg, hw, n_inst, l_inst)


def processing_time(cfg: CNNEqConfig, hw: HWProfile, n_inst: int,
                    l_inst: int, l_in: int) -> float:
    o_act = actual_overlap(cfg, n_inst)
    return l_in / (n_inst * hw.sym_rate_per_inst) * (1 + 2 * o_act / l_inst)


def net_throughput(cfg: CNNEqConfig, hw: HWProfile, n_inst: int,
                   l_inst: int) -> float:
    """T_net in symbols/s (paper eq. 4)."""
    o_act = actual_overlap(cfg, n_inst)
    return n_inst * hw.sym_rate_per_inst / (1 + 2 * o_act / l_inst)


def max_throughput(hw: HWProfile, n_inst: int) -> float:
    """T_max = N_i · V_p · f_clk (ceiling as ℓ_inst → ∞)."""
    return n_inst * hw.sym_rate_per_inst


def min_instances(hw: HWProfile, t_req: float) -> int:
    """Smallest N_i whose T_max exceeds the required throughput."""
    return max(1, math.ceil(t_req / hw.sym_rate_per_inst))
