"""Time-varying channel wrappers — the in-the-field drift scenario.

The source paper trains its equalizer once, but the companion trainable-FPGA
work (Ney & Wehn 2023/2024) makes the deployment reality explicit: optical
and magnetic channels DRIFT — temperature changes the fiber's effective CD,
heads age, components get replaced — and a frozen equalizer's BER degrades
until someone retrains it. This module turns the repo's stationary channel
simulators (`repro.channels.proakis`, `repro.channels.imdd`) into
piecewise-stationary drifting ones so the online-adaptation runtime
(`repro.adapt`) has a scenario to close the loop on.

Model: a drift coordinate t ∈ [0, 1] selects the channel state.

  * `DriftingProakis` — tap rotation: the impulse response blends from
    Proakis-B toward a rotated (postcursor-heavy) tap vector, plus an SNR
    ramp. Tap rotation moves the channel's energy across the response —
    exactly the kind of change that is catastrophic for a frozen equalizer
    but trivially re-learnable (the new taps are still inside the CNN's
    receptive field).
  * `DriftingIMDD` — fiber-length ramp (temperature/aging changes the
    accumulated chromatic dispersion, i.e. the strength of the nonlinear
    CD × square-law ISI) plus an SNR ramp.
  * `DriftSchedule` — burst index → t mapping (hold, then linear ramp,
    then hold at 1): the piecewise-stationary trace `serve.loadgen`'s
    drift replay walks through.

The per-t simulators share ONE jit cache: the drifting parameters (taps,
SNR, fiber length) are traced arguments, so sweeping t costs a single XLA
compile per (cfg, n_syms) — important on interpret-mode CPU hosts where
each compile is ~175 ms. Under a fixed PRNG key every `at(t)` channel
function is bitwise-reproducible call-to-call (`tests/test_channels.py`).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import awgn, bits_to_pam, fir_same, rc_taps, rrc_taps, upsample
from .imdd import C_LIGHT, IMDDConfig
from .proakis import PROAKIS_B, ProakisConfig

# a channel function, as consumed by core.train_eq and the loadgen:
# (key, n_syms) → (rx waveform at n_os samples/symbol, tx symbol indices)
ChannelFn = Callable[[jax.Array, int], Tuple[jnp.ndarray, jnp.ndarray]]


@dataclasses.dataclass(frozen=True)
class DriftSchedule:
    """Burst index → drift coordinate t ∈ [0, 1].

    hold_bursts: bursts at t=0 (the stationary regime the equalizer was
                 trained for) before the ramp starts.
    ramp_bursts: bursts over which t ramps linearly 0 → 1; after the ramp
                 the channel holds at t=1 (the fully drifted state).
    """
    hold_bursts: int = 8
    ramp_bursts: int = 8

    def t_at(self, burst: int) -> float:
        if burst < self.hold_bursts:
            return 0.0
        if self.ramp_bursts <= 0:
            return 1.0
        return min(1.0, (burst - self.hold_bursts) / self.ramp_bursts)

    @property
    def total_to_settle(self) -> int:
        """First burst index at which the channel is fully drifted."""
        return self.hold_bursts + self.ramp_bursts


# ---------------------------------------------------------------------------
# Proakis-B with tap rotation + SNR ramp
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg", "n_syms"))
def simulate_proakis_taps(key: jax.Array, cfg: ProakisConfig, n_syms: int,
                          h: jnp.ndarray, snr_db: jnp.ndarray):
    """`proakis.simulate` with TRACED channel taps and SNR.

    Identical DSP chain (RC shaping at N_os, zero-stuffed symbol-rate ISI,
    AWGN, normalization); only the impulse response `h` (shape (3,)) and
    `snr_db` are runtime values, so every drift state shares one compiled
    program per (cfg, n_syms).
    """
    kbits, knoise = jax.random.split(key)
    syms = jax.random.randint(kbits, (n_syms,), 0, cfg.levels)
    amps = bits_to_pam(syms, cfg.levels)

    taps = jnp.asarray(rc_taps(cfg.rc_taps, cfg.rc_beta, cfg.n_os))
    x = upsample(amps, cfg.n_os)
    x = fir_same(x, taps)

    h_os = upsample(h.astype(jnp.float32), cfg.n_os)[: 2 * cfg.n_os + 1]
    y = fir_same(x, h_os)

    y = awgn(knoise, y, snr_db)
    y = (y - jnp.mean(y)) / (jnp.std(y) + 1e-9)
    return y, syms


class DriftingProakis:
    """Proakis-B magnetic-recording channel under tap rotation + SNR ramp.

    cfg:          the stationary `ProakisConfig` (t=0 state).
    taps_from:    impulse response at t=0 (default: Proakis-B). Passing
                  e.g. (1, 0, 0) with taps_to equal gives an AWGN-only
                  channel where ONLY the SNR ramp drifts — the
                  noise-dominated operating point the link-estimator
                  calibration (bench_link) needs.
    taps_to:      impulse response at t=1 (default: the base taps rotated
                  one position — the channel's energy migrates to the
                  postcursor, a shape a frozen equalizer was never
                  trained on). Blends linearly with the base taps and is
                  renormalized to unit energy at every t, so only the ISI
                  STRUCTURE drifts, not the signal power.
    snr_delta_db: SNR change at t=1 (default −4 dB — aging adds noise).
    """

    def __init__(self, cfg: ProakisConfig = ProakisConfig(),
                 taps_to: Tuple[float, ...] = None,
                 snr_delta_db: float = -4.0,
                 taps_from: Tuple[float, ...] = None):
        self.cfg = cfg
        h0 = (np.asarray(taps_from, np.float32) if taps_from is not None
              else np.asarray(PROAKIS_B, np.float32))
        h1 = (np.asarray(taps_to, np.float32) if taps_to is not None
              else np.roll(h0, 1))
        self._h0 = h0 / np.linalg.norm(h0)
        self._h1 = h1 / np.linalg.norm(h1)
        self.snr_delta_db = float(snr_delta_db)

    @property
    def n_os(self) -> int:
        return self.cfg.n_os

    @property
    def levels(self) -> int:
        return self.cfg.levels

    def taps_at(self, t: float) -> np.ndarray:
        h = (1.0 - t) * self._h0 + t * self._h1
        return (h / np.linalg.norm(h)).astype(np.float32)

    def snr_at(self, t: float) -> float:
        return self.cfg.snr_db + t * self.snr_delta_db

    def at(self, t: float) -> ChannelFn:
        """The channel function frozen at drift coordinate t."""
        t = float(min(1.0, max(0.0, t)))
        h = jnp.asarray(self.taps_at(t))
        snr = jnp.float32(self.snr_at(t))
        return lambda key, n_syms: simulate_proakis_taps(
            key, self.cfg, n_syms, h, snr)


# ---------------------------------------------------------------------------
# IM/DD with fiber-length (CD) + SNR ramp
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg", "n_syms"))
def simulate_imdd_fiber(key: jax.Array, cfg: IMDDConfig, n_syms: int,
                        fiber_km: jnp.ndarray, snr_db: jnp.ndarray):
    """`imdd.simulate` with TRACED fiber length and electrical SNR.

    The CD all-pass phase is computed in-graph from the traced length
    (H(f) = exp(+j·(π λ² D L / c)·f²)); everything else matches the
    stationary simulator exactly.
    """
    kbits, knoise = jax.random.split(key)
    syms = jax.random.randint(kbits, (n_syms,), 0, cfg.levels)
    amps = bits_to_pam(syms, cfg.levels)

    taps = jnp.asarray(rrc_taps(cfg.rrc_taps, cfg.rrc_beta, cfg.sim_os))
    x = upsample(amps, cfg.sim_os)
    x = fir_same(x, taps) * jnp.sqrt(float(cfg.sim_os))

    drive = cfg.mzm_vpi_frac * (np.pi / 2.0) * x
    field = jnp.cos(np.pi / 4.0 - drive / 2.0)

    fs = cfg.baud_rate * cfg.sim_os
    lam = cfg.wavelength_nm * 1e-9
    d = cfg.cd_ps_nm_km * 1e-12 / 1e-9 / 1e3
    f = jnp.asarray(np.fft.fftfreq(int(field.shape[0]), d=1.0 / fs),
                    jnp.float32)
    phase = (np.pi * lam**2 * d / C_LIGHT) * (fiber_km * 1e3) * f**2
    spec = jnp.fft.fft(field.astype(jnp.complex64))
    field_out = jnp.fft.ifft(spec * jnp.exp(1j * phase.astype(jnp.float32)))

    knoise, kase = jax.random.split(knoise)
    p_sig = jnp.mean(jnp.abs(field_out) ** 2)
    p_ase = p_sig / (10.0 ** (cfg.osnr_db / 10.0))
    ase = jnp.sqrt(p_ase / 2.0) * (
        jax.random.normal(kase, field_out.shape)
        + 1j * jax.random.normal(jax.random.fold_in(kase, 1),
                                 field_out.shape))
    field_out = field_out + ase.astype(field_out.dtype)

    current = jnp.abs(field_out) ** 2
    fnp = np.fft.fftfreq(int(current.shape[0]), d=1.0 / fs)
    pd_lpf = jnp.asarray(1.0 / np.sqrt(1.0 + (fnp / cfg.pd_bw_hz) ** 8))
    current = jnp.real(jnp.fft.ifft(jnp.fft.fft(current.astype(jnp.complex64))
                                    * pd_lpf))
    current = awgn(knoise, current.astype(jnp.float32), snr_db)

    step = cfg.sim_os // cfg.n_os
    rx = current[::step]
    rx = (rx - jnp.mean(rx)) / (jnp.std(rx) + 1e-9)
    return rx, syms


class DriftingIMDD:
    """40 GBd IM/DD optical channel under fiber-length (CD) + SNR drift.

    cfg:            the stationary `IMDDConfig` (t=0 state).
    fiber_delta_km: accumulated-dispersion change at t=1 (default +6 km of
                    effective fiber — temperature moves the CD coefficient,
                    which is equivalent to a length change).
    snr_delta_db:   electrical-SNR change at t=1 (default −3 dB).
    """

    def __init__(self, cfg: IMDDConfig = IMDDConfig(),
                 fiber_delta_km: float = 6.0,
                 snr_delta_db: float = -3.0):
        self.cfg = cfg
        self.fiber_delta_km = float(fiber_delta_km)
        self.snr_delta_db = float(snr_delta_db)

    @property
    def n_os(self) -> int:
        return self.cfg.n_os

    @property
    def levels(self) -> int:
        return self.cfg.levels

    def fiber_at(self, t: float) -> float:
        return self.cfg.fiber_km + t * self.fiber_delta_km

    def snr_at(self, t: float) -> float:
        return self.cfg.snr_db + t * self.snr_delta_db

    def at(self, t: float) -> ChannelFn:
        """The channel function frozen at drift coordinate t."""
        t = float(min(1.0, max(0.0, t)))
        fiber = jnp.float32(self.fiber_at(t))
        snr = jnp.float32(self.snr_at(t))
        return lambda key, n_syms: simulate_imdd_fiber(
            key, self.cfg, n_syms, fiber, snr)
