"""ServeRuntime / AsyncServeRuntime — multi-tenant streaming serving facades.

Synchronous facade (the deterministic tier-1 parity surface):

    rt = ServeRuntime(BatchPolicy(max_batch=8, max_wait_s=2e-3))
    rt.open(TenantSpec("link-a", cfg, params=params_a))
    rt.submit("link-a", samples)        # arbitrary chunk sizes
    rt.pump()                           # honour max_wait while idle
    syms = rt.close("link-a")           # flush tail, return the stream

Asynchronous front-end (the production shape — ROADMAP "async serve
front-end"):

    with AsyncServeRuntime(BatchPolicy(max_batch=8)) as rt:
        rt.open(TenantSpec("link-a", cfg, params=params_a))
        fut = rt.submit("link-a", samples)   # returns a per-chunk future
        ...
        syms = rt.close("link-a")            # waits for in-flight launches

Why threads, not asyncio
------------------------
The device phase of a launch is `fn(x)` + `jax.block_until_ready` — a
blocking C++ call with no awaitable completion hook. Under asyncio it would
have to run in an executor thread anyway, so an event loop would add a
second scheduling layer without removing the thread. The runtime therefore
uses two plain daemon threads and `concurrent.futures.Future` per chunk:

  * a LAUNCHER thread owns the device: it pops assembled `LaunchBatch`es
    from a bounded queue, runs the fused kernel, and de-scatters results;
  * a TIMER thread fires the `max_wait_s` pump — time-based flushes no
    longer depend on the caller happening to call `pump()`.

asyncio callers lose nothing: `asyncio.wrap_future(rt.submit(...))` turns
the per-chunk handle into a native awaitable.

Double buffering
----------------
`submit()` does the HOST half of the pipeline on the caller's thread: push
samples into the chunker, enqueue, check the batch policy, and — when a
group is ready — assemble the padded stacked input and per-row weight fn
(`MicroBatcher.take_ready`). The assembled batch is handed to the launcher
through a depth-bounded queue, so while launch k executes on device the
caller/timer threads are already assembling launch k+1 and de-scattering
happens as each launch lands. The queue bound (`queue_depth`, default 2 =
one executing + one assembled-and-waiting) is the double-buffer depth and
doubles as backpressure: submit blocks rather than letting assembly run
unboundedly ahead of the device.

The parity contract survives because ONLY the driving loop changes: same
chunker, same `take_ready` policy/assembly, same stacked launches, and a
single FIFO launcher thread preserves per-session emission order — chunked
streaming output stays bitwise-equal to the offline engine on all fused
backends (tests/test_serve.py runs the parity sweep under both drivers).

Launch failures & recovery (serve/recovery.py)
----------------------------------------------
The launcher retries a failed batch in place (the assembled input is a
self-contained snapshot) up to `launch_retries` times, with exponential
backoff + deterministic jitter between attempts, and — when
`launch_deadline_s` is set — a per-launch watchdog that abandons a hung
device call instead of blocking the launcher thread forever. A failure
that survives the in-place retries used to poison the affected sessions
outright; now it enters bounded per-session FAILOVER: each affected
session's engine is dropped from the pool and rebuilt from its
`TenantSpec` (the PR 3 eviction invariant — engines are disposable), the
lost chunks are re-assembled from their retained `ChunkPlan` input
snapshots and re-executed, and the replayed output is bitwise-equal to
the uninterrupted stream (same plans, same tile alignment, deterministic
engine rebuild). Only a session that exhausts
`RecoveryPolicy.max_session_recoveries` (or whose engine rebuild itself
keeps failing) is poisoned the old way (`Session.failed`), so
`output()`/`close()` still raise rather than returning a stream with a
hole. Corrupted outputs (NaN/saturated — the output sentinel in
`MicroBatcher.descatter` rejects them before anything is emitted) take
the same replay path, optionally rolling the session's weights back to
`prev_spec` first (the PR 5 hot-swap quarantine). A `StragglerMonitor`
over launch latencies can additionally drive graceful degradation —
shrink `BatchPolicy.max_batch`, shed lowest-priority tenants, restore
when healthy (`degrade_on_slow=True`).

Serve-aware autotune (ROADMAP "serve-aware autotune") lives in
`_serve_tile`, shared by both facades: tenants opened with tile_m="auto"
after a tune-key's traffic histograms are warm (≥ `BatchPolicy.retune_after`
launches) get `best_tile_m(probe_batch=mode occupancy,
probe_syms=median live width)` instead of the single-stream default.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import queue
import random
import threading
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

import numpy as np

from ..core import autotune as autotune_lib
from ..core.engine import EqualizerEngine
from ..obs import Observability
from ..runtime.straggler import StragglerConfig
from .pool import EnginePool
from .recovery import (CorruptOutput, DegradationController, FaultPlan,
                       LaunchTimeout, RecoveryPolicy, RecoveryStats,
                       TenantShedError)
from .scheduler import BatchPolicy, LaunchBatch, MicroBatcher, Request
from .session import Session, SessionManager, TenantSpec

# sentinel that tells the launcher thread to exit (after the queue drains)
_SHUTDOWN = object()

# serve-aware probe floor: below this the sweep can't distinguish tiles
_MIN_PROBE_SYMS = 64


def _serve_tile(batcher: MicroBatcher,
                engine: EqualizerEngine) -> Optional[int]:
    """Serve-aware tile for a NEW session, or None to keep the engine's
    single-stream autotune choice.

    Returns a tile only once the engine's tune-key has ≥
    `BatchPolicy.retune_after` recorded launches (the histogram warm-up)
    AND steady-state occupancy is actually batched (mode > 1) — otherwise
    the single-stream tile is already the right model. The sweep probes
    `best_tile_m` with the OBSERVED mode batch occupancy and median launch
    width, and is cached (memory + disk) under the batched
    (probe_batch, probe_syms) key, so one sweep serves every subsequent
    open on this traffic shape.
    """
    pol = batcher.policy
    if pol.retune_after <= 0 or engine.backend == "ref":
        return None                    # disabled, or no tiling knob at all
    stats = batcher.traffic.get(engine.tune_key())
    if stats is None or stats.launches < pol.retune_after:
        return None                    # histogram not warm yet
    occupancy = stats.mode_occupancy()
    if occupancy <= 1:
        return None                    # effectively single-stream traffic
    probe_syms = max(_MIN_PROBE_SYMS,
                     stats.median_width() // engine.cfg.n_os)
    tile = autotune_lib.best_tile_m(
        engine.cfg, engine.backend, engine._make_fn,
        probe_batch=occupancy, probe_syms=probe_syms)
    batcher.tracer.instant(           # profiling hook: the serve-aware
        "autotune", backend=engine.backend,       # retune DECISION itself
        probe_batch=occupancy, probe_syms=probe_syms, tile_m=tile)
    return tile


def _swap_spec(session: Session, params, bn_state, weights) -> TenantSpec:
    """Build the hot-swap TenantSpec: NEW weights, the ACTIVE deployment's
    static kernel config.

    The swapped spec pins backend, formats, and tile to what the stream is
    actually serving (not the original spec's possibly-"auto" values): a
    weight-only swap must land in the same batch group with the same
    chunker tiling — `Session.install_spec` verifies the resulting
    group_key is unchanged and refuses otherwise. The weight epoch bumps by
    one; exactly one of params/weights must be given (TenantSpec's own
    invariant, checked at build).
    """
    engine = session.engine
    return dataclasses.replace(
        session.spec, params=params, bn_state=bn_state, weights=weights,
        formats=engine.formats, backend=engine.backend,
        tile_m=engine.resolved_tile_m(),
        weight_epoch=session.spec.weight_epoch + 1)


def _wire_runtime_obs(rt, obs: Observability) -> None:
    """Register runtime-level telemetry under the "serve" scope (the
    batcher registered its launch instruments there already): lazy
    snapshot-time callbacks that REUSE the existing accounting (pool LRU
    counters, per-session state) — no double counting, no hot-path cost —
    plus the engine-pool build hook that records build/compile events as a
    histogram + trace instants."""
    scope = obs.scope("serve")
    pool = rt.sessions.pool
    pool.clock = obs.clock
    h_build = scope.histogram("pool.build_s")

    def _on_build(key, dt: float) -> None:
        h_build.observe(dt)
        obs.tracer.instant("engine_build", tenant=str(key), build_s=dt)

    pool.build_hook = _on_build
    scope.callback("pool", pool.stats)
    scope.callback("tenants", lambda: len(rt.sessions))
    scope.callback("sessions", lambda: {
        tid: {"syms_emitted": s.syms_emitted,
              "weight_epoch": s.weight_epoch,
              "recoveries": s.recoveries,
              "inflight": s.inflight,
              "shed": s.shed,
              "failed": s.failed is not None}
        for tid, s in rt.sessions.sessions.items()})


class ServeRuntime:
    """Synchronous single-threaded serving facade.

    Launches happen inside `submit`/`pump`/`drain` on the caller's thread,
    which keeps results deterministic (bitwise-reproducible vs the offline
    engine — the tier-1 test surface) while still modelling the real
    coalescing policy with timestamps. `AsyncServeRuntime` moves WHERE the
    phases run without changing any of them.

    policy:       `BatchPolicy` coalescing knobs (default: max_batch=8,
                  max_wait_s=2 ms).
    max_engines:  LRU engine-pool bound (count; default 32). Evicting an
                  engine loses no stream state — it rebuilds from the
                  tenant's spec on next use.
    clock:        timestamp source (seconds; default time.perf_counter) —
                  injectable for deterministic policy tests.
    fault_plan:   optional `FaultPlan` chaos schedule (launch + build
                  faults; see `repro.serve.recovery`). The sync driver has
                  no failover loop — an injected fault surfaces to the
                  caller like any launch error, and the un-executed
                  batches requeue for the next pump (the existing
                  transient-retry semantic).
    sentinel_limit: output-sentinel bound (|y| ≤ limit, finite; default
                  None = disabled on the sync path). A rejected batch
                  raises `CorruptOutput` with its inputs unconsumed.
    obs:          optional `repro.obs.Observability` hub (metrics registry
                  + chunk tracer + `Retention` bounds). Default None builds
                  a private hub with tracing OFF; pass
                  `Observability(tracing=True)` for chunk-lifecycle spans.
                  `rt.obs.snapshot()` is the normalized telemetry tree —
                  `stats()` stays as a thin legacy wrapper (key map in
                  docs/OBSERVABILITY.md).
    link:         optional `repro.obs.LinkMonitor` — every tenant opened on
                  this runtime is auto-attached for streaming EVM/SNR/SER
                  estimation (``link.<tenant>.*`` in the obs registry).
    """

    def __init__(self, policy: Optional[BatchPolicy] = None,
                 max_engines: int = 32,
                 clock: Callable[[], float] = time.perf_counter,
                 fault_plan: Optional[FaultPlan] = None,
                 sentinel_limit: Optional[float] = None,
                 obs: Optional[Observability] = None,
                 link=None):
        self.obs = obs if obs is not None else Observability(clock=clock)
        self.link = link
        self.sessions = SessionManager(
            max_engines=max_engines,
            swap_log_max=self.obs.retention.swap_log)
        self.batcher = MicroBatcher(policy, clock=clock, obs=self.obs)
        self.batcher.fault_plan = fault_plan
        self.batcher.sentinel_limit = sentinel_limit
        self.sessions.pool.fault_plan = fault_plan
        _wire_runtime_obs(self, self.obs)

    # -- tenant lifecycle --------------------------------------------------

    def open(self, spec: TenantSpec) -> Session:
        """Admit a tenant: build (or pool-hit) its engine, start a stream.
        Raises ValueError if the tenant_id is already open. Specs with
        tile_m="auto" may receive a serve-aware tile (see `_serve_tile`)."""
        session = self.sessions.open(
            spec, tile_tuner=lambda e: _serve_tile(self.batcher, e))
        if self.link is not None:
            self.link.attach(session)
        return session

    def close(self, tenant_id: str) -> np.ndarray:
        """End a tenant's stream: flush the receptive-field tail, launch
        ONLY this tenant's pending requests (other tenants' partial
        batches keep waiting for their policy), release the session;
        returns the full symbol stream (identical to the offline engine
        on the whole waveform)."""
        self.finish(tenant_id)
        self.batcher.flush_session(self.sessions.get(tenant_id))
        return self.sessions.close(tenant_id).output()

    # -- weight hot-swap ---------------------------------------------------

    def swap_weights(self, tenant_id: str, params=None, bn_state=None,
                     weights=None) -> int:
        """Hot-swap a live tenant's weights at a chunk boundary.

        Flushes the tenant's pending requests first (other tenants'
        partial batches keep waiting), so every position planned so far is
        emitted with the OLD weights; positions planned afterwards use the
        NEW ones. The chunker's carry is tile-aligned at that boundary,
        so within each weight epoch the streamed output stays
        bitwise-equal to the offline engine of that epoch's spec applied
        to the whole waveform (the per-epoch slice of contract #4 —
        docs/ADAPTATION.md). Backend, formats, and tile are pinned from
        the live engine; a swap that would change any of them raises
        ValueError and leaves the stream untouched. Returns the new
        weight epoch."""
        s = self.sessions.get(tenant_id)
        self.batcher.flush_session(s)
        epoch = s.install_spec(_swap_spec(s, params, bn_state, weights))
        self.obs.tracer.instant("hot_swap", tenant=tenant_id, epoch=epoch)
        return epoch

    def rollback_weights(self, tenant_id: str) -> int:
        """Restore the spec active before the last swap — bit-identical
        weights (specs rebuild engines deterministically) under a NEW
        epoch. Raises RuntimeError if there is nothing to roll back to."""
        s = self.sessions.get(tenant_id)
        if s.prev_spec is None:
            raise RuntimeError(f"tenant {tenant_id!r}: no previous weights")
        prev = dataclasses.replace(s.prev_spec,
                                   weight_epoch=s.spec.weight_epoch + 1)
        self.batcher.flush_session(s)
        epoch = s.install_spec(prev)
        self.obs.tracer.instant("rollback", tenant=tenant_id, epoch=epoch)
        return epoch

    # -- streaming ---------------------------------------------------------

    def submit(self, tenant_id: str, samples) -> Optional[Request]:
        """Feed a chunk of waveform samples; may trigger batched launches
        (max_batch reached, or another group's max_wait expired). Returns
        the queued request (symbols populated once launched) or None when
        the chunk is buffered below one emittable position."""
        s = self.sessions.get(tenant_id)
        s.chunker.push(np.asarray(samples))
        req = self.batcher.enqueue(s)
        self.batcher.pump()
        return req

    def finish(self, tenant_id: str) -> Optional[Request]:
        """End-of-stream marker: queue the zero-padded tail flush."""
        s = self.sessions.get(tenant_id)
        if not s.chunker.finished:
            s.chunker.finish()
        return self.batcher.enqueue(s)

    def pump(self) -> int:
        """Time-based flush (call while idle to honour max_wait_s)."""
        return self.batcher.pump()

    def drain(self) -> int:
        """Launch every pending request now."""
        return self.batcher.drain()

    def output(self, tenant_id: str) -> np.ndarray:
        return self.sessions.get(tenant_id).output()

    # -- accounting --------------------------------------------------------

    @property
    def pool(self) -> EnginePool:
        return self.sessions.pool

    def stats(self) -> Dict:
        """Thin legacy wrapper over the obs registry's providers (key map
        in docs/OBSERVABILITY.md); `self.obs.snapshot()` is the full
        normalized tree. `errors_total` is always present (0 here — the
        sync driver surfaces launch errors to the caller instead of
        recording them), matching the async/fleet schema."""
        st = {"tenants": len(self.sessions),
              "pending": self.batcher.pending(),
              "errors_total": 0,
              "pool": self.pool.stats(),
              "traffic": self.batcher.traffic_stats()}
        st.update(self.batcher.latency_stats())
        return st


class AsyncServeRuntime:
    """Event-loop serving front-end: same chunker, same policy, same
    stacked launches as `ServeRuntime` — driven by threads instead of the
    caller (see module docstring for the full design rationale).

    policy:         `BatchPolicy` coalescing knobs. `max_wait_s` is
                    honoured by the built-in timer thread — no caller
                    pump() needed.
    max_engines:    LRU engine-pool bound (count; default 32).
    clock:          timestamp source (seconds; default time.perf_counter).
    queue_depth:    double-buffer depth — assembled launches allowed ahead
                    of the device (count; default 2 = one executing + one
                    waiting). submit() blocks when full (backpressure).
    launch_retries: in-place retries for a failed device launch before the
                    batch enters failover (count; default 2), with
                    exponential backoff + jitter between attempts
                    (`RecoveryPolicy.backoff_base_s`/`backoff_max_s`).
    launch_deadline_s: per-launch watchdog deadline (seconds; default None
                    = disabled). When set, a device call that exceeds it is
                    ABANDONED (`LaunchTimeout`, counted as a failed
                    attempt) instead of blocking the launcher forever.
                    Leave None on interpret-mode (CPU) hosts — first-touch
                    kernel compiles legitimately take seconds there.
    recovery:       `RecoveryPolicy` failover bounds (default: the policy
                    defaults — failover ON, 4 rounds/session, output
                    sentinel at 1e4). Terminal failures beyond the bounds
                    fail the chunk futures, record the error in `errors`,
                    and poison the sessions involved, exactly as before.
    fault_plan:     optional `FaultPlan` chaos schedule, wired into the
                    batcher (launch faults) and engine pool (build
                    faults). Testing/benching hook; None in production.
    straggler:      `StragglerConfig` for the launch-latency monitor
                    (default: stock config — 3σ, patience 3, warmup 5).
    degrade_on_slow: opt-in graceful degradation (default False: the
                    monitor observes and reports, but never mutates the
                    batch policy or sheds tenants — silently rejecting
                    traffic is a policy decision). When True, persistent
                    slowness halves `BatchPolicy.max_batch` and sheds the
                    `shed_count` lowest-priority tenants (their submits
                    raise `TenantShedError`); both revert when healthy.

    Thread-safety: `submit`/`finish`/`pump`/`drain`/`open`/`close`/
    `output`/`stats` may be called from any thread; per-TENANT calls must
    not race each other (one producer per stream — chunk order would
    otherwise be ambiguous anyway). Always `shutdown()` (or use as a
    context manager): abandoned runtimes leak two daemon threads until
    process exit.
    """

    ERRORS_MAX = 256                   # bounded error window (see stats())

    def __init__(self, policy: Optional[BatchPolicy] = None,
                 max_engines: int = 32,
                 clock: Callable[[], float] = time.perf_counter,
                 queue_depth: int = 2,
                 launch_retries: int = 2,
                 launch_deadline_s: Optional[float] = None,
                 recovery: Optional[RecoveryPolicy] = None,
                 fault_plan: Optional[FaultPlan] = None,
                 straggler: Optional[StragglerConfig] = None,
                 degrade_on_slow: bool = False,
                 shed_count: int = 1,
                 obs: Optional[Observability] = None,
                 link=None):
        if queue_depth < 1:
            raise ValueError("queue_depth must be ≥ 1")
        self.obs = obs if obs is not None else Observability(clock=clock)
        # optional LinkMonitor — tenants auto-attach at open (see
        # ServeRuntime); the tap runs in descatter under _lock, and
        # LinkMonitor.observe is itself locked, so it is thread-safe here
        self.link = link
        self.sessions = SessionManager(
            max_engines=max_engines,
            swap_log_max=self.obs.retention.swap_log)
        self.batcher = MicroBatcher(policy, clock=clock, obs=self.obs)
        self.launch_retries = launch_retries
        self.launch_deadline_s = launch_deadline_s
        self.recovery = recovery if recovery is not None else RecoveryPolicy()
        self.recovery_stats = RecoveryStats()
        self.fault_plan = fault_plan
        self.batcher.fault_plan = fault_plan
        self.batcher.sentinel_limit = self.recovery.sentinel_limit
        self.sessions.pool.fault_plan = fault_plan
        # seeded: backoff sleep sequences reproduce run-to-run
        self._backoff_rng = random.Random(0)
        self.degradation = DegradationController(
            self.batcher, self.sessions, cfg=straggler,
            shed_count=shed_count, mitigate=degrade_on_slow)
        self._launch_seq = 0           # launches observed by the monitor
        # bounded: a persistently failing stream must not grow host memory
        # without limit; `errors_total` keeps the failure RATE observable
        # after the window wraps (same pattern as OnlineAdapter.errors).
        # The bound comes from the retention policy (default == ERRORS_MAX)
        self.errors: Deque[BaseException] = deque(
            maxlen=self.obs.retention.errors)
        self.errors_total = 0
        _wire_runtime_obs(self, self.obs)
        scope = self.obs.scope("serve")
        scope.callback("inflight", lambda: self._inflight)
        scope.callback("errors", lambda: {
            "total": self.errors_total,
            "window": len(self.errors),
            "dropped": self.errors_total - len(self.errors)})
        scope.callback("recovery", self.recovery_stats.as_dict)
        scope.callback("degradation", self.degradation.state)
        self._lock = threading.RLock()
        # serializes take→enqueue sequences: without it, thread A could
        # pop batch k under the lock, get preempted before the queue put,
        # and thread B (timer vs producer) could put batch k+1 first —
        # inverting the FIFO the per-session emission order relies on.
        # Ordering: _dispatch_mutex is always taken BEFORE _lock, and the
        # launcher thread never touches it, so a blocking put (queue full)
        # cannot deadlock against descatter.
        self._dispatch_mutex = threading.Lock()
        self._done = threading.Condition(self._lock)
        self._inflight = 0             # requests taken but not yet landed
        self._launch_q: "queue.Queue" = queue.Queue(maxsize=queue_depth)
        self._stop = threading.Event()
        self._launcher = threading.Thread(
            target=self._launch_loop, name="serve-launcher", daemon=True)
        self._timer = threading.Thread(
            target=self._timer_loop, name="serve-pump-timer", daemon=True)
        self._launcher.start()
        self._timer.start()

    # -- lifecycle ---------------------------------------------------------

    def shutdown(self) -> None:
        """Stop the timer and launcher threads (idempotent). Pending
        batches already queued are still executed; pending requests that
        never assembled stay unlaunched — call `drain()` first for a clean
        flush."""
        if self._stop.is_set():
            return
        self._stop.set()
        self._timer.join()
        self._launch_q.put(_SHUTDOWN)
        self._launcher.join()

    def __enter__(self) -> "AsyncServeRuntime":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- tenant lifecycle --------------------------------------------------

    def open(self, spec: TenantSpec) -> Session:
        """Admit a tenant (see `ServeRuntime.open`). A serve-aware autotune
        sweep (cold cache + warm histograms) runs under the runtime lock —
        rare and bounded, but expect the first such open to pause other
        host-side progress for the sweep duration."""
        with self._lock:
            self._check_running()
            session = self.sessions.open(
                spec, tile_tuner=lambda e: _serve_tile(self.batcher, e))
            if self.link is not None:
                self.link.attach(session)
            return session

    def close(self, tenant_id: str) -> np.ndarray:
        """End a tenant's stream: flush the tail, launch ONLY this tenant's
        pending requests, WAIT for its in-flight launches to land, release
        the session, and return the full stream (bitwise-equal to the
        offline engine). Raises RuntimeError if a launch for this stream
        was lost (see `launch_retries`)."""
        with self._dispatch_mutex:
            with self._lock:
                self._check_running()
                s = self.sessions.get(tenant_id)
                if not s.chunker.finished:
                    s.chunker.finish()
                req = self.batcher.enqueue(s)
                if req is not None:
                    req.future = concurrent.futures.Future()
                batches = self._take(self.batcher.take_session(s))
            self._dispatch(batches)
        with self._done:
            while s.inflight > 0 and s.failed is None:
                self._done.wait(0.05)
            return self.sessions.close(tenant_id).output()

    # -- weight hot-swap ---------------------------------------------------

    def _swap_barrier(self, tenant_id: str, make_spec,
                      marker: str = "hot_swap") -> int:
        """Shared swap machinery: build the candidate engine OUTSIDE the
        locks (BN fold + weight quantization take hundreds of ms on
        interpret-mode hosts — serving must not stall behind them), then
        flush the tenant's pending requests, WAIT for its in-flight
        launches to land, and install — the barrier-and-install runs under
        `_dispatch_mutex`, so no producer/timer thread can plan new
        positions between the barrier and the install (the swap boundary
        stays exact). Holding the dispatch mutex while waiting is safe:
        the launcher thread lands batches under `_lock` only, and
        `_done.wait` releases `_lock`. Concurrent swaps of the SAME tenant
        are the caller's bug (one adapter per tenant); the epoch check
        below turns that race into a loud error instead of a corrupted
        swap_log."""
        with self._lock:
            self._check_running()
            s = self.sessions.get(tenant_id)
            new_spec = make_spec(s)            # cheap: dataclass replace
        candidate = new_spec.build_engine()    # expensive: NO locks held
        with self._dispatch_mutex:
            with self._lock:
                self._check_running()
                if s.spec.weight_epoch != new_spec.weight_epoch - 1:
                    raise RuntimeError(
                        f"tenant {tenant_id!r}: concurrent weight swap "
                        f"detected (epoch moved while building)")
                batches = self._take(self.batcher.take_session(s))
            self._dispatch(batches)
            with self._done:
                while s.inflight > 0 and s.failed is None:
                    self._done.wait(0.05)
                if s.failed is not None:
                    raise RuntimeError(
                        f"stream {tenant_id!r} lost a chunk to a failed "
                        f"launch; refusing to swap weights") from s.failed
                epoch = s.install_spec(new_spec, prebuilt=candidate)
                self.obs.tracer.instant(marker, tenant=tenant_id,
                                        epoch=epoch)
                return epoch

    def swap_weights(self, tenant_id: str, params=None, bn_state=None,
                     weights=None) -> int:
        """Hot-swap a live tenant's weights at a chunk boundary (see
        `ServeRuntime.swap_weights`). Thread-safe against concurrent
        submits: the swap holds the dispatch mutex while its barrier
        drains, so the epoch boundary in `Session.swap_log` is exact even
        with a producer racing the swap."""
        return self._swap_barrier(
            tenant_id, lambda s: _swap_spec(s, params, bn_state, weights))

    def rollback_weights(self, tenant_id: str) -> int:
        """Restore the pre-swap weights bit-identically under a new epoch
        (see `ServeRuntime.rollback_weights`)."""
        def mk(s: Session) -> TenantSpec:
            if s.prev_spec is None:
                raise RuntimeError(
                    f"tenant {tenant_id!r}: no previous weights")
            return dataclasses.replace(
                s.prev_spec, weight_epoch=s.spec.weight_epoch + 1)
        return self._swap_barrier(tenant_id, mk, marker="rollback")

    # -- streaming ---------------------------------------------------------

    def submit(self, tenant_id: str,
               samples) -> Optional[concurrent.futures.Future]:
        """Feed a chunk of waveform samples. Returns a per-chunk future
        resolving to this chunk's emitted symbols (np.ndarray) — or None
        when the samples were buffered without reaching an emittable
        position (they will ride in a later chunk's future). The future
        raises the terminal launch error if the chunk's batch was lost.
        Blocks only on backpressure (launch queue full). Raises
        `TenantShedError` while this tenant is load-shed by the
        degradation controller (`degrade_on_slow`) — shed tenants are
        readmitted automatically once launch health returns."""
        with self._dispatch_mutex:
            with self._lock:
                self._check_running()
                s = self.sessions.get(tenant_id)
                if s.shed:
                    raise TenantShedError(
                        f"tenant {tenant_id!r} is load-shed while the "
                        f"runtime is degraded; resubmit after recovery")
                s.chunker.push(np.asarray(samples))
                req = self.batcher.enqueue(s)
                if req is not None:
                    req.future = concurrent.futures.Future()
                batches = self._take(self.batcher.take_ready())
            self._dispatch(batches)
        return req.future if req is not None else None

    def finish(self, tenant_id: str) -> Optional[concurrent.futures.Future]:
        """End-of-stream marker: queue the zero-padded tail flush. Returns
        the tail chunk's future (None if the stream had no residue)."""
        with self._dispatch_mutex:
            with self._lock:
                self._check_running()
                s = self.sessions.get(tenant_id)
                if not s.chunker.finished:
                    s.chunker.finish()
                req = self.batcher.enqueue(s)
                if req is not None:
                    req.future = concurrent.futures.Future()
                batches = self._take(self.batcher.take_ready())
            self._dispatch(batches)
        return req.future if req is not None else None

    def pump(self) -> int:
        """Manual scheduling pass (normally unnecessary — the timer thread
        owns max_wait flushes). Returns launches SCHEDULED, not landed."""
        with self._dispatch_mutex:
            with self._lock:
                batches = self._take(self.batcher.take_ready())
            self._dispatch(batches)
        return len(batches)

    def drain(self) -> int:
        """Schedule every pending request and BLOCK until the pipeline is
        empty (all launches landed or terminally failed). Returns the
        number of launches scheduled by this call."""
        n = 0
        while True:
            with self._dispatch_mutex:
                with self._lock:
                    batches = self._take(
                        self.batcher.take_ready(force=True))
                self._dispatch(batches)
            if batches:
                n += len(batches)
                continue
            with self._done:
                while self._inflight > 0:
                    self._done.wait(0.05)
                if self.batcher.pending() == 0:
                    return n

    def output(self, tenant_id: str) -> np.ndarray:
        """Symbols emitted so far (stream order). NOT a barrier: in-flight
        launches land asynchronously — use the chunk futures, `drain()`, or
        `close()` for completion. Raises if the stream lost a chunk."""
        with self._lock:
            return self.sessions.get(tenant_id).output()

    # -- accounting --------------------------------------------------------

    @property
    def pool(self) -> EnginePool:
        return self.sessions.pool

    def stats(self) -> Dict:
        """Thin legacy wrapper over the obs registry's providers (key map
        in docs/OBSERVABILITY.md); `self.obs.snapshot()` is the full
        normalized tree. `errors_total` (the canonical cross-runtime key)
        and the historical `errors` int both report the lifetime count —
        the drifted schema kept `errors` for callers that already read
        it."""
        with self._lock:
            st = {"tenants": len(self.sessions),
                  "pending": self.batcher.pending(),
                  "inflight": self._inflight,
                  "queue_depth": self._launch_q.maxsize,
                  "errors": self.errors_total,
                  "errors_total": self.errors_total,
                  "errors_dropped": self.errors_total - len(self.errors),
                  "pool": self.pool.stats(),
                  "traffic": self.batcher.traffic_stats(),
                  "recovery": self.recovery_stats.as_dict(),
                  "degradation": self.degradation.state()}
            st.update(self.batcher.latency_stats())
            return st

    # -- internals ---------------------------------------------------------

    def _check_running(self) -> None:
        if self._stop.is_set():
            raise RuntimeError("runtime is shut down")

    def _take(self, batches: List[LaunchBatch]) -> List[LaunchBatch]:
        """Account freshly assembled batches as in-flight (lock held)."""
        for b in batches:
            for r in b.reqs:
                r.session.inflight += 1
            self._inflight += len(b.reqs)
        return batches

    def _dispatch(self, batches: List[LaunchBatch]) -> None:
        """Hand assembled batches to the launcher thread. Blocking put on
        the depth-bounded queue = the backpressure/double-buffer bound.
        Always called holding `_dispatch_mutex` but NEVER `_lock` (the
        launcher needs the latter to land batches and free queue slots).
        If a put fails, the un-dispatched batches are un-accounted and
        requeued so drain()/close() cannot wait on work that will never
        execute."""
        for i, b in enumerate(batches):
            try:
                self._launch_q.put(b)
            except BaseException:
                with self._lock:
                    for rb in reversed(batches[i:]):
                        self.batcher.requeue(rb)
                        for r in rb.reqs:
                            r.session.inflight -= 1
                        self._inflight -= len(rb.reqs)
                    self._done.notify_all()
                raise

    def _timer_loop(self) -> None:
        """The event loop's clock: fire a pump pass on a max_wait_s-scaled
        cadence so time-based flushes don't depend on caller activity."""
        while not self._stop.is_set():
            wait = self.batcher.policy.max_wait_s
            self._stop.wait(min(max(wait / 4.0, 1e-3), 0.05))
            if self._stop.is_set():
                return
            try:
                with self._dispatch_mutex:
                    with self._lock:
                        batches = self._take(self.batcher.take_ready())
                    self._dispatch(batches)
            except Exception as e:  # noqa: BLE001 — keep the clock alive
                with self._lock:
                    self._record_error(e)

    def _record_error(self, e: BaseException) -> None:
        self.errors.append(e)          # bounded window (ERRORS_MAX)
        self.errors_total += 1

    def _launch_loop(self) -> None:
        """The device owner: execute each assembled batch (NO lock — this
        is the overlap window), then land it under the lock. A failed
        execute retries in place (backoff between attempts), then enters
        bounded failover (`_failover`); the launcher runs replays inline,
        preserving FIFO order and therefore per-session stream order."""
        while True:
            batch = self._launch_q.get()
            if batch is _SHUTDOWN:
                self._launch_q.task_done()
                return
            self._run_batch(batch)
            self._launch_q.task_done()

    def _run_batch(self, batch: LaunchBatch) -> None:
        """Drive one assembled batch to a terminal state: every request is
        descattered exactly once, or its future fails and its session is
        poisoned. Failover rounds replay the surviving requests through
        rebuilt engines until they land or exhaust their budget."""
        t_fail: Optional[float] = None
        round_idx = 0
        while True:
            y, err = self._try_execute(batch)
            if err is None:
                with self._lock:
                    try:
                        self.batcher.descatter(batch, y)
                        self._land_locked(batch)
                        if t_fail is not None:
                            self.recovery_stats.record_recovery(
                                self.batcher.clock() - t_fail)
                        return
                    except CorruptOutput as e:
                        # sentinel rejected the output BEFORE anything was
                        # emitted: batch state intact → quarantine + replay
                        self.recovery_stats.bump("corrupt_detected")
                        err = e
                    except Exception as e:  # noqa: BLE001 — launcher lives
                        # descatter failed MIDWAY: emission state ambiguous,
                        # replay could double-emit — poison, as before
                        self._record_error(e)
                        self.batcher.fail(batch, e)
                        self._land_locked(batch)
                        return
            if t_fail is None:
                t_fail = self.batcher.clock()
            batch = self._failover(batch, err)
            if batch is None:
                return                 # everything poisoned and landed
            time.sleep(self.recovery.backoff_s(round_idx, self._backoff_rng))
            round_idx += 1

    def _try_execute(self, batch: LaunchBatch):
        """In-place launch attempts: `launch_retries` retries with
        exponential backoff + jitter, each under the watchdog deadline.
        Returns (y, None) on success, (None, last error) when exhausted.
        Every attempt's latency feeds the straggler monitor (timeouts
        count at the deadline — the watchdog saw at least that much).
        Latencies come from the runtime's injectable clock (same source
        as the batcher timestamps), so fake-clock tests see deterministic
        values; failed attempts append a "retry" child event to each
        affected chunk's span."""
        clk = self.batcher.clock
        err: Optional[BaseException] = None
        for attempt in range(self.launch_retries + 1):
            if attempt:
                time.sleep(self.recovery.backoff_s(attempt - 1,
                                                   self._backoff_rng))
            t0 = clk()
            try:
                y = self._execute_deadline(batch)
            except Exception as e:  # noqa: BLE001 — retried/reported
                err = e
                dt = (self.launch_deadline_s
                      if isinstance(e, LaunchTimeout)
                      else clk() - t0)
                self._observe_launch(dt)
                if self.batcher.tracer.enabled:
                    t = clk()
                    for r in batch.reqs:
                        if r.plan.span is not None:
                            r.plan.span.event("retry", t, attempt=attempt,
                                              error=repr(e))
                continue
            self._observe_launch(clk() - t0)
            return y, None
        return None, err

    def _execute_deadline(self, batch: LaunchBatch) -> np.ndarray:
        """One device attempt, watchdog-bounded when `launch_deadline_s`
        is set: the blocking call runs on a daemon worker thread; if it
        misses the deadline the worker is ABANDONED (it cannot be killed —
        a hung C++ device call holds no Python-visible cancellation point)
        and `LaunchTimeout` is raised so the launcher stays live. The
        abandoned attempt's output, if it ever lands, is dropped on the
        floor — only the launcher thread descatters."""
        deadline = self.launch_deadline_s
        if deadline is None:
            return self.batcher.execute(batch)
        result: Dict[str, object] = {}
        done = threading.Event()

        def _worker() -> None:
            try:
                result["y"] = self.batcher.execute(batch)
            except BaseException as e:  # noqa: BLE001 — re-raised below
                result["e"] = e
            finally:
                done.set()

        t = threading.Thread(target=_worker, name="serve-watchdog-exec",
                             daemon=True)
        t.start()
        if not done.wait(deadline):
            self.recovery_stats.bump("deadline_timeouts")
            raise LaunchTimeout(
                f"launch exceeded deadline {deadline:g}s; "
                f"hung device call abandoned")
        if "e" in result:
            raise result["e"]          # type: ignore[misc]
        return result["y"]             # type: ignore[return-value]

    def _observe_launch(self, dt: float) -> None:
        """Feed one launch-attempt latency to the degradation controller
        (which needs the lock: it may shrink the policy / shed tenants)."""
        with self._lock:
            idx = self._launch_seq
            self._launch_seq += 1
            self.degradation.observe(idx, dt)

    def _land_locked(self, batch: LaunchBatch) -> None:
        """Account a batch's requests as no longer in flight (lock held)."""
        for r in batch.reqs:
            r.session.inflight -= 1
        self._inflight -= len(batch.reqs)
        self._done.notify_all()

    def _failover(self, batch: LaunchBatch,
                  err: BaseException) -> Optional[LaunchBatch]:
        """One bounded failover round for a terminally failed (or
        corrupted) batch. Requests whose session still has recovery budget
        get their engine rebuilt from its `TenantSpec` (pool drop + build
        — the PR 3 eviction invariant) and are re-assembled into a replay
        batch from their retained `ChunkPlan` input snapshots; the rest
        are poisoned exactly like the pre-recovery terminal path. Returns
        the replay batch, or None when nothing survived (all landed).

        Bitwise safety: plans are input snapshots committed at enqueue,
        engine rebuilds are deterministic, and `assemble` recomputes the
        identical width bucket — so a replayed launch is the SAME stacked
        computation the failed one would have produced (contract #9)."""
        corrupt = isinstance(err, CorruptOutput)
        with self._lock:
            self._record_error(err)
            distinct = {id(r.session): r.session for r in batch.reqs}
            for s in distinct.values():
                s.recoveries += 1
            keep: List[Request] = []
            doomed: List[Request] = []
            for r in batch.reqs:
                s = r.session
                over = s.recoveries > self.recovery.max_session_recoveries
                (doomed if over or s.failed is not None else keep).append(r)
            self._poison_locked(doomed, err)
        if not keep:
            return None
        # engine rebuilds run OUTSIDE the lock: builds fold BN + quantize
        # (hundreds of ms on interpret-mode hosts) and rebuild backoff
        # sleeps — producers/timer must keep planning meanwhile
        alive: Dict[int, bool] = {}
        build_err: Optional[BaseException] = None
        for s in {id(r.session): r.session for r in keep}.values():
            e = self._recover_session(s, corrupt)
            alive[id(s)] = e is None
            build_err = e or build_err
        good = [r for r in keep if alive[id(r.session)]]
        dead = [r for r in keep if not alive[id(r.session)]]
        with self._lock:
            if dead:
                self._poison_locked(dead, build_err or err)
            if not good:
                return None
            # re-assembly under the lock (fn cache is not thread-safe);
            # rebuilt engines have fresh ids → natural stacked-fn cache
            # miss → the replay binds the NEW engines' weights
            if self.batcher.tracer.enabled:
                t = self.batcher.clock()
                for r in good:
                    if r.plan.span is not None:
                        r.plan.span.event("replay", t,
                                          error=type(err).__name__)
            replay = self.batcher.assemble(batch.key, good)
            self.recovery_stats.bump("recoveries")
            self.recovery_stats.bump("chunks_replayed", len(good))
        return replay

    def _poison_locked(self, reqs: List[Request],
                       err: BaseException) -> None:
        """Terminal path for requests that exhausted (or never had) their
        recovery budget: fail futures, poison sessions, land (lock held).
        No-op on an empty list."""
        if not reqs:
            return
        newly = {id(r.session) for r in reqs if r.session.failed is None}
        self.batcher.fail_requests(reqs, err)
        self.recovery_stats.bump("sessions_poisoned", len(newly))
        for r in reqs:
            r.session.inflight -= 1
        self._inflight -= len(reqs)
        self._done.notify_all()

    def _recover_session(self, s: Session,
                         corrupt: bool) -> Optional[BaseException]:
        """Rebuild one session's engine for replay (no locks held).
        On a corrupt-output failover, first try the PR 5 quarantine: roll
        the weights back to `prev_spec` bit-identically (at most once per
        session — `rolled_back` latches, so a corruption that survives
        the rollback cannot ping-pong between specs). Otherwise — or when
        there is nothing to roll back to — drop the pool entry and rebuild
        from the active spec, retrying `build_retries` times with backoff
        (an injected/real build failure is itself transient-retryable).
        Returns None on success, the last build error on failure."""
        if (corrupt and self.recovery.rollback_on_corrupt
                and s.prev_spec is not None and not s.rolled_back):
            try:
                prev = dataclasses.replace(
                    s.prev_spec, weight_epoch=s.spec.weight_epoch + 1)
                s.install_spec(prev)   # replaces the pool entry itself
                s.rolled_back = True
                self.recovery_stats.bump("rollbacks")
                self.recovery_stats.bump("engine_rebuilds")
                self.obs.tracer.instant(
                    "rollback", tenant=s.spec.tenant_id,
                    epoch=prev.weight_epoch, reason="corrupt_quarantine")
                return None
            except Exception:  # noqa: BLE001 — fall back to plain rebuild
                pass
        err: Optional[BaseException] = None
        self.pool.drop(s.spec.tenant_id)
        for attempt in range(self.recovery.build_retries + 1):
            if attempt:
                time.sleep(self.recovery.backoff_s(attempt - 1,
                                                   self._backoff_rng))
            try:
                s.engine               # pool miss → spec.build_engine()
                self.recovery_stats.bump("engine_rebuilds")
                return None
            except Exception as e:  # noqa: BLE001 — bounded retries
                err = e
        return err
