"""ServeRuntime / AsyncServeRuntime — multi-tenant streaming serving facades.

Synchronous facade (the deterministic tier-1 parity surface):

    rt = ServeRuntime(BatchPolicy(max_batch=8, max_wait_s=2e-3))
    rt.open(TenantSpec("link-a", cfg, params=params_a))
    rt.submit("link-a", samples)        # arbitrary chunk sizes
    rt.pump()                           # honour max_wait while idle
    syms = rt.close("link-a")           # flush tail, return the stream

Asynchronous front-end (the production shape — ROADMAP "async serve
front-end"):

    with AsyncServeRuntime(BatchPolicy(max_batch=8)) as rt:
        rt.open(TenantSpec("link-a", cfg, params=params_a))
        fut = rt.submit("link-a", samples)   # returns a per-chunk future
        ...
        syms = rt.close("link-a")            # waits for in-flight launches

Why threads, not asyncio
------------------------
The device phase of a launch is `fn(x)` + `jax.block_until_ready` — a
blocking C++ call with no awaitable completion hook. Under asyncio it would
have to run in an executor thread anyway, so an event loop would add a
second scheduling layer without removing the thread. The runtime therefore
uses two plain daemon threads and `concurrent.futures.Future` per chunk:

  * a LAUNCHER thread owns the device: it pops assembled `LaunchBatch`es
    from a bounded queue, runs the fused kernel, and de-scatters results;
  * a TIMER thread fires the `max_wait_s` pump — time-based flushes no
    longer depend on the caller happening to call `pump()`.

asyncio callers lose nothing: `asyncio.wrap_future(rt.submit(...))` turns
the per-chunk handle into a native awaitable.

Double buffering
----------------
`submit()` does the HOST half of the pipeline on the caller's thread: push
samples into the chunker, enqueue, check the batch policy, and — when a
group is ready — assemble the padded stacked input and per-row weight fn
(`MicroBatcher.take_ready`). The assembled batch is handed to the launcher
through a depth-bounded queue, so while launch k executes on device the
caller/timer threads are already assembling launch k+1 and de-scattering
happens as each launch lands. The queue bound (`queue_depth`, default 2 =
one executing + one assembled-and-waiting) is the double-buffer depth and
doubles as backpressure: submit blocks rather than letting assembly run
unboundedly ahead of the device.

The parity contract survives because ONLY the driving loop changes: same
chunker, same `take_ready` policy/assembly, same stacked launches, and a
single FIFO launcher thread preserves per-session emission order — chunked
streaming output stays bitwise-equal to the offline engine on all fused
backends (tests/test_serve.py runs the parity sweep under both drivers).

Launch failures: the launcher retries a failed batch in place (the
assembled input is a self-contained snapshot) up to `launch_retries` times;
a terminal failure fails the affected chunk futures AND poisons the
affected sessions (`Session.failed`) so `output()`/`close()` raise instead
of silently returning a stream with a hole.

Serve-aware autotune (ROADMAP "serve-aware autotune") lives in
`_serve_tile`, shared by both facades: tenants opened with tile_m="auto"
after a tune-key's traffic histograms are warm (≥ `BatchPolicy.retune_after`
launches) get `best_tile_m(probe_batch=mode occupancy,
probe_syms=median live width)` instead of the single-stream default.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import queue
import threading
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from ..core import autotune as autotune_lib
from ..core.engine import EqualizerEngine
from .pool import EnginePool
from .scheduler import BatchPolicy, LaunchBatch, MicroBatcher, Request
from .session import Session, SessionManager, TenantSpec

# sentinel that tells the launcher thread to exit (after the queue drains)
_SHUTDOWN = object()

# serve-aware probe floor: below this the sweep can't distinguish tiles
_MIN_PROBE_SYMS = 64


def _serve_tile(batcher: MicroBatcher,
                engine: EqualizerEngine) -> Optional[int]:
    """Serve-aware tile for a NEW session, or None to keep the engine's
    single-stream autotune choice.

    Returns a tile only once the engine's tune-key has ≥
    `BatchPolicy.retune_after` recorded launches (the histogram warm-up)
    AND steady-state occupancy is actually batched (mode > 1) — otherwise
    the single-stream tile is already the right model. The sweep probes
    `best_tile_m` with the OBSERVED mode batch occupancy and median launch
    width, and is cached (memory + disk) under the batched
    (probe_batch, probe_syms) key, so one sweep serves every subsequent
    open on this traffic shape.
    """
    pol = batcher.policy
    if pol.retune_after <= 0 or engine.backend == "ref":
        return None                    # disabled, or no tiling knob at all
    stats = batcher.traffic.get(engine.tune_key())
    if stats is None or stats.launches < pol.retune_after:
        return None                    # histogram not warm yet
    occupancy = stats.mode_occupancy()
    if occupancy <= 1:
        return None                    # effectively single-stream traffic
    probe_syms = max(_MIN_PROBE_SYMS,
                     stats.median_width() // engine.cfg.n_os)
    return autotune_lib.best_tile_m(
        engine.cfg, engine.backend, engine._make_fn,
        probe_batch=occupancy, probe_syms=probe_syms)


def _swap_spec(session: Session, params, bn_state, weights) -> TenantSpec:
    """Build the hot-swap TenantSpec: NEW weights, the ACTIVE deployment's
    static kernel config.

    The swapped spec pins backend, formats, and tile to what the stream is
    actually serving (not the original spec's possibly-"auto" values): a
    weight-only swap must land in the same batch group with the same
    chunker tiling — `Session.install_spec` verifies the resulting
    group_key is unchanged and refuses otherwise. The weight epoch bumps by
    one; exactly one of params/weights must be given (TenantSpec's own
    invariant, checked at build).
    """
    engine = session.engine
    return dataclasses.replace(
        session.spec, params=params, bn_state=bn_state, weights=weights,
        formats=engine.formats, backend=engine.backend,
        tile_m=engine.resolved_tile_m(),
        weight_epoch=session.spec.weight_epoch + 1)


class ServeRuntime:
    """Synchronous single-threaded serving facade.

    Launches happen inside `submit`/`pump`/`drain` on the caller's thread,
    which keeps results deterministic (bitwise-reproducible vs the offline
    engine — the tier-1 test surface) while still modelling the real
    coalescing policy with timestamps. `AsyncServeRuntime` moves WHERE the
    phases run without changing any of them.

    policy:       `BatchPolicy` coalescing knobs (default: max_batch=8,
                  max_wait_s=2 ms).
    max_engines:  LRU engine-pool bound (count; default 32). Evicting an
                  engine loses no stream state — it rebuilds from the
                  tenant's spec on next use.
    clock:        timestamp source (seconds; default time.perf_counter) —
                  injectable for deterministic policy tests.
    """

    def __init__(self, policy: Optional[BatchPolicy] = None,
                 max_engines: int = 32,
                 clock: Callable[[], float] = time.perf_counter):
        self.sessions = SessionManager(max_engines=max_engines)
        self.batcher = MicroBatcher(policy, clock=clock)

    # -- tenant lifecycle --------------------------------------------------

    def open(self, spec: TenantSpec) -> Session:
        """Admit a tenant: build (or pool-hit) its engine, start a stream.
        Raises ValueError if the tenant_id is already open. Specs with
        tile_m="auto" may receive a serve-aware tile (see `_serve_tile`)."""
        return self.sessions.open(
            spec, tile_tuner=lambda e: _serve_tile(self.batcher, e))

    def close(self, tenant_id: str) -> np.ndarray:
        """End a tenant's stream: flush the receptive-field tail, launch
        ONLY this tenant's pending requests (other tenants' partial
        batches keep waiting for their policy), release the session;
        returns the full symbol stream (identical to the offline engine
        on the whole waveform)."""
        self.finish(tenant_id)
        self.batcher.flush_session(self.sessions.get(tenant_id))
        return self.sessions.close(tenant_id).output()

    # -- weight hot-swap ---------------------------------------------------

    def swap_weights(self, tenant_id: str, params=None, bn_state=None,
                     weights=None) -> int:
        """Hot-swap a live tenant's weights at a chunk boundary.

        Flushes the tenant's pending requests first (other tenants'
        partial batches keep waiting), so every position planned so far is
        emitted with the OLD weights; positions planned afterwards use the
        NEW ones. The chunker's carry is tile-aligned at that boundary,
        so within each weight epoch the streamed output stays
        bitwise-equal to the offline engine of that epoch's spec applied
        to the whole waveform (the per-epoch slice of contract #4 —
        docs/ADAPTATION.md). Backend, formats, and tile are pinned from
        the live engine; a swap that would change any of them raises
        ValueError and leaves the stream untouched. Returns the new
        weight epoch."""
        s = self.sessions.get(tenant_id)
        self.batcher.flush_session(s)
        return s.install_spec(_swap_spec(s, params, bn_state, weights))

    def rollback_weights(self, tenant_id: str) -> int:
        """Restore the spec active before the last swap — bit-identical
        weights (specs rebuild engines deterministically) under a NEW
        epoch. Raises RuntimeError if there is nothing to roll back to."""
        s = self.sessions.get(tenant_id)
        if s.prev_spec is None:
            raise RuntimeError(f"tenant {tenant_id!r}: no previous weights")
        prev = dataclasses.replace(s.prev_spec,
                                   weight_epoch=s.spec.weight_epoch + 1)
        self.batcher.flush_session(s)
        return s.install_spec(prev)

    # -- streaming ---------------------------------------------------------

    def submit(self, tenant_id: str, samples) -> Optional[Request]:
        """Feed a chunk of waveform samples; may trigger batched launches
        (max_batch reached, or another group's max_wait expired). Returns
        the queued request (symbols populated once launched) or None when
        the chunk is buffered below one emittable position."""
        s = self.sessions.get(tenant_id)
        s.chunker.push(np.asarray(samples))
        req = self.batcher.enqueue(s)
        self.batcher.pump()
        return req

    def finish(self, tenant_id: str) -> Optional[Request]:
        """End-of-stream marker: queue the zero-padded tail flush."""
        s = self.sessions.get(tenant_id)
        if not s.chunker.finished:
            s.chunker.finish()
        return self.batcher.enqueue(s)

    def pump(self) -> int:
        """Time-based flush (call while idle to honour max_wait_s)."""
        return self.batcher.pump()

    def drain(self) -> int:
        """Launch every pending request now."""
        return self.batcher.drain()

    def output(self, tenant_id: str) -> np.ndarray:
        return self.sessions.get(tenant_id).output()

    # -- accounting --------------------------------------------------------

    @property
    def pool(self) -> EnginePool:
        return self.sessions.pool

    def stats(self) -> Dict:
        st = {"tenants": len(self.sessions),
              "pending": self.batcher.pending(),
              "pool": self.pool.stats(),
              "traffic": self.batcher.traffic_stats()}
        st.update(self.batcher.latency_stats())
        return st


class AsyncServeRuntime:
    """Event-loop serving front-end: same chunker, same policy, same
    stacked launches as `ServeRuntime` — driven by threads instead of the
    caller (see module docstring for the full design rationale).

    policy:         `BatchPolicy` coalescing knobs. `max_wait_s` is
                    honoured by the built-in timer thread — no caller
                    pump() needed.
    max_engines:    LRU engine-pool bound (count; default 32).
    clock:          timestamp source (seconds; default time.perf_counter).
    queue_depth:    double-buffer depth — assembled launches allowed ahead
                    of the device (count; default 2 = one executing + one
                    waiting). submit() blocks when full (backpressure).
    launch_retries: in-place retries for a failed device launch before the
                    batch is declared lost (count; default 2). Terminal
                    failure fails the chunk futures, records the error in
                    `errors`, and poisons the sessions involved.

    Thread-safety: `submit`/`finish`/`pump`/`drain`/`open`/`close`/
    `output`/`stats` may be called from any thread; per-TENANT calls must
    not race each other (one producer per stream — chunk order would
    otherwise be ambiguous anyway). Always `shutdown()` (or use as a
    context manager): abandoned runtimes leak two daemon threads until
    process exit.
    """

    def __init__(self, policy: Optional[BatchPolicy] = None,
                 max_engines: int = 32,
                 clock: Callable[[], float] = time.perf_counter,
                 queue_depth: int = 2,
                 launch_retries: int = 2):
        if queue_depth < 1:
            raise ValueError("queue_depth must be ≥ 1")
        self.sessions = SessionManager(max_engines=max_engines)
        self.batcher = MicroBatcher(policy, clock=clock)
        self.launch_retries = launch_retries
        self.errors: List[BaseException] = []
        self._lock = threading.RLock()
        # serializes take→enqueue sequences: without it, thread A could
        # pop batch k under the lock, get preempted before the queue put,
        # and thread B (timer vs producer) could put batch k+1 first —
        # inverting the FIFO the per-session emission order relies on.
        # Ordering: _dispatch_mutex is always taken BEFORE _lock, and the
        # launcher thread never touches it, so a blocking put (queue full)
        # cannot deadlock against descatter.
        self._dispatch_mutex = threading.Lock()
        self._done = threading.Condition(self._lock)
        self._inflight = 0             # requests taken but not yet landed
        self._launch_q: "queue.Queue" = queue.Queue(maxsize=queue_depth)
        self._stop = threading.Event()
        self._launcher = threading.Thread(
            target=self._launch_loop, name="serve-launcher", daemon=True)
        self._timer = threading.Thread(
            target=self._timer_loop, name="serve-pump-timer", daemon=True)
        self._launcher.start()
        self._timer.start()

    # -- lifecycle ---------------------------------------------------------

    def shutdown(self) -> None:
        """Stop the timer and launcher threads (idempotent). Pending
        batches already queued are still executed; pending requests that
        never assembled stay unlaunched — call `drain()` first for a clean
        flush."""
        if self._stop.is_set():
            return
        self._stop.set()
        self._timer.join()
        self._launch_q.put(_SHUTDOWN)
        self._launcher.join()

    def __enter__(self) -> "AsyncServeRuntime":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- tenant lifecycle --------------------------------------------------

    def open(self, spec: TenantSpec) -> Session:
        """Admit a tenant (see `ServeRuntime.open`). A serve-aware autotune
        sweep (cold cache + warm histograms) runs under the runtime lock —
        rare and bounded, but expect the first such open to pause other
        host-side progress for the sweep duration."""
        with self._lock:
            self._check_running()
            return self.sessions.open(
                spec, tile_tuner=lambda e: _serve_tile(self.batcher, e))

    def close(self, tenant_id: str) -> np.ndarray:
        """End a tenant's stream: flush the tail, launch ONLY this tenant's
        pending requests, WAIT for its in-flight launches to land, release
        the session, and return the full stream (bitwise-equal to the
        offline engine). Raises RuntimeError if a launch for this stream
        was lost (see `launch_retries`)."""
        with self._dispatch_mutex:
            with self._lock:
                self._check_running()
                s = self.sessions.get(tenant_id)
                if not s.chunker.finished:
                    s.chunker.finish()
                req = self.batcher.enqueue(s)
                if req is not None:
                    req.future = concurrent.futures.Future()
                batches = self._take(self.batcher.take_session(s))
            self._dispatch(batches)
        with self._done:
            while s.inflight > 0 and s.failed is None:
                self._done.wait(0.05)
            return self.sessions.close(tenant_id).output()

    # -- weight hot-swap ---------------------------------------------------

    def _swap_barrier(self, tenant_id: str, make_spec) -> int:
        """Shared swap machinery: build the candidate engine OUTSIDE the
        locks (BN fold + weight quantization take hundreds of ms on
        interpret-mode hosts — serving must not stall behind them), then
        flush the tenant's pending requests, WAIT for its in-flight
        launches to land, and install — the barrier-and-install runs under
        `_dispatch_mutex`, so no producer/timer thread can plan new
        positions between the barrier and the install (the swap boundary
        stays exact). Holding the dispatch mutex while waiting is safe:
        the launcher thread lands batches under `_lock` only, and
        `_done.wait` releases `_lock`. Concurrent swaps of the SAME tenant
        are the caller's bug (one adapter per tenant); the epoch check
        below turns that race into a loud error instead of a corrupted
        swap_log."""
        with self._lock:
            self._check_running()
            s = self.sessions.get(tenant_id)
            new_spec = make_spec(s)            # cheap: dataclass replace
        candidate = new_spec.build_engine()    # expensive: NO locks held
        with self._dispatch_mutex:
            with self._lock:
                self._check_running()
                if s.spec.weight_epoch != new_spec.weight_epoch - 1:
                    raise RuntimeError(
                        f"tenant {tenant_id!r}: concurrent weight swap "
                        f"detected (epoch moved while building)")
                batches = self._take(self.batcher.take_session(s))
            self._dispatch(batches)
            with self._done:
                while s.inflight > 0 and s.failed is None:
                    self._done.wait(0.05)
                if s.failed is not None:
                    raise RuntimeError(
                        f"stream {tenant_id!r} lost a chunk to a failed "
                        f"launch; refusing to swap weights") from s.failed
                return s.install_spec(new_spec, prebuilt=candidate)

    def swap_weights(self, tenant_id: str, params=None, bn_state=None,
                     weights=None) -> int:
        """Hot-swap a live tenant's weights at a chunk boundary (see
        `ServeRuntime.swap_weights`). Thread-safe against concurrent
        submits: the swap holds the dispatch mutex while its barrier
        drains, so the epoch boundary in `Session.swap_log` is exact even
        with a producer racing the swap."""
        return self._swap_barrier(
            tenant_id, lambda s: _swap_spec(s, params, bn_state, weights))

    def rollback_weights(self, tenant_id: str) -> int:
        """Restore the pre-swap weights bit-identically under a new epoch
        (see `ServeRuntime.rollback_weights`)."""
        def mk(s: Session) -> TenantSpec:
            if s.prev_spec is None:
                raise RuntimeError(
                    f"tenant {tenant_id!r}: no previous weights")
            return dataclasses.replace(
                s.prev_spec, weight_epoch=s.spec.weight_epoch + 1)
        return self._swap_barrier(tenant_id, mk)

    # -- streaming ---------------------------------------------------------

    def submit(self, tenant_id: str,
               samples) -> Optional[concurrent.futures.Future]:
        """Feed a chunk of waveform samples. Returns a per-chunk future
        resolving to this chunk's emitted symbols (np.ndarray) — or None
        when the samples were buffered without reaching an emittable
        position (they will ride in a later chunk's future). The future
        raises the terminal launch error if the chunk's batch was lost.
        Blocks only on backpressure (launch queue full)."""
        with self._dispatch_mutex:
            with self._lock:
                self._check_running()
                s = self.sessions.get(tenant_id)
                s.chunker.push(np.asarray(samples))
                req = self.batcher.enqueue(s)
                if req is not None:
                    req.future = concurrent.futures.Future()
                batches = self._take(self.batcher.take_ready())
            self._dispatch(batches)
        return req.future if req is not None else None

    def finish(self, tenant_id: str) -> Optional[concurrent.futures.Future]:
        """End-of-stream marker: queue the zero-padded tail flush. Returns
        the tail chunk's future (None if the stream had no residue)."""
        with self._dispatch_mutex:
            with self._lock:
                self._check_running()
                s = self.sessions.get(tenant_id)
                if not s.chunker.finished:
                    s.chunker.finish()
                req = self.batcher.enqueue(s)
                if req is not None:
                    req.future = concurrent.futures.Future()
                batches = self._take(self.batcher.take_ready())
            self._dispatch(batches)
        return req.future if req is not None else None

    def pump(self) -> int:
        """Manual scheduling pass (normally unnecessary — the timer thread
        owns max_wait flushes). Returns launches SCHEDULED, not landed."""
        with self._dispatch_mutex:
            with self._lock:
                batches = self._take(self.batcher.take_ready())
            self._dispatch(batches)
        return len(batches)

    def drain(self) -> int:
        """Schedule every pending request and BLOCK until the pipeline is
        empty (all launches landed or terminally failed). Returns the
        number of launches scheduled by this call."""
        n = 0
        while True:
            with self._dispatch_mutex:
                with self._lock:
                    batches = self._take(
                        self.batcher.take_ready(force=True))
                self._dispatch(batches)
            if batches:
                n += len(batches)
                continue
            with self._done:
                while self._inflight > 0:
                    self._done.wait(0.05)
                if self.batcher.pending() == 0:
                    return n

    def output(self, tenant_id: str) -> np.ndarray:
        """Symbols emitted so far (stream order). NOT a barrier: in-flight
        launches land asynchronously — use the chunk futures, `drain()`, or
        `close()` for completion. Raises if the stream lost a chunk."""
        with self._lock:
            return self.sessions.get(tenant_id).output()

    # -- accounting --------------------------------------------------------

    @property
    def pool(self) -> EnginePool:
        return self.sessions.pool

    def stats(self) -> Dict:
        with self._lock:
            st = {"tenants": len(self.sessions),
                  "pending": self.batcher.pending(),
                  "inflight": self._inflight,
                  "queue_depth": self._launch_q.maxsize,
                  "errors": len(self.errors),
                  "pool": self.pool.stats(),
                  "traffic": self.batcher.traffic_stats()}
            st.update(self.batcher.latency_stats())
            return st

    # -- internals ---------------------------------------------------------

    def _check_running(self) -> None:
        if self._stop.is_set():
            raise RuntimeError("runtime is shut down")

    def _take(self, batches: List[LaunchBatch]) -> List[LaunchBatch]:
        """Account freshly assembled batches as in-flight (lock held)."""
        for b in batches:
            for r in b.reqs:
                r.session.inflight += 1
            self._inflight += len(b.reqs)
        return batches

    def _dispatch(self, batches: List[LaunchBatch]) -> None:
        """Hand assembled batches to the launcher thread. Blocking put on
        the depth-bounded queue = the backpressure/double-buffer bound.
        Always called holding `_dispatch_mutex` but NEVER `_lock` (the
        launcher needs the latter to land batches and free queue slots).
        If a put fails, the un-dispatched batches are un-accounted and
        requeued so drain()/close() cannot wait on work that will never
        execute."""
        for i, b in enumerate(batches):
            try:
                self._launch_q.put(b)
            except BaseException:
                with self._lock:
                    for rb in reversed(batches[i:]):
                        self.batcher.requeue(rb)
                        for r in rb.reqs:
                            r.session.inflight -= 1
                        self._inflight -= len(rb.reqs)
                    self._done.notify_all()
                raise

    def _timer_loop(self) -> None:
        """The event loop's clock: fire a pump pass on a max_wait_s-scaled
        cadence so time-based flushes don't depend on caller activity."""
        while not self._stop.is_set():
            wait = self.batcher.policy.max_wait_s
            self._stop.wait(min(max(wait / 4.0, 1e-3), 0.05))
            if self._stop.is_set():
                return
            try:
                with self._dispatch_mutex:
                    with self._lock:
                        batches = self._take(self.batcher.take_ready())
                    self._dispatch(batches)
            except Exception as e:  # noqa: BLE001 — keep the clock alive
                with self._lock:
                    self.errors.append(e)

    def _launch_loop(self) -> None:
        """The device owner: execute each assembled batch (NO lock — this
        is the overlap window), then land it under the lock. A failed
        execute retries in place, preserving FIFO order and therefore
        per-session stream order."""
        while True:
            batch = self._launch_q.get()
            if batch is _SHUTDOWN:
                self._launch_q.task_done()
                return
            y, err = None, None
            for _ in range(self.launch_retries + 1):
                try:
                    y = self.batcher.execute(batch)
                    err = None
                    break
                except Exception as e:  # noqa: BLE001 — retried/reported
                    err = e
            with self._lock:
                try:
                    if err is None:
                        self.batcher.descatter(batch, y)
                    else:
                        self.errors.append(err)
                        self.batcher.fail(batch, err)
                except Exception as e:  # noqa: BLE001 — launcher must live
                    self.errors.append(e)
                    self.batcher.fail(batch, e)
                finally:
                    for r in batch.reqs:
                        r.session.inflight -= 1
                    self._inflight -= len(batch.reqs)
                    self._done.notify_all()
            self._launch_q.task_done()
