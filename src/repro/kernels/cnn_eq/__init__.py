from .cnn_eq import cnn_eq_fused, receptive_halo
from .ops import equalize, strides_of, weights_of
from .ref import cnn_eq as cnn_eq_ref

__all__ = ["cnn_eq_fused", "cnn_eq_ref", "equalize", "receptive_halo",
           "strides_of", "weights_of"]
