"""Fleet serving — the chaos-gated device-loss migration benchmark.

Runs a 2-worker `FleetRuntime` (repro.serve.fleet) under a deterministic
`FaultPlan` that kills worker 0 MID-STREAM (`device_lost` after its 2nd
launch) and injects latency on worker 1 (`device_slow`), against 6 tenants
across fused_fp32 and fused_int8, and records in `BENCH_fleet.json` at the
repo root:

  * recovery — the fleet-wide sum and PER-WORKER `RecoveryStats` ledgers:
    device losses, sessions migrated out/in, chunks replayed, engine
    rebuilds, and the p50/max migration latency (worker death → replayed
    batch landed on the survivor). Latencies are host-speed dependent and
    recorded for trend-watching only; `--check` does NOT gate on them.
  * criteria.fleet_recovery_ok — the HARD host-independent gate: under
    the injected device faults every submitted chunk is emitted exactly
    once (stream lengths match offline), every finished stream is BITWISE
    equal to offline equalization (contract #10: output independent of
    which worker served which chunk), no session is poisoned, and both
    device faults actually fired. Deterministic under its fixed seeds —
    `--check` fails hard if it breaks.
  * placement / health — where tenants landed before and after the
    migration, plus each worker's straggler-fed launch-latency summary.
  * timing — wall time of the faulted pass vs an identical clean pass
    (informational; interpret-mode compiles dominate both).
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Optional

import jax
import numpy as np

from repro.core import equalizer as eq
from repro.serve import (BatchPolicy, Fault, FaultPlan, FleetRuntime,
                         TenantSpec, chop)

from .common import Bench

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT_PATH = REPO_ROOT / "BENCH_fleet.json"

CFG = eq.CNNEqConfig()
TILE_M = 32
INT8_FMT = tuple((2, 5, 3, 4) for _ in range(CFG.layers))
N_TENANTS = 6
N_WORKERS = 2
FLEET_FAULT_KINDS = ("device_lost", "device_slow")


def _weights(seed: int):
    params = eq.init(jax.random.PRNGKey(seed), CFG)
    folded = eq.fold_bn(params, eq.init_bn_state(CFG), CFG)
    return eq.folded_weights(folded)


def _spec(i: int) -> TenantSpec:
    backend = ("fused_fp32", "fused_int8")[i % 2]
    return TenantSpec(
        f"t{i}", CFG, weights=_weights(200 + i),
        formats=INT8_FMT if backend == "fused_int8" else None,
        backend=backend, tile_m=TILE_M, priority=i)


def _offline(spec: TenantSpec, wave: np.ndarray) -> np.ndarray:
    import jax.numpy as jnp
    return np.asarray(spec.build_engine()(jnp.asarray(wave[None])))[0]


def _wave(seed: int, n_syms: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.standard_normal(n_syms * CFG.n_os).astype(np.float32)


def _fault_plan() -> FaultPlan:
    # device kinds schedule per WORKER index: `at` names the worker,
    # `after` its first eligible execute. Worker 0 dies after two launches
    # have landed (mid-stream, so migration must replay retained plans);
    # worker 1 — the migration TARGET — takes an injected slow launch, so
    # the survivor's health monitor sees it while absorbing the refugees.
    return FaultPlan([
        Fault("device_lost", at=0, after=2),
        Fault("device_slow", at=1, after=1, delay_s=0.05),
    ])


def _chaos_pass(specs, waves, fault_plan: Optional[FaultPlan]):
    """Serve every wave chopped into jittered chunks, round-robin across
    tenants on a 2-worker fleet; returns (per-tenant outputs, placement
    at open, fleet stats, wall seconds)."""
    t0 = time.time()
    with FleetRuntime(n_workers=N_WORKERS,
                      policy=BatchPolicy(max_batch=3, max_wait_s=1e9),
                      launch_retries=1, fault_plan=fault_plan) as rt:
        for s in specs:
            rt.open(s)
        placement_open = rt.stats()["placement"]
        streams = {t: iter(chop(w, 120 * CFG.n_os, seed=i, jitter=0.5))
                   for i, (t, w) in enumerate(sorted(waves.items()))}
        live = set(streams)
        while live:
            for t in sorted(live):
                c = next(streams[t], None)
                if c is None:
                    live.discard(t)
                    rt.finish(t)
                else:
                    rt.submit(t, c)
        rt.drain()
        outputs = {s.tenant_id: rt.output(s.tenant_id) for s in specs}
        stats = rt.stats()
    return outputs, placement_open, stats, time.time() - t0


def run(out_path: Optional[pathlib.Path] = OUT_PATH) -> dict:
    bench = Bench("fleet_recovery",
                  "robustness: device-loss migration, chaos-gated")
    specs = [_spec(i) for i in range(N_TENANTS)]
    # streams must exceed one kernel tile (tile_m · v_parallel symbols) —
    # below that the offline reference legally shrinks its tile and the
    # contract is ~1 ULP, not bitwise (see chunker module docstring)
    waves = {s.tenant_id: _wave(300 + i, 280 + 16 * i)
             for i, s in enumerate(specs)}
    offline = {s.tenant_id: _offline(s, waves[s.tenant_id]) for s in specs}

    fp = _fault_plan()
    n_injected = fp.pending
    outputs, placement_open, stats, fault_wall = _chaos_pass(
        specs, waves, fault_plan=fp)
    _, _, _, clean_wall = _chaos_pass(specs, waves, fault_plan=None)

    streams_rep = {}
    zero_loss = bitwise = True
    for tid, got in sorted(outputs.items()):
        want = offline[tid]
        same_shape = got.shape == want.shape
        same_bits = same_shape and bool(np.array_equal(got, want))
        zero_loss &= same_shape
        bitwise &= same_bits
        streams_rep[tid] = {"syms": int(want.shape[0]),
                            "exactly_once": same_shape,
                            "bitwise": same_bits}

    rec = stats["recovery"]
    device_faults_fired = (fp.pending == 0
                           and set(fp.summary()) == set(FLEET_FAULT_KINDS))
    criteria = {
        "zero_loss": bool(zero_loss),
        "bitwise": bool(bitwise),
        "sessions_poisoned": rec["sessions_poisoned"],
        "device_faults_fired": bool(device_faults_fired),
        "fleet_recovery_ok": bool(zero_loss and bitwise
                                  and device_faults_fired
                                  and rec["sessions_poisoned"] == 0),
    }
    migrated = rec["sessions_migrated_in"]
    lat = max(w["recovery"].get("max_recovery_s", 0.0)
              for w in stats["workers"])
    print(f"[bench_fleet] {n_injected} device fault(s) injected, "
          f"{len(fp.fired)} fired {fp.summary()}; "
          f"{rec['device_losses']} device loss(es), "
          f"{migrated} session(s) migrated, "
          f"{rec['chunks_replayed']} chunk(s) replayed, "
          f"{rec['engine_rebuilds']} engine rebuild(s)")
    print(f"[bench_fleet] placement {placement_open} → "
          f"{stats['placement']}; worst migration latency {lat:.3f}s; "
          f"wall {fault_wall:.1f}s faulted vs {clean_wall:.1f}s clean")
    print(f"[bench_fleet] fleet_recovery_ok="
          f"{criteria['fleet_recovery_ok']} "
          f"(zero_loss={criteria['zero_loss']} "
          f"bitwise={criteria['bitwise']} "
          f"poisoned={criteria['sessions_poisoned']} "
          f"device_faults_fired={criteria['device_faults_fired']})")

    report = {
        "backend_default": jax.default_backend(),
        "scenario": {
            "n_tenants": N_TENANTS,
            "n_workers": N_WORKERS,
            "backends": ["fused_fp32", "fused_int8"],
            "tile_m": TILE_M,
            "chunk_samples": 120 * CFG.n_os,
            "max_batch": 3, "launch_retries": 1,
            "faults": [{"kind": k, "at": at} for k, at in fp.fired],
        },
        "recovery": rec,
        "workers": [{"worker": w["worker"], "alive": w["alive"],
                     "tenants": w["tenants"],
                     "recovery": w["recovery"],
                     "health": w["health"]}
                    for w in stats["workers"]],
        "placement": {"at_open": placement_open,
                      "after_migration": stats["placement"]},
        "migrations": stats["migrations"],
        "faults": {"injected": n_injected, "fired": fp.summary()},
        "streams": streams_rep,
        "criteria": criteria,
        "timing": {
            "fault_wall_s": fault_wall, "clean_wall_s": clean_wall,
            "note": ("host-speed dependent (interpret-mode compiles "
                     "dominate both arms); informational only — the "
                     "--check gate is criteria.fleet_recovery_ok"),
        },
    }
    if out_path is not None:
        out_path.write_text(json.dumps(report, indent=2))
        print(f"[bench_fleet] wrote {out_path}")
    bench.record("report", report)
    return bench.finish()


if __name__ == "__main__":
    run()
