"""moonshot-v1-16b-a3b — fine-grained MoE (Moonlight-16B-A3B style)
[hf:moonshotai/Moonlight-16B-A3B; hf].

48L · d_model 2048 · 16 heads (GQA kv=16) · expert d_ff 1408 ·
vocab 163840 · 64 experts top-6.  Experts shard EP16 over the model axis
(64 % 16 == 0) — the dispatch einsums lower to all-to-all (§Roofline).
"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=163840,
    n_experts=64, top_k=6,
    tp=16, train_accum=8, moe_group=2048,
)

REDUCED = ModelConfig(
    name="moonshot-reduced", family="moe",
    n_layers=3, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=96, vocab=512, n_experts=8, top_k=2,
    moe_group=64, dtype="float32",
)
