"""§7.2 — the 64-instance high-throughput configuration: multi-instance
halo-partitioned equalization ≡ the single-instance output, overlap
accounting at N_i = 64, and the end-to-end stream path (OGM → split →
64 × CNN → merge → ORM) in its pure-JAX reference form (the shard_map
version runs in tests/test_halo.py on 8 fake devices)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.channels import imdd
from repro.configs import equalizer_ht as HT
from repro.core import equalizer as eq
from repro.core import stream_partition as sp
from repro.core import timing_model as tm
from repro.core.engine import EqualizerEngine

from .common import Bench


def run(n_syms_per_inst: int = 1024) -> dict:
    bench = Bench("stream_64inst", "§7.2 / Fig. 9")
    cfg = HT.CNN
    n_inst = HT.N_INSTANCES
    key = jax.random.PRNGKey(0)
    params = eq.init(key, cfg)
    # production path: the fused-kernel engine feeds the OGM/SSM pipeline
    engine = EqualizerEngine.from_params(params, eq.init_bn_state(cfg), cfg,
                                         backend="fused_fp32", tile_m="auto")

    n_syms = n_syms_per_inst * n_inst
    rx, _ = imdd.simulate(key, imdd.IMDDConfig(), n_syms)

    y_split = sp.partitioned_apply(engine, rx, n_inst, cfg)
    y_ref = engine(rx)
    # record AFTER the first call so tile_m shows the resolved value, not
    # the "auto" placeholder
    bench.record("engine", engine.describe())
    o = sp.overlap_symbols(cfg)
    interior_err = float(jnp.max(jnp.abs(y_split[o:-o] - y_ref[o:-o])))

    o_act = sp.actual_overlap(cfg, n_inst)
    overhead = 2.0 * o_act / n_syms_per_inst
    bench.record("n_instances", n_inst)
    bench.record("o_sym", o)
    bench.record("o_act", o_act)                      # paper: 1024 @ N_i=64
    bench.record("interior_max_abs_err", interior_err)
    bench.record("overlap_overhead_at_l_inst",
                 {"l_inst": n_syms_per_inst, "overhead": overhead})

    hw = tm.fpga_profile(cfg, f_clk=HT.F_CLK)
    bench.record("t_max_gsyms", tm.max_throughput(hw, n_inst) / 1e9)
    bench.record("t_net_at_paper_l_inst_gsyms",
                 tm.net_throughput(cfg, hw, n_inst, HT.L_INST) / 1e9)
    ok = interior_err < 1e-4
    bench.record("equal_on_interior", bool(ok))
    print(f"[bench_stream] 64-instance interior err {interior_err:.2e} "
          f"(≡ single-instance: {ok}); o_act={o_act}, "
          f"T_net(7320)={bench.results['t_net_at_paper_l_inst_gsyms']:.1f}"
          " GSa/s")
    return bench.finish()


if __name__ == "__main__":
    run()
