"""Per-architecture smoke tests: REDUCED config of the same family runs one
forward/train step on CPU with correct shapes and no NaNs, plus decode-vs-
prefill consistency (the serving path equals the training-time function)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import registry
from repro.optim import AdamW

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b, s, key=KEY):
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "encdec":
        batch["enc_embed"] = 0.1 * jax.random.normal(
            key, (b, cfg.enc_len, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["embed_prefix"] = 0.1 * jax.random.normal(
            key, (b, cfg.img_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_arch_smoke_train_step(arch):
    cfg = configs.get_config(arch, reduced=True)
    model = registry.build(cfg)
    params = model.init(KEY)
    opt = AdamW(lr=1e-3, grad_clip_norm=1.0)
    opt_state = opt.init(params)
    batch = _batch(cfg, 2, 64)

    loss0, _ = model.loss_fn(params, batch)
    assert jnp.isfinite(loss0), arch

    (loss, _), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(
        params, batch)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gn) and float(gn) > 0, arch
    new_params, _ = opt.update(grads, opt_state, params)
    loss1, _ = model.loss_fn(new_params, batch)
    assert jnp.isfinite(loss1), arch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_arch_decode_matches_prefill(arch):
    # capacity_factor 8: capacity-based MoE drops tokens when an expert
    # overflows, which legitimately makes prefill ≠ decode at the drop
    # boundary — the equality claim is for the no-drop regime.
    cfg = dataclasses.replace(configs.get_config(arch, reduced=True),
                              dtype="float32", capacity_factor=8.0)
    model = registry.build(cfg)
    params = model.init(KEY)
    b, s = 2, 24
    batch = _batch(cfg, b, s + 1)
    short = dict(batch, tokens=batch["tokens"][:, :s],
                 labels=batch["labels"][:, :s])

    st = model.init_serve_state(b, 48)
    _, st = model.prefill(params, short, st)
    # decode position is GLOBAL: a VLM prefix shifts text positions
    pos = s + (cfg.img_tokens if cfg.family == "vlm" else 0)
    lg_dec, _ = model.decode(params, batch["tokens"][:, s:s + 1],
                             jnp.asarray(pos, jnp.int32), st)

    st2 = model.init_serve_state(b, 48)
    lg_full, _ = model.prefill(params, batch, st2)
    err = float(jnp.max(jnp.abs(lg_dec - lg_full)))
    assert err < 2e-3, f"{arch}: decode≠prefill (err {err})"


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_arch_config_exactness(arch):
    """The FULL configs carry the assigned numbers (spot checks)."""
    cfg = configs.get_config(arch)
    expected = {
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab)
    assert got == expected, f"{arch}: {got} != {expected}"


def test_param_counts_in_range():
    """Full-config parameter counts match the advertised scale."""
    import math
    # lm_head is untied (adds vocab·d to the tied-embedding counts:
    # smollm 135M + 28M ≈ 163M)
    expected_range = {
        "internlm2-1.8b": (1.5e9, 2.3e9),
        "smollm-135m": (1.2e8, 1.7e8),
        "deepseek-7b": (6e9, 8e9),
        "xlstm-125m": (1.0e8, 1.9e8),
    }
    for arch, (lo, hi) in expected_range.items():
        cfg = configs.get_config(arch, reduced=False)
        cfg = dataclasses.replace(cfg, tp=1)
        model = registry.build(cfg)
        shapes = jax.eval_shape(lambda m=model: m.init(jax.random.PRNGKey(0)))
        n = sum(math.prod(s.shape) for s in jax.tree.leaves(shapes))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params out of range"


def test_vlm_prefix_changes_text_logits():
    cfg = dataclasses.replace(configs.get_config("llava-next-34b",
                                                 reduced=True),
                              dtype="float32")
    model = registry.build(cfg)
    params = model.init(KEY)
    b = _batch(cfg, 1, 16)
    from repro.models import transformer
    lg1, _ = transformer.forward(params, b["tokens"], cfg,
                                 embed_prefix=b["embed_prefix"])
    lg2, _ = transformer.forward(params, b["tokens"], cfg,
                                 embed_prefix=2.0 * b["embed_prefix"])
    assert float(jnp.max(jnp.abs(lg1 - lg2))) > 1e-6


def test_moe_aux_loss_and_capacity():
    cfg = configs.get_config("moonshot-v1-16b-a3b", reduced=True)
    from repro.models import mlp
    p = mlp.moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 64, cfg.d_model), jnp.float32)
    y, aux = mlp.moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert jnp.isfinite(aux) and float(aux) > 0
    # capacity formula: ≥ 4-aligned and scales with cf·g·k/E
    c = mlp.capacity(cfg, 64)
    assert c % 4 == 0
    assert c >= cfg.capacity_factor * 64 * cfg.top_k / cfg.n_experts - 4
