"""End-to-end distributed training driver.

Wires together: arch configs → model → pjit train step (grad accumulation,
2-D sharding) → sharded data pipeline → checkpoint manager (atomic, keep-k)
→ fault-tolerant restart loop → straggler monitor.

On this CPU container it runs REDUCED configs on small meshes (the full
configs are exercised via the dry-run); on a real pod the same code path
takes `--arch <id> --full`.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 100 --batch 8 --seq 256 --mesh 1x1
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import logging
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import configs
from ..checkpoint import CheckpointManager
from ..data import PipelineConfig, lm_batches
from ..models import registry
from ..models.common import ModelConfig
from ..optim import AdamW
from ..parallel import sharding
from ..runtime import (FailureInjector, StragglerMonitor, TrainLoopConfig,
                       run_with_restarts)
from . import steps as steps_lib
from .mesh import make_mesh

log = logging.getLogger("repro.train")


def build(cfg: ModelConfig, mesh, lr: float, accum: int):
    """(init_fn, train_step, batch_spec) for the given mesh."""
    sharding.set_mesh(mesh, "train")
    model = registry.build(cfg)
    opt = AdamW(lr=lr, grad_clip_norm=1.0)
    params_sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    pspecs = sharding.param_specs(params_sds, mesh, "train")
    opt_sds = jax.eval_shape(opt.init, params_sds)
    ospecs = sharding.param_specs(opt_sds, mesh, "train")
    ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t)

    b_axes = sharding.batch_axes(mesh)
    bspec = {"tokens": P(None, b_axes, None), "labels": P(None, b_axes, None)}

    def init_state():
        params = jax.jit(model.init, out_shardings=ns(pspecs))(
            jax.random.PRNGKey(0))
        opt_state = jax.jit(opt.init, out_shardings=ns(ospecs))(params)
        return params, opt_state

    step = steps_lib.build_train_step(model, opt)
    train_step = jax.jit(step,
                         in_shardings=(ns(pspecs), ns(ospecs), ns(bspec)),
                         out_shardings=(ns(pspecs), ns(ospecs), None),
                         donate_argnums=(0, 1))
    return init_state, train_step, bspec, (pspecs, ospecs)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=configs.ARCHS)
    ap.add_argument("--full", action="store_true",
                    help="full config (pods); default: reduced (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default="1x1",
                    help="DATAxMODEL, e.g. 4x2 (device count must match)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject worker failures at these steps (demo)")
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")

    cfg = configs.get_config(args.arch, reduced=not args.full)
    dp, mp = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((dp, mp), ("data", "model"))
    cfg = dataclasses.replace(cfg, tp=mp)

    init_state, train_step, bspec, _ = build(cfg, mesh, args.lr, args.accum)
    pipe = PipelineConfig(seq_len=args.seq, global_batch=args.batch,
                          accum=args.accum)
    ckpt = CheckpointManager(args.ckpt_dir, keep_k=3)
    monitor = StragglerMonitor()
    injector = FailureInjector(fail_at=tuple(args.fail_at))

    def batches(start_step):
        return lm_batches(pipe, cfg, mesh, bspec, start_step=start_step)

    def on_step(step, metrics):
        monitor.observe(step, time.perf_counter() - on_step.t0)
        on_step.t0 = time.perf_counter()
    on_step.t0 = time.perf_counter()

    with mesh:
        out = run_with_restarts(
            TrainLoopConfig(total_steps=args.steps,
                            checkpoint_every=args.ckpt_every),
            ckpt, init_state, train_step, batches,
            injector=injector, on_step=on_step)
    log.info("done: %d steps, %d restarts, straggler summary %s",
             out["steps"], out["restarts"], monitor.summary())
    losses = [l for _, l in out["history"]]
    if len(losses) >= 2:
        log.info("loss %0.4f → %0.4f", losses[0], losses[-1])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
