"""Uniform model interface over all architecture families.

Every assigned architecture reduces to one of five family implementations:

    dense / moe / vlm  → models.transformer   (llava = prefix-LM stub)
    hybrid             → models.zamba2
    ssm                → models.xlstm
    encdec             → models.whisper

`build(cfg)` returns a `Model` whose five methods are what the launcher,
trainer, server, and dry-run lower — the families differ only in what their
"serve state" is (KV ring caches, SSM states, or both).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from . import transformer, whisper, xlstm, zamba2
from .common import ModelConfig


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[..., Any]
    loss_fn: Callable[..., Any]           # (params, batch) → (loss, metrics)
    init_serve_state: Callable[..., Any]  # (batch, max_len) → state
    prefill: Callable[..., Any]           # (params, batch, state) → (logits, state)
    decode: Callable[..., Any]            # (params, token, pos, state) → (logits, state)


def _transformer_model(cfg: ModelConfig) -> Model:
    def prefill(params, batch, state):
        return transformer.prefill(params, batch["tokens"], cfg, state,
                                   embed_prefix=batch.get("embed_prefix"))

    return Model(
        cfg=cfg,
        init=lambda key: transformer.init(key, cfg),
        loss_fn=lambda params, batch: transformer.loss_fn(params, batch, cfg),
        init_serve_state=lambda batch, max_len: transformer.init_cache(
            cfg, batch, max_len),
        prefill=prefill,
        decode=lambda params, token, pos, state: transformer.decode_step(
            params, token, pos, state, cfg),
    )


def _zamba2_model(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        init=lambda key: zamba2.init(key, cfg),
        loss_fn=lambda params, batch: zamba2.loss_fn(params, batch, cfg),
        init_serve_state=lambda batch, max_len: zamba2.init_state(
            cfg, batch, max_len),
        prefill=lambda params, batch, state: zamba2.prefill(
            params, batch["tokens"], cfg, state),
        decode=lambda params, token, pos, state: zamba2.decode_step(
            params, token, pos, state, cfg),
    )


def _xlstm_model(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        init=lambda key: xlstm.init(key, cfg),
        loss_fn=lambda params, batch: xlstm.loss_fn(params, batch, cfg),
        init_serve_state=lambda batch, max_len: xlstm.init_states(cfg, batch),
        prefill=lambda params, batch, state: xlstm.prefill(
            params, batch["tokens"], cfg, state),
        decode=lambda params, token, pos, state: xlstm.decode_step(
            params, token, pos, state, cfg),
    )


def _whisper_model(cfg: ModelConfig) -> Model:
    return Model(
        cfg=cfg,
        init=lambda key: whisper.init(key, cfg),
        loss_fn=lambda params, batch: whisper.loss_fn(params, batch, cfg),
        init_serve_state=lambda batch, max_len: whisper.init_state(
            cfg, batch, max_len),
        prefill=lambda params, batch, state: whisper.prefill(
            params, batch["tokens"], batch["enc_embed"], cfg, state),
        decode=lambda params, token, pos, state: whisper.decode_step(
            params, token, pos, state, cfg),
    )


_FAMILIES = {
    "dense": _transformer_model,
    "moe": _transformer_model,
    "vlm": _transformer_model,
    "hybrid": _zamba2_model,
    "ssm": _xlstm_model,
    "encdec": _whisper_model,
}


def build(cfg: ModelConfig) -> Model:
    if cfg.family not in _FAMILIES:
        raise ValueError(f"unknown family {cfg.family!r}")
    return _FAMILIES[cfg.family](cfg)


def param_count(params: Any) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params))
