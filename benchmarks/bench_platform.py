"""Figs. 13/14/15 — platform comparison for the CNN equalizer.

Measured on THIS machine: the jitted JAX-CPU implementation across batch
sizes (the paper's CPU row). Projected from the roofline model: one TPU-v5e
chip running the fused Pallas equalizer (compute/memory terms from the
kernel's arithmetic; the §Roofline machinery), and the paper's reported
FPGA/GPU numbers carried as reference constants for the comparison table.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import equalizer_ht as HT
from repro.core import equalizer as eq
from repro.kernels.cnn_eq import ops as cnn_ops
from repro.launch import roofline as rl

from .common import Bench

# paper-reported reference points (Gbit/s at large batch; §7.3)
PAPER_REFS = {
    "fpga_ht_gbps": 40.0,              # > 40 GBd PAM2 ⇒ 40 Gbit/s
    "rtx2080ti_tensorrt_gbps": 12.0,
    "cpu_i9_gbps": 0.4,
    "fpga_vs_gpu_same_batch": 4500.0,
}


def tpu_projection(cfg) -> dict:
    """Roofline projection of the fused kernel on one v5e chip."""
    macs_per_sym = cfg.mac_per_symbol()
    flops_per_sym = 2.0 * macs_per_sym
    # bytes/sym: stream in (N_os samples bf16) + out (1 sym bf16); weights
    # stay in VMEM
    bytes_per_sym = (cfg.n_os + 1) * 2.0
    t_comp = flops_per_sym / rl.PEAK_FLOPS
    t_mem = bytes_per_sym / rl.HBM_BW
    sym_rate = 1.0 / max(t_comp, t_mem)
    return {
        "sym_rate_gsyms": sym_rate / 1e9,
        "throughput_gbps_pam2": sym_rate / 1e9,
        "bound": "compute" if t_comp > t_mem else "memory",
        "mfu_at_bound": flops_per_sym / (sym_rate ** -1) / rl.PEAK_FLOPS,
    }


def run(batches=(1, 8, 64, 512), n_syms: int = 16384) -> dict:
    bench = Bench("platform_comparison", "Figs. 13/14/15 / §7.3")
    cfg = HT.CNN
    key = jax.random.PRNGKey(0)
    params = eq.init(key, cfg)
    bn = eq.init_bn_state(cfg)
    folded = eq.fold_bn(params, bn, cfg)
    weights = cnn_ops.weights_of(folded)
    strides = cnn_ops.strides_of(cfg)

    from repro.kernels.cnn_eq.ref import cnn_eq as ref_fn
    fn = jax.jit(lambda x: ref_fn(x, weights, strides))

    rows = []
    for b in batches:
        x = jax.random.normal(key, (b, n_syms * cfg.n_os))
        fn(x).block_until_ready()                      # compile + warm
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            fn(x).block_until_ready()
        dt = (time.perf_counter() - t0) / reps
        syms = b * n_syms
        rows.append({
            "batch": b, "syms_per_batch": n_syms,
            "throughput_gbps": syms / dt / 1e9,        # PAM2: 1 bit/sym
            "latency_ms": dt * 1e3,
        })
        print(f"[bench_platform] cpu-jax b={b}: "
              f"{rows[-1]['throughput_gbps']:.4f} Gbit/s, "
              f"{rows[-1]['latency_ms']:.1f} ms")
    bench.record("cpu_jax_measured", rows)

    proj = tpu_projection(cfg)
    proj["projected_instances_equivalent"] = (
        proj["sym_rate_gsyms"] * 1e9 / (HT.F_CLK * cfg.v_parallel))
    bench.record("tpu_v5e_projected_single_chip", proj)
    bench.record("paper_reference_points", PAPER_REFS)
    # the structural claim (Fig. 13): a platform whose architecture is
    # matched to the CNN (FPGA there, TPU-roofline here) beats the
    # general-purpose CPU by orders of magnitude
    cpu_best = max(r["throughput_gbps"] for r in rows)
    bench.record("tpu_over_cpu_ratio",
                 proj["throughput_gbps_pam2"] / max(cpu_best, 1e-9))
    print(f"[bench_platform] TPU-projected {proj['throughput_gbps_pam2']:.1f}"
          f" Gbit/s ({proj['bound']}-bound) vs CPU best {cpu_best:.3f}")
    return bench.finish()


if __name__ == "__main__":
    run()
