"""tools/check_docs.py — the docs anti-rot tripwire (tier-2, but cheap
enough to run in tier-1): real docs must pass, and each reference form
must actually FAIL when stale (otherwise the tripwire is decorative)."""
import pathlib
import sys

import pytest

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT))

from tools import check_docs  # noqa: E402


def test_committed_docs_are_clean():
    assert check_docs.main([]) == 0


def _run_on(tmp_path, text: str) -> int:
    doc = tmp_path / "doc.md"
    doc.write_text(text)
    return check_docs.main([str(doc)])


@pytest.mark.parametrize("stale_ref", [
    "`src/repro/serve/no_such_file.py`",                    # R1
    "`src/repro/serve/runtime.py::NoSuchSymbol`",           # R2 symbol
    "`src/repro/gone/runtime.py::ServeRuntime`",            # R2 file
    "`repro.serve.no_such_module`",                         # R3 module
    "`repro.core.autotune.no_such_symbol`",                 # R3 symbol
    "`no_such_function_anywhere()`",                        # R4
    "`fused_int4`",                                         # R5
    "`BENCH_nothing.json`",                                 # R6
])
def test_each_stale_form_fails(tmp_path, stale_ref):
    assert _run_on(tmp_path, f"see {stale_ref} for details\n") == 1


@pytest.mark.parametrize("good_ref", [
    "`src/repro/serve/runtime.py`",
    "`src/repro/serve/runtime.py::AsyncServeRuntime`",
    "`src/repro/serve/chunker.py::StreamChunker.commit`",
    "`repro.core.autotune.best_tile_m`",
    "`benchmarks.bench_serve`",
    "`best_tile_m()`",
    "`fused_bf16`",
    "`BENCH_serve.json`",
    # gitignored = generated artifact: valid even before it is generated
    "`reports/not_yet_generated.json`",
    "`just prose with spaces`",            # unrecognized forms are ignored
    "`rt.submit(samples)`",
])
def test_each_good_form_passes(tmp_path, good_ref):
    assert _run_on(tmp_path, f"see {good_ref} for details\n") == 0


def test_fenced_blocks_check_paths_but_not_prose(tmp_path):
    ok = ("```bash\nPYTHONPATH=src python benchmarks/run.py --check\n"
          "pytest tests/test_serve.py\n```\n")
    assert _run_on(tmp_path, ok) == 0
    stale = "```bash\ncat src/repro/serve/legacy_runtime.py\n```\n"
    assert _run_on(tmp_path, stale) == 1
