from .ref import slstm as slstm_ref
from .slstm import slstm_fused
