"""Benchmark orchestrator: `PYTHONPATH=src python -m benchmarks.run`.

One benchmark per paper table/figure (see DESIGN.md §6):

    bench_dse       Fig. 2   DSE: CNN vs FIR vs Volterra on IM/DD
    bench_proakis   Fig. 4   the same on the magnetic-recording channel
    bench_quant     Fig. 5/6 3-phase QAT bit-width/BER curves per QLF
    bench_dop       Fig. 8   flexible-DOP study (TPU tile-utilization axis)
    bench_stream    Fig. 9/§7.2  64-instance stream partitioning
    bench_engine    §7       engine backend throughput → BENCH_engine.json
    bench_timing    Fig. 12  timing model vs simulated measurement
    bench_platform  Fig. 13-15  CPU measured / TPU roofline-projected
    bench_roofline  Table 1 / §Roofline  aggregate the dry-run artifacts

`--full` runs paper-scale sweeps (hours); the default is a reduced pass
whose orderings (not absolute BERs) carry the claims.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

from . import (bench_dop, bench_dse, bench_engine, bench_platform,
               bench_proakis, bench_quant, bench_roofline, bench_stream,
               bench_timing)
from .common import REPORT_DIR


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sweeps (hours)")
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args(argv)

    steps = 700 if not args.full else 10_000
    jobs = [
        ("timing", lambda: bench_timing.run()),
        ("engine", lambda: bench_engine.run()),
        ("stream", lambda: bench_stream.run()),
        ("dop", lambda: bench_dop.run()),
        ("roofline", lambda: bench_roofline.run()),
        ("platform", lambda: bench_platform.run()),
        ("proakis", lambda: bench_proakis.run(steps=min(steps, 800))),
        ("quant", lambda: bench_quant.run(steps=min(steps, 600))),
        ("dse", lambda: bench_dse.run(full=args.full, steps=steps)),
    ]
    if args.only:
        jobs = [(n, f) for n, f in jobs if n in args.only]

    t0 = time.time()
    failures = []
    summary = {}
    for name, fn in jobs:
        print(f"\n=== bench:{name} " + "=" * 50)
        try:
            out = fn()
            summary[name] = {"status": "ok",
                             "elapsed_s": out.get("elapsed_s")}
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failures.append(name)
            summary[name] = {"status": f"failed: {e}"}
    summary["total_elapsed_s"] = round(time.time() - t0, 1)
    REPORT_DIR.mkdir(parents=True, exist_ok=True)
    (REPORT_DIR / "benchmarks_summary.json").write_text(
        json.dumps(summary, indent=2))
    print("\n=== benchmark summary ===")
    for k, v in summary.items():
        print(f"  {k}: {v}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
