"""Multi-tenant streaming equalizer serving runtime (see runtime.py and
docs/ARCHITECTURE.md).

Layers:
  chunker    — stateful overlap-save: arbitrary chunk sizes, offline-exact
               (carry snapshot/restore is the failover primitive)
  pool       — LRU-bounded engine pool (session-manager memory bound)
  session    — TenantSpec / Session / SessionManager
  scheduler  — BatchPolicy / MicroBatcher: dynamic micro-batching into
               stacked fused-kernel launches with per-row tenant weights,
               split into assemble/execute/descatter phases; TrafficStats
               feed the serve-aware autotune
  recovery   — fault taxonomy, deterministic FaultPlan chaos injection,
               RecoveryPolicy failover bounds, output sentinel, and the
               straggler-driven DegradationController
  runtime    — ServeRuntime (sync) / AsyncServeRuntime (threaded
               front-end: timer-driven pump, double-buffered launches,
               per-chunk futures, deadline/backoff launch discipline,
               bounded session failover)
  fleet      — FleetRuntime: N workers over a device mesh, shard-by-tenant
               placement, per-worker health (StragglerMonitor heartbeat,
               consecutive-failure / deadline device-loss detection), and
               bitwise stream migration on worker death; also the single
               source of device-set truth (worker_devices / best_mesh)
  loadgen    — reproducible tenant traffic for benches/examples
"""
from .chunker import CarrySnapshot, ChunkPlan, StreamChunker
from .fleet import FleetRuntime, FleetWorker, best_mesh, worker_devices
from .loadgen import (chop, drift_streams, random_waveforms, replay,
                      replay_adaptive, replay_wire)
from .pool import EnginePool
from .recovery import (CorruptOutput, DegradationController, DeviceLost,
                       Fault, FaultPlan, InjectedFault, LaunchTimeout,
                       RecoveryPolicy, RecoveryStats, TenantShedError)
from .runtime import AsyncServeRuntime, ServeRuntime
from .scheduler import (BatchPolicy, LaunchBatch, MicroBatcher, Request,
                        TrafficStats)
from .session import Session, SessionManager, TenantSpec

__all__ = ["AsyncServeRuntime", "BatchPolicy", "CarrySnapshot", "ChunkPlan",
           "CorruptOutput", "DegradationController", "DeviceLost",
           "EnginePool", "Fault", "FaultPlan", "FleetRuntime", "FleetWorker",
           "InjectedFault", "LaunchBatch", "LaunchTimeout", "MicroBatcher",
           "RecoveryPolicy", "RecoveryStats", "Request", "ServeRuntime",
           "Session", "SessionManager", "StreamChunker", "TenantShedError",
           "TenantSpec", "TrafficStats", "best_mesh", "chop",
           "drift_streams", "random_waveforms", "replay", "replay_adaptive",
           "replay_wire",
           "worker_devices"]
