"""Pallas TPU kernels for the paper's compute hot-spots.

Each subpackage ships <name>.py (pl.pallas_call + BlockSpec), ops.py (jitted
wrapper) and ref.py (pure-jnp oracle). Validated with interpret=True on CPU;
BlockSpecs target TPU VMEM/MXU.
"""
from . import cnn_eq, conv1d, quant, volterra

__all__ = ["cnn_eq", "conv1d", "quant", "volterra"]
