"""Dynamic micro-batching — many tenant streams, one fused-kernel launch.

The paper's FPGA hits its throughput target by instantiating N_i parallel
CNN instances and streaming one link through each; the GPU baseline it beats
by three orders of magnitude loses exactly because small per-link calls
cannot fill the device. The TPU serving answer is the same shape as the
FPGA's: keep the datapath full by running MANY links per launch — here by
stacking the pending chunks of all tenants that share a `group_key()`
(topology + backend + static kernel config) into one batched fused kernel
with per-row tenant weights (`core.engine.stacked_engine_fn`).

Coalescing policy (the classic dynamic-batching trade-off):
  * max_batch   — launch as soon as this many tenant chunks are pending
                  in a group (throughput knob);
  * max_wait_s  — … or as soon as the OLDEST pending chunk has waited this
                  long (tail-latency knob);
  * `drain()`   — launch everything now (end of stream / shutdown).

Every request carries submit/launch/done timestamps; `latency_stats()`
reports p50/p99 queueing and total latency plus batch-occupancy history —
the numbers `benchmarks/bench_serve.py` publishes.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.engine import stacked_engine_fn
from .chunker import ChunkPlan
from .session import Session

_CONSUMED = np.zeros((0,), np.float32)     # placeholder for launched inputs


@dataclasses.dataclass(frozen=True)
class BatchPolicy:
    max_batch: int = 8           # coalesce up to this many tenant chunks
    max_wait_s: float = 2e-3     # flush when the oldest waits this long
    width_bucket: int = 0        # row padding quantum; 0 → tile_m·ts (auto)


@dataclasses.dataclass
class Request:
    """One tenant chunk queued for a batched launch."""
    session: Session
    plan: ChunkPlan
    t_submit: float
    t_launch: float = 0.0
    t_done: float = 0.0
    batch_size: int = 0
    symbols: Optional[np.ndarray] = None

    @property
    def done(self) -> bool:
        return self.symbols is not None

    @property
    def wait_s(self) -> float:
        return self.t_launch - self.t_submit

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit


class MicroBatcher:
    """Groups pending requests by engine `group_key()` and launches them as
    stacked fused calls under the max-batch / max-wait policy."""

    # stacked-fn cache bound: steady-state traffic cycles through few
    # distinct (ordered) tenant sets; 64 covers many groups without
    # pinning unbounded weight stacks
    FN_CACHE_MAX = 64
    # latency records kept for stats — a bounded window, not the full
    # history (unbounded streams would otherwise leak one Request, with
    # its symbols array, per chunk forever)
    COMPLETED_MAX = 8192

    def __init__(self, policy: Optional[BatchPolicy] = None,
                 clock: Callable[[], float] = time.perf_counter):
        self.policy = policy or BatchPolicy()
        self.clock = clock
        self._groups: Dict[Tuple, List[Request]] = {}
        # (id(engine), …) → (engine refs, stacked fn). Holding the refs
        # keeps the ids valid; bounded FIFO so evicted engines can be GC'd.
        self._fn_cache: "Dict[Tuple, Tuple[list, Callable]]" = {}
        self.completed: Deque[Request] = deque(maxlen=self.COMPLETED_MAX)
        self.batch_sizes: Deque[int] = deque(maxlen=self.COMPLETED_MAX)
        self.total_requests = 0
        self.launches = 0

    # -- queueing ----------------------------------------------------------

    def enqueue(self, session: Session) -> Optional[Request]:
        """Turn the session's pending stream samples into a queued request
        (None if the chunker has nothing emittable yet).

        The chunker commits here — at enqueue, not at launch — so a tenant
        can queue several requests back-to-back without double-planning the
        same positions. That is safe because a plan is a self-contained
        input snapshot: a failed launch re-queues its requests (see pump /
        flush_session) and never needs the chunker rewound.
        """
        plan = session.chunker.plan()
        if plan is None:
            return None
        session.chunker.commit(plan)
        req = Request(session=session, plan=plan, t_submit=self.clock())
        key = session.engine.group_key()
        self._groups.setdefault(key, []).append(req)
        return req

    def pending(self) -> int:
        return sum(len(v) for v in self._groups.values())

    # -- policy / launching ------------------------------------------------

    def pump(self, force: bool = False) -> int:
        """Launch every group that meets the policy (or all, if force).
        Returns the number of launches performed."""
        now = self.clock()
        n = 0
        for key in list(self._groups):
            reqs = self._groups[key]
            while reqs and (
                    force
                    or len(reqs) >= self.policy.max_batch
                    or now - reqs[0].t_submit >= self.policy.max_wait_s):
                take = reqs[:self.policy.max_batch]
                del reqs[:self.policy.max_batch]
                try:
                    self._launch(take)
                except Exception:
                    # plans are self-contained input snapshots, so a failed
                    # launch (transient device error) is retryable: put the
                    # requests back in order and surface the error
                    reqs[:0] = take
                    raise
                n += 1
            if not reqs:
                del self._groups[key]
        return n

    def drain(self) -> int:
        return self.pump(force=True)

    def flush_session(self, session: Session) -> int:
        """Launch ONLY this session's pending requests (tenant close/tail
        flush). Other tenants' partial batches stay queued so their
        max_batch/max_wait policy — and batch occupancy — is untouched."""
        n = 0
        for key in list(self._groups):
            reqs = self._groups[key]
            mine = [r for r in reqs if r.session is session]
            if not mine:
                continue
            rest = [r for r in reqs if r.session is not session]
            if rest:
                self._groups[key] = rest
            else:
                del self._groups[key]
            for i in range(0, len(mine), self.policy.max_batch):
                try:
                    self._launch(mine[i:i + self.policy.max_batch])
                except Exception:
                    # re-queue this tenant's unlaunched plans (retryable,
                    # same rationale as pump)
                    pending = mine[i:]
                    self._groups.setdefault(key, [])[:0] = pending
                    raise
                n += 1
        return n

    def _bucket_width(self, reqs: List[Request]) -> int:
        e = reqs[0].session.engine
        tile_q = e.resolved_tile_m() * e.total_stride
        q = self.policy.width_bucket
        # the bucket MUST be a whole number of tiles: a sub-tile-width row
        # would shrink the kernel's effective tile (n_pos < tile_m) and
        # void the chunker's tile-alignment ⇒ bitwise-offline invariant,
        # so a user quantum is rounded up to the tile quantum
        q = tile_q if q <= 0 else (-(-q // tile_q) * tile_q)
        w = max(r.plan.width for r in reqs)
        return -(-w // q) * q                      # ceil to bucket quantum

    def _group_fn(self, engines) -> Callable:
        """Memoized stacked launch fn: steady-state round-robin traffic
        re-batches the SAME engines in the SAME order every round, so the
        per-launch weight re-stack (and its host→device transfer) is paid
        once per tenant set, not once per launch."""
        key = tuple(id(e) for e in engines)
        hit = self._fn_cache.get(key)
        if hit is not None:
            return hit[1]
        fn = stacked_engine_fn(engines)
        self._fn_cache[key] = (list(engines), fn)
        while len(self._fn_cache) > self.FN_CACHE_MAX:
            self._fn_cache.pop(next(iter(self._fn_cache)))
        return fn

    def _launch(self, reqs: List[Request]) -> None:
        """ONE stacked fused-kernel launch for ≤ max_batch tenant chunks."""
        t_launch = self.clock()
        engines = [r.session.engine for r in reqs]
        fn = self._group_fn(engines)
        width = self._bucket_width(reqs)
        x = np.zeros((len(reqs), width), np.float32)
        for i, r in enumerate(reqs):
            x[i, :r.plan.width] = r.plan.data      # right zero-pad = offline
        y = fn(jnp.asarray(x))
        y = np.asarray(jax.block_until_ready(y))
        t_done = self.clock()
        for i, r in enumerate(reqs):
            vp = r.session.v_parallel
            syms = y[i, r.plan.skip * vp:(r.plan.skip + r.plan.n_emit) * vp]
            r.symbols = syms
            r.t_launch, r.t_done, r.batch_size = t_launch, t_done, len(reqs)
            r.session.append_output(syms)
            r.plan.data = _CONSUMED        # release the input buffer; the
            self.completed.append(r)       # record keeps only timing+syms
        self.total_requests += len(reqs)
        self.batch_sizes.append(len(reqs))
        self.launches += 1

    # -- accounting --------------------------------------------------------

    def latency_stats(self) -> Dict[str, float]:
        """Percentiles over the last COMPLETED_MAX requests (full history
        for any run shorter than the window, e.g. the benches)."""
        if not self.completed:
            return {"requests": 0}
        lat = np.array([r.latency_s for r in self.completed])
        wait = np.array([r.wait_s for r in self.completed])
        occ = np.array(self.batch_sizes, np.float64)
        return {
            "requests": self.total_requests,
            "launches": self.launches,
            "mean_batch": float(occ.mean()),
            "p50_latency_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_latency_ms": float(np.percentile(lat, 99) * 1e3),
            "p50_wait_ms": float(np.percentile(wait, 50) * 1e3),
            "p99_wait_ms": float(np.percentile(wait, 99) * 1e3),
        }
