"""Figs. 5/6 — the 3-phase quantization-aware training for several QLFs:
course of the average bit width and of the BER, final learned formats, the
TPU deployment-dtype mapping, AND the actual deployment: each trained
quantizer is handed to `EqualizerEngine.from_params`, which goes int8 when
the learned formats fit — closing the train → deploy loop the paper's
FPGA flow has."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.channels import imdd
from repro.core import equalizer as eq
from repro.core import qat as qat_lib
from repro.core.engine import EqualizerEngine
from repro.core.equalizer import CNNEqConfig
from repro.core.train_eq import EqTrainConfig, train_equalizer
from repro.data.equalizer_data import channel_fn

from .common import Bench

QLFS = (5e-2, 5e-3, 5e-4)         # paper sweeps 0.5 … 0.0005


def run(steps: int = 600) -> dict:
    bench = Bench("quantization", "Figs. 5/6 / §4")
    fn = channel_fn("imdd", imdd.IMDDConfig())
    cfg = CNNEqConfig()
    tcfg = EqTrainConfig(steps=steps, batch=8, seq_syms=256, lr=3e-3,
                         eval_syms=1 << 14)
    key = jax.random.PRNGKey(0)

    _, _, fp = train_equalizer(key, "cnn", cfg, fn, tcfg)
    bench.record("fp32", {"ber": fp["ber"]})
    print(f"[bench_quant] fp32 BER {fp['ber']:.3e}")

    curves = {}
    for qlf in QLFS:
        qcfg = qat_lib.QATConfig(qlf=qlf, init_int_bits=8.0,
                                 init_frac_bits=8.0)
        params, bn_state, info = train_equalizer(key, "cnn", cfg, fn, tcfg,
                                                 qat_cfg=qcfg,
                                                 record_every=25)
        plan = qat_lib.deployment_plan(params["qat"])
        # the deployment step itself: auto-backend engine from the trained
        # quantizer (fused_int8 when every layer's format fits 8 bits)
        engine = EqualizerEngine.from_params(params, bn_state, cfg,
                                             backend="auto", tile_m=64)
        rx_probe, _ = fn(jax.random.PRNGKey(7), 1 << 12)
        y_dep = engine(rx_probe)
        y_fq, _ = eq.apply(params, rx_probe, cfg, train=False,
                           bn_state=bn_state, qat_enabled=True)
        o = cfg.receptive_field_syms
        dep_err = float(jnp.max(jnp.abs(y_dep[o:-o] - y_fq[o:-o])))
        curves[f"qlf_{qlf:g}"] = {
            "ber": info["ber"],
            "bits_params": info["bits_params"],
            "bits_acts": info["bits_acts"],
            "deployment_dtypes": plan["dtypes"],
            "deployment_backend": engine.backend,
            "deployment_max_err_vs_fake_quant": dep_err,
            "history": info["history"],
        }
        print(f"[bench_quant] qlf={qlf:g}: {info['bits_params']:.1f}b w / "
              f"{info['bits_acts']:.1f}b a, BER {info['ber']:.3e} → "
              f"{plan['dtypes']} (engine: {engine.backend}, "
              f"deploy err {dep_err:.2e})")
    bench.record("qlf_curves", curves)
    # paper claim: a moderate QLF reaches ≈13b weights / ≈10b activations
    # at ~fp32 BER; aggressive QLFs sacrifice BER (Fig. 6)
    mid = curves["qlf_0.005"]
    bench.record("claim_moderate_qlf_near_fp32",
                 bool(mid["ber"] < max(3 * fp["ber"], fp["ber"] + 0.02)))
    bench.record("claim_aggressive_qlf_fewer_bits", bool(
        curves["qlf_0.05"]["bits_params"]
        <= curves["qlf_0.0005"]["bits_params"]))
    return bench.finish()


if __name__ == "__main__":
    run()
