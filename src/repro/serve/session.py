"""Tenant sessions — channel config + trained params + QAT formats → engine.

A TENANT is one equalized link (an optical channel, a magnetic-recording
head, …) with its own trained parameters and learned fixed-point formats.
A SESSION is a tenant's live streaming state: the overlap-save chunker
carry, output accumulator, and latency counters. Engines themselves live in
the LRU `EnginePool` (pool.py) and are rebuilt on demand after eviction —
sessions never pin one.

Serve-aware autotune hook: `Session` accepts a `tile_tuner` callback
(provided by the runtime, see `runtime._serve_tile`). For a spec with
tile_m="auto" it may return a tile width tuned against LIVE traffic
histograms instead of the engine's single-stream autotune default. The
chosen tile is frozen into the session's spec copy at open time, so engine
rebuilds after LRU eviction reproduce it deterministically and the chunker's
tile-alignment (bitwise-vs-offline) invariant holds for the stream's whole
lifetime.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from ..core.engine import EqualizerEngine
from ..core.equalizer import CNNEqConfig
from .chunker import StreamChunker
from .pool import EnginePool

# a tile_tuner maps a freshly built engine to a tile width (or None to keep
# the engine's own single-stream autotune choice)
TileTuner = Callable[[EqualizerEngine], Optional[int]]


@dataclasses.dataclass
class TenantSpec:
    """Everything needed to (re)build a tenant's engine deterministically.

    tenant_id: unique key (string) — engine-pool identity; opening the same
               id twice on one runtime raises ValueError.
    cfg:       the CNN topology (`CNNEqConfig`).
    params:    trained (unfolded) parameters; BN is folded and QAT formats
               are picked up automatically at engine build
               (`EqualizerEngine.from_params`). Exactly one of
               params/weights must be given, else build_engine raises
               ValueError.
    bn_state:  running BN statistics to fold (default None → init stats).
    weights:   pre-folded fp32 weights (alternative to params).
    formats:   per-layer (w_int, w_frac, a_int, a_frac) fixed-point
               formats — required for backend="fused_int8" with explicit
               weights; ignored otherwise.
    backend:   "auto" (default; deploys the QAT ladder int8→bf16→fp32),
               or an explicit backend name. Explicit "fused_int8" raises at
               build if the formats don't fit int8 or the BN-folded weights
               overflow the learned grid (see docs/QUANTIZATION.md).
    tile_m:    kernel sequence-tile width. "auto" (default) → autotune
               sweep, possibly serve-aware (live-traffic histograms) when
               opened through a runtime with warm stats; an explicit int is
               NEVER re-tuned. Fixed for the life of the stream.
    """
    tenant_id: str
    cfg: CNNEqConfig
    params: Optional[Dict[str, Any]] = None
    bn_state: Optional[Dict[str, Any]] = None
    weights: Optional[tuple] = None
    formats: Optional[tuple] = None
    backend: str = "auto"
    tile_m: int | str = "auto"

    def build_engine(self) -> EqualizerEngine:
        if (self.params is None) == (self.weights is None):
            raise ValueError(
                f"tenant {self.tenant_id!r}: exactly one of params/weights")
        if self.params is not None:
            return EqualizerEngine.from_params(
                self.params, self.bn_state, self.cfg,
                backend=self.backend, tile_m=self.tile_m)
        return EqualizerEngine(cfg=self.cfg, weights=self.weights,
                               backend=self.backend, tile_m=self.tile_m,
                               formats=self.formats)


class Session:
    """One tenant's live stream state (engine NOT held — see pool).

    `failed` is None on the happy path; the async runtime sets it to the
    terminal exception when a launch for this stream exhausted its retries,
    after which `output()` raises instead of returning a stream with a
    silent hole (a lost chunk would otherwise just shorten the output).
    """

    def __init__(self, spec: TenantSpec, pool: EnginePool,
                 tile_tuner: Optional[TileTuner] = None):
        self._pool = pool
        # a NEW stream must never inherit a pool entry built (or tile-
        # mutated) for an earlier session under the same tenant_id — the
        # chunker below must be sized off an engine that this session's
        # spec rebuilds identically after LRU eviction
        pool.drop(spec.tenant_id)
        engine = pool.get(spec.tenant_id, spec.build_engine)
        if tile_tuner is not None and spec.tile_m == "auto":
            tuned = tile_tuner(engine)
            if tuned is not None:
                # freeze the serve-aware tile into the session's spec copy:
                # rebuilds after LRU eviction must reproduce it, and the
                # caller's spec object stays untouched
                spec = dataclasses.replace(spec, tile_m=int(tuned))
                engine.tile_m = int(tuned)
        self.spec = spec
        self.chunker = StreamChunker(            # sized off the built engine
            halo=engine.halo_samples,
            total_stride=engine.total_stride,
            tile_m=engine.resolved_tile_m())
        self.v_parallel = engine.cfg.v_parallel
        self._out: List[np.ndarray] = []
        self.syms_emitted = 0
        self.failed: Optional[BaseException] = None
        # requests taken for launch but not yet descattered/failed —
        # maintained (under its lock) by AsyncServeRuntime so close() can
        # wait for a tenant's in-flight work; always 0 on the sync path
        self.inflight = 0

    @property
    def engine(self) -> EqualizerEngine:
        """Fetch (or rebuild after LRU eviction) this tenant's engine."""
        return self._pool.get(self.spec.tenant_id, self.spec.build_engine)

    def append_output(self, syms: np.ndarray) -> None:
        self._out.append(syms)
        self.syms_emitted += int(syms.shape[0])

    def output(self) -> np.ndarray:
        """All symbols emitted so far, in stream order. Raises the stream's
        terminal launch error (if any) rather than returning a stream with
        missing chunks."""
        if self.failed is not None:
            raise RuntimeError(
                f"stream {self.spec.tenant_id!r} lost a chunk to a failed "
                f"launch") from self.failed
        if not self._out:
            return np.zeros((0,), np.float32)
        return np.concatenate(self._out)


class SessionManager:
    """tenant_id → Session registry over a shared LRU engine pool."""

    def __init__(self, pool: Optional[EnginePool] = None,
                 max_engines: int = 32):
        self.pool = pool if pool is not None else EnginePool(max_engines)
        self._sessions: Dict[str, Session] = {}

    def open(self, spec: TenantSpec,
             tile_tuner: Optional[TileTuner] = None) -> Session:
        if spec.tenant_id in self._sessions:
            raise ValueError(f"tenant {spec.tenant_id!r} already open")
        s = Session(spec, self.pool, tile_tuner=tile_tuner)
        self._sessions[spec.tenant_id] = s
        return s

    def get(self, tenant_id: str) -> Session:
        return self._sessions[tenant_id]

    def close(self, tenant_id: str) -> Session:
        s = self._sessions.pop(tenant_id)
        self.pool.drop(tenant_id)
        return s

    def __contains__(self, tenant_id: str) -> bool:
        return tenant_id in self._sessions

    def __len__(self) -> int:
        return len(self._sessions)

    @property
    def sessions(self) -> Dict[str, Session]:
        return dict(self._sessions)
