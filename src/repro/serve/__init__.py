"""Multi-tenant streaming equalizer serving runtime (see runtime.py).

Layers:
  chunker    — stateful overlap-save: arbitrary chunk sizes, offline-exact
  pool       — LRU-bounded engine pool (session-manager memory bound)
  session    — TenantSpec / Session / SessionManager
  scheduler  — BatchPolicy / MicroBatcher: dynamic micro-batching into
               stacked fused-kernel launches with per-row tenant weights
  runtime    — ServeRuntime facade
  loadgen    — reproducible tenant traffic for benches/examples
"""
from .chunker import ChunkPlan, StreamChunker
from .loadgen import chop, random_waveforms, replay
from .pool import EnginePool
from .runtime import ServeRuntime
from .scheduler import BatchPolicy, MicroBatcher, Request
from .session import Session, SessionManager, TenantSpec

__all__ = ["BatchPolicy", "ChunkPlan", "EnginePool", "MicroBatcher",
           "Request", "ServeRuntime", "Session", "SessionManager",
           "StreamChunker", "TenantSpec", "chop", "random_waveforms",
           "replay"]
