"""Whisper-large-v3 backbone: encoder–decoder transformer (arXiv:2212.04356).

The conv/mel frontend is a STUB per the assignment: `input_specs()` provides
precomputed frame embeddings (B, enc_len=1500, d_model) standing in for the
log-mel → conv1d×2 downsampling. The backbone dimensions are exact:
32+32 layers, d_model 1280, 20 heads (MHA), d_ff 5120, GELU, sinusoidal
positions (rope_theta=0 disables RoPE in the attention module).

Serving decodes with a self-attn KV ring cache + precomputed cross-attn K/V
(computed once at prefill from the encoder output and carried in the state).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..parallel import sharding
from . import attention, mlp
from .common import (ModelConfig, dense_init, rms_norm, sinusoidal_positions,
                     stack_layers)


def init_enc_layer(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    dt = cfg.param_dtype()
    return {
        "attn_norm": jnp.ones((cfg.d_model,), dt),
        "attn": attention.init(k1, cfg),
        "mlp_norm": jnp.ones((cfg.d_model,), dt),
        "mlp": mlp.init(k2, cfg),
    }


def init_dec_layer(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    dt = cfg.param_dtype()
    return {
        "attn_norm": jnp.ones((cfg.d_model,), dt),
        "attn": attention.init(k1, cfg),
        "xattn_norm": jnp.ones((cfg.d_model,), dt),
        "xattn": attention.init(k2, cfg),
        "mlp_norm": jnp.ones((cfg.d_model,), dt),
        "mlp": mlp.init(k3, cfg),
    }


def init(key: jax.Array, cfg: ModelConfig) -> Dict[str, Any]:
    keys = jax.random.split(key, cfg.enc_layers + cfg.n_layers + 3)
    dt = cfg.param_dtype()
    enc = [init_enc_layer(keys[i], cfg) for i in range(cfg.enc_layers)]
    dec = [init_dec_layer(keys[cfg.enc_layers + i], cfg)
           for i in range(cfg.n_layers)]
    return {
        "enc_layers": stack_layers(enc),
        "enc_norm": jnp.ones((cfg.d_model,), dt),
        "embed": dense_init(keys[-2], (cfg.vocab_padded, cfg.d_model), dt,
                            scale=1.0),
        "dec_layers": stack_layers(dec),
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "lm_head": dense_init(keys[-1], (cfg.d_model, cfg.vocab_padded), dt),
    }


def encode(params, frames: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """frames: (B, enc_len, d) stub embeddings → encoder output."""
    pos = sinusoidal_positions(frames.shape[1], cfg.d_model)
    h = frames.astype(cfg.param_dtype()) + pos[None].astype(cfg.param_dtype())
    h = sharding.logical(h, ("batch", None, None))
    positions = jnp.arange(h.shape[1])

    def body(hh, lp):
        x = rms_norm(hh, lp["attn_norm"])
        a, _ = attention.self_attention(lp["attn"], x, cfg, positions,
                                        causal=False)
        hh = hh + a
        hh = hh + mlp.apply(lp["mlp"], rms_norm(hh, lp["mlp_norm"]), cfg)
        return hh, None

    fn = jax.checkpoint(lambda c, lp: body(c, lp)) if cfg.remat else body
    h, _ = jax.lax.scan(fn, h, params["enc_layers"])
    return rms_norm(h, params["enc_norm"])


def decode_train(params, tokens: jnp.ndarray, enc_out: jnp.ndarray,
                 cfg: ModelConfig) -> jnp.ndarray:
    pos = sinusoidal_positions(tokens.shape[1], cfg.d_model)
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.param_dtype())
    h = h + pos[None].astype(h.dtype)
    h = sharding.logical(h, ("batch", None, None))
    positions = jnp.arange(h.shape[1])

    def body(hh, lp):
        a, _ = attention.self_attention(
            lp["attn"], rms_norm(hh, lp["attn_norm"]), cfg, positions,
            q_chunk=cfg.q_chunk)
        hh = hh + a
        x, _ = attention.cross_attention(
            lp["xattn"], rms_norm(hh, lp["xattn_norm"]), enc_out, cfg)
        hh = hh + x
        hh = hh + mlp.apply(lp["mlp"], rms_norm(hh, lp["mlp_norm"]), cfg)
        return hh, None

    fn = jax.checkpoint(lambda c, lp: body(c, lp)) if cfg.remat else body
    h, _ = jax.lax.scan(fn, h, params["dec_layers"])
    h = rms_norm(h, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
    return sharding.logical(logits, ("batch", None, "vocab"))


def loss_fn(params, batch, cfg: ModelConfig):
    from .transformer import cross_entropy
    enc_out = encode(params, batch["enc_embed"], cfg)
    logits = decode_train(params, batch["tokens"], enc_out, cfg)
    ce = cross_entropy(logits[:, :-1, :], batch["labels"][:, 1:], cfg.vocab)
    return ce, {"ce": ce, "aux": jnp.zeros(())}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_state(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    _, kv_eff = sharding.resolve_heads(cfg.n_heads, cfg.n_kv_heads, cfg.tp)
    dt = cfg.param_dtype()
    return {
        "k": jnp.zeros((cfg.n_layers, batch, max_len, kv_eff, cfg.head_dim),
                       dt),
        "v": jnp.zeros((cfg.n_layers, batch, max_len, kv_eff, cfg.head_dim),
                       dt),
        # cross-attn K/V precomputed from the encoder output at prefill
        "xk": jnp.zeros((cfg.n_layers, batch, cfg.enc_len, kv_eff,
                         cfg.head_dim), dt),
        "xv": jnp.zeros((cfg.n_layers, batch, cfg.enc_len, kv_eff,
                         cfg.head_dim), dt),
    }


def prefill(params, tokens: jnp.ndarray, frames: jnp.ndarray,
            cfg: ModelConfig, state: Dict[str, Any]):
    """Encoder pass + decoder prefill. Returns (last_logits, state)."""
    from .transformer import _ring_write
    enc_out = encode(params, frames, cfg)
    pos_emb = sinusoidal_positions(tokens.shape[1], cfg.d_model)
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.param_dtype())
    h = h + pos_emb[None].astype(h.dtype)
    positions = jnp.arange(h.shape[1])

    def body(carry, lp):
        hh, ck_all, cv_all, i = carry
        from .transformer import _set_layer
        x = rms_norm(hh, lp["attn_norm"])
        q, k, v = attention.qkv(lp["attn"], x, cfg, positions)
        ck_all = _set_layer(ck_all, i, _ring_write(ck_all[i], k, 0))
        cv_all = _set_layer(cv_all, i, _ring_write(cv_all[i], v, 0))
        o = attention.attend_causal(q, k, v, 0, 0, cfg.q_chunk,
                                    fused=cfg.fused_attention)
        hh = hh + attention.out_proj(lp["attn"], o)
        xo, (xk, xv) = attention.cross_attention(
            lp["xattn"], rms_norm(hh, lp["xattn_norm"]), enc_out, cfg)
        hh = hh + xo
        hh = hh + mlp.apply(lp["mlp"], rms_norm(hh, lp["mlp_norm"]), cfg)
        return (hh, ck_all, cv_all, i + 1), (xk, xv)

    (h, ck, cv, _), (xk, xv) = jax.lax.scan(
        body, (h, state["k"], state["v"], jnp.zeros((), jnp.int32)),
        params["dec_layers"])
    h = rms_norm(h[:, -1:, :], params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
    logits = sharding.logical(logits, ("batch", None, "vocab"))
    return logits[:, 0], {"k": ck, "v": cv, "xk": xk, "xv": xv}


def decode_step(params, token: jnp.ndarray, pos: jnp.ndarray,
                state: Dict[str, Any], cfg: ModelConfig):
    from .transformer import _ring_write
    w = state["k"].shape[2]
    pos_emb = sinusoidal_positions(w, cfg.d_model)
    h = jnp.take(params["embed"], token, axis=0).astype(cfg.param_dtype())
    h = h + jax.lax.dynamic_slice_in_dim(pos_emb, jnp.minimum(pos, w - 1),
                                         1, axis=0)[None].astype(h.dtype)
    positions = jnp.full((1,), pos, jnp.int32)
    scale = 1.0 / np.sqrt(cfg.head_dim)

    def body(carry, xs):
        hh, ck_all, cv_all, i = carry
        lp, xk, xv = xs
        from .transformer import _set_layer
        x = rms_norm(hh, lp["attn_norm"])
        q, k, v = attention.qkv(lp["attn"], x, cfg, positions)
        new_ck = _ring_write(ck_all[i], k, pos)
        new_cv = _ring_write(cv_all[i], v, pos)
        ck_all = _set_layer(ck_all, i, new_ck)
        cv_all = _set_layer(cv_all, i, new_cv)
        kk, vv = new_ck, new_cv
        rep = q.shape[2] // kk.shape[2]
        if rep > 1:
            kk = jnp.repeat(kk, rep, axis=2)
            vv = jnp.repeat(vv, rep, axis=2)
        slot = jnp.arange(w)[None, :]
        age = jnp.mod(pos - slot, w)
        valid = age <= pos
        o = attention._attend_dense(q, kk, vv, valid[None, None], scale)
        hh = hh + attention.out_proj(lp["attn"], o)
        xo, _ = attention.cross_attention(
            lp["xattn"], rms_norm(hh, lp["xattn_norm"]), None, cfg,
            cached_kv=(xk, xv))
        hh = hh + xo
        hh = hh + mlp.apply(lp["mlp"], rms_norm(hh, lp["mlp_norm"]), cfg)
        return (hh, ck_all, cv_all, i + 1), None

    (h, ck, cv, _), _ = jax.lax.scan(
        body, (h, state["k"], state["v"], jnp.zeros((), jnp.int32)),
        (params["dec_layers"], state["xk"], state["xv"]))
    h = rms_norm(h, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
    logits = sharding.logical(logits, ("batch", None, "vocab"))
    return logits[:, 0], {"k": ck, "v": cv, "xk": state["xk"],
                          "xv": state["xv"]}
