"""Register-style control plane carried in CTRL/ACK frames.

Modeled on the FPGA demonstrator's APB register interface: the host does
not reach into the datapath — it posts a write to a typed register, the
core applies it at a safe boundary (here: the existing runtime APIs,
whose swap barrier already lands weight changes at a chunk boundary),
and a per-command ACK frame reports success or a typed error. Commands
are validated against the register map BEFORE anything is applied, so a
malformed or unknown-register command returns an error ack and leaves
every session untouched.

Register map (`Reg`):

  OPEN        admit a tenant: CNNEqConfig fields + folded weights (npz
              blob) + optional formats/backend/tile_m; replies with the
              granted credit total and the int8 wire grid.
  CLOSE       release a finished tenant; replies with the emitted count.
              Refused (error ack) while symbols are still in flight —
              close cannot be allowed to strand un-framed symbols.
  SWAP_WEIGHTS  hot-swap folded weights mid-stream (npz blob); replies
              with the new weight epoch (PR 5 splice contract holds).
  ROLLBACK    restore the pre-swap weights; replies with the new epoch.
  SET_POLICY  retune `BatchPolicy` knobs on every batcher (fleet: all
              workers); replies with the resulting policy.
  READ_STATS  JSON-sanitized `runtime.stats()` snapshot.

Wire encoding of a CTRL payload: ``u32 json_len | json | npz?`` — the
JSON dict carries ``{"reg": int, **fields}``, the optional npz blob the
weight arrays (w0,b0,w1,b1,...). The ACK payload is the same encoding
with ``{"ok": bool, ...result-or-error}`` and no blob; the ACK's seq
echoes the command's seq (the command id the client matches on).
"""
from __future__ import annotations

import dataclasses
import io
import struct
from typing import Dict, Optional, Tuple

import numpy as np

from .frame import Frame, FrameType, WireDtype, encode_frame, wire_grid

_JLEN = struct.Struct("<I")


class ControlError(ValueError):
    """Typed command rejection (unknown register, bad/missing fields)."""


# -- payload codec ------------------------------------------------------------

def pack_control(fields: dict, arrays: Optional[dict] = None) -> bytes:
    import json
    blob = b""
    if arrays:
        buf = io.BytesIO()
        np.savez(buf, **arrays)
        blob = buf.getvalue()
    body = json.dumps(fields).encode("utf-8")
    return _JLEN.pack(len(body)) + body + blob


def unpack_control(payload: bytes) -> Tuple[dict, dict]:
    import json
    if len(payload) < _JLEN.size:
        raise ControlError("control payload shorter than its length prefix")
    (jlen,) = _JLEN.unpack_from(payload, 0)
    if _JLEN.size + jlen > len(payload):
        raise ControlError("control payload truncated")
    try:
        fields = json.loads(payload[_JLEN.size:_JLEN.size + jlen])
    except (UnicodeDecodeError, ValueError) as e:
        raise ControlError(f"control JSON undecodable: {e}") from None
    if not isinstance(fields, dict):
        raise ControlError("control JSON must be an object")
    arrays: dict = {}
    blob = payload[_JLEN.size + jlen:]
    if blob:
        try:
            with np.load(io.BytesIO(blob)) as z:
                arrays = {k: z[k] for k in z.files}
        except Exception as e:
            raise ControlError(f"weight blob undecodable: {e}") from None
    return fields, arrays


def _jsonable(obj):
    """Best-effort JSON sanitizer for stats/ack payloads."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return _jsonable(dataclasses.asdict(obj))
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    return repr(obj)


# -- register map -------------------------------------------------------------

class Reg:
    """The typed register map (u16 register ids on the wire)."""
    OPEN = 1
    CLOSE = 2
    SWAP_WEIGHTS = 3
    ROLLBACK = 4
    SET_POLICY = 5
    READ_STATS = 6


@dataclasses.dataclass(frozen=True)
class RegSpec:
    """One register's schema: required/optional field names → types
    (checked before the handler runs) and whether a weight blob may ride
    along."""
    name: str
    required: Dict[str, type]
    optional: Dict[str, type]
    arrays: bool = False


_NUM = (int, float)
REGISTERS: Dict[int, RegSpec] = {
    Reg.OPEN: RegSpec("open", {"cfg": dict},
                      {"backend": str, "tile_m": (int, str),
                       "formats": list, "per_channel": bool,
                       "priority": int, "credits": int}, arrays=True),
    Reg.CLOSE: RegSpec("close", {}, {}),
    Reg.SWAP_WEIGHTS: RegSpec("swap_weights", {}, {}, arrays=True),
    Reg.ROLLBACK: RegSpec("rollback", {}, {}),
    Reg.SET_POLICY: RegSpec("set_policy", {},
                            {"max_batch": int, "max_wait_s": _NUM,
                             "width_bucket": int, "retune_after": int}),
    Reg.READ_STATS: RegSpec("read_stats", {}, {}),
}


def _validate(spec: RegSpec, fields: dict, arrays: dict) -> None:
    for k, t in spec.required.items():
        if k not in fields:
            raise ControlError(f"{spec.name}: missing field {k!r}")
    for k, v in fields.items():
        if k == "reg":
            continue
        t = spec.required.get(k) or spec.optional.get(k)
        if t is None:
            raise ControlError(f"{spec.name}: unknown field {k!r}")
        if not isinstance(v, t):
            raise ControlError(f"{spec.name}: field {k!r} wants "
                               f"{t}, got {type(v).__name__}")
    if arrays and not spec.arrays:
        raise ControlError(f"{spec.name}: takes no weight blob")


def weights_to_arrays(weights) -> dict:
    """Folded (w, b) pairs → the npz naming convention (w0,b0,w1,b1,...)."""
    out = {}
    for i, (w, b) in enumerate(weights):
        out[f"w{i}"] = np.asarray(w)
        out[f"b{i}"] = np.asarray(b)
    return out


def arrays_to_weights(arrays: dict) -> tuple:
    layers = sum(1 for k in arrays if k.startswith("w"))
    if layers == 0 or any(f"b{i}" not in arrays or f"w{i}" not in arrays
                          for i in range(layers)):
        raise ControlError("weight blob wants w0,b0,...,wN,bN arrays")
    return tuple((arrays[f"w{i}"], arrays[f"b{i}"]) for i in range(layers))


# -- server side --------------------------------------------------------------

class ControlPlane:
    """Executes validated register commands against the runtime and acks
    every command (success or typed error) on the gateway's transport."""

    #: how many executed (tenant, seq) command ids to remember for
    #: duplicate suppression — an impaired wire may duplicate a CTRL
    #: frame, and commands must execute at most once (a doubled
    #: SWAP_WEIGHTS would silently burn a weight epoch).
    ACK_CACHE = 256

    def __init__(self, runtime, gateway):
        self.runtime = runtime
        self.gateway = gateway
        self.commands = runtime.obs.scope("net").counter("ctrl_commands")
        self.errors = runtime.obs.scope("net").counter("ctrl_errors")
        self._acked: Dict[Tuple[str, int], bytes] = {}
        self._acked_order: list = []

    def handle(self, frame: Frame) -> None:
        key = (frame.tenant, frame.seq)
        cached = self._acked.get(key)
        if cached is not None:      # duplicate command: resend ack, don't
            self.gateway.transport.send(cached)   # execute again
            return
        self.commands.inc()
        try:
            fields, arrays = unpack_control(frame.payload)
            reg = fields.get("reg")
            spec = REGISTERS.get(reg)
            if spec is None:
                raise ControlError(f"unknown register {reg!r}")
            _validate(spec, fields, arrays)
            result = getattr(self, f"_do_{spec.name}")(frame.tenant,
                                                       fields, arrays)
            ack = {"ok": True, **_jsonable(result)}
        except Exception as e:
            self.errors.inc()
            ack = {"ok": False,
                   "error": f"{type(e).__name__}: {e}"}
        wire_ack = encode_frame(FrameType.ACK, frame.tenant, frame.seq,
                                pack_control(ack))
        self._acked[key] = wire_ack
        self._acked_order.append(key)
        if len(self._acked_order) > self.ACK_CACHE:
            self._acked.pop(self._acked_order.pop(0), None)
        self.gateway.transport.send(wire_ack)

    # -- handlers (one per register) -----------------------------------------

    def _do_open(self, tenant: str, fields: dict, arrays: dict) -> dict:
        from ..core.equalizer import CNNEqConfig
        from ..serve.session import TenantSpec
        cfg = CNNEqConfig(**fields["cfg"])
        formats = fields.get("formats")
        if formats is not None:
            formats = tuple(tuple(f) for f in formats)
        spec = TenantSpec(
            tenant, cfg, weights=arrays_to_weights(arrays),
            formats=formats, backend=fields.get("backend", "auto"),
            tile_m=fields.get("tile_m", "auto"),
            per_channel=fields.get("per_channel", False),
            priority=fields.get("priority", 0))
        session = self.runtime.open(spec)
        state = self.gateway.ingress.register(tenant,
                                              credits=fields.get("credits"))
        a_int, a_frac = wire_grid(session.engine)
        wire_dtype = (WireDtype.INT8 if spec.backend == "fused_int8"
                      else WireDtype.FP32)
        return {"granted": state.granted_total, "a_int": a_int,
                "a_frac": a_frac, "wire_dtype": int(wire_dtype),
                "backend": session.engine.backend}

    def _do_close(self, tenant: str, fields: dict, arrays: dict) -> dict:
        ingress = self.gateway.ingress
        state = ingress.tenants.get(tenant)
        egress = self.gateway.egress.streams.get(tenant)
        if state is not None:
            if not state.eos_done:
                raise ControlError("close before EOS: stream unfinished")
            if egress is not None and (egress.fifo or not egress.eos_sent):
                raise ControlError("close while symbols in flight")
        stream = self.runtime.close(tenant)
        ingress.release(tenant)
        return {"syms_emitted": int(stream.shape[0])}

    def _do_swap_weights(self, tenant: str, fields: dict,
                         arrays: dict) -> dict:
        epoch = self.runtime.swap_weights(
            tenant, weights=arrays_to_weights(arrays))
        return {"epoch": int(epoch)}

    def _do_rollback(self, tenant: str, fields: dict, arrays: dict) -> dict:
        return {"epoch": int(self.runtime.rollback_weights(tenant))}

    def _do_set_policy(self, tenant: str, fields: dict,
                       arrays: dict) -> dict:
        knobs = {k: v for k, v in fields.items() if k != "reg"}
        if not knobs:
            raise ControlError("set_policy: no knobs given")
        batchers = ([w.batcher for w in self.runtime.workers]
                    if hasattr(self.runtime, "workers")
                    else [self.runtime.batcher])
        for b in batchers:
            b.policy = dataclasses.replace(b.policy, **knobs)
        return {"policy": dataclasses.asdict(batchers[0].policy)}

    def _do_read_stats(self, tenant: str, fields: dict,
                       arrays: dict) -> dict:
        return {"stats": _jsonable(self.runtime.stats())}
