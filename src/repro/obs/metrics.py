"""Metrics registry: Counter/Gauge/Histogram under hierarchical dotted names.

Design constraints (see docs/OBSERVABILITY.md):

  * every instrument carries its own lock — hot paths never contend on a
    registry-wide mutex (the registry lock is taken only at get-or-create
    and snapshot time);
  * histograms keep a *bounded* sliding-window reservoir (a deque of the
    last `window` observations) plus lifetime count/sum/min/max, so memory
    is constant no matter how long a session runs;
  * the clock is injectable for deterministic tests (`snapshot()` stamps
    uptime from it);
  * `callback(name, fn)` registers a lazy provider evaluated only at
    snapshot time — runtimes use this to expose existing state (pool LRU
    counters, placement maps, recovery ledgers) without double-accounting.

Names are dot-separated segments of ``[A-Za-z0-9_-]``; the snapshot is the
nested dict tree obtained by splitting on dots.
"""
from __future__ import annotations

import json
import math
import re
import threading
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

_NAME_RE = re.compile(r"^[A-Za-z0-9_\-]+(\.[A-Za-z0-9_\-]+)*$")

DEFAULT_WINDOW = 1024


def _check_name(name: str) -> str:
    if not _NAME_RE.match(name):
        raise ValueError(f"bad metric name {name!r}: want dotted "
                         "[A-Za-z0-9_-] segments")
    return name


def safe_segment(raw: str) -> str:
    """Map an arbitrary string (tenant ids are user-chosen) to one valid
    metric-name segment — the one sanitization every layer that keys
    metrics by tenant must share (`adapt`, `link`, `slo`), or their
    subtrees land under different names for the same tenant."""
    return re.sub(r"[^A-Za-z0-9_\-]", "_", raw) or "_"


class Counter:
    """Monotonic counter. `inc` only; negative increments are rejected."""

    __slots__ = ("_lock", "_value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError("Counter.inc requires n >= 0")
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        with self._lock:
            return self._value


class Gauge:
    """Point-in-time value; last write wins."""

    __slots__ = ("_lock", "_value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def add(self, dv: float) -> None:
        with self._lock:
            self._value += float(dv)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Sliding-window reservoir: the last `window` observations, plus
    lifetime count/sum/min/max.  Quantiles are computed over the window
    (recency-weighted by construction); memory is O(window) forever."""

    __slots__ = ("_lock", "_window", "_count", "_sum", "_min", "_max")

    def __init__(self, window: int = DEFAULT_WINDOW) -> None:
        if window < 1:
            raise ValueError("Histogram window must be >= 1")
        self._lock = threading.Lock()
        self._window: Deque[float] = deque(maxlen=window)
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self._window.append(v)
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    def observe_many(self, vs) -> None:
        """Record a batch of observations under ONE lock acquisition — the
        shape hot callers like the link-quality tap need (one served chunk
        is hundreds of per-symbol confidences)."""
        xs = [float(v) for v in vs]
        if not xs:
            return
        with self._lock:
            self._window.extend(xs)
            self._count += len(xs)
            self._sum += sum(xs)
            mn, mx = min(xs), max(xs)
            if mn < self._min:
                self._min = mn
            if mx > self._max:
                self._max = mx

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    def window_mean(self) -> float:
        """Mean over the current window (NaN when empty) — the value SLO
        rules evaluate for histogram-valued metrics (`summary()`'s mean is
        lifetime, which would never recover after a long degradation)."""
        with self._lock:
            if not self._window:
                return math.nan
            return sum(self._window) / len(self._window)

    def quantile(self, q: float) -> float:
        """Linear-interpolated quantile over the current window (NaN when
        empty); q in [0, 1]."""
        if not 0.0 <= q <= 1.0:
            raise ValueError("quantile q must be in [0, 1]")
        with self._lock:
            xs = sorted(self._window)
        if not xs:
            return math.nan
        pos = q * (len(xs) - 1)
        lo = int(math.floor(pos))
        hi = min(lo + 1, len(xs) - 1)
        frac = pos - lo
        return xs[lo] * (1.0 - frac) + xs[hi] * frac

    def summary(self) -> Dict[str, float]:
        with self._lock:
            xs = sorted(self._window)
            count, total = self._count, self._sum
            mn, mx = self._min, self._max
        if not xs:
            return {"count": 0, "sum": 0.0}

        def q(p: float) -> float:
            pos = p * (len(xs) - 1)
            lo = int(math.floor(pos))
            hi = min(lo + 1, len(xs) - 1)
            frac = pos - lo
            return xs[lo] * (1.0 - frac) + xs[hi] * frac

        return {
            "count": count,
            "sum": total,
            "min": mn,
            "max": mx,
            "mean": total / count,
            "p50": q(0.50),
            "p90": q(0.90),
            "p99": q(0.99),
            "window": len(xs),
        }


class MetricsRegistry:
    """Get-or-create registry of named instruments + lazy callbacks.

    `snapshot()` returns the nested tree: counters as ints, gauges as
    floats, histograms as summary dicts, callbacks as whatever they
    return (scalars or dict subtrees)."""

    def __init__(self, clock: Callable[[], float] = time.perf_counter) -> None:
        self._lock = threading.Lock()
        self._metrics: Dict[str, Any] = {}
        self._callbacks: Dict[str, Callable[[], Any]] = {}
        self.clock = clock
        self._t0 = clock()

    # -- get-or-create ----------------------------------------------------
    def _get(self, name: str, kind: type, factory: Callable[[], Any]):
        _check_name(name)
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                if name in self._callbacks:
                    raise ValueError(
                        f"metric {name!r} already registered as a callback")
                m = self._metrics[name] = factory()
            elif not isinstance(m, kind):
                raise ValueError(f"metric {name!r} already registered as "
                                 f"{type(m).__name__}, not {kind.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge, Gauge)

    def histogram(self, name: str, window: int = DEFAULT_WINDOW) -> Histogram:
        return self._get(name, Histogram, lambda: Histogram(window))

    def callback(self, name: str, fn: Callable[[], Any]) -> None:
        """Register (or replace) a lazy provider evaluated at snapshot
        time; may return a scalar or a dict subtree."""
        _check_name(name)
        with self._lock:
            if name in self._metrics:
                raise ValueError(
                    f"metric {name!r} already registered as an instrument")
            self._callbacks[name] = fn

    def instrument(self, name: str) -> Optional[Any]:
        """The live instrument registered under `name`, or None — the
        read-only lookup SLO rule evaluation uses (callbacks are not
        instruments and resolve to None: a rule cannot breach on a lazy
        provider whose evaluation might itself throw)."""
        with self._lock:
            return self._metrics.get(name)

    def scope(self, prefix: str) -> "Scope":
        return Scope(self, _check_name(prefix))

    def names(self) -> List[str]:
        with self._lock:
            return sorted(set(self._metrics) | set(self._callbacks))

    # -- export -----------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            metrics = dict(self._metrics)
            callbacks = dict(self._callbacks)
            uptime = self.clock() - self._t0
        tree: Dict[str, Any] = {}
        for name, m in metrics.items():
            if isinstance(m, Counter):
                val: Any = m.value
            elif isinstance(m, Gauge):
                val = m.value
            else:
                val = m.summary()
            _insert(tree, name, val)
        for name, fn in callbacks.items():
            try:
                val = fn()
            except Exception as exc:  # snapshots must never throw
                val = {"error": repr(exc)}
            _insert(tree, name, val)
        tree["meta"] = {"uptime_s": uptime, "metric_names": len(metrics),
                        "callback_names": len(callbacks)}
        return tree

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True,
                          default=_json_default)

    def to_prometheus(self) -> str:
        """Prometheus text exposition: flattened names with dots mapped to
        underscores; histograms emit _count/_sum plus quantile gauges."""
        lines: List[str] = []
        for name, value in sorted(_flatten(self.snapshot())):
            flat = re.sub(r"[^A-Za-z0-9_]", "_", name)
            if isinstance(value, bool):
                lines.append(f"{flat} {int(value)}")
            elif isinstance(value, (int, float)):
                if isinstance(value, float) and not math.isfinite(value):
                    continue
                lines.append(f"{flat} {value}")
            elif isinstance(value, str):
                lines.append(f'{flat}{{value="{value}"}} 1')
        return "\n".join(lines) + "\n"


class Scope:
    """A registry view that prefixes every name — layers hold a Scope and
    stay ignorant of where they sit in the hierarchy."""

    __slots__ = ("_registry", "_prefix")

    def __init__(self, registry: MetricsRegistry, prefix: str) -> None:
        self._registry = registry
        self._prefix = prefix

    @property
    def prefix(self) -> str:
        return self._prefix

    @property
    def registry(self) -> MetricsRegistry:
        return self._registry

    def _name(self, name: str) -> str:
        return f"{self._prefix}.{name}"

    def counter(self, name: str) -> Counter:
        return self._registry.counter(self._name(name))

    def gauge(self, name: str) -> Gauge:
        return self._registry.gauge(self._name(name))

    def histogram(self, name: str, window: int = DEFAULT_WINDOW) -> Histogram:
        return self._registry.histogram(self._name(name), window)

    def callback(self, name: str, fn: Callable[[], Any]) -> None:
        self._registry.callback(self._name(name), fn)

    def scope(self, sub: str) -> "Scope":
        return Scope(self._registry, self._name(_check_name(sub)))


# ---------------------------------------------------------------------------
# tree helpers
# ---------------------------------------------------------------------------

def _insert(tree: Dict[str, Any], dotted: str, value: Any) -> None:
    parts = dotted.split(".")
    node = tree
    for p in parts[:-1]:
        nxt = node.get(p)
        if not isinstance(nxt, dict):
            nxt = node[p] = {}
        node = nxt
    leaf = parts[-1]
    if isinstance(node.get(leaf), dict) and isinstance(value, dict):
        node[leaf].update(value)
    else:
        node[leaf] = value


def _flatten(tree: Dict[str, Any], prefix: str = "") -> List[Tuple[str, Any]]:
    out: List[Tuple[str, Any]] = []
    for k, v in tree.items():
        name = f"{prefix}.{k}" if prefix else str(k)
        if isinstance(v, dict):
            out.extend(_flatten(v, name))
        elif isinstance(v, (list, tuple)):
            out.append((name, json.dumps(v, default=_json_default)))
        else:
            out.append((name, v))
    return out


def _json_default(o: Any) -> Any:
    try:
        return float(o)
    except Exception:
        return repr(o)
