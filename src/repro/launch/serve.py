"""Batched serving driver: prefill + greedy decode loop.

Continuous-batching-lite: requests are grouped into a fixed batch, prefilled
once, then decoded step-by-step with the donated-state decode step (KV ring
caches / SSM states, per family). On CPU this serves REDUCED configs; the
full-config serve paths are lowered by the dry-run.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import dataclasses
import logging
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import configs
from ..models import registry
from ..parallel import sharding
from . import steps as steps_lib
from .mesh import make_mesh

log = logging.getLogger("repro.serve")


def serve_session(cfg, mesh, batch: int, prompt_len: int, max_len: int):
    mode = "serve_fsdp" if cfg.serve_fsdp else "serve"
    sharding.set_mesh(mesh, mode)
    model = registry.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = model.init_serve_state(batch, max_len)

    prefill = jax.jit(steps_lib.build_prefill_step(model))
    decode = jax.jit(steps_lib.build_decode_step(model), donate_argnums=(3,))
    return model, params, state, prefill, decode


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=configs.ARCHS)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mesh", default="1x1")
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO)

    cfg = configs.get_config(args.arch, reduced=not args.full)
    dp, mp = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((dp, mp), ("data", "model"))
    cfg = dataclasses.replace(cfg, tp=mp)
    max_len = args.prompt_len + args.gen

    with mesh:
        model, params, state, prefill, decode = serve_session(
            cfg, mesh, args.batch, args.prompt_len, max_len)

        key = jax.random.PRNGKey(7)
        batch = {"tokens": jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab)}
        if cfg.family == "encdec":
            batch["enc_embed"] = jnp.zeros(
                (args.batch, cfg.enc_len, cfg.d_model), cfg.param_dtype())
        if cfg.family == "vlm":
            batch["embed_prefix"] = jnp.zeros(
                (args.batch, cfg.img_tokens, cfg.d_model), cfg.param_dtype())

        t0 = time.perf_counter()
        logits, state = prefill(params, batch, state)
        logits.block_until_ready()
        t_prefill = time.perf_counter() - t0

        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        generated = [tok]
        t0 = time.perf_counter()
        for i in range(args.gen):
            pos = jnp.asarray(args.prompt_len + i, jnp.int32)
            tok, logits, state = decode(params, tok, pos, state)
            generated.append(tok)
        tok.block_until_ready()
        t_decode = time.perf_counter() - t0

    toks_out = jnp.concatenate(generated, axis=1)
    tput = args.batch * args.gen / t_decode
    log.info("prefill %.3fs; decode %d steps in %.3fs "
             "(%.1f tok/s, %.2f ms/tok)", t_prefill, args.gen, t_decode,
             tput, 1e3 * t_decode / args.gen)
    log.info("sample row 0: %s", toks_out[0, :16].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
