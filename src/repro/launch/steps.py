"""Step builders: the jit-able train/prefill/decode functions per
(architecture × shape), their input ShapeDtypeStructs, and their sharding
trees — consumed by dryrun.py (lower/compile), train.py, and serve.py.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .. import configs
from ..configs.shapes import ShapeSpec
from ..models import registry
from ..models.common import ModelConfig
from ..optim import AdamW
from ..parallel import sharding


# ---------------------------------------------------------------------------
# config resolution per (arch, shape)
# ---------------------------------------------------------------------------

def resolve_config(arch: str, shape: ShapeSpec, reduced: bool = False
                   ) -> ModelConfig:
    cfg = configs.get_config(arch, reduced=reduced)
    if shape.name == "long_500k" and cfg.family == "hybrid":
        # zamba2 long-context decode: shared attn falls back to a sliding
        # window ring cache (DESIGN.md §9); Mamba2 state carries long range.
        cfg = dataclasses.replace(cfg, decode_window=4096)
    return cfg


def cell_supported(arch: str, shape: ShapeSpec) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped) per the DESIGN.md long_500k policy."""
    if shape.name == "long_500k" and not configs.long_500k_runnable(arch):
        return False, ("full attention is quadratic in seq; 500k-token "
                       "decode requires a sub-quadratic family "
                       "(DESIGN.md §5 long_500k policy)")
    return True, ""


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStructs — never allocated)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Abstract inputs for the step kind. Training batches carry a leading
    grad-accumulation axis (the pipeline emits them pre-split)."""
    sds = jax.ShapeDtypeStruct
    edt = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        acc = max(1, cfg.train_accum)
        mb = shape.global_batch // acc
        assert shape.global_batch % acc == 0
        s = shape.seq_len
        batch: Dict[str, Any] = {}
        if cfg.family == "vlm":
            s_txt = s - cfg.img_tokens
            batch["embed_prefix"] = sds((acc, mb, cfg.img_tokens,
                                         cfg.d_model), edt)
            batch["tokens"] = sds((acc, mb, s_txt), jnp.int32)
            batch["labels"] = sds((acc, mb, s_txt), jnp.int32)
        elif cfg.family == "encdec":
            batch["enc_embed"] = sds((acc, mb, cfg.enc_len, cfg.d_model), edt)
            batch["tokens"] = sds((acc, mb, s), jnp.int32)
            batch["labels"] = sds((acc, mb, s), jnp.int32)
        else:
            batch["tokens"] = sds((acc, mb, s), jnp.int32)
            batch["labels"] = sds((acc, mb, s), jnp.int32)
        return batch
    if shape.kind == "prefill":
        b, s = shape.global_batch, shape.seq_len
        batch = {}
        if cfg.family == "vlm":
            batch["embed_prefix"] = sds((b, cfg.img_tokens, cfg.d_model), edt)
            batch["tokens"] = sds((b, s - cfg.img_tokens), jnp.int32)
        elif cfg.family == "encdec":
            batch["enc_embed"] = sds((b, cfg.enc_len, cfg.d_model), edt)
            batch["tokens"] = sds((b, s), jnp.int32)
        else:
            batch["tokens"] = sds((b, s), jnp.int32)
        return batch
    # decode: one new token against a state of capacity seq_len
    return {"token": sds((shape.global_batch, 1), jnp.int32),
            "pos": sds((), jnp.int32)}


def batch_specs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh
                ) -> Dict[str, Any]:
    """PartitionSpec tree matching input_specs."""
    b_axes = sharding.batch_axes(mesh)
    b = b_axes if _divides(shape_batch(cfg, shape), b_axes, mesh) else None
    if shape.kind == "train":
        out = {"tokens": P(None, b, None), "labels": P(None, b, None)}
        if cfg.family == "vlm":
            out["embed_prefix"] = P(None, b, None, None)
        if cfg.family == "encdec":
            out["enc_embed"] = P(None, b, None, None)
        return out
    if shape.kind == "prefill":
        out = {"tokens": P(b, None)}
        if cfg.family == "vlm":
            out["embed_prefix"] = P(b, None, None)
        if cfg.family == "encdec":
            out["enc_embed"] = P(b, None, None)
        return out
    return {"token": P(b, None), "pos": P()}


def shape_batch(cfg: ModelConfig, shape: ShapeSpec) -> int:
    if shape.kind == "train":
        return shape.global_batch // max(1, cfg.train_accum)
    return shape.global_batch


def _divides(n: int, axes, mesh: Mesh) -> bool:
    size = 1
    for a in (axes or ()):
        size *= mesh.shape[a]
    return n % size == 0 if size else False


# ---------------------------------------------------------------------------
# serve-state sharding specs (per family)
# ---------------------------------------------------------------------------

def _auto_spec(leaf, hints, mesh: Mesh):
    """Build a P from logical hints with divisibility fallback."""
    out = []
    for dim, ax in zip(leaf.shape, hints):
        if ax == "batch":
            cand = sharding.batch_axes(mesh)
        elif ax == "model":
            cand = ("model",) if "model" in mesh.axis_names else ()
        else:
            cand = ()
        size = 1
        for a in cand:
            size *= mesh.shape[a]
        ok = cand and size and dim % size == 0
        out.append((cand if len(cand) > 1 else cand[0]) if ok else None)
    return P(*out)


def serve_state_specs(cfg: ModelConfig, state_shapes, mesh: Mesh):
    """Spec tree for a serve state built from its abstract shapes.

    Heuristics per family (explicit, not guessed): rank-5 stacked KV caches
    shard (layer=None, batch, seq=None, heads→model, hd=None); Mamba conv
    states shard the channel dim; SSD states shard the head dim; xLSTM cell
    matrices shard the last (head-dim) axis.
    """
    def spec(path_leaf):
        path, leaf = path_leaf
        nd = len(leaf.shape)
        base = path.split("/")[-1]
        if base in ("k", "v", "xk", "xv"):
            hints = {5: (None, "batch", None, "model", None),
                     4: ("batch", None, "model", None)}.get(
                         nd, (None,) * nd)
            return _auto_spec(leaf, hints, mesh)
        if "conv" in path:
            hints = (None,) * (nd - 1) + ("model",)
            hints = ("batch",) + hints[1:] if nd >= 2 else hints
            if nd >= 3:
                hints = ((None, "batch") if nd == 4 else ("batch",)) \
                    + (None,) * (nd - 2) + ("model",)
            return _auto_spec(leaf, hints, mesh)
        if "ssm" in path and nd >= 4:
            hints = (None,) * (nd - 4) + ("batch", "model", None, None)
            return _auto_spec(leaf, hints, mesh)
        if nd >= 2:
            return _auto_spec(leaf, ("batch",) + (None,) * (nd - 2)
                              + ("model",), mesh)
        return P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(state_shapes)
    specs = [spec((sharding._path_str(p), l)) for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# int8 weight-gathered serving (§Perf iteration 5)
# ---------------------------------------------------------------------------
# serve_fsdp models (mixtral: 280 GB bf16) pay a per-layer weight all-gather
# over `data`; storing the big weights as int8 + per-tensor scale HALVES
# those collective bytes. The gather is pinned BEFORE dequantization with an
# explicit sharding constraint so GSPMD moves int8, not bf16 — this is the
# paper's learned-precision deployment (§4, deployment_dtype → int8) applied
# to the serving collectives.

_INT8_MIN_SIZE = 1 << 16


def _is_qleaf(node) -> bool:
    return isinstance(node, dict) and set(node.keys()) == {"q", "s"}


def quantize_weights_int8(params):
    def one(leaf):
        if leaf.ndim >= 2 and leaf.size >= _INT8_MIN_SIZE:
            s = (jnp.max(jnp.abs(leaf.astype(jnp.float32))) / 127.0 + 1e-12)
            q = jnp.clip(jnp.round(leaf.astype(jnp.float32) / s),
                         -127, 127).astype(jnp.int8)
            return {"q": q, "s": s.astype(jnp.float32)}
        return leaf
    return jax.tree.map(one, params)


def dequantize_weights(qparams, gather_specs, mesh: Mesh, dtype):
    """Gather int8 (explicit constraint = the serve-mode spec, i.e. without
    the fsdp axis) and dequantize locally."""
    def one(node, spec):
        if _is_qleaf(node):
            qg = jax.lax.with_sharding_constraint(
                node["q"], NamedSharding(mesh, spec["q"]))
            return (qg.astype(jnp.float32) * node["s"]).astype(dtype)
        return node
    return jax.tree.map(one, qparams, gather_specs, is_leaf=_is_qleaf)


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def build_train_step(model: registry.Model, opt: AdamW):
    """Gradient-accumulating train step: batch has a leading accum axis."""

    def train_step(params, opt_state, batch):
        accum = jax.tree.leaves(batch)[0].shape[0]

        def micro(gsum, mb):
            (loss, _), grads = jax.value_and_grad(
                model.loss_fn, has_aux=True)(params, mb)
            gsum = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), gsum, grads)
            return gsum, loss

        gsum0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        gsum, losses = jax.lax.scan(micro, gsum0, batch)
        grads = jax.tree.map(lambda g: g / accum, gsum)
        new_params, new_opt = opt.update(grads, opt_state, params)
        return new_params, new_opt, {"loss": jnp.mean(losses)}

    return train_step


def build_prefill_step(model: registry.Model):
    def prefill_step(params, batch, state):
        logits, new_state = model.prefill(params, batch, state)
        return logits, new_state
    return prefill_step


def build_decode_step(model: registry.Model):
    def decode_step(params, token, pos, state):
        logits, new_state = model.decode(params, token, pos, state)
        # greedy next token — serving loops feed it back
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, logits, new_state
    return decode_step


# ---------------------------------------------------------------------------
# full lowering assembly per cell
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Lowerable:
    """Everything needed to lower one (arch × shape × mesh) cell."""
    cfg: ModelConfig
    fn: Any                  # the jit-wrapped step
    args_sds: tuple          # ShapeDtypeStructs to pass to .lower()
    kind: str


def make_lowerable(arch: str, shape: ShapeSpec, mesh: Mesh,
                   reduced: bool = False, lr: float = 1e-3,
                   cfg_overrides: Optional[dict] = None) -> Lowerable:
    cfg = resolve_config(arch, shape, reduced=reduced)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    model = registry.build(cfg)
    if shape.kind == "train":
        mode = "train"
    else:
        mode = "serve_fsdp" if cfg.serve_fsdp else "serve"
    sharding.set_mesh(mesh, mode)

    params_sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    pspecs = sharding.param_specs(params_sds, mesh, mode)
    ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree)

    if shape.kind == "train":
        opt = AdamW(lr=lr, grad_clip_norm=1.0)
        opt_sds = jax.eval_shape(opt.init, params_sds)
        ospecs = sharding.param_specs(opt_sds, mesh, mode)
        batch_sds = input_specs(cfg, shape)
        bspecs = batch_specs(cfg, shape, mesh)
        step = build_train_step(model, opt)
        fn = jax.jit(step,
                     in_shardings=(ns(pspecs), ns(ospecs), ns(bspecs)),
                     out_shardings=(ns(pspecs), ns(ospecs), None),
                     donate_argnums=(0, 1))
        return Lowerable(cfg, fn, (params_sds, opt_sds, batch_sds), "train")

    # serving cells
    b = shape.global_batch
    state_sds = jax.eval_shape(
        lambda: model.init_serve_state(b, shape.seq_len))
    sspecs = serve_state_specs(cfg, state_sds, mesh)

    deq = None
    if cfg.serve_int8_weights:
        params_sds = jax.eval_shape(quantize_weights_int8, params_sds)
        pspecs = sharding.param_specs(params_sds, mesh, mode)
        gspecs = sharding.param_specs(params_sds, mesh, "serve")
        dt = cfg.param_dtype()
        deq = lambda qp: dequantize_weights(qp, gspecs, mesh, dt)

    if shape.kind == "prefill":
        batch_sds = input_specs(cfg, shape)
        bspecs = batch_specs(cfg, shape, mesh)
        inner = build_prefill_step(model)
        step = (inner if deq is None else
                (lambda p, batch, st: inner(deq(p), batch, st)))
        fn = jax.jit(step,
                     in_shardings=(ns(pspecs), ns(bspecs), ns(sspecs)),
                     out_shardings=(None, ns(sspecs)),
                     donate_argnums=(2,))
        return Lowerable(cfg, fn, (params_sds, batch_sds, state_sds),
                         "prefill")

    tok_sds = input_specs(cfg, shape)
    bspecs = batch_specs(cfg, shape, mesh)
    inner = build_decode_step(model)
    step = (inner if deq is None else
            (lambda p, tok, pos, st: inner(deq(p), tok, pos, st)))
    fn = jax.jit(step,
                 in_shardings=(ns(pspecs), ns(bspecs["token"]),
                               ns(bspecs["pos"]), ns(sspecs)),
                 out_shardings=(None, None, ns(sspecs)),
                 donate_argnums=(3,))
    return Lowerable(cfg, fn,
                     (params_sds, tok_sds["token"], tok_sds["pos"],
                      state_sds), "decode")
