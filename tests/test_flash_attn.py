"""Flash-attention Pallas kernel (fwd + bwd) vs the pure-jnp oracle,
swept over shapes/dtypes/windows/GQA ratios, plus the end-to-end fused
train path equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import flash_attention, mha_ref
from repro.kernels.flash_attn.flash_attn import (attention_costs,
                                                 flash_attention_bwd,
                                                 flash_attention_fwd)

KEY = jax.random.PRNGKey(0)


def _qkv(b, sq, sk, h, hkv, d, dtype=jnp.float32):
    q = jax.random.normal(KEY, (b, sq, h, d), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, sk, hkv, d), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, sk, hkv, d), dtype)
    return q, k, v


@pytest.mark.parametrize("b,sq,sk,h,hkv,d,causal,win,qoff", [
    (2, 128, 128, 4, 4, 64, True, 0, 0),
    (1, 256, 256, 4, 2, 64, True, 64, 0),       # GQA + sliding window
    (2, 100, 100, 2, 2, 32, True, 0, 0),        # non-block-aligned
    (1, 1, 320, 4, 4, 64, True, 0, 319),        # decode: 1 query at offset
    (2, 64, 192, 2, 2, 64, False, 0, 0),        # bidirectional
    (1, 96, 96, 8, 1, 16, True, 0, 0),          # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_fwd_vs_ref(b, sq, sk, h, hkv, d, causal, win, qoff, dtype):
    q, k, v = _qkv(b, sq, sk, h, hkv, d, dtype)
    got = flash_attention(q, k, v, causal=causal, window=win, q_offset=qoff,
                          block_q=64, block_k=64, interpret=True)
    kr, vr = jnp.repeat(k, h // hkv, axis=2), jnp.repeat(v, h // hkv, axis=2)
    want = mha_ref(q, kr, vr, causal=causal, window=win, q_offset=qoff)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


def test_flash_block_shape_invariance():
    q, k, v = _qkv(1, 200, 200, 4, 4, 64)
    outs = [flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
            for bq, bk in ((32, 32), (64, 128), (256, 64))]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=2e-5)


@pytest.mark.parametrize("b,s,h,hkv,d,win", [
    (2, 128, 4, 4, 64, 0),
    (1, 192, 4, 2, 32, 64),
    (2, 100, 2, 2, 64, 0),
    (1, 130, 4, 2, 32, 48),
])
def test_flash_bwd_vs_autodiff(b, s, h, hkv, d, win):
    q, k, v = _qkv(b, s, s, h, hkv, d)
    g = jax.random.normal(jax.random.fold_in(KEY, 3), (b, s, h, d))
    o, lse = flash_attention_fwd(q, k, v, window=win, block_q=64,
                                 block_k=64, interpret=True)

    def ref(q_, k_, v_):
        kr = jnp.repeat(k_, h // hkv, axis=2)
        vr = jnp.repeat(v_, h // hkv, axis=2)
        return mha_ref(q_, kr, vr, causal=True, window=win)

    o_ref, vjp = jax.vjp(ref, q, k, v)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-5)
    want = vjp(g)
    got = flash_attention_bwd(q, k, v, o, lse, g, window=win, block_q=64,
                              block_k=64, interpret=True)
    for a, r, name in zip(got, want, ("dq", "dk", "dv")):
        err = float(jnp.max(jnp.abs(a - r)))
        assert err < 5e-4, f"{name}: {err}"


def test_fused_train_path_matches_xla():
    """loss + grads identical between fused-kernel and XLA attention."""
    from repro.models import registry
    from repro.models.common import ModelConfig
    cfg0 = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                       dtype="float32", q_chunk=32)
    toks = jax.random.randint(KEY, (2, 96), 0, 256)
    batch = {"tokens": toks, "labels": toks}
    m0 = registry.build(cfg0)
    params = m0.init(KEY)
    (l0, _), g0 = jax.value_and_grad(m0.loss_fn, has_aux=True)(params, batch)
    m1 = registry.build(dataclasses.replace(cfg0, fused_attention=True))
    (l1, _), g1 = jax.value_and_grad(m1.loss_fn, has_aux=True)(params, batch)
    assert abs(float(l0) - float(l1)) < 1e-5
    worst = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), g0, g1)))
    assert worst < 1e-3, worst


def test_attention_costs_model():
    c = attention_costs(b=1, sq=1024, sk=1024, h=8, d=64, causal=True)
    assert c["flops"] == pytest.approx(4 * 8 * (1024 * 1024 / 2) * 64)
    cw = attention_costs(b=1, sq=1024, sk=1024, h=8, d=64, causal=True,
                         window=128)
    assert cw["flops"] < c["flops"]         # window caps the pair count
    assert c["hbm_bytes"] == 2 * 8 * 64 * 4 * 1024  # q,k,v,o streams
