"""Short trainings that validate the paper's core CLAIMS at reduced scale:

  * the CNN equalizer learns the nonlinear IM/DD channel and beats a
    same-complexity linear FIR (paper Fig. 2's ordering);
  * on the LINEAR Proakis-B channel the gap closes (paper Fig. 4);
  * 3-phase QAT shrinks the learned widths below init while keeping BER
    near the fp32 model (paper Figs. 5/6);
  * the LM train step reduces loss on structured synthetic data.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.channels import imdd, proakis
from repro.core import dse, qat as qat_lib
from repro.core.equalizer import CNNEqConfig
from repro.core.fir import FIRConfig
from repro.core.train_eq import EqTrainConfig, train_equalizer
from repro.data.equalizer_data import channel_fn

KEY = jax.random.PRNGKey(42)
FAST = EqTrainConfig(steps=260, batch=8, seq_syms=256, lr=3e-3,
                     eval_syms=1 << 14)


@pytest.fixture(scope="module")
def imdd_fn():
    return channel_fn("imdd", imdd.IMDDConfig(snr_db=25.0))


@pytest.fixture(scope="module")
def proakis_fn():
    return channel_fn("proakis", proakis.ProakisConfig(snr_db=14.0))


@pytest.mark.slow
def test_cnn_beats_fir_on_imdd(imdd_fn):
    """Paper §3.5 headline, at MATCHED complexity: "the BER achieved by a
    linear equalizer with the same complexity as the CNN is around four
    times higher."  On our simulated 31.5 km link the linear equalizer
    FLOORS (CD nulls + square-law make the channel nonlinear); a CNN of
    the same MAC budget (C=10, 169 MAC/sym ↔ FIR 185 taps) goes under the
    floor. (The FPGA-ceiling point C=5 only MATCHES the floor here — our
    simulated channel is harsher than the lab link; EXPERIMENTS.md §Claims.)
    """
    cnn_cfg = CNNEqConfig(channels=10)            # 169 MAC/sym
    fir_cfg = FIRConfig(taps=185)                 # 185 MAC/sym
    long_cfg = EqTrainConfig(steps=2200, batch=8, seq_syms=256, lr=3e-3,
                             eval_syms=1 << 14)
    _, _, cnn = train_equalizer(KEY, "cnn", cnn_cfg, imdd_fn, long_cfg)
    _, _, fir = train_equalizer(KEY, "fir", fir_cfg, imdd_fn, FAST)
    assert cnn["ber"] < 0.05, f"CNN did not learn (BER {cnn['ber']})"
    assert cnn["ber"] < fir["ber"] * 0.6, \
        f"CNN {cnn['ber']:.4f} vs FIR {fir['ber']:.4f}"


@pytest.mark.slow
def test_fir_competitive_on_linear_channel(proakis_fn):
    """Fig. 4: on the LINEAR channel the FIR is close to the CNN."""
    cnn_cfg = CNNEqConfig()
    fir_cfg = FIRConfig(taps=57)
    _, _, cnn = train_equalizer(KEY, "cnn", cnn_cfg, proakis_fn, FAST)
    _, _, fir = train_equalizer(KEY, "fir", fir_cfg, proakis_fn, FAST)
    assert fir["ber"] < 0.2 and cnn["ber"] < 0.2
    # gap much smaller than on IM/DD: FIR within 3× of the CNN
    assert fir["ber"] <= max(3.0 * cnn["ber"], cnn["ber"] + 0.02)


@pytest.mark.slow
def test_qat_three_phase_shrinks_widths(proakis_fn):
    cfg = CNNEqConfig()
    qcfg = qat_lib.QATConfig(qlf=1e-3, init_int_bits=8.0, init_frac_bits=8.0)
    tcfg = EqTrainConfig(steps=300, batch=8, seq_syms=256, lr=3e-3,
                         eval_syms=1 << 13)
    params, _, q = train_equalizer(KEY, "cnn", cfg, proakis_fn, tcfg,
                                   qat_cfg=qcfg, record_every=50)
    _, _, fp = train_equalizer(KEY, "cnn", cfg, proakis_fn, tcfg)
    # widths shrank below init (8+8+1 = 17 bits)
    assert q["bits_params"] < 16.0
    assert q["bits_acts"] < 16.0
    # PER-LAYER widths are frozen to integers in phase 3 (the average over
    # layers need not be an integer — paper Fig. 5's final snap-up)
    for layer_q in params["qat"].values():
        for v in layer_q.values():
            assert float(v) == int(float(v))
    # communication performance stays in the same regime as fp32
    assert q["ber"] < max(3.0 * fp["ber"], fp["ber"] + 0.03)
    # history recorded the width descent
    bits = [h["bits_params"] for h in q["history"] if "bits_params" in h]
    assert bits and bits[-1] <= bits[0]


def test_dse_pareto_and_selection():
    entries = [
        dse.DSEEntry("cnn", None, mac_per_sym=10, ber=0.05, feasible=True),
        dse.DSEEntry("cnn", None, mac_per_sym=20, ber=0.01, feasible=True),
        dse.DSEEntry("cnn", None, mac_per_sym=30, ber=0.02, feasible=True),
        dse.DSEEntry("fir", None, mac_per_sym=40, ber=0.005, feasible=False),
    ]
    front = dse.pareto_front(entries)
    assert [e.mac_per_sym for e in front] == [10, 20, 40]
    pick = dse.select_operating_point(entries)
    assert pick.mac_per_sym == 20      # best BER among feasible


def test_dse_mac_ceilings():
    # paper: DSP_avail/T_req·f_clk·1.2 for the XCVU13P @ 200 MHz, 40 GBd
    assert dse.mac_sym_max_fpga() == pytest.approx(
        12288 / 40e9 * 200e6 * 1.2)
    assert dse.mac_sym_max_fpga() == pytest.approx(73.728)
    # the paper's operating point (56.25 MAC/sym) is feasible, K=15 C=5
    # L=3 V_p=8 (≈ 93.75) is not:
    assert CNNEqConfig().mac_per_symbol() <= dse.mac_sym_max_fpga()
    assert CNNEqConfig(kernel=15).mac_per_symbol() > dse.mac_sym_max_fpga()
    # TPU analogue scales with chips
    assert dse.mac_sym_max_tpu(chips=2) == 2 * dse.mac_sym_max_tpu(chips=1)


def test_cnn_grid_is_paper_sized():
    assert len(list(dse.cnn_grid())) == 135      # 5·3·3·3 models (paper §3.5)


@pytest.mark.slow
def test_lm_training_reduces_loss():
    """examples/quickstart-scale: a reduced smollm learns synthetic data."""
    import dataclasses
    from repro import configs
    from repro.models import registry
    from repro.optim import AdamW
    from repro.data import PipelineConfig, TokenSource

    cfg = configs.get_config("smollm-135m", reduced=True)
    model = registry.build(cfg)
    params = model.init(KEY)
    opt = AdamW(lr=3e-3, grad_clip_norm=1.0)
    opt_state = opt.init(params)
    src = TokenSource(PipelineConfig(seq_len=128, global_batch=8), cfg.vocab)

    @jax.jit
    def step(params, opt_state, toks):
        batch = {"tokens": toks, "labels": toks}
        (loss, _), g = jax.value_and_grad(model.loss_fn, has_aux=True)(
            params, batch)
        params, opt_state = opt.update(g, opt_state, params)
        return params, opt_state, loss

    losses = []
    for i in range(150):
        toks = jnp.stack([jnp.asarray(src.block(i, r)) for r in range(8)])
        params, opt_state, loss = step(params, opt_state, toks)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.4, losses[::30]
