"""Straggler detection & mitigation.

At 1000+ nodes the slowest worker sets the step time (synchronous SGD), so
the controller needs (a) detection — a robust running estimate of the step
time distribution — and (b) mitigation hooks. This module implements the
detection machinery and three mitigations, exercised in tests with injected
delays:

  * `deadline-skip`: if a step exceeds μ + k·σ (or an absolute deadline),
    flag it; after `patience` consecutive flags, fire the mitigation
    callback (production: preempt + reschedule the slow host; here: the
    callback is pluggable — the fault loop uses a controlled restart);
  * `microbatch rebalance`: shrink the accum factor for flagged workers
    (returned as a recommendation — the data pipeline consumes it);
  * bookkeeping for EXPERIMENTS.md (flag counts, step-time quantiles).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional


@dataclasses.dataclass
class StragglerConfig:
    ema_alpha: float = 0.1
    sigma_factor: float = 3.0        # flag threshold: μ + k·σ
    abs_deadline_s: Optional[float] = None
    patience: int = 3                # consecutive flags before mitigation
    warmup_steps: int = 5            # ignore compile/first-touch steps


class StragglerMonitor:
    def __init__(self, cfg: StragglerConfig = StragglerConfig(),
                 on_straggler: Optional[Callable[[int, float], None]] = None):
        self.cfg = cfg
        self.on_straggler = on_straggler
        self.mean = 0.0
        self.var = 0.0
        self.n = 0
        self.consecutive = 0
        self.flags: List[int] = []
        self.times: List[float] = []
        self._t0: Optional[float] = None

    # -- timing interface ---------------------------------------------------

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> bool:
        assert self._t0 is not None, "stop() without start()"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        return self.observe(step, dt)

    def observe(self, step: int, dt: float) -> bool:
        """Record a step time; returns True if the step is flagged."""
        self.times.append(dt)
        self.n += 1
        if self.n <= self.cfg.warmup_steps:
            # prime the estimate but never flag during warmup
            a = 0.5
            self.mean = (1 - a) * self.mean + a * dt if self.n > 1 else dt
            return False
        flagged = False
        sd = self.var ** 0.5
        thresh = self.mean + self.cfg.sigma_factor * max(sd, 1e-9)
        if self.cfg.abs_deadline_s is not None:
            thresh = min(thresh, self.cfg.abs_deadline_s)
        if dt > thresh:
            flagged = True
            self.flags.append(step)
            self.consecutive += 1
            if self.consecutive >= self.cfg.patience \
                    and self.on_straggler is not None:
                self.on_straggler(step, dt)
                self.consecutive = 0
        else:
            self.consecutive = 0
            # update stats from non-straggler steps only (robustness)
            a = self.cfg.ema_alpha
            delta = dt - self.mean
            self.mean += a * delta
            self.var = (1 - a) * (self.var + a * delta * delta)
        return flagged

    # -- mitigation recommendations ------------------------------------------

    def recommend_accum(self, base_accum: int) -> int:
        """Shrink per-worker accumulation when persistently slow (the
        microbatch-rebalance mitigation): slow worker does less local work,
        the optimizer sees the same global batch via gradient reweighting."""
        if len(self.flags) >= self.cfg.patience:
            return max(1, base_accum // 2)
        return base_accum

    def summary(self) -> dict:
        ts = sorted(self.times)
        q = lambda f: ts[int(f * (len(ts) - 1))] if ts else 0.0
        return {"steps": self.n, "flagged": len(self.flags),
                "p50_s": q(0.5), "p95_s": q(0.95), "p99_s": q(0.99),
                "mean_s": self.mean}
