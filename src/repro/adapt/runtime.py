"""OnlineAdapter — the control loop that closes collect → fine-tune →
shadow-eval → promote/rollback over a serving runtime.

One adapter manages any number of ADAPTIVE tenants on one
`ServeRuntime`/`AsyncServeRuntime`. Per tenant it owns a
`SampleCollector` (wired into the session's descatter tap at attach), and
on each `step()` runs at most one adaptation cycle:

  1. ROLLBACK CHECK — if the last action was a promotion, re-score the
     pre-swap engine against the active one on fresh held-out traffic;
     if the old weights now win by the promotion hysteresis, the
     promotion was wrong (or the channel moved again in its favour) and
     the stream rolls back bit-identically.
  2. CADENCE — skip unless `adapt_every_syms` new labelled symbols
     arrived since the last fine-tune (background training should track
     the drift rate, not spin).
  3. FINE-TUNE — weight-only QAT resume from the ACTIVE params on the
     buffered training slice (`repro.adapt.trainer`).
  4. SHADOW EVAL — candidate vs active on the held-out slice
     (`repro.adapt.shadow`); the candidate engine is built through the
     same pinned-formats spec the hot-swap would install, so the score is
     of the real deployed artifact.
  5. PROMOTE — on a hysteresis-guarded win, hot-swap the weights into the
     live stream (`swap_weights`: lands at a chunk boundary, bitwise
     within each weight epoch); otherwise the candidate is discarded.

`step()` is synchronous and deterministic — the form the tests and the
sync benches drive. `start()` runs the same cycles from a daemon thread
(`interval_s` cadence) against an `AsyncServeRuntime`, whose swap barrier
makes hot-swaps safe under concurrent traffic; pair it with the sync
`ServeRuntime` only if nothing else touches that runtime concurrently
(the sync runtime is single-threaded by contract).
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

import jax
import numpy as np

from ..obs.metrics import safe_segment
from ..serve.session import Session, TenantSpec
from .collector import SampleCollector
from .shadow import PromotionPolicy, ShadowReport, shadow_evaluate
from .trainer import FineTuneConfig, fine_tune_from_buffer


@dataclasses.dataclass(frozen=True)
class AdaptPolicy:
    """When to adapt, and how candidate promotion is guarded.

    min_train_syms:   don't fine-tune before this many buffered TRAINING
                      symbols (default 4096; must also exceed the
                      fine-tune window).
    adapt_every_syms: cadence — new labelled symbols between cycles
                      (default 4096). The knob that balances tracking
                      speed against background compute.
    eval_capacity:    collector ring bound in symbols (default 32768).
    eval_every:       collector holdout interleave (default 4 → 25%).
    promotion:        the `PromotionPolicy` hysteresis for both the
                      promote and the rollback comparisons.
    """
    min_train_syms: int = 4096
    adapt_every_syms: int = 4096
    eval_capacity: int = 1 << 15
    eval_every: int = 4
    promotion: PromotionPolicy = PromotionPolicy()


@dataclasses.dataclass
class AdaptReport:
    """One adaptation cycle's outcome for one tenant.

    action ∈ {"idle", "rejected", "promoted", "rolled_back",
    "swap_refused"}; `shadow` carries the BER evidence when an evaluation
    ran; `weight_epoch` is the tenant's epoch AFTER the cycle.
    """
    tenant_id: str
    action: str
    weight_epoch: int
    shadow: Optional[ShadowReport] = None
    train_info: Optional[dict] = None


@dataclasses.dataclass
class _TenantState:
    collector: SampleCollector
    key: jax.Array
    last_adapt_syms: int = 0
    check_rollback: bool = False     # set after a promotion
    requested: bool = False          # event-driven bypass of the cadence
                                     # guard (request_adapt / SLO breach)


class OnlineAdapter:
    """Background adaptation controller over one serving runtime."""

    # recent-error window: a daemon loop failing every interval_s forever
    # must not grow host memory without bound
    ERRORS_MAX = 256

    def __init__(self, runtime, policy: Optional[AdaptPolicy] = None,
                 fine_tune: Optional[FineTuneConfig] = None, seed: int = 0):
        self.runtime = runtime
        self.policy = policy or AdaptPolicy()
        self.fine_tune = fine_tune or FineTuneConfig()
        self._key = jax.random.PRNGKey(seed)
        self._states: Dict[str, _TenantState] = {}
        self.history: List[AdaptReport] = []
        # observability rides the RUNTIME's hub (one snapshot tree per
        # deployment) — older runtimes without one fall back to no-op
        self.obs = getattr(runtime, "obs", None)
        errors_max = (self.obs.retention.errors if self.obs is not None
                      else self.ERRORS_MAX)
        # background-loop failures land here (mirrors
        # AsyncServeRuntime.errors) — a persistently failing adapter must
        # be distinguishable from a healthy idle one. The deque keeps the
        # RECENT failures; `errors_total` keeps the RATE observable after
        # the window wraps (errors_total - len(errors) = dropped).
        self.errors: Deque[BaseException] = deque(maxlen=errors_max)
        self.errors_total = 0
        # closed-loop seam (repro.obs.slo): called with the tenant id after
        # every promotion — an SloEngine resolves the tenant's latched
        # breaches here, completing breach → request_adapt → promote → clear
        self.on_promoted: Optional[Callable[[str], None]] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._m_actions: Dict[str, object] = {}
        if self.obs is not None:
            scope = self.obs.scope("adapt")
            for action in ("idle", "rejected", "promoted", "rolled_back",
                           "swap_refused"):
                self._m_actions[action] = scope.counter(f"actions.{action}")
            scope.callback("errors", lambda: {
                "total": self.errors_total,
                "window": len(self.errors),
                "dropped": self.errors_total - len(self.errors)})
            scope.callback("cycles", lambda: len(self.history))
            scope.callback("tenants", lambda: len(self._states))

    # -- tenant lifecycle --------------------------------------------------

    def attach(self, spec: TenantSpec) -> Session:
        """Open the tenant on the serving runtime AND wire its descatter
        tap into a fresh collector. Adaptive tenants must be opened with
        `params` (fine-tuning resumes from them; a weights-only spec has
        nothing to train)."""
        if spec.params is None:
            raise ValueError(
                f"tenant {spec.tenant_id!r}: adaptation needs params "
                f"(weight-only specs cannot be fine-tuned)")
        session = self.runtime.open(spec)
        self._key, sub = jax.random.split(self._key)
        col = SampleCollector(n_os=spec.cfg.n_os, levels=spec.cfg.levels,
                              capacity_syms=self.policy.eval_capacity,
                              eval_every=self.policy.eval_every)
        session.add_tap(col.on_segment)
        self._states[spec.tenant_id] = _TenantState(collector=col, key=sub)
        return session

    def request_adapt(self, tenant_id: str) -> None:
        """Ask for a fine-tune on the NEXT step regardless of cadence — the
        event-driven entry point (SLO breach handlers call this). The data
        sufficiency guard still applies: a request cannot conjure training
        symbols, only skip the adapt_every_syms wait."""
        self._states[tenant_id].requested = True

    def feed_pilots(self, tenant_id: str, syms: np.ndarray) -> None:
        """Queue true tx symbols (stream order) as labels for the tenant's
        next served symbols — see `SampleCollector.add_pilots`."""
        self._states[tenant_id].collector.add_pilots(syms)

    def collector(self, tenant_id: str) -> SampleCollector:
        return self._states[tenant_id].collector

    @property
    def tenants(self):
        """IDs of the tenants attached to this adapter."""
        return tuple(self._states)

    # -- the adaptation cycle ----------------------------------------------

    def step(self, tenant_id: Optional[str] = None) -> List[AdaptReport]:
        """Run one adaptation cycle for `tenant_id` (or every attached
        tenant). Returns the per-tenant reports (also appended to
        `history`)."""
        ids = [tenant_id] if tenant_id is not None else list(self._states)
        out = []
        for tid in ids:
            rep = self._step_one(tid)
            self.history.append(rep)
            self._record(rep)
            out.append(rep)
        return out

    def _record(self, rep: AdaptReport) -> None:
        """Publish one cycle's outcome into the runtime's obs hub: action
        counters, per-tenant shadow-BER gauges, and trace instants for the
        actions that change the live stream (promote / rollback)."""
        if rep.action == "promoted" and self.on_promoted is not None:
            self.on_promoted(rep.tenant_id)
        if self.obs is None:
            return
        m = self._m_actions.get(rep.action)
        if m is not None:
            m.inc()
        # tenant ids are user-chosen; keep only metric-name-safe chars
        tid = safe_segment(rep.tenant_id)
        scope = self.obs.scope("adapt")
        scope.gauge(f"{tid}.weight_epoch").set(rep.weight_epoch)
        if rep.shadow is not None:
            sh = rep.shadow
            if not np.isnan(sh.ber_active):
                scope.gauge(f"{tid}.shadow.ber_active").set(sh.ber_active)
            if not np.isnan(sh.ber_candidate):
                scope.gauge(f"{tid}.shadow.ber_candidate").set(
                    sh.ber_candidate)
            scope.gauge(f"{tid}.shadow.eval_syms").set(sh.eval_syms)
        if rep.action in ("promoted", "rolled_back"):
            self.obs.tracer.instant(
                f"adapt_{rep.action}", tenant=rep.tenant_id,
                epoch=rep.weight_epoch,
                reason=rep.shadow.reason if rep.shadow else "")

    def _step_one(self, tid: str) -> AdaptReport:
        st = self._states[tid]
        session = self.runtime.sessions.get(tid)
        pol = self.policy

        _, _, eval_rx, eval_syms = st.collector.training_view()

        # 1. rollback check — did the last promotion survive fresh data?
        if st.check_rollback and session.prev_spec is not None:
            prev_engine = session.prev_spec.build_engine()
            rb = shadow_evaluate(session.engine, prev_engine,
                                 eval_rx, eval_syms, pol.promotion)
            if rb.promote:           # the OLD weights win → undo the swap
                epoch = self.runtime.rollback_weights(tid)
                st.check_rollback = False
                st.last_adapt_syms = st.collector.total_syms
                return AdaptReport(tid, "rolled_back", epoch, shadow=rb)
            if not np.isnan(rb.ber_active):
                st.check_rollback = False      # verdict reached: it holds

        # 2. cadence + data sufficiency — an explicit request (SLO breach)
        # waives the cadence wait but never the data floor
        train_rx, train_syms, _, _ = st.collector.training_view()
        fresh = st.collector.total_syms - st.last_adapt_syms
        if ((fresh < pol.adapt_every_syms and not st.requested)
                or train_syms.shape[0] < max(pol.min_train_syms,
                                             self.fine_tune.seq_syms + 1)):
            return AdaptReport(tid, "idle", session.weight_epoch)
        st.requested = False

        # 3. fine-tune from the ACTIVE params (weight-only, frozen formats)
        st.key, ktrain = jax.random.split(st.key)
        params, bn_state, info = fine_tune_from_buffer(
            ktrain, session.spec.params, session.spec.bn_state,
            session.spec.cfg, train_rx, train_syms, self.fine_tune)
        st.last_adapt_syms = st.collector.total_syms

        # 4. shadow-evaluate the REAL candidate artifact (pinned formats)
        engine = session.engine
        cand_spec = dataclasses.replace(
            session.spec, params=params, bn_state=bn_state, weights=None,
            formats=engine.formats, backend=engine.backend,
            tile_m=engine.resolved_tile_m())
        shadow = shadow_evaluate(engine, cand_spec.build_engine(),
                                 eval_rx, eval_syms, pol.promotion)
        if not shadow.promote:
            return AdaptReport(tid, "rejected", session.weight_epoch,
                               shadow=shadow, train_info=info)

        # 5. promote — hot-swap at a chunk boundary
        try:
            epoch = self.runtime.swap_weights(tid, params=params,
                                              bn_state=bn_state)
        except ValueError:
            # the swap guard refused (deployment identity would change) —
            # the stream keeps its weights; recorded, not raised: the loop
            # must keep running for the other tenants
            return AdaptReport(tid, "swap_refused", session.weight_epoch,
                               shadow=shadow, train_info=info)
        st.check_rollback = True
        return AdaptReport(tid, "promoted", epoch, shadow=shadow,
                           train_info=info)

    # -- background mode ---------------------------------------------------

    def start(self, interval_s: float = 0.25) -> None:
        """Run `step()` cycles from a daemon thread every `interval_s`.
        Use with `AsyncServeRuntime` (its swap barrier serializes against
        live traffic); the sync runtime is only safe here if no other
        thread drives it. Cycle failures never kill the thread (the
        stream itself is not at risk) but are recorded in `errors` —
        check it when a tenant that should be adapting is not."""
        if self._thread is not None:
            raise RuntimeError("adapter already started")
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                try:
                    self.step()
                except Exception as e:   # noqa: BLE001 — keep adapting
                    self.errors.append(e)          # bounded (ERRORS_MAX)
                    self.errors_total += 1
                self._stop.wait(interval_s)

        self._thread = threading.Thread(target=loop, name="online-adapter",
                                        daemon=True)
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None
