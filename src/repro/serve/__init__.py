"""Multi-tenant streaming equalizer serving runtime (see runtime.py and
docs/ARCHITECTURE.md).

Layers:
  chunker    — stateful overlap-save: arbitrary chunk sizes, offline-exact
  pool       — LRU-bounded engine pool (session-manager memory bound)
  session    — TenantSpec / Session / SessionManager
  scheduler  — BatchPolicy / MicroBatcher: dynamic micro-batching into
               stacked fused-kernel launches with per-row tenant weights,
               split into assemble/execute/descatter phases; TrafficStats
               feed the serve-aware autotune
  runtime    — ServeRuntime (sync) / AsyncServeRuntime (threaded
               front-end: timer-driven pump, double-buffered launches,
               per-chunk futures)
  loadgen    — reproducible tenant traffic for benches/examples
"""
from .chunker import ChunkPlan, StreamChunker
from .loadgen import (chop, drift_streams, random_waveforms, replay,
                      replay_adaptive)
from .pool import EnginePool
from .runtime import AsyncServeRuntime, ServeRuntime
from .scheduler import (BatchPolicy, LaunchBatch, MicroBatcher, Request,
                        TrafficStats)
from .session import Session, SessionManager, TenantSpec

__all__ = ["AsyncServeRuntime", "BatchPolicy", "ChunkPlan", "EnginePool",
           "LaunchBatch", "MicroBatcher", "Request", "ServeRuntime",
           "Session", "SessionManager", "StreamChunker", "TenantSpec",
           "TrafficStats", "chop", "drift_streams", "random_waveforms",
           "replay", "replay_adaptive"]
