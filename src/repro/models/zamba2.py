"""Zamba2-style hybrid: a Mamba2 backbone with a SHARED attention block
applied every `cfg.attn_every` layers (arXiv:2411.15242).

The shared block (one set of attention+MLP weights, reused at every
application point) is the architecture's parameter-efficiency trick; the
per-use LoRA adapters of the published model are omitted (DESIGN.md §9) —
the shared-weights structure is what matters for sharding and roofline.

long_500k policy (DESIGN.md §5): the Mamba2 blocks carry unbounded-range
state at O(1) memory; the shared attention block decodes with a sliding
window (`cfg.decode_window`) ring cache, i.e. the paper's bounded-receptive-
field stream split applied at serving time. decode_32k uses the full cache.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..parallel import sharding
from . import attention, mamba2, mlp
from .common import ModelConfig, dense_init, rms_norm, stack_layers


def attn_points(cfg: ModelConfig) -> List[int]:
    """Layer indices AFTER which the shared block is applied."""
    if cfg.attn_every <= 0:
        return []
    return [i for i in range(cfg.n_layers) if (i + 1) % cfg.attn_every == 0]


def init(key: jax.Array, cfg: ModelConfig) -> Dict[str, Any]:
    keys = jax.random.split(key, cfg.n_layers + 4)
    dt = cfg.param_dtype()
    layers = [{"norm": jnp.ones((cfg.d_model,), dt),
               "mamba": mamba2.init(keys[i], cfg)}
              for i in range(cfg.n_layers)]
    shared = {
        "attn_norm": jnp.ones((cfg.d_model,), dt),
        "attn": attention.init(keys[-4], cfg),
        "mlp_norm": jnp.ones((cfg.d_model,), dt),
        "mlp": mlp.init(keys[-3], cfg),
    }
    return {
        "embed": dense_init(keys[-2], (cfg.vocab_padded, cfg.d_model), dt,
                            scale=1.0),
        "layers": stack_layers(layers),
        "shared": shared,
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "lm_head": dense_init(keys[-1], (cfg.d_model, cfg.vocab_padded), dt),
    }


def _mamba_layer(lp, h, cfg, state):
    x = rms_norm(h, lp["norm"])
    y, new_state = mamba2.apply(lp["mamba"], x, cfg, state)
    return h + y, new_state


def _shared_block(sp, h, cfg, positions, cache=None, cache_pos=None):
    a, new_cache = attention.self_attention(
        sp["attn"], rms_norm(h, sp["attn_norm"]), cfg, positions,
        cache=cache, cache_pos=cache_pos, q_chunk=cfg.q_chunk)
    h = h + a
    h = h + mlp.apply(sp["mlp"], rms_norm(h, sp["mlp_norm"]), cfg)
    return h, new_cache


def forward(params, tokens, cfg: ModelConfig):
    """Training path: scan groups of mamba layers, shared attn between."""
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.param_dtype())
    h = sharding.logical(h, ("batch", None, None))
    positions = jnp.arange(h.shape[1])
    points = set(attn_points(cfg))

    def mamba_body(hh, lp):
        out, _ = _mamba_layer(lp, hh, cfg, None)
        return out, None

    fn = jax.checkpoint(mamba_body) if cfg.remat else mamba_body
    shared_fn = (jax.checkpoint(
        lambda hh, sp: _shared_block(sp, hh, cfg, positions)[0])
        if cfg.remat else
        (lambda hh, sp: _shared_block(sp, hh, cfg, positions)[0]))

    # contiguous runs of mamba layers between shared-attn applications
    start = 0
    for end in sorted(points) + ([cfg.n_layers - 1]
                                 if (cfg.n_layers - 1) not in points else []):
        seg = jax.tree.map(lambda a: a[start:end + 1], params["layers"])
        h, _ = jax.lax.scan(lambda c, lp: fn(c, lp), h, seg)
        if end in points:
            h = shared_fn(h, params["shared"])
        start = end + 1
    h = rms_norm(h, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
    return sharding.logical(logits, ("batch", None, "vocab"))


def loss_fn(params, batch, cfg: ModelConfig):
    from .transformer import cross_entropy
    logits = forward(params, batch["tokens"], cfg)
    ce = cross_entropy(logits[:, :-1, :], batch["labels"][:, 1:], cfg.vocab)
    return ce, {"ce": ce, "aux": jnp.zeros(())}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_state(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    """Mamba states (stacked over layers) + per-application attn caches."""
    n_apps = len(attn_points(cfg))
    _, kv_eff = sharding.resolve_heads(cfg.n_heads, cfg.n_kv_heads, cfg.tp)
    win = cfg.decode_window or cfg.window
    w = min(max_len, win) if win > 0 else max_len
    per_layer = [mamba2.init_state(cfg, batch) for _ in range(cfg.n_layers)]
    return {
        "mamba": stack_layers(per_layer),
        "attn": {
            "k": jnp.zeros((n_apps, batch, w, kv_eff, cfg.head_dim),
                           cfg.param_dtype()),
            "v": jnp.zeros((n_apps, batch, w, kv_eff, cfg.head_dim),
                           cfg.param_dtype()),
        },
    }


def _serve_pass(params, tokens, pos, state, cfg: ModelConfig):
    """Shared serve path: prefill (S≥1, pos=0) or decode (S=1, pos=t)."""
    from .transformer import _ring_write
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.param_dtype())
    s = h.shape[1]
    decode = s == 1
    positions = (jnp.full((1,), pos, jnp.int32) if decode
                 else jnp.arange(s))
    points = sorted(attn_points(cfg))
    w = state["attn"]["k"].shape[2]
    win = cfg.decode_window or cfg.window or w
    scale = 1.0 / np.sqrt(cfg.head_dim)

    new_mamba = []
    new_k, new_v = [], []
    app = 0
    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[i], params["layers"])
        st = jax.tree.map(lambda a: a[i], state["mamba"])
        h, ns = _mamba_layer(lp, h, cfg, st)
        new_mamba.append(ns)
        if i in points:
            ck = state["attn"]["k"][app]
            cv = state["attn"]["v"][app]
            sp = params["shared"]
            x = rms_norm(h, sp["attn_norm"])
            q, k, v = attention.qkv(sp["attn"], x, cfg, positions)
            ck = _ring_write(ck, k, pos)
            cv = _ring_write(cv, v, pos)
            if decode:
                kk, vv = ck, cv
                rep = q.shape[2] // kk.shape[2]
                if rep > 1:
                    kk = jnp.repeat(kk, rep, axis=2)
                    vv = jnp.repeat(vv, rep, axis=2)
                slot = jnp.arange(w)[None, :]
                age = jnp.mod(pos - slot, w)
                valid = (age <= pos) & (age < win)
                o = attention._attend_dense(q, kk, vv, valid[None, None],
                                            scale)
            else:
                o = attention.attend_causal(q, k, v, 0, win, cfg.q_chunk,
                                            fused=cfg.fused_attention)
            h = h + attention.out_proj(sp["attn"], o)
            h = h + mlp.apply(sp["mlp"], rms_norm(h, sp["mlp_norm"]), cfg)
            new_k.append(ck)
            new_v.append(cv)
            app += 1
    h = rms_norm(h[:, -1:, :], params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
    logits = sharding.logical(logits, ("batch", None, "vocab"))
    new_state = {
        "mamba": stack_layers(new_mamba),
        "attn": {"k": jnp.stack(new_k), "v": jnp.stack(new_v)},
    }
    return logits[:, 0], new_state


def prefill(params, tokens, cfg: ModelConfig, state):
    return _serve_pass(params, tokens, 0, state, cfg)


def decode_step(params, token, pos, state, cfg: ModelConfig):
    return _serve_pass(params, token, pos, state, cfg)
