"""Serve a small LM with batched requests through the production serving
path (prefill + donated-state greedy decode) — reduced qwen3 config on CPU;
the same code path serves the full configs on a pod (launch/serve.py).

    PYTHONPATH=src python examples/serve_lm.py [--arch qwen3-0.6b]
"""
import argparse

from repro.launch import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    args = ap.parse_args()
    serve.main(["--arch", args.arch, "--batch", "4",
                "--prompt-len", "64", "--gen", "24"])


if __name__ == "__main__":
    main()
