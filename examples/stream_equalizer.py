"""Multi-instance stream equalization — the paper's §5.3 hardware path:

    OGM (overlap) → SSM tree (split) → N_i × CNN → MSM (merge) → ORM

run two ways: (1) the pure-JAX reference (any machine), and (2) the
TPU-native halo-exchange shard_map over N_i fake CPU devices (this script
re-executes itself with XLA_FLAGS to get the device pool).

    PYTHONPATH=src python examples/stream_equalizer.py [--instances 8]
"""
import argparse
import os
import subprocess
import sys


def main_inner(n_inst: int):
    import jax
    import jax.numpy as jnp
    from repro.channels import imdd
    from repro.core import equalizer as eq
    from repro.core import seqlen_opt, stream_partition as sp
    from repro.core import timing_model as tm
    from repro.core.engine import EqualizerEngine
    from repro.parallel import halo

    key = jax.random.PRNGKey(0)
    cfg = eq.CNNEqConfig()
    params = eq.init(key, cfg)
    # the production inference path: BN-folded, fused Pallas kernel,
    # autotuned tiling ("auto" backend upgrades to int8 when QAT formats
    # are present in params)
    engine = EqualizerEngine.from_params(params, eq.init_bn_state(cfg), cfg,
                                         backend="auto", tile_m="auto")

    n_syms = 1024 * n_inst
    rx, _ = imdd.simulate(key, imdd.IMDDConfig(), n_syms)

    y_single = engine(rx)
    y_ref = sp.partitioned_apply(engine, rx, n_inst, cfg)
    mesh = jax.make_mesh((n_inst,), ("data",))
    y_halo = halo.halo_apply(engine, rx, cfg, mesh)
    o = sp.overlap_symbols(cfg)
    err_ref = float(jnp.max(jnp.abs(y_ref[o:-o] - y_single[o:-o])))
    err_halo = float(jnp.max(jnp.abs(y_halo[o:-o] - y_single[o:-o])))
    print(f"{n_inst} instances over {len(jax.devices())} devices "
          f"(engine: {engine.describe()}):")
    print(f"  split-tree reference vs single instance (interior): "
          f"max err {err_ref:.2e}")
    print(f"  halo-exchange shard_map vs single instance (interior): "
          f"max err {err_halo:.2e}")

    hw = tm.fpga_profile(cfg)
    if tm.max_throughput(hw, n_inst) > 80e9:
        l_inst = seqlen_opt.optimal_l_inst(cfg, hw, n_inst, 80e9)
        print(f"  ℓ_inst for 80 GSa/s: {l_inst} "
              f"(λ = {tm.symbol_latency(cfg, hw, n_inst, l_inst)*1e6:.1f} µs)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--instances", type=int, default=8)
    ap.add_argument("--inner", action="store_true")
    args = ap.parse_args()
    if args.inner:
        main_inner(args.instances)
    else:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                            f"{args.instances}")
        sys.exit(subprocess.run(
            [sys.executable, __file__, "--inner",
             "--instances", str(args.instances)], env=env).returncode)
