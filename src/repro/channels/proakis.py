"""Simulated magnetic-recording channel (paper §2.2): Proakis-B.

h_ch = [0.407, 0.815, 0.407] (severe linear ISI, spectral null), RC pulse
shaping, AWGN, oversampling N_os = 2 — exactly the paper's setup (SNR 20 dB).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .common import awgn, bits_to_pam, fir_same, rc_taps, upsample

PROAKIS_B = (0.407, 0.815, 0.407)


@dataclasses.dataclass(frozen=True)
class ProakisConfig:
    n_os: int = 2
    rc_beta: float = 0.3
    rc_taps: int = 65
    snr_db: float = 20.0
    levels: int = 2


@functools.partial(jax.jit, static_argnames=("cfg", "n_syms"))
def simulate(key: jax.Array, cfg: ProakisConfig, n_syms: int):
    """Returns (rx[n_syms*n_os], syms[n_syms]) like imdd.simulate."""
    kbits, knoise = jax.random.split(key)
    syms = jax.random.randint(kbits, (n_syms,), 0, cfg.levels)
    amps = bits_to_pam(syms, cfg.levels)

    # pulse shaping at N_os
    taps = jnp.asarray(rc_taps(cfg.rc_taps, cfg.rc_beta, cfg.n_os))
    x = upsample(amps, cfg.n_os)
    x = fir_same(x, taps)

    # channel impulse response operates at symbol rate; at N_os we interleave
    # by upsampling h (zero-stuffed) so ISI couples neighbouring symbols.
    h = jnp.asarray(PROAKIS_B, dtype=jnp.float32)
    h_os = upsample(h, cfg.n_os)[: 2 * cfg.n_os + 1]
    y = fir_same(x, h_os)

    y = awgn(knoise, y, cfg.snr_db)
    y = (y - jnp.mean(y)) / (jnp.std(y) + 1e-9)
    return y, syms
