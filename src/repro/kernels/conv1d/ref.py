"""Pure-jnp oracle for the strided 1-D convolution kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
           stride: int) -> jnp.ndarray:
    """VALID strided 1-D convolution (cross-correlation, like the FPGA MACs).

    x: (B, C_in, W)   w: (C_out, C_in, K)   b: (C_out,)
    → (B, C_out, (W - K)//stride + 1)
    """
    y = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride,), padding="VALID",
        dimension_numbers=("NCH", "OIH", "NCH"))
    return (y + b.astype(jnp.float32)[None, :, None]).astype(x.dtype)
