from .cnn_eq import (cnn_eq_fused, cnn_eq_fused_int8, quantize_weights_int8,
                     receptive_halo)
from .ops import equalize, strides_of, weights_of
from .ref import cnn_eq as cnn_eq_ref
from .ref import cnn_eq_quant as cnn_eq_quant_ref

__all__ = ["cnn_eq_fused", "cnn_eq_fused_int8", "cnn_eq_ref",
           "cnn_eq_quant_ref", "equalize", "quantize_weights_int8",
           "receptive_halo", "strides_of", "weights_of"]
