"""Pure-jnp oracle for the fused sLSTM recurrence kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def slstm(xg: jnp.ndarray, r: jnp.ndarray, state):
    """Stabilized sLSTM over time (the oracle the kernel must match).

    xg: (B, S, 4, d) input pre-activations [z, i, f, o];
    r:  (4, H, dh, dh) block-diagonal recurrent weights (d = H·dh);
    state: (c, n, h, m) each (B, d) f32.
    Returns (hs (B, S, d) f32, new_state).
    """
    bb, s, _, d = xg.shape
    g, nh, dh, _ = r.shape
    rf = r.astype(jnp.float32)

    def step(carry, x_t):
        c, n, h, m = carry
        hh = h.reshape(bb, nh, dh)
        rec = jnp.einsum("bhd,ghde->bghe", hh, rf).reshape(bb, 4, d)
        pre = x_t.astype(jnp.float32) + rec
        z = jnp.tanh(pre[:, 0])
        i_pre, f_pre = pre[:, 1], pre[:, 2]
        o = jax.nn.sigmoid(pre[:, 3])
        m_new = jnp.maximum(f_pre + m, i_pre)
        i_s = jnp.exp(i_pre - m_new)
        f_s = jnp.exp(f_pre + m - m_new)
        c_new = f_s * c + i_s * z
        n_new = f_s * n + i_s
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, h_new, m_new), h_new

    (c, n, h, m), hs = jax.lax.scan(step, state,
                                    jnp.moveaxis(xg, 1, 0))
    return jnp.moveaxis(hs, 0, 1), (c, n, h, m)
