"""Engine throughput trajectory — ref vs fused_fp32/bf16/int8.

Measures end-to-end symbols/sec of every `EqualizerEngine` backend on both
DOP operating points (equalizer_ht, equalizer_lp) and writes a
machine-readable `BENCH_engine.json` at the repo root, so future PRs have a
perf baseline to regress against (the paper's headline is exactly this
number: the quantized fused datapath's symbol rate).

The int8 backend runs with Q2.5 weight / Q3.4 activation formats — the
paper's learned formats land in this range for moderate QLFs (Fig. 6).
On a CPU host the kernels execute in interpret mode, so ABSOLUTE rates are
not meaningful across machines; the per-backend RATIOS and their evolution
over PRs are the tracked signal.
"""
from __future__ import annotations

import json
import pathlib

import jax
import jax.numpy as jnp

from repro.configs import equalizer_ht as HT
from repro.configs import equalizer_lp as LP
from repro.core import equalizer as eq
from repro.core.autotune import time_callable
from repro.core.engine import BACKENDS, EqualizerEngine

from .common import Bench

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT_PATH = REPO_ROOT / "BENCH_engine.json"

INT8_FORMATS = {"w_int": 2, "w_frac": 5, "a_int": 3, "a_frac": 4}


def _qat_params(cfg, key):
    params = eq.init(key, cfg)
    params["qat"] = {
        f"layer{i}": {k: jnp.asarray(float(v))
                      for k, v in INT8_FORMATS.items()}
        for i in range(cfg.layers)}
    return params


def _throughput(engine, x, n_syms: int, iters: int = 5) -> float:
    # best-of-3 five-iteration means: stable enough for the 10% --check gate
    return max(n_syms / time_callable(engine, x, iters=iters)
               for _ in range(3))


def run(n_syms: int = 1 << 15, tile_m: int = 64,
        out_path: pathlib.Path | None = OUT_PATH) -> dict:
    bench = Bench("engine_throughput", "§7 deployment path")
    key = jax.random.PRNGKey(0)
    configs = {"equalizer_ht": HT.CNN, "equalizer_lp": LP.CNN}
    report = {"n_syms": n_syms, "tile_m": tile_m,
              "backend_default": jax.default_backend(), "configs": {}}

    for name, cfg in configs.items():
        params = _qat_params(cfg, key)
        bn = eq.init_bn_state(cfg)
        x = jax.random.normal(key, (1, n_syms * cfg.n_os))
        rates = {}
        for backend in BACKENDS:
            engine = EqualizerEngine.from_params(params, bn, cfg,
                                                 backend=backend,
                                                 tile_m=tile_m)
            rates[backend] = _throughput(engine, x, n_syms)
        report["configs"][name] = {
            "syms_per_s": rates,
            "int8_formats": INT8_FORMATS,
            "speedup_fused_fp32_vs_ref":
                rates["fused_fp32"] / rates["ref"],
            "speedup_fused_bf16_vs_ref":
                rates["fused_bf16"] / rates["ref"],
            "speedup_fused_int8_vs_ref":
                rates["fused_int8"] / rates["ref"],
        }
        print(f"[bench_engine] {name}: " + ", ".join(
            f"{b}={r:,.0f} sym/s" for b, r in rates.items()))

    if out_path is not None:
        out_path.write_text(json.dumps(report, indent=2))
        print(f"[bench_engine] wrote {out_path}")
    bench.record("report", report)
    return bench.finish()


if __name__ == "__main__":
    run()
