"""Linear feedforward (FIR) equalizer baseline (paper §3.2).

y_i = Σ_{m=-M*}^{M*} x_{i+m} · w(m + M*),  M* = ⌊M/2⌋.

With oversampling N_os=2, every second output sample is a symbol estimate.
Trained with MSE + Adam exactly like the CNN.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class FIRConfig:
    taps: int = 25           # M
    n_os: int = 2
    levels: int = 2

    def mac_per_symbol(self) -> float:
        # M MACs per output sample; N_os samples per symbol, but only every
        # N_os-th output is a symbol → M · N_os inputs processed per symbol
        # at symbol rate the filter runs once per sample: M · N_os MACs/sym?
        # The paper counts MACs to compute ONE output symbol = M (the filter
        # output at the symbol instant).
        return float(self.taps)


def init(key: jax.Array, cfg: FIRConfig) -> Dict[str, jnp.ndarray]:
    w = jnp.zeros((cfg.taps,), jnp.float32)
    # centre-spike initialization (identity-ish start helps convergence)
    w = w.at[cfg.taps // 2].set(1.0)
    return {"w": w, "b": jnp.zeros((), jnp.float32)}


def apply(params: Dict[str, jnp.ndarray], x: jnp.ndarray,
          cfg: FIRConfig) -> jnp.ndarray:
    """x: waveform (S·N_os,) or (batch, S·N_os) → symbol estimates (…, S)."""
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None]
    k = cfg.taps
    pad = (k // 2, k - 1 - k // 2)
    w = params["w"][None, None, :]  # (C_out=1, C_in=1, K)
    y = jax.lax.conv_general_dilated(
        x[:, None, :], w, window_strides=(cfg.n_os,), padding=[pad],
        dimension_numbers=("NCH", "OIH", "NCH"))[:, 0, :]
    y = y + params["b"]
    return y[0] if squeeze else y
