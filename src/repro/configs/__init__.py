"""Architecture registry: --arch <id> → ModelConfig."""
from __future__ import annotations

import dataclasses

from . import (deepseek_7b, internlm2_1_8b, llava_next_34b, mixtral_8x22b,
               moonshot_v1_16b_a3b, qwen3_0_6b, smollm_135m, whisper_large_v3,
               xlstm_125m, zamba2_1_2b)
from .shapes import LONG_CONTEXT_ARCHS, SHAPES, ShapeSpec, long_500k_runnable

_MODULES = {
    "internlm2-1.8b": internlm2_1_8b,
    "deepseek-7b": deepseek_7b,
    "smollm-135m": smollm_135m,
    "qwen3-0.6b": qwen3_0_6b,
    "llava-next-34b": llava_next_34b,
    "mixtral-8x22b": mixtral_8x22b,
    "moonshot-v1-16b-a3b": moonshot_v1_16b_a3b,
    "zamba2-1.2b": zamba2_1_2b,
    "xlstm-125m": xlstm_125m,
    "whisper-large-v3": whisper_large_v3,
}

ARCHS = tuple(_MODULES)


def get_config(arch: str, reduced: bool = False, **overrides):
    if arch not in _MODULES:
        raise ValueError(f"unknown arch {arch!r}; choose from {ARCHS}")
    cfg = _MODULES[arch].REDUCED if reduced else _MODULES[arch].CONFIG
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg
