from .conv1d import conv1d as conv1d_pallas
from .ops import conv1d_same_lower
from .ref import conv1d as conv1d_ref

__all__ = ["conv1d_pallas", "conv1d_ref", "conv1d_same_lower"]
