"""deepseek-7b — dense llama-arch transformer [arXiv:2401.02954; hf].

30L · d_model 4096 · 32 heads (GQA kv=32, i.e. MHA) · d_ff 11008 ·
vocab 102400.
"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b", family="dense",
    n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=11008, vocab=102400,
    tp=16, train_accum=8,
)

REDUCED = ModelConfig(
    name="deepseek-reduced", family="dense",
    n_layers=3, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=344, vocab=512, dtype="float32",
)
