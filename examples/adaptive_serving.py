"""Online adaptation under channel drift — the repro.adapt runtime.

Two tenants stream the SAME drifting Proakis-B magnetic-recording channel
(tap rotation + SNR ramp, `repro.channels.drift`) through one serving
runtime, both starting from one equalizer trained on the pre-drift
channel:

  * "frozen"   — served as-is; its BER degrades as the channel drifts
                 away from what it was trained for;
  * "adaptive" — attached to an `OnlineAdapter`: served traffic is tapped
                 into a sample buffer (pilot labels here — the load
                 generator knows the tx symbols), a background fine-tune
                 resumes training from the live weights, a shadow
                 evaluator scores each candidate on held-out traffic, and
                 winning candidates hot-swap into the live stream at a
                 chunk boundary (bitwise-per-epoch — docs/ADAPTATION.md).

The printed per-burst BER trajectories show the story: both tenants track
each other until the ramp, the frozen tenant falls off a cliff, the
adaptive one recovers within a few bursts of the first promotion.

    PYTHONPATH=src python examples/adaptive_serving.py \
        [--bursts 26] [--train-steps 600] [--driver sync|async]
"""
import argparse

import jax
import numpy as np

from repro.adapt import (AdaptPolicy, FineTuneConfig, OnlineAdapter,
                         PromotionPolicy, engine_ber, hard_decide)
from repro.channels.drift import DriftingProakis, DriftSchedule
from repro.core import equalizer as eq
from repro.core.train_eq import EqTrainConfig, train_equalizer
from repro.serve import (AsyncServeRuntime, BatchPolicy, ServeRuntime,
                         TenantSpec, drift_streams, replay_adaptive)

CFG = eq.CNNEqConfig()


def burst_ber(soft, pilots):
    decided = hard_decide(np.asarray(soft), CFG.levels)
    out, pos = [], 0
    for true in pilots:
        n = min(int(true.shape[0]), decided.shape[0] - pos)
        if n <= 0:
            break
        out.append(float(np.mean(decided[pos:pos + n] != true[:n])))
        pos += n
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bursts", type=int, default=26)
    ap.add_argument("--syms-per-burst", type=int, default=2048)
    ap.add_argument("--train-steps", type=int, default=600)
    ap.add_argument("--driver", choices=("sync", "async"), default="sync")
    args = ap.parse_args(argv)

    channel = DriftingProakis()
    print(f"training the shared base equalizer on the pre-drift channel "
          f"({args.train_steps} steps)…")
    params, bn, info = train_equalizer(
        jax.random.PRNGKey(0), "cnn", CFG, channel.at(0.0),
        EqTrainConfig(steps=args.train_steps, eval_syms=1 << 14))
    print(f"  pre-drift BER: {info['ber']:.3e}")

    rt = (AsyncServeRuntime if args.driver == "async" else ServeRuntime)(
        BatchPolicy(max_batch=2, max_wait_s=1e9))
    adapter = OnlineAdapter(
        rt,
        AdaptPolicy(min_train_syms=3072, adapt_every_syms=3072,
                    eval_capacity=8192,
                    promotion=PromotionPolicy(min_eval_syms=1024,
                                              eval_bucket_syms=512)),
        FineTuneConfig(steps=200, batch=8, seq_syms=256, lr=3e-3))

    def spec(tid):
        return TenantSpec(tid, CFG, params=params, bn_state=bn,
                          backend="fused_fp32", tile_m=16)

    rt.open(spec("frozen"))
    sess = adapter.attach(spec("adaptive"))

    sched = DriftSchedule(hold_bursts=4, ramp_bursts=6)
    streams, pilots = drift_streams(
        channel, sched, ["frozen", "adaptive"], n_bursts=args.bursts,
        syms_per_burst=args.syms_per_burst, seed=3)
    print(f"replaying {args.bursts} bursts × {args.syms_per_burst} syms "
          f"(drift settles at burst {sched.total_to_settle}) "
          f"on {type(rt).__name__}…")
    replay_adaptive(rt, streams, pilots=pilots, adapter=adapter,
                    step_every=2)

    traj_f = burst_ber(rt.output("frozen"), pilots["frozen"])
    traj_a = burst_ber(rt.output("adaptive"), pilots["adaptive"])
    # swap_log positions are engine passes (V_p symbols each)
    swaps = {pos * CFG.v_parallel // args.syms_per_burst
             for _, pos in sess.swap_log[1:]}
    print(f"\n  burst    t    frozen BER   adaptive BER")
    for b, (bf, ba) in enumerate(zip(traj_f, traj_a)):
        mark = "  ← weights hot-swapped" if b in swaps else ""
        print(f"  {b:5d}  {sched.t_at(b):4.2f}   {bf:10.4f}   "
              f"{ba:10.4f}{mark}")

    rx1, sy1 = channel.at(1.0)(jax.random.PRNGKey(77), 1 << 14)
    rx1, sy1 = np.asarray(rx1), np.asarray(sy1)
    bf = engine_ber(rt.sessions.get("frozen").engine, rx1, sy1)
    ba = engine_ber(sess.engine, rx1, sy1)
    actions = [r.action for r in adapter.history if r.action != "idle"]
    print(f"\npost-drift (fresh t=1 data): frozen {bf:.3e} vs adaptive "
          f"{ba:.3e} ({bf / max(ba, 1e-4):,.0f}x better)")
    print(f"adaptation actions: {actions}")
    print(f"weight epochs (epoch, start position): {sess.swap_log}")
    if args.driver == "async":
        rt.shutdown()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
