"""Benchmark orchestrator: `PYTHONPATH=src python -m benchmarks.run`.

One benchmark per paper table/figure (see DESIGN.md §6):

    bench_dse       Fig. 2   DSE: CNN vs FIR vs Volterra on IM/DD
    bench_proakis   Fig. 4   the same on the magnetic-recording channel
    bench_quant     Fig. 5/6 3-phase QAT bit-width/BER curves per QLF
    bench_dop       Fig. 8   flexible-DOP study (TPU tile-utilization axis)
    bench_stream    Fig. 9/§7.2  64-instance stream partitioning
    bench_engine    §7       engine backend throughput → BENCH_engine.json
    bench_serve     §5.3     multi-tenant serving → BENCH_serve.json
    bench_timing    Fig. 12  timing model vs simulated measurement
    bench_platform  Fig. 13-15  CPU measured / TPU roofline-projected
    bench_roofline  Table 1 / §Roofline  aggregate the dry-run artifacts

`--full` runs paper-scale sweeps (hours); the default is a reduced pass
whose orderings (not absolute BERs) carry the claims.

`--check` is the perf-regression gate: it re-measures bench_engine and
bench_serve (without overwriting the committed baselines) and exits
non-zero if any tracked throughput fell more than 10% below the
`BENCH_engine.json` / `BENCH_serve.json` committed at the repo root.
Compare like with like: the committed baseline must come from the same
host class (CPU hosts run the kernels in interpret mode).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time
import traceback

from . import (bench_dop, bench_dse, bench_engine, bench_platform,
               bench_proakis, bench_quant, bench_roofline, bench_serve,
               bench_stream, bench_timing)
from .common import REPORT_DIR

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _engine_rates(rep: dict) -> dict:
    return {f"engine/{c}/{b}": r
            for c, e in rep.get("configs", {}).items()
            for b, r in e.get("syms_per_s", {}).items()}


def _serve_rates(rep: dict) -> dict:
    return {f"serve/{c}/N{n}": t["serve"]["agg_syms_per_s"]
            for c, e in rep.get("configs", {}).items()
            for n, t in e.get("tenants", {}).items()}


def check(tol: float = 0.10) -> int:
    """Regress fresh engine/serve throughput against committed baselines."""
    gates = (
        ("engine", REPO_ROOT / "BENCH_engine.json",
         lambda: bench_engine.run(out_path=None), _engine_rates),
        ("serve", REPO_ROOT / "BENCH_serve.json",
         lambda: bench_serve.run(out_path=None), _serve_rates))
    # validate the configuration before burning minutes of re-measurement
    missing = [p.name for _, p, _, _ in gates if not p.exists()]
    if missing:
        print(f"[check] FAIL: no committed baseline(s): {', '.join(missing)}")
        return 2
    failures = []
    compared = 0
    for name, path, bench_fn, extract in gates:
        baseline = extract(json.loads(path.read_text()))
        fresh = extract(bench_fn()["results"]["report"])
        for key in sorted(baseline):
            if key not in fresh:
                print(f"[check] warn: {key} in baseline but not re-measured")
                continue
            compared += 1
            ratio = fresh[key] / baseline[key]
            status = "ok" if ratio >= 1.0 - tol else "REGRESSION"
            print(f"[check] {status}: {key} {fresh[key]:,.0f} vs baseline "
                  f"{baseline[key]:,.0f} sym/s ({ratio:.2f}x)")
            if ratio < 1.0 - tol:
                failures.append(key)
    print(f"[check] {compared} rates compared, {len(failures)} regressions")
    return 1 if failures else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sweeps (hours)")
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument("--check", action="store_true",
                    help="re-measure engine/serve throughput and fail on "
                         ">10%% regression vs the committed BENCH_*.json")
    ap.add_argument("--tol", type=float, default=0.10,
                    help="--check regression tolerance (fraction; raise on "
                         "noisy shared hosts)")
    args = ap.parse_args(argv)

    if args.check:
        return check(tol=args.tol)

    steps = 700 if not args.full else 10_000
    jobs = [
        ("timing", lambda: bench_timing.run()),
        ("engine", lambda: bench_engine.run()),
        ("serve", lambda: bench_serve.run()),
        ("stream", lambda: bench_stream.run()),
        ("dop", lambda: bench_dop.run()),
        ("roofline", lambda: bench_roofline.run()),
        ("platform", lambda: bench_platform.run()),
        ("proakis", lambda: bench_proakis.run(steps=min(steps, 800))),
        ("quant", lambda: bench_quant.run(steps=min(steps, 600))),
        ("dse", lambda: bench_dse.run(full=args.full, steps=steps)),
    ]
    if args.only:
        jobs = [(n, f) for n, f in jobs if n in args.only]

    t0 = time.time()
    failures = []
    summary = {}
    for name, fn in jobs:
        print(f"\n=== bench:{name} " + "=" * 50)
        try:
            out = fn()
            summary[name] = {"status": "ok",
                             "elapsed_s": out.get("elapsed_s")}
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failures.append(name)
            summary[name] = {"status": f"failed: {e}"}
    summary["total_elapsed_s"] = round(time.time() - t0, 1)
    REPORT_DIR.mkdir(parents=True, exist_ok=True)
    (REPORT_DIR / "benchmarks_summary.json").write_text(
        json.dumps(summary, indent=2))
    print("\n=== benchmark summary ===")
    for k, v in summary.items():
        print(f"  {k}: {v}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
