"""Shared model machinery: config, norms, RoPE, init helpers."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"        # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 256
    vocab: int = 1024
    d_head: int = 0              # 0 → d_model // n_heads
    qk_norm: bool = False
    window: int = 0              # sliding-window attention (0 = full)
    rope_theta: float = 1e4
    mlp_act: str = "silu"        # silu (gated) | gelu (2-matrix)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM / hybrid / xlstm
    ssm_state: int = 0
    d_conv: int = 4
    expand: int = 2
    ssm_head: int = 64           # mamba2 head dim P
    attn_every: int = 0          # zamba2: shared attention block period
    slstm_at: Tuple[int, ...] = ()
    # enc-dec (whisper)
    enc_layers: int = 0
    enc_len: int = 0
    # vlm (llava)
    img_tokens: int = 0
    # numerics / parallelism
    dtype: str = "bfloat16"
    tp: int = 1                  # tensor-parallel degree for head padding
    remat: bool = True
    scan_layers: bool = True
    moe_group: int = 2048        # tokens per MoE dispatch group
    train_accum: int = 1         # gradient-accumulation microbatches (train_4k)
    serve_fsdp: bool = False     # serve with 2-D-sharded params (see sharding.py)
    fused_attention: bool = False  # flash-attention Pallas kernel (§Perf it. 3)
    serve_int8_weights: bool = False  # int8 weight gathers at serve (§Perf it. 5)
    q_chunk: int = 1024          # query chunking for causal attention
    ssd_chunk: int = 64          # chunk length for SSD / chunkwise mLSTM
    # long-context handling: quadratic attention refuses seq > this unless
    # window/ssm makes it sub-quadratic (DESIGN.md long_500k policy)
    max_full_attn_seq: int = 65536
    # long-context decode: cap attention scope (hybrid archs fall back to a
    # sliding window in shared-attn blocks for long_500k — DESIGN.md §9)
    decode_window: int = 0       # 0 = full cache

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to the 128-lane boundary (Megatron-style padding;
        only whisper's 51866 actually pads). Loss masks the padded tail."""
        return ((self.vocab + 127) // 128) * 128

    def param_dtype(self):
        return jnp.dtype(self.dtype)

    def n_params_dense(self) -> int:
        """Rough parameter count (for MODEL_FLOPS = 6·N·D)."""
        d, f, v, l = self.d_model, self.d_ff, self.vocab, self.n_layers
        hq = self.n_heads * self.head_dim
        hkv = self.n_kv_heads * self.head_dim
        attn = d * hq + 2 * d * hkv + hq * d
        if self.n_experts:
            mlp_dense = 0
            moe = self.n_experts * (3 * d * f) + d * self.n_experts
        else:
            mlp_dense = 3 * d * f if self.mlp_act == "silu" else 2 * d * f
            moe = 0
        return l * (attn + mlp_dense + moe) + 2 * v * d

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        if not self.n_experts:
            return self.n_params_dense()
        d, f, v, l = self.d_model, self.d_ff, self.vocab, self.n_layers
        hq = self.n_heads * self.head_dim
        hkv = self.n_kv_heads * self.head_dim
        attn = d * hq + 2 * d * hkv + hq * d
        act = self.top_k * (3 * d * f) + d * self.n_experts
        return l * (attn + act) + 2 * v * d


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------

def _rms_norm_impl(x: jnp.ndarray, scale: jnp.ndarray,
                   eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True)
                          + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


@jax.custom_vjp
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """RMSNorm with f32 internal math and STREAM-DTYPE cotangents.

    §Perf iteration 1: bf16 cotangents (no measured change — kept for the
    numerics contract). Iteration 7 tried stream-dtype ELEMENTWISE math as
    well and measured WORSE traffic (internlm2 train t_mem 5785 → 7678 ms):
    XLA fuses the f32 chain into the surrounding fusions efficiently, and
    the extra converts broke that fusion — REVERTED to this form.
    """
    return _rms_norm_impl(x, scale)


def _rms_fwd(x, scale):
    return _rms_norm_impl(x, scale), (x, scale)


def _rms_bwd(res, g):
    x, scale = res
    eps = 1e-6
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True) + eps
    r = jax.lax.rsqrt(ms)
    xhat = xf * r
    gs = gf * scale.astype(jnp.float32)
    dx = r * (gs - xhat * jnp.mean(gs * xhat, axis=-1, keepdims=True))
    dscale = jnp.sum(gf * xhat, axis=tuple(range(x.ndim - 1)))
    return dx.astype(x.dtype), dscale.astype(scale.dtype)


rms_norm.defvjp(_rms_fwd, _rms_bwd)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: (B, S, H, D), positions: (B, S) or (S,)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) *
                    (jnp.log(theta) / half))
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------

def dense_init(key: jax.Array, shape: Tuple[int, ...], dtype,
               scale: Optional[float] = None) -> jnp.ndarray:
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else 1.0 / jnp.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


def stack_layers(layer_params: list) -> Any:
    """[{...}, {...}] → {...} with leading layer dim (for lax.scan)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *layer_params)
