"""Unified observability layer (repro.obs) — the ISSUE-8 acceptance
surface.

  * metrics units: Counter/Gauge/Histogram semantics, hierarchical name
    validation, get-or-create type conflicts, lazy callbacks, Scope
    prefixing, snapshot tree merge, JSON and Prometheus exporters;
  * tracer units: disabled no-ops, per-tenant sequence numbers, idempotent
    sealing, the bounded sealed-span ring, Chrome `trace_event` export;
  * OBSERVATION CHANGES NOTHING: the sync runtime and the chaos sweeps
    run with tracing ON and must stay bitwise-equal to offline — and every
    emitted chunk must carry exactly one complete sealed span (no orphans,
    no duplicates), retries/replays/migrations visible as child events;
  * a device-loss fleet migration exports a Chrome trace whose migrated
    chunks carry the full span chain including the migration event;
  * retention: `Session.swap_log`, the scheduler's completed-request
    window, error deques, and the trace ring are all bounded by one
    `Retention` policy (steady memory under unbounded streams);
  * injectable clocks everywhere: a frozen clock yields all-zero latency
    telemetry on both the sync runtime and the fleet (no wall-time leaks);
  * legacy `stats()` schemas stay as thin wrappers over the snapshot tree
    (`errors_total` normalized across runtimes);
  * the `repro.obs.report` console renderer and CLI.
"""
import json
import threading

import jax
import numpy as np
import pytest

from repro.core import equalizer as eq
from repro.obs import (ChunkSpan, Counter, Gauge, Histogram, MetricsRegistry,
                       Observability, PHASES, Retention, Tracer)
from repro.obs.report import main as report_main, render
from repro.serve import (AsyncServeRuntime, BatchPolicy, Fault, FaultPlan,
                         FleetRuntime, ServeRuntime, TenantSpec, chop)

CFG = eq.CNNEqConfig()
INT8_FMT = tuple((2, 5, 3, 4) for _ in range(CFG.layers))


def _weights(seed, cfg=CFG):
    params = eq.init(jax.random.PRNGKey(seed), cfg)
    folded = eq.fold_bn(params, eq.init_bn_state(cfg), cfg)
    return eq.folded_weights(folded)


def _spec(tid, backend, seed, tile_m=32, priority=0):
    return TenantSpec(
        tid, CFG, weights=_weights(seed),
        formats=INT8_FMT if backend == "fused_int8" else None,
        backend=backend, tile_m=tile_m, priority=priority)


def _offline(spec, wave):
    import jax.numpy as jnp
    return np.asarray(spec.build_engine()(jnp.asarray(wave[None])))[0]


def _wave(seed, n_syms):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(n_syms * CFG.n_os).astype(np.float32)


def _policy():
    return BatchPolicy(max_batch=3, max_wait_s=1e9)


def _assert_span_chains(tracer, emitted_syms):
    """Every emitted chunk → exactly one COMPLETE sealed span.

    `emitted_syms` maps tenant → total symbols its stream emitted; the ok
    spans' `n_emit` positions (v_parallel symbols each) must account for
    the whole stream exactly once — a missing span (orphan chunk) comes
    up short, a duplicated span overshoots. (Submit calls are NOT 1:1
    with spans: a small jittered submit may buffer without crossing an
    emittable-position boundary, so no plan — and no span — exists for
    it.) Also: (tenant, seq) unique, seqs gapless, no unsealed leaks."""
    assert tracer.spans_started == tracer.spans_sealed
    spans = tracer.sealed_spans()
    keys = [(s.tenant, s.seq) for s in spans]
    assert len(keys) == len(set(keys)), "duplicate spans"
    by_tenant = {}
    for s in spans:
        by_tenant.setdefault(s.tenant, []).append(s)
    assert set(by_tenant) == set(emitted_syms)
    for t, sp in by_tenant.items():
        assert sorted(s.seq for s in sp) == list(range(len(sp)))
        ok = [s for s in sp if s.status == "ok"]
        for s in ok:
            assert s.complete(), (t, s.seq, s.marks)
            assert s.n_emit > 0
        assert (sum(s.n_emit for s in ok) * CFG.v_parallel
                == emitted_syms[t]), t
    return spans


# ---------------------------------------------------------------------------
# metrics units
# ---------------------------------------------------------------------------

def test_counter_gauge_histogram_semantics():
    c = Counter()
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(ValueError, match="n >= 0"):
        c.inc(-1)

    g = Gauge()
    g.set(2.5)
    g.add(-1.0)
    assert g.value == 1.5

    h = Histogram(window=4)
    for v in (1.0, 2.0, 3.0, 4.0, 5.0):      # 1.0 falls out of the window
        h.observe(v)
    s = h.summary()
    assert s["count"] == 5 and s["sum"] == 15.0      # lifetime
    assert s["min"] == 1.0 and s["max"] == 5.0       # lifetime extrema
    assert s["window"] == 4 and s["p50"] == 3.5      # windowed quantiles
    assert h.quantile(0.0) == 2.0 and h.quantile(1.0) == 5.0
    assert np.isnan(Histogram().quantile(0.5))
    assert Histogram().summary() == {"count": 0, "sum": 0.0}
    with pytest.raises(ValueError, match="window"):
        Histogram(window=0)


def test_registry_names_conflicts_and_scopes():
    reg = MetricsRegistry()
    with pytest.raises(ValueError, match="bad metric name"):
        reg.counter("has space")
    with pytest.raises(ValueError, match="bad metric name"):
        reg.counter("trailing.")

    c = reg.counter("serve.requests_total")
    assert reg.counter("serve.requests_total") is c    # get-or-create
    with pytest.raises(ValueError, match="already registered as Counter"):
        reg.gauge("serve.requests_total")
    reg.callback("serve.pending", lambda: 3)
    with pytest.raises(ValueError, match="as a callback"):
        reg.counter("serve.pending")
    with pytest.raises(ValueError, match="as an instrument"):
        reg.callback("serve.requests_total", lambda: 0)

    w0 = reg.scope("fleet").scope("worker0")
    w0.counter("launches_total").inc(2)
    assert "fleet.worker0.launches_total" in reg.names()


def test_snapshot_tree_exporters_and_callback_errors():
    reg = MetricsRegistry(clock=lambda: 0.0)
    reg.counter("serve.requests_total").inc(7)
    reg.gauge("serve.occupancy").set(0.5)
    reg.histogram("serve.launch.latency_s").observe(1.0)
    # an instrument and a callback SHARING a subtree merge, not clobber
    reg.histogram("serve.pool.build_s").observe(0.25)
    reg.callback("serve.pool", lambda: {"hits": 3, "misses": 1})
    reg.callback("serve.broken", lambda: 1 / 0)

    snap = reg.snapshot()
    assert snap["serve"]["requests_total"] == 7
    assert snap["serve"]["launch"]["latency_s"]["count"] == 1
    pool = snap["serve"]["pool"]
    assert pool["hits"] == 3 and pool["build_s"]["count"] == 1
    assert "ZeroDivisionError" in snap["serve"]["broken"]["error"]
    assert snap["meta"]["metric_names"] == 4

    snap2 = json.loads(reg.to_json())                 # JSON round-trips
    assert snap2["serve"]["requests_total"] == 7

    prom = reg.to_prometheus()
    assert "serve_requests_total 7" in prom
    assert "serve_launch_latency_s_p50 1.0" in prom
    assert "serve_pool_hits 3" in prom


# ---------------------------------------------------------------------------
# tracer units
# ---------------------------------------------------------------------------

def test_tracer_disabled_is_inert():
    tr = Tracer(enabled=False)
    assert tr.begin("t0") is None
    tr.seal(None)                                     # no-op, no raise
    tr.instant("hot_swap", tenant="t0")
    assert tr.stats()["spans_started"] == 0
    assert tr.stats()["instants"] == 0


def test_tracer_spans_seal_once_and_ring_bounds():
    tr = Tracer(enabled=True, capacity=3, clock=lambda: 1.0)
    spans = []
    for i in range(5):
        s = tr.begin("t0")
        assert s.seq == i                              # per-tenant seq
        for j, p in enumerate(PHASES):
            s.stamp(p, float(j))
        assert s.complete()
        tr.seal(s)
        tr.seal(s)                                     # idempotent
        spans.append(s)
    assert tr.begin("t1").seq == 0                     # seq is per tenant
    st = tr.stats()
    assert st["spans_sealed"] == 5
    assert st["spans_buffered"] == 3                   # ring bound
    assert tr.spans_dropped == 2
    assert [s.seq for s in tr.sealed_spans("t0")] == [2, 3, 4]

    with pytest.raises(ValueError, match="unknown phase"):
        spans[0].stamp("teleport", 0.0)
    incomplete = ChunkSpan("t2", 0)
    incomplete.stamp("submit", 1.0)
    assert not incomplete.complete()
    # non-monotone marks are not "complete" either
    bad = ChunkSpan("t2", 1)
    for j, p in enumerate(PHASES):
        bad.stamp(p, float(-j))
    assert not bad.complete()


def test_tracer_chrome_export_shape():
    tr = Tracer(enabled=True, clock=lambda: 0.0)
    s = tr.begin("t0")
    for j, p in enumerate(PHASES):
        s.stamp(p, j * 1e-3)
    s.event("retry", 2.5e-3, attempt=1)
    s.n_emit = 120
    tr.seal(s)
    tr.instant("hot_swap", tenant="t0", epoch=1)

    doc = tr.export_chrome()
    ev = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    names = [e["name"] for e in ev]
    assert "chunk t0#0" in names                       # top-level X
    assert names.count("submit") == 1                  # phase children
    assert "retry t0#0" in names                       # span child event
    assert "hot_swap" in names                         # runtime instant
    chunk = next(e for e in ev if e["name"] == "chunk t0#0")
    assert chunk["ph"] == "X" and chunk["dur"] == pytest.approx(5e3)
    assert chunk["args"]["n_emit"] == 120
    # metadata lanes: process plus one thread per tenant
    metas = [e for e in ev if e["ph"] == "M"]
    assert {m["args"]["name"] for m in metas} == {
        "repro.serve", "runtime", "tenant t0"}


def test_retention_validates():
    r = Retention()
    assert (r.latency_window, r.swap_log, r.errors) == (8192, 256, 256)
    with pytest.raises(ValueError, match="swap_log"):
        Retention(swap_log=0)
    with pytest.raises(ValueError, match="trace_capacity"):
        Retention(trace_capacity=-1)


# ---------------------------------------------------------------------------
# observation changes nothing: sync runtime, tracing ON, bitwise
# ---------------------------------------------------------------------------

def test_sync_runtime_tracing_on_stays_bitwise_with_full_chains(tmp_path):
    spec = _spec("t0", "fused_fp32", seed=11)
    wave = _wave(5, 400)
    obs = Observability(tracing=True)
    rt = ServeRuntime(_policy(), obs=obs)
    rt.open(spec)
    chunks = list(chop(wave, 120 * CFG.n_os, seed=3, jitter=0.5))
    for c in chunks:
        rt.submit("t0", c)
    rt.finish("t0")
    rt.drain()
    got = rt.output("t0")
    np.testing.assert_array_equal(got, _offline(spec, wave))

    spans = _assert_span_chains(obs.tracer, {"t0": got.shape[0]})
    assert len(spans) == len(chunks) + 1          # +1: the finish tail

    # registry snapshot observed the run through the same instruments
    snap = obs.snapshot()
    assert snap["serve"]["requests_total"] == len(chunks) + 1
    assert snap["serve"]["launch"]["latency_s"]["count"] == len(chunks) + 1
    assert snap["trace"]["spans_sealed"] == len(chunks) + 1

    # the bundle export writes valid JSON for both artifacts
    obs.export_bundle(str(tmp_path / "run"))
    with open(tmp_path / "run.trace.json") as f:
        doc = json.load(f)
    assert any(e["name"].startswith("chunk t0#")
               for e in doc["traceEvents"])
    with open(tmp_path / "run.snapshot.json") as f:
        assert json.load(f)["serve"]["requests_total"] == len(chunks) + 1


def test_frozen_clock_yields_zero_latency_telemetry_sync():
    """A frozen injectable clock must freeze EVERY latency metric and
    span mark — any nonzero value is a wall-time leak past the clock."""
    frozen = lambda: 42.0                                    # noqa: E731
    spec = _spec("t0", "fused_fp32", seed=12)
    wave = _wave(6, 300)
    obs = Observability(tracing=True, clock=frozen)
    rt = ServeRuntime(_policy(), clock=frozen, obs=obs)
    rt.open(spec)
    for c in chop(wave, 120 * CFG.n_os, seed=0):
        rt.submit("t0", c)
    rt.finish("t0")
    rt.drain()
    np.testing.assert_array_equal(rt.output("t0"), _offline(spec, wave))
    for s in obs.tracer.sealed_spans():
        assert set(s.marks.values()) == {42.0}
    snap = obs.snapshot()["serve"]["launch"]
    for key in ("latency_s", "wait_s", "device_s", "descatter_s"):
        assert snap[key]["max"] == 0.0, key


# ---------------------------------------------------------------------------
# chaos sweeps with tracing ON (the acceptance gates)
# ---------------------------------------------------------------------------

@pytest.mark.chaos
def test_chaos_sweep_with_tracing_bitwise_and_trace_integrity():
    """The ISSUE-6 all-fault-kinds sweep, re-run with tracing ON: every
    stream bitwise, every emitted chunk exactly one complete span, and
    the injected faults visible as retry/replay child events."""
    fp = FaultPlan([
        Fault("launch_delay", 1, delay_s=0.05),
        Fault("launch_error", 2), Fault("launch_error", 3),  # terminal
        Fault("corrupt", 5, mode="saturate"),
        Fault("build_error", 6),
    ])
    backends = ["fused_fp32", "fused_int8"]
    specs = [_spec(f"t{i}", backends[i % 2], seed=200 + i, priority=i)
             for i in range(6)]
    waves = {s.tenant_id: _wave(300 + i, 280 + 16 * i)
             for i, s in enumerate(specs)}
    obs = Observability(tracing=True)
    emitted_syms = {}
    with AsyncServeRuntime(_policy(), launch_retries=1, fault_plan=fp,
                           obs=obs) as rt:
        for s in specs:
            rt.open(s)
        streams = {t: iter(chop(w, 120 * CFG.n_os, seed=i, jitter=0.5))
                   for i, (t, w) in enumerate(sorted(waves.items()))}
        live = set(streams)
        while live:
            for t in sorted(live):
                c = next(streams[t], None)
                if c is None:
                    live.discard(t)
                    rt.finish(t)
                else:
                    rt.submit(t, c)
        rt.drain()
        for s in specs:
            got = rt.output(s.tenant_id)
            want = _offline(s, waves[s.tenant_id])
            assert got.shape == want.shape           # exactly-once emission
            np.testing.assert_array_equal(got, want)
            emitted_syms[s.tenant_id] = got.shape[0]
        st = rt.stats()
        assert st["recovery"]["sessions_poisoned"] == 0
        assert st["errors_total"] == st["errors"]    # normalized schema

    assert fp.pending == 0
    spans = _assert_span_chains(obs.tracer, emitted_syms)
    events = [name for s in spans for (name, _, _) in s.events]
    assert "retry" in events                   # injected faults left marks
    assert "replay" in events
    # engine-build instants cover the opens plus the failover rebuilds
    builds = [i for i in obs.tracer.instants if i[0] == "engine_build"]
    assert len(builds) >= len(specs) + 1


@pytest.mark.chaos
def test_fleet_migration_chrome_trace_has_complete_chains():
    """Device-loss migration on a 2-worker fleet with tracing ON: streams
    stay bitwise, spans survive the worker handoff, and the exported
    Chrome trace carries the full chain of a migrated chunk INCLUDING its
    migration child event and the fleet-level instants."""
    fp = FaultPlan([Fault("device_lost", at=0, after=2)])
    specs = [_spec(f"t{i}", ("fused_fp32", "fused_int8")[i % 2],
                   seed=200 + i, priority=i) for i in range(4)]
    waves = {s.tenant_id: _wave(300 + i, 280 + 16 * i)
             for i, s in enumerate(specs)}
    obs = Observability(tracing=True)
    with FleetRuntime(n_workers=2, policy=_policy(), launch_retries=1,
                      fault_plan=fp, obs=obs) as rt:
        for s in specs:
            rt.open(s)
        streams = {t: iter(chop(w, 120 * CFG.n_os, seed=i, jitter=0.5))
                   for i, (t, w) in enumerate(sorted(waves.items()))}
        live = set(streams)
        while live:
            for t in sorted(live):
                c = next(streams[t], None)
                if c is None:
                    live.discard(t)
                    rt.finish(t)
                else:
                    rt.submit(t, c)
        rt.drain()
        outputs = {s.tenant_id: rt.output(s.tenant_id) for s in specs}
        st = rt.stats()
        snap = obs.snapshot()

    for s in specs:
        want = _offline(s, waves[s.tenant_id])
        np.testing.assert_array_equal(outputs[s.tenant_id], want)
    assert st["migrations"] == 1 and st["errors_total"] >= 1

    spans = _assert_span_chains(
        obs.tracer, {t: o.shape[0] for t, o in outputs.items()})
    migrated = [s for s in spans
                if any(n == "migrate" for (n, _, _) in s.events)]
    assert migrated, "no span recorded the migration"
    for s in migrated:
        args = next(a for (n, _, a) in s.events if n == "migrate")
        assert args == {"src": 0, "dst": 1}

    doc = obs.chrome_trace()
    names = [e["name"] for e in doc["traceEvents"]]
    assert "device_lost" in names and "migrate_session" in names
    m = migrated[0]
    assert f"chunk {m.tenant}#{m.seq}" in names
    assert f"migrate {m.tenant}#{m.seq}" in names

    # the fleet snapshot mirrors the legacy stats() ledger
    assert snap["fleet"]["migrations"] == 1
    assert snap["fleet"]["recovery"]["device_losses"] == 1
    assert snap["fleet"]["worker0"]["alive"] is False
    assert snap["fleet"]["worker1"]["alive"] is True


@pytest.mark.chaos
def test_fleet_frozen_clock_zero_latency_telemetry():
    """Satellite: `FleetRuntime`'s launch path must time through the
    injected fleet clock only (fleet.py previously hardcoded
    time.perf_counter)."""
    frozen = lambda: 7.0                                     # noqa: E731
    spec = _spec("t0", "fused_fp32", seed=13)
    wave = _wave(9, 300)
    obs = Observability(tracing=True, clock=frozen)
    with FleetRuntime(n_workers=1, policy=_policy(), clock=frozen,
                      obs=obs) as rt:
        rt.open(spec)
        for c in chop(wave, 120 * CFG.n_os, seed=0):
            rt.submit("t0", c)
        rt.finish("t0")
        rt.drain()
        got = rt.output("t0")
        snap = obs.snapshot()
    np.testing.assert_array_equal(got, _offline(spec, wave))
    for s in obs.tracer.sealed_spans():
        assert set(s.marks.values()) == {7.0}
    launch = snap["fleet"]["worker0"]["launch"]
    for key in ("latency_s", "wait_s", "device_s"):
        assert launch[key]["max"] == 0.0, key


# ---------------------------------------------------------------------------
# retention: one policy bounds every unbounded-stream buffer
# ---------------------------------------------------------------------------

def test_retention_bounds_swap_log_window_and_trace_ring():
    ret = Retention(latency_window=4, swap_log=3, errors=2,
                    trace_capacity=5)
    obs = Observability(tracing=True, retention=ret)
    params = eq.init(jax.random.PRNGKey(0), CFG)
    bn = eq.init_bn_state(CFG)
    spec = TenantSpec("t0", CFG, params=params, bn_state=bn,
                      backend="fused_fp32", tile_m=32)
    wave = _wave(21, 900)
    rt = ServeRuntime(_policy(), obs=obs)
    sess = rt.open(spec)
    chunks = list(chop(wave, 120 * CFG.n_os, seed=0))
    for i, c in enumerate(chunks):
        rt.submit("t0", c)
        if i in (2, 4):      # swaps exercise the swap_log bound
            for _ in range(3):
                rt.swap_weights("t0", params=params, bn_state=bn)
    rt.finish("t0")
    rt.drain()

    # swap_log: still a plain LIST (API compat), trimmed to the bound,
    # most recent entries kept
    assert isinstance(sess.swap_log, list)
    assert len(sess.swap_log) == 3
    epochs = [e for e, _ in sess.swap_log]
    assert epochs == sorted(epochs) and epochs[-1] == 6
    # completed-request window and latency reservoir share the bound
    assert rt.batcher.completed.maxlen == 4
    assert len(rt.batcher.completed) == 4
    assert rt.batcher.latency_stats()["requests"] > 4     # lifetime count
    assert obs.snapshot()["serve"]["launch"]["latency_s"]["window"] <= 4
    # trace ring: bounded, drops counted, never grows past capacity
    st = obs.tracer.stats()
    assert st["spans_buffered"] == 5
    assert st["spans_sealed"] > 5
    assert st["spans_dropped"] == st["spans_sealed"] - 5


def test_retention_bounds_error_deques():
    ret = Retention(errors=2)
    obs = Observability(retention=ret)
    rt = AsyncServeRuntime(_policy(), obs=obs)
    try:
        assert rt.errors.maxlen == 2
    finally:
        rt.shutdown()
    with FleetRuntime(n_workers=1, policy=_policy(),
                      obs=Observability(retention=ret)) as fl:
        assert fl.errors.maxlen == 2


# ---------------------------------------------------------------------------
# legacy stats() schemas: thin wrappers, normalized error accounting
# ---------------------------------------------------------------------------

def test_stats_schemas_normalized_over_snapshot():
    spec = _spec("t0", "fused_fp32", seed=31)
    wave = _wave(7, 300)
    obs = Observability()
    rt = ServeRuntime(_policy(), obs=obs)
    rt.open(spec)
    for c in chop(wave, 120 * CFG.n_os, seed=0):
        rt.submit("t0", c)
    rt.finish("t0")
    rt.drain()
    st = rt.stats()
    snap = obs.snapshot()
    assert st["errors_total"] == 0                    # sync driver: none
    # the wrapper keys and the snapshot tree agree on shared state
    assert st["pool"] == {k: v for k, v in snap["serve"]["pool"].items()
                          if k != "build_s"}
    # latency_stats() keys flatten into stats(); the snapshot keeps the
    # same provider under serve.latency — no double accounting
    assert st["requests"] == snap["serve"]["latency"]["requests"]
    assert st["p50_latency_ms"] == snap["serve"]["latency"]["p50_latency_ms"]
    assert snap["serve"]["tenants"] == st["tenants"] == 1
    assert (snap["serve"]["sessions"]["t0"]["syms_emitted"]
            == rt.output("t0").shape[0])

    with AsyncServeRuntime(_policy()) as art:
        ast = art.stats()
        assert ast["errors_total"] == ast["errors"] == 0
        asnap = art.obs.snapshot()
        assert asnap["serve"]["errors"] == {
            "total": 0, "window": 0, "dropped": 0}
        assert "recovery" in ast and "degradation" in ast


def test_observability_snapshot_is_thread_safe_under_writes():
    """Snapshotting while instruments are being hammered from another
    thread must neither crash nor corrupt the tree."""
    obs = Observability()
    scope = obs.scope("serve")
    c = scope.counter("requests_total")
    h = scope.histogram("launch.latency_s")
    stop = threading.Event()

    def writer():
        while not stop.is_set():
            c.inc()
            h.observe(0.5)

    t = threading.Thread(target=writer)
    t.start()
    try:
        for _ in range(50):
            snap = obs.snapshot()
            assert snap["serve"]["requests_total"] >= 0
    finally:
        stop.set()
        t.join()
    assert obs.snapshot()["serve"]["requests_total"] == c.value


# ---------------------------------------------------------------------------
# console report
# ---------------------------------------------------------------------------

def test_report_renders_all_sections(capsys, tmp_path):
    obs = Observability(tracing=True, clock=lambda: 0.0)  # uptime frozen
    s = obs.scope("serve")
    s.counter("requests_total").inc(9)
    s.histogram("launch.latency_s").observe(0.01)
    s.callback("sessions", lambda: {
        "t0": {"syms_emitted": 300, "weight_epoch": 1, "recoveries": 0,
               "inflight": 0, "shed": False, "failed": None}})
    f = obs.scope("fleet")
    f.callback("migrations", lambda: 1)
    f.callback("placement", lambda: {"t0": 1})
    f.scope("worker0").callback("alive", lambda: False)
    a = obs.scope("adapt")
    a.counter("actions.promoted").inc(2)
    a.gauge("t0.shadow.ber_active").set(0.01)

    txt = render(obs.snapshot())
    for frag in ("[serve]", "[fleet]", "[adapt]", "[trace]",
                 "requests=9", "latency_s", "t0", "migrations=1",
                 "t0->w1", "[worker0] alive=False", "promoted=2",
                 "ber_active=0.01", "enabled=True"):
        assert frag in txt, frag

    # the CLI renders the exported snapshot JSON byte-identically
    path = tmp_path / "snap.json"
    obs.write_snapshot(str(path))
    assert report_main([str(path)]) == 0
    assert capsys.readouterr().out.rstrip("\n") == txt
    assert render({}) == "observability snapshot — empty"
