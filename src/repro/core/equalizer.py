"""The paper's CNN equalizer topology template (§3.1, Fig. 1/3).

Topology (for L layers, kernel K, channels C, parallel symbols V_p, oversampling
N_os):

    conv1  : 1   → C     stride V_p   + BN + ReLU
    conv i : C   → C     stride 1     + BN + ReLU      (i = 2 … L-1)
    conv L : C   → V_p   stride N_os  (linear output)
    flatten: (width, V_p) → width · V_p output symbols

Input is a real waveform at N_os samples/symbol of length S·N_os; output is S
soft symbol estimates which are sliced to the nearest constellation point.

The module is pure JAX (init/apply), supports batched input, optional
learned-bit-width QAT (core/qat.py) and exposes `fold_bn()` so the inference
path matches the FPGA deployment (BN folded into conv weights).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import qat as qat_lib


@dataclasses.dataclass(frozen=True)
class CNNEqConfig:
    layers: int = 3          # L
    kernel: int = 9          # K
    channels: int = 5        # C
    v_parallel: int = 8      # V_p — symbols per network pass
    n_os: int = 2            # oversampling of the input waveform
    levels: int = 2          # PAM order
    bn_momentum: float = 0.9

    @property
    def receptive_field_syms(self) -> int:
        """Overlap formula of paper §6.1 (after Araujo et al.):
        o_sym = (K-1)(1 + V_p(L-1)) / 2 symbols on EACH side."""
        return (self.kernel - 1) * (1 + self.v_parallel * (self.layers - 1)) // 2

    def mac_per_symbol(self) -> float:
        """Paper's complexity metric MAC_sym (§3.5)."""
        k, c, l, vp, nos = (self.kernel, self.channels, self.layers,
                            self.v_parallel, self.n_os)
        return k * c / vp + (l - 2) * k * c * c / vp + k * c / nos

    def layer_specs(self):
        """[(c_in, c_out, stride), ...] for each conv layer."""
        specs = [(1, self.channels, self.v_parallel)]
        for _ in range(self.layers - 2):
            specs.append((self.channels, self.channels, 1))
        specs.append((self.channels, self.v_parallel, self.n_os))
        return specs


# ---------------------------------------------------------------------------
# init / apply
# ---------------------------------------------------------------------------

def init(key: jax.Array, cfg: CNNEqConfig,
         qat: Optional[qat_lib.QATConfig] = None) -> Dict[str, Any]:
    """He-initialized parameters. Layout: w[l] has shape (C_out, C_in, K)."""
    params: Dict[str, Any] = {"conv": [], "bn": []}
    keys = jax.random.split(key, cfg.layers)
    for i, (c_in, c_out, _) in enumerate(cfg.layer_specs()):
        fan_in = c_in * cfg.kernel
        w = jax.random.normal(keys[i], (c_out, c_in, cfg.kernel),
                              jnp.float32) * jnp.sqrt(2.0 / fan_in)
        b = jnp.zeros((c_out,), jnp.float32)
        params["conv"].append({"w": w, "b": b})
        if i < cfg.layers - 1:
            params["bn"].append({"scale": jnp.ones((c_out,), jnp.float32),
                                 "bias": jnp.zeros((c_out,), jnp.float32)})
    if qat is not None and qat.enabled:
        params["qat"] = qat_lib.init_qparams(
            [f"layer{i}" for i in range(cfg.layers)], qat)
    return params


def init_bn_state(cfg: CNNEqConfig) -> Dict[str, Any]:
    """Running statistics for BN (non-trainable state)."""
    state = []
    for i, (_, c_out, _) in enumerate(cfg.layer_specs()):
        if i < cfg.layers - 1:
            state.append({"mean": jnp.zeros((c_out,), jnp.float32),
                          "var": jnp.ones((c_out,), jnp.float32)})
    return {"bn": state}


def _conv1d(x: jnp.ndarray, w: jnp.ndarray, stride: int,
            padding: str | Tuple[int, int] = "SAME_LOWER") -> jnp.ndarray:
    """x: (N, C_in, W), w: (C_out, C_in, K) → (N, C_out, W_out)."""
    k = w.shape[-1]
    if padding == "SAME_LOWER":
        pad = (k // 2, k - 1 - k // 2)
    else:
        pad = padding
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride,), padding=[pad],
        dimension_numbers=("NCH", "OIH", "NCH"))


def apply(params: Dict[str, Any], x: jnp.ndarray, cfg: CNNEqConfig,
          *, train: bool = False, bn_state: Optional[Dict[str, Any]] = None,
          qat_enabled: bool = False):
    """Forward pass.

    Args:
      x: waveform, shape (S·N_os,) or (batch, S·N_os).
    Returns:
      (soft_symbols[(batch,) S], new_bn_state)
    """
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None]
    h = x[:, None, :]  # (N, 1, W)
    new_bn = {"bn": []}
    qp = params.get("qat")

    for i, (c_in, c_out, stride) in enumerate(cfg.layer_specs()):
        w = params["conv"][i]["w"]
        b = params["conv"][i]["b"]
        if qat_enabled and qp is not None:
            q = qp[f"layer{i}"]
            w = qat_lib.apply_weight_quant(w, q)
            h = qat_lib.apply_act_quant(h, q)
        h = _conv1d(h, w, stride) + b[None, :, None]
        if i < cfg.layers - 1:
            bn_p = params["bn"][i]
            if train or bn_state is None:
                mean = jnp.mean(h, axis=(0, 2))
                var = jnp.var(h, axis=(0, 2))
            else:
                mean = bn_state["bn"][i]["mean"]
                var = bn_state["bn"][i]["var"]
            if train and bn_state is not None:
                m = cfg.bn_momentum
                new_bn["bn"].append({
                    "mean": m * bn_state["bn"][i]["mean"] + (1 - m) * mean,
                    "var": m * bn_state["bn"][i]["var"] + (1 - m) * var,
                })
            h = (h - mean[None, :, None]) / jnp.sqrt(var[None, :, None] + 1e-5)
            h = h * bn_p["scale"][None, :, None] + bn_p["bias"][None, :, None]
            h = jax.nn.relu(h)

    # flatten (N, V_p, W_L) → (N, W_L · V_p): feature-map elements ARE the
    # output symbols (paper: "the feature map is flattened so that each
    # element corresponds to one output symbol")
    y = jnp.swapaxes(h, 1, 2).reshape(h.shape[0], -1)
    if squeeze:
        y = y[0]
    if not new_bn["bn"]:
        new_bn = bn_state
    return y, new_bn


def fold_bn(params: Dict[str, Any], bn_state: Dict[str, Any],
            cfg: CNNEqConfig) -> Dict[str, Any]:
    """Fold BN running stats into conv weights (FPGA-style deployment).

    After folding, `apply_folded` needs no BN state and matches eval-mode
    `apply` exactly — this is what the fused Pallas kernel consumes.
    """
    folded = {"conv": []}
    for i, _ in enumerate(cfg.layer_specs()):
        w = params["conv"][i]["w"]
        b = params["conv"][i]["b"]
        if i < cfg.layers - 1:
            bn_p = params["bn"][i]
            mean = bn_state["bn"][i]["mean"]
            var = bn_state["bn"][i]["var"]
            g = bn_p["scale"] / jnp.sqrt(var + 1e-5)
            w = w * g[:, None, None]
            b = (b - mean) * g + bn_p["bias"]
        folded["conv"].append({"w": w, "b": b})
    return folded


def folded_weights(folded: Dict[str, Any]) -> Tuple:
    """Folded params → ((w, b), …) kernel argument layout (single source of
    truth for the folded-layout convention; engine and kernel ops import
    this)."""
    return tuple((l["w"], l["b"]) for l in folded["conv"])


def layer_strides(cfg: CNNEqConfig) -> Tuple[int, ...]:
    """(V_p, 1, …, N_os) — per-layer strides in kernel-argument form."""
    return tuple(s for _, _, s in cfg.layer_specs())


def apply_folded(folded: Dict[str, Any], x: jnp.ndarray, cfg: CNNEqConfig):
    """Inference with BN pre-folded (ReLU still applied between layers)."""
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None]
    h = x[:, None, :]
    for i, (_, _, stride) in enumerate(cfg.layer_specs()):
        w = folded["conv"][i]["w"]
        b = folded["conv"][i]["b"]
        h = _conv1d(h, w, stride) + b[None, :, None]
        if i < cfg.layers - 1:
            h = jax.nn.relu(h)
    y = jnp.swapaxes(h, 1, 2).reshape(h.shape[0], -1)
    return y[0] if squeeze else y
