"""Online-adaptation runtime (repro.adapt + serve weight hot-swap) — the
ISSUE-5 acceptance surface.

  * collector units: decision-directed labels, pilot FIFO lockstep, ring
    capacity, deterministic train/eval interleave;
  * descatter tap: the segments a session's tap sees reassemble the
    served waveform and output exactly, in stream order;
  * shadow/promotion units: hysteresis band, insufficient-data refusal;
  * fine-tune: WEIGHT-ONLY — the QAT subtree (the learned formats) stays
    bit-identical while conv weights move;
  * hot-swap invariants (sync AND async drivers, fp32 AND int8 backends):
    chunked output is bitwise-equal to the offline engine of the epoch's
    spec on each side of the swap boundary; rollback restores the active
    weights bit-identically; `install_spec` refuses identity changes;
  * the drift-recovery criterion (slow): under `channels/drift.py` drift
    the frozen tenant's BER degrades ≥4× while the adaptive tenant's
    post-promotion BER lands within 2× of a freshly trained equalizer.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.adapt import (AdaptPolicy, FineTuneConfig, OnlineAdapter,
                         PromotionPolicy, SampleCollector, engine_ber,
                         hard_decide, pam_amplitudes, shadow_evaluate)
from repro.channels.drift import DriftingProakis, DriftSchedule
from repro.core import equalizer as eq
from repro.core.train_eq import (EqTrainConfig, fine_tune_equalizer,
                                 train_equalizer)
from repro.serve import (AsyncServeRuntime, BatchPolicy, ServeRuntime,
                         TenantSpec, chop, drift_streams, replay_adaptive)

CFG = eq.CNNEqConfig()
TS = CFG.v_parallel * CFG.n_os           # samples per engine pass
INT8_QAT = {"w_int": 2.0, "w_frac": 5.0, "a_int": 3.0, "a_frac": 4.0}


def _params(seed, qat=False):
    p = eq.init(jax.random.PRNGKey(seed), CFG)
    if qat:
        p["qat"] = {f"layer{i}": {k: jnp.asarray(v)
                                  for k, v in INT8_QAT.items()}
                    for i in range(CFG.layers)}
    return p


def _spec(tid, seed, backend="fused_fp32", tile_m=16):
    qat = backend == "auto"
    return TenantSpec(tid, CFG, params=_params(seed, qat=qat),
                      bn_state=eq.init_bn_state(CFG), backend=backend,
                      tile_m=tile_m)


def _offline(spec, wave):
    return np.asarray(spec.build_engine()(jnp.asarray(wave[None])))[0]


# ---------------------------------------------------------------------------
# collector
# ---------------------------------------------------------------------------

def test_collector_decision_labels_and_ring_capacity():
    col = SampleCollector(n_os=2, levels=2, capacity_syms=64, eval_every=4)
    soft = np.array([-0.9, 0.8, -1.1, 1.2] * 8, np.float32)    # 32 syms
    rx = np.zeros((soft.size * 2,), np.float32)
    col.on_segment(rx, soft)
    col.on_segment(rx, soft)
    assert col.total_syms == 64 and col.buffered_syms == 64
    tr_rx, tr_sy, ev_rx, ev_sy = col.training_view()
    np.testing.assert_array_equal(
        np.unique(np.concatenate([tr_sy, ev_sy])), [0, 1])
    assert tr_sy.shape[0] + ev_sy.shape[0] == 64
    # decisions match the hard slicer
    np.testing.assert_array_equal(tr_sy[:32], hard_decide(soft, 2))
    # ring: a third segment evicts the oldest
    col.on_segment(rx, soft)
    assert col.buffered_syms == 64 and col.total_syms == 96


def test_collector_pilot_fifo_consumes_in_lockstep():
    col = SampleCollector(n_os=2, levels=2, eval_every=4)
    col.add_pilots(np.array([1, 1, 1, 1, 1]))        # 5 pilot labels
    soft = np.full((4,), -0.7, np.float32)           # decisions would be 0
    rx = np.zeros((8,), np.float32)
    col.on_segment(rx, soft)                         # 4 piloted
    col.on_segment(rx, soft)                         # 1 pilot + 3 decisions
    tr_rx, tr_sy, ev_rx, ev_sy = col.training_view()
    labels = np.concatenate([tr_sy, ev_sy])
    assert labels.shape[0] == 8
    assert col.pilot_labelled == 5
    assert labels.sum() == 5                          # pilots said 1
    assert col.stats()["pilots_queued"] == 0


def test_collector_eval_split_is_deterministic_blocked_interleave():
    """Every eval_every-th BLOCK of EVAL_BLOCK consecutive segments is
    held out — contiguous runs, so concatenation splices are rare."""
    from repro.adapt.collector import EVAL_BLOCK
    col = SampleCollector(n_os=1, levels=2, eval_every=3,
                          capacity_syms=1 << 12)
    n_segs = 6 * EVAL_BLOCK                          # two full super-periods
    for i in range(n_segs):
        col.on_segment(np.full((4,), float(i), np.float32),
                       np.full((4,), -1.0, np.float32))
    tr_rx, _, ev_rx, _ = col.training_view()
    # blocks 2 and 5 (0-based) are held out, EVAL_BLOCK segments each
    want_eval = [float(i) for b in (2, 5)
                 for i in range(b * EVAL_BLOCK, (b + 1) * EVAL_BLOCK)]
    np.testing.assert_array_equal(np.unique(ev_rx), want_eval)
    assert tr_rx.shape[0] == (n_segs - 2 * EVAL_BLOCK) * 4
    assert ev_rx.shape[0] == 2 * EVAL_BLOCK * 4


# ---------------------------------------------------------------------------
# descatter tap
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("driver", ["sync", "async"])
def test_tap_segments_reassemble_stream(driver):
    """The tap sees exactly the served waveform (real samples behind the
    emitted positions) and exactly the emitted symbols, in stream order."""
    rt = (AsyncServeRuntime if driver == "async" else ServeRuntime)(
        BatchPolicy(max_batch=1, max_wait_s=1e9))
    try:
        spec = _spec("tap", seed=3)
        sess = rt.open(spec)
        got_rx, got_sy = [], []
        sess.tap = lambda rx, sy: (got_rx.append(np.array(rx)),
                                   got_sy.append(np.array(sy)))
        rng = np.random.default_rng(5)
        wave = rng.standard_normal(40 * TS).astype(np.float32)
        for c in chop(wave, 200, seed=1):
            rt.submit("tap", c)
        rt.finish("tap")
        rt.drain()
        np.testing.assert_array_equal(np.concatenate(got_rx), wave)
        np.testing.assert_array_equal(np.concatenate(got_sy),
                                      rt.output("tap"))
    finally:
        if driver == "async":
            rt.shutdown()


# ---------------------------------------------------------------------------
# shadow evaluation / promotion hysteresis
# ---------------------------------------------------------------------------

class _FakeEngine:
    """Deterministic engine stub: returns PAM amplitudes of given symbols
    with the first `n_err` of every 100 flipped."""

    def __init__(self, syms, n_err):
        self.cfg = CFG
        self.total_stride = 1
        const = pam_amplitudes(CFG.levels)
        out = np.array(syms)
        for i in range(0, out.size, 100):
            out[i:i + n_err] ^= 1
        self._soft = const[out].astype(np.float32)

    def __call__(self, x):
        return self._soft[None, : x.shape[1] // self.cfg.n_os]


def test_shadow_promotes_only_on_clear_wins():
    rng = np.random.default_rng(7)
    syms = rng.integers(0, 2, size=4096).astype(np.int32)
    rx = np.zeros((syms.size * CFG.n_os,), np.float32)
    pol = PromotionPolicy(min_eval_syms=2048, min_rel_gain=0.15,
                          min_abs_gain=2e-3, eval_bucket_syms=1024)
    active = _FakeEngine(syms, n_err=10)             # BER 0.10
    # clear win: 0.10 → 0.05
    rep = shadow_evaluate(active, _FakeEngine(syms, 5), rx, syms, pol)
    assert rep.promote and rep.ber_active == pytest.approx(0.10, rel=0.01)
    # inside the hysteresis band: 0.10 → 0.095 (rel margin is 0.015)
    rep = shadow_evaluate(active, _FakeEngine(syms, 9), rx, syms, pol)
    assert not rep.promote
    # both perfect: absolute margin blocks a 0→0 swap
    perfect = _FakeEngine(syms, 0)
    rep = shadow_evaluate(perfect, _FakeEngine(syms, 0), rx, syms, pol)
    assert not rep.promote
    # not enough held-out data → refuse with NaN BERs
    rep = shadow_evaluate(active, perfect, rx[:512 * CFG.n_os],
                          syms[:512], pol)
    assert not rep.promote and np.isnan(rep.ber_active)
    assert "insufficient" in rep.reason


# ---------------------------------------------------------------------------
# fine-tune: weight-only, formats frozen
# ---------------------------------------------------------------------------

def test_fine_tune_trains_weights_only_formats_bit_identical():
    params = _params(11, qat=True)
    bn = eq.init_bn_state(CFG)
    rng = np.random.default_rng(13)

    def sample_fn(key):
        xs = rng.standard_normal((4, 64 * CFG.n_os)).astype(np.float32)
        ys = rng.standard_normal((4, 64)).astype(np.float32)
        return xs, ys

    new_params, new_bn, info = fine_tune_equalizer(
        jax.random.PRNGKey(0), params, bn, CFG, sample_fn, steps=5, lr=1e-2)
    for name, q in params["qat"].items():
        for k, v in q.items():
            np.testing.assert_array_equal(
                np.asarray(v), np.asarray(new_params["qat"][name][k]))
    assert not np.array_equal(np.asarray(params["conv"][0]["w"]),
                              np.asarray(new_params["conv"][0]["w"]))
    assert info["steps"] == 5


# ---------------------------------------------------------------------------
# weight hot-swap invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("driver", ["sync", "async"])
@pytest.mark.parametrize("backend", ["fused_fp32", "auto"])
def test_hot_swap_bitwise_per_epoch(driver, backend):
    """Chunked output == offline equalization with each epoch's weights
    applied from its swap boundary — on both drivers, for the fp32 backend
    and the auto→int8 deployment (QAT formats pinned across the swap)."""
    rt = (AsyncServeRuntime if driver == "async" else ServeRuntime)(
        BatchPolicy(max_batch=1, max_wait_s=1e9))
    try:
        spec0 = _spec("hs", seed=1, backend=backend)
        sess = rt.open(spec0)
        if backend == "auto":
            assert sess.engine.backend == "fused_int8"
        rng = np.random.default_rng(2)
        wave = rng.standard_normal(60 * TS).astype(np.float32)
        chunks = chop(wave, 300, seed=4)
        half = len(chunks) // 2
        for c in chunks[:half]:
            rt.submit("hs", c)
        epoch = rt.swap_weights(
            "hs", params=_params(99, qat=backend == "auto"),
            bn_state=eq.init_bn_state(CFG))
        assert epoch == 1 and sess.weight_epoch == 1
        for c in chunks[half:]:
            rt.submit("hs", c)
        got = rt.close("hs")
        (_, p0), (_, p1) = sess.swap_log
        assert p0 == 0 and p1 > 0
        vp = CFG.v_parallel
        want = np.concatenate([_offline(spec0, wave)[: p1 * vp],
                               _offline(sess.spec, wave)[p1 * vp:]])
        np.testing.assert_array_equal(got, want)
        # group identity never moved (same batch group before and after)
        assert (sess.spec.build_engine().group_key()
                == spec0.build_engine().group_key())
    finally:
        if driver == "async":
            rt.shutdown()


@pytest.mark.parametrize("driver", ["sync", "async"])
def test_rollback_restores_weights_bit_identical(driver):
    """swap → rollback: the stream continues on weights bit-identical to
    the originals, and the full three-epoch output matches offline
    old|new|old equalization at the logged boundaries."""
    rt = (AsyncServeRuntime if driver == "async" else ServeRuntime)(
        BatchPolicy(max_batch=1, max_wait_s=1e9))
    try:
        spec0 = _spec("rb", seed=7)
        sess = rt.open(spec0)
        rng = np.random.default_rng(8)
        wave = rng.standard_normal(72 * TS).astype(np.float32)
        chunks = chop(wave, 320, seed=9)
        third = len(chunks) // 3
        for c in chunks[:third]:
            rt.submit("rb", c)
        rt.swap_weights("rb", params=_params(55),
                        bn_state=eq.init_bn_state(CFG))
        swapped_spec = sess.spec
        for c in chunks[third:2 * third]:
            rt.submit("rb", c)
        epoch = rt.rollback_weights("rb")
        assert epoch == 2
        # active weights are bit-identical to the ORIGINAL deployment
        w_now = sess.spec.build_engine().weights
        w_orig = spec0.build_engine().weights
        for (wa, ba), (wb, bb) in zip(w_now, w_orig):
            np.testing.assert_array_equal(np.asarray(wa), np.asarray(wb))
            np.testing.assert_array_equal(np.asarray(ba), np.asarray(bb))
        for c in chunks[2 * third:]:
            rt.submit("rb", c)
        got = rt.close("rb")
        (_, _), (_, p1), (_, p2) = sess.swap_log
        vp = CFG.v_parallel
        off_old = _offline(spec0, wave)
        off_new = _offline(swapped_spec, wave)
        want = np.concatenate([off_old[: p1 * vp],
                               off_new[p1 * vp: p2 * vp],
                               off_old[p2 * vp:]])
        np.testing.assert_array_equal(got, want)
    finally:
        if driver == "async":
            rt.shutdown()


def test_install_spec_refuses_identity_changes():
    """A 'weight swap' that would change tile or backend is not a weight
    swap: install_spec must refuse and leave the stream untouched."""
    rt = ServeRuntime(BatchPolicy(max_batch=1, max_wait_s=1e9))
    sess = rt.open(_spec("guard", seed=4))
    before = sess.spec
    bad_tile = dataclasses.replace(sess.spec, tile_m=32, weight_epoch=1)
    with pytest.raises(ValueError, match="hot-swap would change"):
        sess.install_spec(bad_tile)
    bad_backend = dataclasses.replace(sess.spec, backend="fused_bf16",
                                      weight_epoch=1)
    with pytest.raises(ValueError, match="hot-swap would change"):
        sess.install_spec(bad_backend)
    assert sess.spec is before and sess.weight_epoch == 0
    assert sess.swap_log == [(0, 0)]


# ---------------------------------------------------------------------------
# adapter control loop
# ---------------------------------------------------------------------------

def test_adapter_requires_params_and_idles_without_data():
    rt = ServeRuntime(BatchPolicy(max_batch=1, max_wait_s=1e9))
    adapter = OnlineAdapter(rt)
    weights_only = TenantSpec(
        "w", CFG, weights=_spec("x", 1).build_engine().weights,
        backend="fused_fp32", tile_m=16)
    with pytest.raises(ValueError, match="needs params"):
        adapter.attach(weights_only)
    adapter.attach(_spec("a", seed=2))
    (rep,) = adapter.step("a")
    assert rep.action == "idle" and rep.weight_epoch == 0


@pytest.fixture(scope="module")
def trained_base():
    """One 600-step stationary training shared by the adapter tests."""
    ch = DriftingProakis()
    params, bn, info = train_equalizer(
        jax.random.PRNGKey(0), "cnn", CFG, ch.at(0.0),
        EqTrainConfig(steps=600, eval_syms=1 << 14))
    return ch, params, bn, info["ber"]


def _adaptive_runtime(trained, tids, ft):
    ch, params, bn, _ = trained
    rt = ServeRuntime(BatchPolicy(max_batch=len(tids), max_wait_s=1e9))
    adapter = OnlineAdapter(
        rt,
        AdaptPolicy(min_train_syms=3072, adapt_every_syms=3072,
                    eval_capacity=8192,
                    promotion=PromotionPolicy(min_eval_syms=1024,
                                              eval_bucket_syms=512)),
        ft)

    def mk(tid):
        return TenantSpec(tid, CFG, params=params, bn_state=bn,
                          backend="fused_fp32", tile_m=16)
    return rt, adapter, mk


def test_adapter_hysteresis_no_thrash_on_stationary_channel(trained_base):
    """A well-trained tenant on a stationary channel with a timid
    fine-tune must never swap: every cycle lands inside the hysteresis
    band (or idles)."""
    ch = trained_base[0]
    rt, adapter, mk = _adaptive_runtime(
        trained_base, ["st"], FineTuneConfig(steps=15, lr=1e-4))
    adapter.attach(mk("st"))
    sched = DriftSchedule(hold_bursts=10_000, ramp_bursts=1)   # never drifts
    streams, pilots = drift_streams(ch, sched, ["st"], n_bursts=8,
                                    syms_per_burst=2048, seed=6)
    replay_adaptive(rt, streams, pilots=pilots, adapter=adapter,
                    step_every=2)
    actions = {r.action for r in adapter.history}
    assert actions <= {"idle", "rejected"}, adapter.history
    assert rt.sessions.get("st").weight_epoch == 0


def test_adapter_background_thread_with_live_async_traffic():
    """Thread mode: the adapter's daemon thread runs cycles (and possibly
    hot-swaps) WHILE the async runtime serves traffic. The stream must
    stay complete and ordered regardless of what the adapter decides —
    the swap barrier serializes against live submits."""
    ch = DriftingProakis()
    with AsyncServeRuntime(BatchPolicy(max_batch=1, max_wait_s=1e9)) as rt:
        adapter = OnlineAdapter(
            rt,
            AdaptPolicy(min_train_syms=1024, adapt_every_syms=512,
                        eval_capacity=4096,
                        promotion=PromotionPolicy(min_eval_syms=512,
                                                  eval_bucket_syms=256)),
            FineTuneConfig(steps=10, batch=4, seq_syms=128, lr=1e-3))
        adapter.attach(_spec("bg", seed=21))
        streams, pilots = drift_streams(
            ch, DriftSchedule(hold_bursts=2, ramp_bursts=3), ["bg"],
            n_bursts=8, syms_per_burst=1024, seed=11)
        adapter.start(interval_s=0.02)
        try:
            for chunk, labels in zip(streams["bg"], pilots["bg"]):
                adapter.feed_pilots("bg", labels)
                rt.submit("bg", chunk)
            rt.finish("bg")
            rt.drain()
        finally:
            adapter.stop()
        assert adapter.history, "background thread never ran a cycle"
        assert not adapter.errors
        assert not rt.errors
        out = rt.output("bg")
        assert out.shape == (8 * 1024,)      # nothing lost, nothing dup'd
        # the epoch log is consistent: monotone epochs, monotone positions
        log = rt.sessions.get("bg").swap_log
        assert [e for e, _ in log] == list(range(len(log)))
        assert all(p1 <= p2 for (_, p1), (_, p2) in zip(log, log[1:]))


@pytest.mark.slow
def test_drift_recovery_acceptance(trained_base):
    """THE acceptance criterion: under tap-rotation + SNR drift, the
    frozen tenant degrades ≥4× its pre-drift BER while the adaptive
    tenant's post-promotion BER recovers to within 2× of a freshly
    trained equalizer (floors guard the near-zero BER regime where ratios
    are measurement noise)."""
    ch, params, bn, ber0 = trained_base
    rt, adapter, mk = _adaptive_runtime(
        trained_base, ["frozen", "adapt"],
        FineTuneConfig(steps=200, batch=8, seq_syms=256, lr=3e-3))
    rt.open(mk("frozen"))
    adapter.attach(mk("adapt"))
    sched = DriftSchedule(hold_bursts=4, ramp_bursts=6)
    streams, pilots = drift_streams(ch, sched, ["frozen", "adapt"],
                                    n_bursts=26, syms_per_burst=2048,
                                    seed=3)
    replay_adaptive(rt, streams, pilots=pilots, adapter=adapter,
                    step_every=2)

    promoted = [r for r in adapter.history if r.action == "promoted"]
    assert promoted, "adaptation never promoted a candidate"
    sess = rt.sessions.get("adapt")
    assert sess.weight_epoch >= 1 and len(sess.swap_log) >= 2

    rx1, sy1 = ch.at(1.0)(jax.random.PRNGKey(77), 1 << 14)
    rx1, sy1 = np.asarray(rx1), np.asarray(sy1)
    params_f, bn_f, _ = train_equalizer(
        jax.random.PRNGKey(1), "cnn", CFG, ch.at(1.0),
        EqTrainConfig(steps=600, eval_syms=1 << 14))
    ber_frozen = engine_ber(rt.sessions.get("frozen").engine, rx1, sy1)
    ber_adapt = engine_ber(sess.engine, rx1, sy1)
    ber_fresh = engine_ber(
        TenantSpec("fresh", CFG, params=params_f, bn_state=bn_f,
                   backend="fused_fp32", tile_m=16).build_engine(),
        rx1, sy1)
    # the frozen tenant fell off a cliff…
    assert ber_frozen >= 4.0 * max(ber0, 1e-3), (ber_frozen, ber0)
    # …the adaptive tenant recovered to near fresh-training quality
    assert ber_adapt <= 2.0 * max(ber_fresh, 2.5e-3), (ber_adapt, ber_fresh)
    assert ber_adapt <= ber_frozen / 4.0
