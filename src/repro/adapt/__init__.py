"""Online adaptation runtime — per-tenant background fine-tuning with
BER-gated weight hot-swap under channel drift (see docs/ADAPTATION.md).

Layers:
  collector — `Session.tap`-driven ring of served (rx, label) pairs
              (pilot or decision-directed labels)
  trainer   — weight-only QAT resume over the buffer (formats frozen, so
              the deployed backend can never change mid-flight)
  shadow    — candidate-vs-active BER on held-out traffic; hysteresis-
              guarded promotion and rollback decisions
  runtime   — `OnlineAdapter`: the collect → fine-tune → shadow-eval →
              promote/rollback control loop over a serving runtime,
              synchronous (`step()`) or as a background thread
"""
from .collector import SampleCollector, hard_decide, pam_amplitudes
from .runtime import AdaptPolicy, AdaptReport, OnlineAdapter
from .shadow import (PromotionPolicy, ShadowReport, engine_ber,
                     shadow_evaluate)
from .trainer import FineTuneConfig, fine_tune_from_buffer, make_sample_fn

__all__ = ["AdaptPolicy", "AdaptReport", "FineTuneConfig", "OnlineAdapter",
           "PromotionPolicy", "SampleCollector", "ShadowReport",
           "engine_ber", "fine_tune_from_buffer", "hard_decide",
           "make_sample_fn", "pam_amplitudes", "shadow_evaluate"]
