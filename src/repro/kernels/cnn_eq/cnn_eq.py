"""Pallas TPU kernel: the FUSED L-layer CNN equalizer (paper §5.1 on TPU).

The FPGA architecture instantiates each conv layer as a pipeline stage with
activations streaming between stages through on-chip FIFOs. The TPU-native
equivalent keeps the whole layer stack inside ONE kernel so inter-layer
activations never leave VMEM:

  HBM ──DMA──▶ VMEM input tile (with receptive-field halo)
                 │ conv1 (stride V_p) + ReLU        ┐ all in VMEM /
                 │ conv2 … conv_{L-1} + ReLU        │ vector registers —
                 │ conv_L (stride N_os)             ┘ zero HBM round-trips
  HBM ◀──DMA── VMEM output tile (tile_m · V_p symbols)

Grid = (batch, sequence tiles): Mosaic overlaps the tile DMAs with compute,
which is exactly the paper's "each layer starts as soon as first inputs
arrive" streaming property, realized at tile granularity.

The input tile is element-indexed with a halo of half a receptive field per
side (`receptive_halo`), the kernel computes VALID convolutions, and the
wrapper pre-pads the stream so the result equals the SAME_LOWER-padded
reference (`ref.cnn_eq`) exactly — including at stream edges.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl


def receptive_halo(kernels: Sequence[int], strides: Sequence[int]) -> int:
    """Half receptive field of the conv stack, in input samples."""
    r, jump = 0, 1
    for k, s in zip(kernels, strides):
        r += (k // 2) * jump
        jump *= s
    return r


def _layer_spans(tile_m: int, kernels: Sequence[int],
                 strides: Sequence[int]) -> list[int]:
    """Positions needed at each level to produce tile_m final positions."""
    spans = [tile_m]
    for k, s in zip(reversed(kernels), reversed(strides)):
        spans.append((spans[-1] - 1) * s + k)
    return list(reversed(spans))  # spans[0] = input samples per tile


def _conv_valid(h: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, stride: int,
                n_out: int) -> jnp.ndarray:
    """(C_in, W) ⊛ (C_out, C_in, K) → (C_out, n_out), tap-unrolled MXU dots."""
    k = w.shape[-1]
    acc = jnp.zeros((w.shape[0], n_out), jnp.float32)
    for kk in range(k):
        xk = jax.lax.slice(h, (0, kk), (h.shape[0], kk + (n_out - 1) * stride + 1),
                           (1, stride))
        acc = acc + jax.lax.dot(w[:, :, kk].astype(jnp.float32), xk,
                                preferred_element_type=jnp.float32)
    return acc + b.astype(jnp.float32)[:, None]


def _cnn_eq_kernel(x_ref, *refs, tile_m: int, kernels, strides, v_parallel):
    n_layers = len(kernels)
    w_refs = refs[:-1][0::2]
    b_refs = refs[:-1][1::2]
    o_ref = refs[-1]
    spans = _layer_spans(tile_m, kernels, strides)

    h = x_ref[...].astype(jnp.float32)       # (1, in_tile) → C_in = 1
    for i in range(n_layers):
        h = _conv_valid(h, w_refs[i][...], b_refs[i][...], strides[i],
                        spans[i + 1])
        if i < n_layers - 1:
            h = jax.nn.relu(h)
    # (V_p, tile_m) → interleave channels: symbol s = m·V_p + c
    y = jnp.swapaxes(h, 0, 1).reshape(1, tile_m * v_parallel)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("strides", "tile_m", "interpret"))
def cnn_eq_fused(x: jnp.ndarray,
                 weights: Tuple[Tuple[jnp.ndarray, jnp.ndarray], ...],
                 strides: Tuple[int, ...], tile_m: int = 64,
                 interpret: bool | None = None) -> jnp.ndarray:
    """Fused equalizer forward. x: (B, W) → (B, W//N_os) symbols.

    weights: ((w_1, b_1), …, (w_L, b_L)) — BN pre-folded (equalizer.fold_bn).
    strides: (V_p, 1, …, N_os). Output length = W // (V_p·N_os) · V_p.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    batch, width = x.shape
    kernels = tuple(int(w.shape[-1]) for w, _ in weights)
    v_parallel = int(weights[-1][0].shape[0])
    total_stride = 1
    for s in strides:
        total_stride *= s
    n_pos = width // total_stride                  # final-layer positions
    n_syms = n_pos * v_parallel

    tile_m = min(tile_m, max(1, n_pos))
    n_tiles = pl.cdiv(n_pos, tile_m)
    halo = receptive_halo(kernels, strides)
    in_tile = _layer_spans(tile_m, kernels, strides)[0]

    # pad: halo on the left; halo + tile rounding on the right
    needed = (n_tiles - 1) * tile_m * total_stride + in_tile
    xp = jnp.pad(x, ((0, 0), (halo, max(0, needed - width - halo))))

    flat: list[jnp.ndarray] = []
    in_specs = [pl.BlockSpec((1, pl.Element(in_tile)),
                             lambda ib, it: (ib, it * tile_m * total_stride))]
    for w, b in weights:
        flat += [w, b]
        in_specs += [pl.BlockSpec(w.shape, lambda ib, it: (0, 0, 0)),
                     pl.BlockSpec(b.shape, lambda ib, it: (0,))]

    out = pl.pallas_call(
        functools.partial(_cnn_eq_kernel, tile_m=tile_m, kernels=kernels,
                          strides=strides, v_parallel=v_parallel),
        grid=(batch, n_tiles),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, tile_m * v_parallel),
                               lambda ib, it: (ib, it)),
        out_shape=jax.ShapeDtypeStruct(
            (batch, n_tiles * tile_m * v_parallel), x.dtype),
        interpret=interpret,
    )(xp, *flat)
    return out[:, :n_syms]
