"""TP head resolution, param-rule divisibility, and serve-state specs."""
import numpy as np
import pytest

from repro import configs
from repro.parallel import sharding


# ---------------------------------------------------------------------------
# resolve_heads: every assigned arch at TP=16
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,want", [
    ("internlm2-1.8b", (16, 16)),       # kv 8 → replicate 2×
    ("deepseek-7b", (32, 32)),          # MHA, shards directly
    ("smollm-135m", (16, 16)),          # 9q/3kv → full expansion
    ("qwen3-0.6b", (16, 16)),
    ("llava-next-34b", (64, 16)),       # 56q/8kv → group pad 7→8, kv ×2
    ("mixtral-8x22b", (48, 16)),        # 48q/8kv → kv ×2
    ("moonshot-v1-16b-a3b", (16, 16)),  # kv16 direct
    ("zamba2-1.2b", (32, 32)),
    ("whisper-large-v3", (32, 32)),     # 20q → pad 32, full expansion
])
def test_resolve_heads_assigned(arch, want):
    cfg = configs.get_config(arch)
    got = sharding.resolve_heads(cfg.n_heads, cfg.n_kv_heads, cfg.tp)
    assert got == want, f"{arch}: {got} != {want}"
    hq, kv_eff = got
    assert hq % cfg.tp == 0
    assert kv_eff % cfg.tp == 0 or kv_eff == cfg.n_kv_heads
    assert hq % kv_eff == 0                      # GQA grouping is whole


def test_resolve_heads_tp1_identity():
    assert sharding.resolve_heads(9, 3, 1) == (9, 3)
    assert sharding.resolve_heads(56, 8, 1) == (56, 8)


def test_kv_head_map_function_preserved():
    """Each (padded) q head must keep attending to its ORIGINAL kv head."""
    # llava: group-padding scheme
    hq, kv_eff = sharding.resolve_heads(56, 8, 16)      # (64, 16)
    idx = sharding.kv_head_map(56, 8, hq, kv_eff)
    rep = hq // kv_eff                                  # q i → expanded i//rep
    q_per = hq // 8                                     # 8 padded per group
    for q in range(hq):
        orig_kv = idx[q // rep]
        assert orig_kv == q // q_per                    # whole groups intact
    # smollm: full-expansion scheme
    hq, kv_eff = sharding.resolve_heads(9, 3, 16)       # (16, 16)
    idx = sharding.kv_head_map(9, 3, hq, kv_eff)
    for q in range(9):
        assert idx[q] == (q * 3) // 9                   # original GQA map
    for q in range(9, 16):
        assert idx[q] == idx[8]                         # padded → last kv


def test_all_arch_dims_divide_tp():
    """d_model and d_ff of every assigned arch divide the model axis (16)."""
    for arch in configs.ARCHS:
        cfg = configs.get_config(arch)
        assert cfg.d_model % 16 == 0, arch
        if cfg.d_ff:
            assert cfg.d_ff % 16 == 0, arch
        assert cfg.vocab_padded % 16 == 0, arch


def test_vocab_padding_only_whisper():
    for arch in configs.ARCHS:
        cfg = configs.get_config(arch)
        if arch == "whisper-large-v3":
            assert cfg.vocab_padded == 51968 != cfg.vocab
        else:
            assert cfg.vocab_padded == cfg.vocab, arch


# ---------------------------------------------------------------------------
# param rules — shape-aware fallbacks (no mesh devices needed: use the
# spec-construction helper directly through a fake mesh namespace)
# ---------------------------------------------------------------------------

def test_moe_rule_fallback_logic():
    # 64 experts divide 16 → EP; 8 experts do not → d_ff TP
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    assert sharding.experts_shardable(64, FakeMesh())
    assert not sharding.experts_shardable(8, FakeMesh())


def test_spec_for_path_divisibility_guard():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    # odd vocab (51866) must NOT shard over model
    spec = sharding._spec_for_path("embed", (51866, 1280), FakeMesh(),
                                   "train")
    assert spec[0] is None and spec[1] == "data"
    # padded vocab shards
    spec = sharding._spec_for_path("embed", (51968, 1280), FakeMesh(),
                                   "train")
    assert spec[0] == "model"
    # mixtral stacked moe_gate: experts replicate, d_ff TP
    spec = sharding._spec_for_path("mu/layers/mlp/moe_gate",
                                   (56, 8, 6144, 16384), FakeMesh(), "train")
    assert tuple(spec) == (None, None, "data", "model")
    # moonshot stacked moe_gate: EP over model, fsdp over data
    spec = sharding._spec_for_path("layers/mlp/moe_gate",
                                   (48, 64, 2048, 1408), FakeMesh(), "train")
    assert tuple(spec) == (None, "model", "data", None)


def test_serve_mode_drops_fsdp():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    train = sharding._spec_for_path("layers/attn/wq", (24, 2048, 16, 128),
                                    FakeMesh(), "train")
    serve = sharding._spec_for_path("layers/attn/wq", (24, 2048, 16, 128),
                                    FakeMesh(), "serve")
    fsdp = sharding._spec_for_path("layers/attn/wq", (24, 2048, 16, 128),
                                   FakeMesh(), "serve_fsdp")
    assert train[1] == "data" and serve[1] is None and fsdp[1] == "data"
    assert train[2] == serve[2] == "model"
