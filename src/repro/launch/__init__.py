# NOTE: dryrun is intentionally not imported here — it sets XLA_FLAGS at
# import time and must only be imported as the program entry point.
from . import mesh, roofline, steps
