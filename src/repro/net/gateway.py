"""NetIngress / NetEgress — the packetized data plane over a runtime.

`NetGateway` is one wire endpoint serving one runtime (`ServeRuntime`,
`AsyncServeRuntime` or `FleetRuntime` — the handle shapes of all three
are adapted uniformly):

  ingress  datagram → decode (`frame.py`) → per-tenant `Reassembler`
           (bounded reorder window, dedup, seq-gap detection) → in-order
           sample chunks → `runtime.submit`, under per-tenant
           CREDIT-based backpressure (frames beyond the granted window
           park in a bounded queue; overflow drops + NACKs — a rude or
           slow tenant cannot grow the queue or stall the others).
  egress   resolved chunk handles → symbol DATA frames back out with the
           same per-tenant seq discipline, plus cumulative CREDIT grants
           (idempotent under wire duplication — each frame carries the
           grant TOTAL, not an increment) and an EOS trailer.

A seq gap (a frame displaced beyond the reorder window, i.e. lost) is a
surfaced per-tenant ``stream_gap`` error + NACK frame, never a silent
hole: the tenant stops emitting and `NetIngress.error()` reports it.

Everything is counted in the runtime's obs registry under ``net.*``
(frames in/out/dropped/crc_errors/reordered/duplicates/gaps/nacks,
credits granted, parked frames) and each emitted chunk's ingress→emit
latency lands in the ``net.ingress_to_emit_s`` histogram.
"""
from __future__ import annotations

import concurrent.futures
from collections import deque
from typing import Dict, List, Optional

import numpy as np

from .frame import (Frame, FrameError, FrameType, WireDtype, decode_frame,
                    encode_frame, encode_samples, wire_grid)

DEFAULT_REORDER_WINDOW = 64
DEFAULT_CREDITS = 64
DEFAULT_PARK_MAX = 256


def handle_done(h) -> bool:
    """True once a runtime chunk handle has landed (sync `Request` or
    async/fleet `concurrent.futures.Future`)."""
    if isinstance(h, concurrent.futures.Future):
        return h.done()
    return bool(h.done)


def handle_result(h) -> np.ndarray:
    """The landed handle's emitted symbols; raises the terminal launch
    error for a failed future."""
    if isinstance(h, concurrent.futures.Future):
        return h.result()
    return h.symbols


class Reassembler:
    """Seq → in-order delivery with a bounded reorder window.

    `offer` returns the items that just became deliverable in order.
    Duplicates (seq already delivered or buffered) are absorbed; a seq
    displaced beyond the window means an earlier frame can no longer
    arrive in-window — that's a permanent `gap`, latched until reset."""

    def __init__(self, window: int = DEFAULT_REORDER_WINDOW):
        self.window = int(window)
        self.expected = 0
        self.buffer: Dict[int, object] = {}
        self.gap: Optional[int] = None      # first missing seq, once latched
        self.duplicates = 0
        self.reordered = 0

    def offer(self, seq: int, item) -> List:
        if self.gap is not None:
            return []
        if seq < self.expected or seq in self.buffer:
            self.duplicates += 1
            return []
        if seq > self.expected and seq - self.expected > self.window:
            self.gap = self.expected
            return []
        if seq != self.expected:
            self.reordered += 1
            self.buffer[seq] = item
            return []
        out = [item]
        self.expected += 1
        while self.expected in self.buffer:
            out.append(self.buffer.pop(self.expected))
            self.expected += 1
        return out


class _TenantWire:
    """Per-tenant ingress state: reassembly, credits, parked backlog."""

    def __init__(self, window: int, credits: int, park_max: int):
        self.reasm = Reassembler(window)
        self.granted_total = credits    # cumulative credit grant (monotone)
        self.consumed = 0               # DATA frames submitted to the runtime
        self.parked: deque = deque()    # in-order items awaiting credit
        self.park_max = park_max
        self.t_oldest: Optional[float] = None
        self.error: Optional[str] = None
        self.eos_done = False


class NetIngress:
    """Datagram → in-order per-tenant sample chunks → `runtime.submit`."""

    def __init__(self, runtime, transport, egress: "NetEgress",
                 control=None, *, reorder_window: int = DEFAULT_REORDER_WINDOW,
                 initial_credits: int = DEFAULT_CREDITS,
                 park_max: int = DEFAULT_PARK_MAX):
        self.runtime = runtime
        self.transport = transport
        self.egress = egress
        self.control = control          # ControlPlane (or None: data-only)
        self.window = int(reorder_window)
        self.initial_credits = int(initial_credits)
        self.park_max = int(park_max)
        self.tenants: Dict[str, _TenantWire] = {}
        obs = runtime.obs
        self._clock = obs.clock
        scope = obs.scope("net")
        self.c_in = scope.counter("frames_in")
        self.c_crc = scope.counter("crc_errors")
        self.c_drop = scope.counter("frames_dropped")
        self.c_dup = scope.counter("duplicates")
        self.c_reord = scope.counter("reordered")
        self.c_gap = scope.counter("gaps")
        self.c_nack = scope.counter("nacks_sent")
        self.c_park = scope.counter("frames_parked")
        self._tracer = obs.tracer

    # -- registration ---------------------------------------------------------

    def register(self, tenant: str, credits: Optional[int] = None,
                 send_credit: bool = True) -> _TenantWire:
        """Start a tenant's wire stream (idempotent); grants its initial
        credit window. Call after `runtime.open` — the control plane's
        OPEN does this for wire-opened tenants."""
        state = self.tenants.get(tenant)
        if state is None:
            state = _TenantWire(self.window,
                                credits or self.initial_credits,
                                self.park_max)
            self.tenants[tenant] = state
            if send_credit:
                self.egress.send_credit(tenant, state.granted_total)
        return state

    def release(self, tenant: str) -> None:
        """Forget a tenant's wire state (after close)."""
        self.tenants.pop(tenant, None)
        self.egress.release(tenant)

    def error(self, tenant: str) -> Optional[str]:
        """The tenant's latched wire error ('stream_gap: ...'), if any."""
        state = self.tenants.get(tenant)
        return state.error if state else None

    # -- polling --------------------------------------------------------------

    def poll(self, max_datagrams: int = 64, timeout: float = 0.0) -> int:
        """Drain up to `max_datagrams` from the transport. Adversarial
        input never raises — malformed datagrams are counted and dropped."""
        n = 0
        for _ in range(max_datagrams):
            data = self.transport.recv(timeout=timeout)
            if data is None:
                break
            n += 1
            self.c_in.inc()
            try:
                frame = decode_frame(data)
            except FrameError as e:
                self.c_crc.inc()
                self.c_drop.inc()
                self._tracer.instant("net_bad_frame", error=repr(e))
                continue
            self._dispatch(frame)
        return n

    def _dispatch(self, frame: Frame) -> None:
        if frame.ftype in (FrameType.DATA, FrameType.EOS):
            self._on_data(frame)
        elif frame.ftype == FrameType.CTRL:
            if self.control is not None:
                self.control.handle(frame)
            else:
                self.c_drop.inc()
        else:                           # CREDIT/NACK/ACK are egress-bound
            self.c_drop.inc()

    def _on_data(self, frame: Frame) -> None:
        state = self.tenants.get(frame.tenant)
        if state is None:
            self.c_drop.inc()
            self._nack(frame.tenant, frame.seq, "unknown_tenant")
            return
        if state.error is not None:
            self.c_drop.inc()
            return
        before = (state.reasm.duplicates, state.reasm.reordered)
        ready = state.reasm.offer(frame.seq, frame)
        self.c_dup.inc(state.reasm.duplicates - before[0])
        self.c_reord.inc(state.reasm.reordered - before[1])
        if state.reasm.gap is not None:
            missing = state.reasm.gap
            state.error = f"stream_gap: seq {missing} lost (window " \
                          f"{state.reasm.window})"
            self.c_gap.inc()
            self._tracer.instant("net_gap", tenant=frame.tenant, seq=missing)
            self._nack(frame.tenant, missing, "stream_gap")
            return
        for f in ready:
            if len(state.parked) >= state.park_max:
                # sender ignoring its credit window: bounded, never grows
                self.c_drop.inc()
                self._nack(frame.tenant, f.seq, "credit_overflow")
                continue
            state.parked.append(f)
            if len(state.parked) > 1:
                self.c_park.inc()
        self._drain_parked(frame.tenant, state)

    def _drain_parked(self, tenant: str, state: _TenantWire) -> None:
        while state.parked:
            head: Frame = state.parked[0]
            if head.ftype == FrameType.EOS:
                state.parked.popleft()
                self._finish(tenant, state)
                continue
            if state.consumed >= state.granted_total:
                break                   # out of credit: parked, not dropped
            state.parked.popleft()
            self._submit(tenant, state, head)

    def _submit(self, tenant: str, state: _TenantWire, frame: Frame) -> None:
        samples = frame.samples()
        if state.t_oldest is None:
            state.t_oldest = self._clock()
        state.consumed += 1
        if frame.trace_id is not None and self._tracer.enabled:
            # v2 trace extension → session context, BEFORE submit so the
            # chunk span this submit opens picks it up at enqueue. With
            # tracing off nothing is queued (the deque would never drain)
            sessions = getattr(self.runtime, "sessions", None)
            try:
                sess = (sessions.get(tenant)
                        if sessions is not None else None)
            except KeyError:           # raced a close; context just drops
                sess = None
            if sess is not None:
                sess.trace_ctx.append(
                    (frame.trace_id, frame.t_client, self._clock()))
        handle = self.runtime.submit(tenant, samples)
        if handle is not None:
            self.egress.track(tenant, handle, 1, state.t_oldest)
            state.t_oldest = None
        else:
            # Sub-tile chunk absorbed into the chunker's carry with no
            # launchable plan: it no longer occupies wire-side memory, so
            # its credit returns NOW — otherwise a window smaller than
            # one tile's worth of frames would deadlock the stream.
            # Frames that DO yield a handle return their credit at emit.
            self.egress.grant(tenant, 1)

    def _finish(self, tenant: str, state: _TenantWire) -> None:
        if state.eos_done:
            return
        state.eos_done = True
        handle = self.runtime.finish(tenant)
        if handle is not None:           # EOS consumed no credit: n_frames=0
            self.egress.track(tenant, handle, 0,
                              state.t_oldest or self._clock())
            state.t_oldest = None
        self.egress.finish(tenant)

    def grant_pending(self, tenant: str, n_frames: int = 0) -> None:
        """Credit granted (egress callback): grow this side's ledger —
        the same total the CREDIT frame announces to the client — and
        retry the parked backlog against it."""
        state = self.tenants.get(tenant)
        if state is not None:
            state.granted_total += int(n_frames)
            if state.error is None:
                self._drain_parked(tenant, state)

    def _nack(self, tenant: str, seq: int, reason: str) -> None:
        self.c_nack.inc()
        payload = reason.encode("utf-8")
        try:
            self.transport.send(encode_frame(FrameType.NACK, tenant, seq,
                                             payload))
        except (OSError, ValueError):
            pass

    def flush_gaps(self) -> List[str]:
        """End-of-run sweep: any tenant still holding reordered frames
        with no way to progress (stream went quiet mid-gap) latches a
        `stream_gap` error. Call only once the wire is known drained."""
        flagged = []
        for tenant, state in self.tenants.items():
            if state.error is None and state.reasm.buffer:
                missing = state.reasm.expected
                state.error = f"stream_gap: seq {missing} lost (stream idle)"
                self.c_gap.inc()
                self._nack(tenant, missing, "stream_gap")
                flagged.append(tenant)
        return flagged


class _EgressStream:
    def __init__(self):
        self.fifo: deque = deque()      # (handle, n_frames, t_ingress)
        self.out_seq = 0
        self.eos_pending = False
        self.eos_sent = False
        self.granted_total = 0          # mirrors ingress grants (cumulative)


class NetEgress:
    """Resolved chunk handles → symbol DATA frames + credit grants out."""

    def __init__(self, runtime, transport,
                 symbol_dtype: WireDtype = WireDtype.FP32):
        self.runtime = runtime
        self.transport = transport
        self.symbol_dtype = symbol_dtype
        self.streams: Dict[str, _EgressStream] = {}
        self.on_credit = None           # ingress.grant_pending, via gateway
        obs = runtime.obs
        self._clock = obs.clock
        scope = obs.scope("net")
        self.c_out = scope.counter("frames_out")
        self.c_credits = scope.counter("credits_granted")
        self.h_latency = scope.histogram(
            "ingress_to_emit_s", window=obs.retention.latency_window)

    def _stream(self, tenant: str) -> _EgressStream:
        s = self.streams.get(tenant)
        if s is None:
            s = self.streams[tenant] = _EgressStream()
        return s

    def release(self, tenant: str) -> None:
        self.streams.pop(tenant, None)

    def track(self, tenant: str, handle, n_frames: int,
              t_ingress: float) -> None:
        self._stream(tenant).fifo.append((handle, n_frames, t_ingress))

    def finish(self, tenant: str) -> None:
        self._stream(tenant).eos_pending = True

    def send_credit(self, tenant: str, granted_total: int) -> None:
        """Announce the cumulative grant (safe to repeat/duplicate)."""
        s = self._stream(tenant)
        s.granted_total = max(s.granted_total, granted_total)
        payload = int(s.granted_total).to_bytes(4, "little")
        self.transport.send(encode_frame(FrameType.CREDIT, tenant, 0,
                                         payload))

    def grant(self, tenant: str, n_frames: int) -> None:
        s = self._stream(tenant)
        self.c_credits.inc(n_frames)
        self.send_credit(tenant, s.granted_total + n_frames)
        if self.on_credit is not None:   # grow the ingress ledger in step
            self.on_credit(tenant, n_frames)

    def pump(self) -> int:
        """Emit every landed head-of-line chunk; returns frames sent."""
        sent = 0
        for tenant, s in list(self.streams.items()):
            while s.fifo and handle_done(s.fifo[0][0]):
                handle, n_frames, t_ingress = s.fifo.popleft()
                syms = handle_result(handle)   # raises on terminal failure
                payload = encode_samples(np.asarray(syms, np.float32),
                                         self.symbol_dtype)
                self.transport.send(encode_frame(
                    FrameType.DATA, tenant, s.out_seq, payload,
                    dtype=self.symbol_dtype))
                s.out_seq += 1
                sent += 1
                self.c_out.inc()
                self.h_latency.observe(self._clock() - t_ingress)
                if n_frames:
                    self.grant(tenant, n_frames)
            if s.eos_pending and not s.fifo and not s.eos_sent:
                self.transport.send(encode_frame(FrameType.EOS, tenant,
                                                 s.out_seq))
                s.out_seq += 1
                s.eos_sent = True
                sent += 1
                self.c_out.inc()
        return sent


class NetGateway:
    """One wire endpoint serving one runtime: ingress + egress (+ control).

        gw = NetGateway(runtime, server_transport)
        gw.open_wire("t0")            # after runtime.open(spec) — or let
                                      # the control plane OPEN do both
        while driving: gw.step()      # poll wire, pump policy, emit
        gw.settle()                   # drain to quiescence at end-of-run
    """

    def __init__(self, runtime, transport, *,
                 reorder_window: int = DEFAULT_REORDER_WINDOW,
                 initial_credits: int = DEFAULT_CREDITS,
                 park_max: int = DEFAULT_PARK_MAX,
                 enable_control: bool = True):
        self.runtime = runtime
        self.transport = transport
        self.egress = NetEgress(runtime, transport)
        control = None
        if enable_control:
            from .control import ControlPlane
            control = ControlPlane(runtime, self)
        self.control = control
        self.ingress = NetIngress(runtime, transport, self.egress, control,
                                  reorder_window=reorder_window,
                                  initial_credits=initial_credits,
                                  park_max=park_max)
        self.egress.on_credit = self.ingress.grant_pending

    def open_wire(self, tenant: str, credits: Optional[int] = None) -> None:
        """Attach an already-`runtime.open`ed tenant to the wire."""
        self.ingress.register(tenant, credits=credits)

    def step(self, max_datagrams: int = 64) -> int:
        """One cooperative scheduling pass; returns an activity count."""
        n = self.ingress.poll(max_datagrams=max_datagrams)
        self.runtime.pump()
        return n + self.egress.pump()

    def settle(self, max_rounds: int = 10_000) -> None:
        """Drive to quiescence: poll the wire dry, force-launch whatever
        is pending (`drain` — batching composition never changes bits,
        contract #4), emit. Loops until a full round does nothing."""
        for _ in range(max_rounds):
            n = self.ingress.poll(max_datagrams=256)
            self.runtime.drain()
            n += self.egress.pump()
            if n == 0:
                return
        raise RuntimeError("NetGateway.settle did not quiesce")
