"""repro — CNN-based equalization at gigabit throughput, as a multi-pod
JAX/TPU framework (reproduction + extension of Ney et al., 2024)."""

__version__ = "1.0.0"
