from . import sharding
from . import halo
