"""High-throughput equalizer operating point (paper §3.5 / §7.2).

The DSE-selected CNN (V_p=8, L=3, K=9, C=5) for the 40 GBd IM/DD optical
channel, deployed at N_i = 64 parallel instances (FPGA: XCVU13P @ 200 MHz →
T_max = 102 GSa/s ≥ the 80 GSa/s requirement; ℓ_inst = 7320 ⇒ 17.5 µs
symbol latency).
"""
from ..channels.imdd import IMDDConfig
from ..core.equalizer import CNNEqConfig

CNN = CNNEqConfig(layers=3, kernel=9, channels=5, v_parallel=8, n_os=2,
                  levels=2)
CHANNEL = IMDDConfig()
N_INSTANCES = 64
F_CLK = 200e6                 # FPGA clock (timing-model baseline)
T_REQ_SAMPLES = 80e9          # 40 GBd × N_os
L_INST = 7320                 # paper's selected per-instance length (symbols)
