"""qwen3-0.6b — dense GQA with qk-norm [hf:Qwen/Qwen3-8B family; hf].

28L · d_model 1024 · 16 heads (GQA kv=8) · head_dim 128 (decoupled from
d_model, as in Qwen3) · d_ff 3072 · vocab 151936 · qk_norm.
"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, d_head=128,
    d_ff=3072, vocab=151936, qk_norm=True,
    tp=16, train_accum=2,
)

REDUCED = ModelConfig(
    name="qwen3-reduced", family="dense",
    n_layers=3, d_model=128, n_heads=4, n_kv_heads=2, d_head=48,
    d_ff=256, vocab=512, qk_norm=True, dtype="float32",
)
