"""Stateful overlap-save chunking — streaming ⇒ offline equivalence.

A tenant streams waveform samples in ARBITRARY chunk sizes (including chunks
smaller than the receptive field); the serving runtime must emit exactly the
symbols the offline engine would produce on the concatenated stream —
bitwise for the fp32/bf16 datapaths, ≤1 LSB (observed: bitwise) for int8.

This is the paper's OGM/ORM overlap machinery turned stateful: instead of
splitting one long recorded stream into overlapped chunks (stream_partition),
the chunker carries the receptive-field tail of an UNBOUNDED stream between
arrivals.

How bitwise equivalence is achieved
-----------------------------------
The fused kernel computes output position p (one network pass = V_p symbols)
from the input window  x[p·ts − halo, p·ts + halo]  (ts = V_p·N_os samples
per pass, halo = half a receptive field in samples), processing positions in
tiles of `tile_m` with identical per-tile shapes everywhere in the stream.
Each output element is an independent chain of tap dots over its own window
— no cross-position reduction — so an element's value depends ONLY on

  (a) its window's sample values, and
  (b) its position WITHIN a tile (which fixes the op shapes around it).

The chunker therefore keeps its carry aligned to TILE boundaries: the buffer
always starts at a sample offset  o = o_pos·ts  with  o_pos ≡ 0 (mod
tile_m), so every position lands in the same tile column as in the offline
call, and its window content is identical ⇒ bitwise-equal output. The
positions recomputed for alignment/context (≤ tile_m + ⌈halo/ts⌉ per launch)
are sliced off before emission.

`StreamChunker` is pure bookkeeping (numpy, host-side) — it never runs the
engine. It hands out `ChunkPlan`s: (engine input row, positions to skip,
positions to emit); the micro-batcher pads plans from many tenants to a
common width bucket and runs them as ONE stacked fused launch.

The contract is UNCONDITIONAL on stream length: `_fused_call` never
shrinks the requested `tile_m` (a stream shorter than one tile pads the
tile out exactly like serve's full-tile buckets do), so the offline call
tiles identically to the serve launches even for micro-streams — it once
clamped `tile_m` to the stream's positions, which changed the tile-column
op shapes and cost micro-streams 1–2 ULP vs serve
(`tests/test_net.py::test_wire_micro_stream_lengths_bitwise` regresses
the fix).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class CarrySnapshot:
    """An immutable copy of a `StreamChunker`'s full stream state.

    Taken by `StreamChunker.snapshot` and reinstalled by `restore` — the
    failover primitive: a session whose engine died mid-stream rebuilds
    the engine from its `TenantSpec` and re-equalizes from the saved
    carry, emitting exactly the symbols the uninterrupted stream would
    have (the chunker is pure bookkeeping, so state capture IS stream
    capture). The arrays are copied on both capture and restore, so a
    snapshot stays valid however the live chunker advances afterwards.
    """
    buf: np.ndarray
    o_pos: int
    next_pos: int
    total_samples: int
    finished: bool


@dataclasses.dataclass
class ChunkPlan:
    """One pending engine launch for one tenant stream.

    data:    (W,) fp32 engine input — carry + new samples (+ flush padding).
    skip:    leading output positions to DROP (alignment/context recompute).
    n_emit:  output positions to emit after `skip` (V_p symbols each).
    span:    optional `repro.obs.ChunkSpan` lifecycle trace attached at
             enqueue when tracing is on (None otherwise). It rides the plan
             through retries, failover replays, and fleet migrations so the
             chunk's full recovery path lands in one span.
    """
    data: np.ndarray
    skip: int
    n_emit: int
    span: Optional[object] = None

    @property
    def width(self) -> int:
        return int(self.data.shape[0])


class StreamChunker:
    """Carries the receptive-field tail of one tenant's sample stream.

    halo:         half receptive field, in SAMPLES (engine.halo_samples;
                  ≥ 0 or __init__ raises ValueError).
    total_stride: samples consumed per output position, V_p · N_os
                  (engine.total_stride; ≥ 1 or ValueError).
    tile_m:       the engine's resolved tile width, in POSITIONS (≥ 1 or
                  ValueError) — carry stays tile-aligned so chunked output
                  is bitwise-equal to offline (see module docstring). Must
                  be the tile the launches actually use; fixed for the
                  stream's lifetime.

    Failure modes: `push()` after `finish()` raises RuntimeError (the
    stream contract is append-then-seal); everything else is total —
    `plan()` returns None rather than raising when nothing is emittable.
    """

    def __init__(self, halo: int, total_stride: int, tile_m: int):
        if total_stride <= 0 or tile_m <= 0 or halo < 0:
            raise ValueError("halo ≥ 0, total_stride ≥ 1, tile_m ≥ 1")
        self.halo = halo
        self.ts = total_stride
        self.tile_m = tile_m
        # positions needed as left context before the next unemitted one
        self._ctx_pos = -(-halo // total_stride)           # ceil
        self._buf = np.zeros((0,), np.float32)
        self._o_pos = 0          # global position index of buf sample 0
        self._next_pos = 0       # next global position to emit
        self._total_samples = 0  # total samples pushed so far
        self.finished = False

    # -- stream input ------------------------------------------------------

    def push(self, samples: np.ndarray) -> None:
        """Append a chunk of waveform samples (any length ≥ 0)."""
        if self.finished:
            raise RuntimeError("stream already finished")
        s = np.asarray(samples, np.float32).reshape(-1)
        self._buf = np.concatenate([self._buf, s])
        self._total_samples += s.shape[0]

    def finish(self) -> None:
        """Mark end-of-stream: remaining positions flush with zero right-
        padding, exactly like the offline engine pads its stream tail."""
        self.finished = True

    # -- launch planning ---------------------------------------------------

    def pending_positions(self) -> int:
        """Positions ready to emit right now (full real-sample windows; at
        end-of-stream, everything up to ⌊total/ts⌋ — the offline count)."""
        if self.finished:
            total = self._total_samples // self.ts
            return max(0, total - self._next_pos)
        n = self._buf.shape[0]
        if n <= self.halo:
            return 0
        avail = (n - 1 - self.halo) // self.ts + 1         # windows complete
        avail = min(avail, n // self.ts)                   # engine computes
        return max(0, avail - (self._next_pos - self._o_pos))

    def plan(self) -> Optional[ChunkPlan]:
        """Build the next launch plan, or None if nothing is emittable."""
        n_emit = self.pending_positions()
        if n_emit == 0:
            return None
        skip = self._next_pos - self._o_pos
        data = self._buf
        need = (skip + n_emit) * self.ts                   # engine n_pos cover
        if data.shape[0] < need:                           # flush tail pad
            data = np.concatenate(
                [data, np.zeros((need - data.shape[0],), np.float32)])
        return ChunkPlan(data=data, skip=skip, n_emit=n_emit)

    def commit(self, plan: ChunkPlan) -> None:
        """Advance the stream past `plan` and trim the carry tile-aligned."""
        self._next_pos += plan.n_emit
        # keep ≥ ctx_pos positions of context, rounded DOWN to a tile edge
        new_o = max(0, ((self._next_pos - self._ctx_pos)
                        // self.tile_m) * self.tile_m)
        new_o = max(new_o, self._o_pos)                    # monotonic
        drop = (new_o - self._o_pos) * self.ts
        if drop:
            self._buf = self._buf[drop:]
            self._o_pos = new_o

    # -- failover: carry snapshot / restore --------------------------------

    def snapshot(self) -> CarrySnapshot:
        """Capture the complete stream state (deep copy). Bitwise-exact:
        a chunker restored from this snapshot plans and emits the same
        positions, with the same tile alignment, as one that never
        detoured — regardless of any pushes/commits in between."""
        return CarrySnapshot(buf=self._buf.copy(), o_pos=self._o_pos,
                             next_pos=self._next_pos,
                             total_samples=self._total_samples,
                             finished=self.finished)

    def restore(self, snap: CarrySnapshot) -> None:
        """Reinstall a snapshot taken from THIS stream (or a stream with
        the same halo/stride/tile geometry — restoring across geometries
        would break the tile-alignment invariant, and is the caller's
        bug). Everything pushed or committed since the snapshot is
        discarded."""
        self._buf = snap.buf.copy()
        self._o_pos = snap.o_pos
        self._next_pos = snap.next_pos
        self._total_samples = snap.total_samples
        self.finished = snap.finished

    # -- introspection -----------------------------------------------------

    @property
    def carry_samples(self) -> int:
        return int(self._buf.shape[0])

    @property
    def emitted_positions(self) -> int:
        return self._next_pos
