"""Jitted wrapper: run the fused Pallas equalizer from core params."""
from __future__ import annotations

from typing import Any, Dict

import jax.numpy as jnp

from ...core.equalizer import CNNEqConfig, fold_bn
from .cnn_eq import cnn_eq_fused
from .ref import cnn_eq as cnn_eq_ref


def strides_of(cfg: CNNEqConfig):
    return tuple(s for _, _, s in cfg.layer_specs())


def weights_of(folded: Dict[str, Any]):
    return tuple((l["w"], l["b"]) for l in folded["conv"])


def equalize(params: Dict[str, Any], bn_state, x: jnp.ndarray,
             cfg: CNNEqConfig, use_pallas: bool = True,
             tile_m: int = 64) -> jnp.ndarray:
    """Deployment-path inference: fold BN, run the fused kernel."""
    folded = fold_bn(params, bn_state, cfg)
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None]
    fn = cnn_eq_fused if use_pallas else cnn_eq_ref
    kwargs = {"tile_m": tile_m} if use_pallas else {}
    y = fn(x, weights_of(folded), strides_of(cfg), **kwargs)
    return y[0] if squeeze else y


__all__ = ["cnn_eq_fused", "cnn_eq_ref", "equalize", "strides_of", "weights_of"]
