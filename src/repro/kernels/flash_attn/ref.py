"""Pure-jnp oracle for the flash-attention forward kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def mha(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
        causal: bool = True, window: int = 0,
        q_offset: int = 0) -> jnp.ndarray:
    """q: (B, Sq, H, D), k/v: (B, Sk, H, D) → (B, Sq, H, D).

    Softmax in f32; positions: q[i] is absolute q_offset + i, k[j] is j.
    """
    scale = 1.0 / np.sqrt(q.shape[-1])
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = q_offset + jnp.arange(q.shape[1])[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones_like(s, bool)
    if causal:
        mask &= (kpos <= qpos)[None, None]
    if window > 0:
        mask &= (kpos > qpos - window)[None, None]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
