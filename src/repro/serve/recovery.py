"""Fault-tolerant serving — deterministic fault injection, session
failover, and straggler-driven graceful degradation.

The source paper's receiver sits in a live signal path: an equalizer that
stops emitting symbols because one launch died has failed its contract
even if every bit it DID emit was perfect. This module upgrades the
serving stack's failure semantics from "poison the stream on terminal
failure" to "recover with bitwise-intact streams", exploiting the PR 3
invariant the ROADMAP names: engines are disposable — a session rebuilds
its engine deterministically from `TenantSpec`, and the chunker carry
(plus the self-contained `ChunkPlan` input snapshots of in-flight chunks)
is the complete stream state. Failover is therefore a REBUILD + REPLAY,
not a loss:

  * `FaultPlan` — a deterministic chaos schedule (generalizing the
    training loop's `repro.runtime.fault` `FailureInjector` from "fail at
    step k" to four serving fault kinds): launch exceptions, launch
    delays, engine-build failures, and NaN/saturated output corruption,
    each at scheduled launch/build indices. Wired as an optional hook
    through `MicroBatcher.execute` (injection), `MicroBatcher.descatter`
    (sentinel detection) and `EnginePool.get` builds — both serving
    drivers can inject, so chaos tests and `benchmarks/bench_fault.py`
    share one mechanism.
  * `RecoveryPolicy` + `RecoveryStats` — failover bounds (recoveries per
    session, engine-rebuild retries, backoff shape, output-sentinel
    limit) and the counters/latency histogram `bench_fault` publishes.
  * `output_ok` — the cheap output-sentinel check: every emitted value
    must be finite and inside `sentinel_limit`. PAM soft symbols live in
    O(1) range, so a huge limit still catches NaN/Inf and saturated
    garbage without ever tripping on healthy traffic. A corrupted stacked
    output raises `CorruptOutput` BEFORE any row is emitted; the async
    runtime quarantines it — replays the chunks through a rebuilt engine,
    and (when the session recently hot-swapped weights) rolls the weights
    back via the PR 5 `prev_spec` path instead of emitting garbage.
  * `DegradationController` — a revived `repro.runtime.straggler`
    `StragglerMonitor` over LAUNCH latencies: under persistent slowness
    it shrinks `BatchPolicy.max_batch` (smaller stacked launches → lower
    per-launch latency) and sheds the lowest-priority tenants
    (`TenantSpec.priority`; their submits raise `TenantShedError` until
    health returns); after `patience` consecutive clean launches both
    mitigations are restored.

Everything here is host-side bookkeeping — no jax imports; the device
only ever sees replayed `ChunkPlan` snapshots, which is why replayed
output is bitwise-identical to the uninterrupted stream (contract #9 in
docs/ARCHITECTURE.md "Failure semantics & recovery").
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..runtime.straggler import StragglerConfig, StragglerMonitor


# ---------------------------------------------------------------------------
# failure taxonomy
# ---------------------------------------------------------------------------

class InjectedFault(RuntimeError):
    """A fault fired by a `FaultPlan` (launch or engine-build)."""


class LaunchTimeout(RuntimeError):
    """The launch watchdog expired: the device call exceeded its deadline
    and was abandoned (the hung worker thread is discarded)."""


class CorruptOutput(RuntimeError):
    """The output sentinel rejected a stacked launch result (NaN/Inf or
    out-of-range values) before anything was emitted."""


class DeviceLost(RuntimeError):
    """A fleet worker's device is gone (injected `device_lost` fault, or
    declared by the fleet health model after consecutive terminal launch
    failures). Not retryable on the same worker — the fleet controller
    migrates the worker's sessions to a surviving device instead
    (`repro.serve.fleet`)."""


class TenantShedError(RuntimeError):
    """Submit refused: the tenant is currently shed by the degradation
    controller. Back off and retry after the runtime reports healthy."""


# ---------------------------------------------------------------------------
# deterministic fault injection
# ---------------------------------------------------------------------------

# fault kinds and the index space their `at` is scheduled in
_LAUNCH_KINDS = ("launch_error", "launch_delay", "corrupt")   # execute index
_BUILD_KINDS = ("build_error",)                               # build index
_DEVICE_KINDS = ("device_lost", "device_slow")                # worker index


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault.

    kind:    "launch_error" (execute raises), "launch_delay" (execute
             sleeps `delay_s` before dispatch — drives the straggler
             monitor and, past the deadline, the watchdog),
             "build_error" (an `EnginePool` miss's build raises — hits
             session opens AND failover rebuilds), "corrupt" (the
             stacked output is overwritten with NaN/saturated values),
             "device_lost" (a fleet worker's execute raises `DeviceLost`
             — the whole worker dies and its sessions migrate), or
             "device_slow" (a fleet worker's execute sleeps `delay_s` —
             drives the worker's straggler-fed health model).
    at:      the scheduled index — the batcher's execute-attempt counter
             for launch kinds, the pool's build counter for build_error,
             and the WORKER index for device kinds (which worker of the
             fleet the fault hits). Each fault fires AT MOST ONCE
             (replays consume fresh indices, so a recovered launch is
             clean by construction).
    after:   device kinds only: the worker's execute-attempt index at or
             beyond which the fault fires (default 0 = the worker's first
             launch). Lets a chaos test kill a worker MID-stream, after
             some launches have already landed.
    delay_s: sleep for "launch_delay" / "device_slow" (seconds).
    mode:    corruption shape for "corrupt": "nan" or "saturate" (±1e9).
    rows:    stacked rows to corrupt (None → every row).
    """
    kind: str
    at: int
    after: int = 0
    delay_s: float = 0.0
    mode: str = "nan"
    rows: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        if self.kind not in _LAUNCH_KINDS + _BUILD_KINDS + _DEVICE_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.mode not in ("nan", "saturate"):
            raise ValueError(f"unknown corrupt mode {self.mode!r}")
        if self.after and self.kind not in _DEVICE_KINDS:
            raise ValueError(
                f"`after` only applies to device fault kinds, not "
                f"{self.kind!r}")


class FaultPlan:
    """Deterministic fault schedule for serving chaos tests and
    `benchmarks/bench_fault.py`.

    Hooks (each fires its fault at most once, under an internal lock —
    pool builds and launches run on different threads):

      on_execute(idx)      — called by `MicroBatcher.execute` before the
                             device dispatch; may sleep (launch_delay) or
                             raise `InjectedFault` (launch_error).
      on_output(idx, y)    — called after the launch lands; returns `y`
                             or a corrupted copy (corrupt).
      on_build(idx)        — called by `EnginePool.get` before a miss's
                             build; may raise `InjectedFault`.
      on_worker(worker, idx) — called by `MicroBatcher.execute` when the
                             batcher belongs to a fleet worker
                             (`worker_index` set), BEFORE on_execute; may
                             sleep (device_slow) or raise `DeviceLost`
                             (device_lost) once the worker's execute
                             index reaches the fault's `after`.

    `fired` lists (kind, at) in fire order — the assertion surface for
    tests ("the chaos really happened") and the bench report.
    """

    def __init__(self, faults: Sequence[Fault] = ()):
        self._faults: Dict[Tuple[str, int], Fault] = {}
        for f in faults:
            key = (f.kind, f.at)
            if key in self._faults:
                raise ValueError(f"duplicate fault {key}")
            self._faults[key] = f
        self._lock = threading.Lock()
        self.fired: List[Tuple[str, int]] = []

    def _take(self, kind: str, idx: int) -> Optional[Fault]:
        with self._lock:
            f = self._faults.get((kind, idx))
            if f is None or (kind, idx) in self.fired:
                return None
            self.fired.append((kind, idx))
            return f

    def _take_after(self, kind: str, worker: int,
                    idx: int) -> Optional[Fault]:
        """Take a device fault scheduled on `worker` once that worker's
        execute index has reached the fault's `after` (at most once,
        thread-safe — fleet workers launch concurrently)."""
        with self._lock:
            f = self._faults.get((kind, worker))
            if (f is None or (kind, worker) in self.fired
                    or idx < f.after):
                return None
            self.fired.append((kind, worker))
            return f

    # -- hooks -------------------------------------------------------------

    def on_execute(self, idx: int) -> None:
        f = self._take("launch_delay", idx)
        if f is not None:
            time.sleep(f.delay_s)
        f = self._take("launch_error", idx)
        if f is not None:
            raise InjectedFault(f"injected launch error at launch {idx}")

    def on_output(self, idx: int, y: np.ndarray) -> np.ndarray:
        f = self._take("corrupt", idx)
        if f is None:
            return y
        y = np.array(y, copy=True)
        rows = range(y.shape[0]) if f.rows is None else f.rows
        bad = np.nan if f.mode == "nan" else 1e9
        for r in rows:
            if 0 <= r < y.shape[0]:
                y[r] = bad
        return y

    def on_build(self, idx: int) -> None:
        f = self._take("build_error", idx)
        if f is not None:
            raise InjectedFault(f"injected engine-build failure "
                                f"at build {idx}")

    def on_worker(self, worker: int, idx: int) -> None:
        """Device-level faults for fleet worker `worker` at its execute
        index `idx` (each fires at most once; see `Fault.after`)."""
        f = self._take_after("device_slow", worker, idx)
        if f is not None:
            time.sleep(f.delay_s)
        f = self._take_after("device_lost", worker, idx)
        if f is not None:
            raise DeviceLost(f"injected device loss on worker {worker} "
                             f"at execute {idx}")

    # -- introspection -----------------------------------------------------

    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._faults) - len(self.fired)

    def summary(self) -> Dict[str, int]:
        with self._lock:
            out: Dict[str, int] = {}
            for kind, _ in self.fired:
                out[kind] = out.get(kind, 0) + 1
            return out


# ---------------------------------------------------------------------------
# output sentinel
# ---------------------------------------------------------------------------

def output_ok(y: np.ndarray, limit: float) -> bool:
    """Cheap corruption check on a stacked launch output: every value
    finite and |value| ≤ limit. One vectorized pass — O(B·S) adds, noise
    next to the kernel launch it guards."""
    m = float(np.max(np.abs(y))) if y.size else 0.0
    return bool(np.isfinite(m) and m <= limit)


# ---------------------------------------------------------------------------
# recovery policy / accounting
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """Failover bounds and backoff shape for `AsyncServeRuntime`.

    max_session_recoveries: failover rounds a single session may consume
                  before its stream is poisoned the old way (count;
                  default 4). The bound that keeps a permanently dead
                  device from looping forever.
    build_retries: engine-rebuild attempts per failover before the
                  session is declared unrecoverable (count; default 2).
    backoff_base_s / backoff_max_s: exponential backoff between in-place
                  launch retries, rebuild attempts, and failover rounds —
                  base·2^attempt, capped (seconds; defaults 0.02 / 1.0).
                  Back-to-back retries against a sick device only pile
                  more work on it.
    jitter:       backoff randomization fraction (default 0.25); the
                  jitter RNG is seeded per runtime, so sleep sequences
                  are reproducible run-to-run.
    sentinel_limit: output-sentinel bound (|value| ≤ limit, finite;
                  default 1e4 — PAM soft symbols are O(1), so this only
                  trips on genuine garbage). None disables the check.
    rollback_on_corrupt: when corrupted output is detected on a session
                  that has hot-swapped weights (`prev_spec` present),
                  roll the weights back bit-identically before replaying
                  (at most once per session; default True).
    device_lost_after: fleet health model only — consecutive TERMINAL
                  launch failures on one worker before the fleet declares
                  its device lost and migrates every resident session
                  (count; default None = never; `FleetRuntime` defaults
                  its own policy to 2). Meaningless for the single-device
                  `AsyncServeRuntime`, which has nowhere to migrate.
    """
    max_session_recoveries: int = 4
    build_retries: int = 2
    backoff_base_s: float = 0.02
    backoff_max_s: float = 1.0
    jitter: float = 0.25
    sentinel_limit: Optional[float] = 1e4
    rollback_on_corrupt: bool = True
    device_lost_after: Optional[int] = None

    def backoff_s(self, attempt: int, rng: random.Random) -> float:
        """Backoff before retry `attempt` (0-based): exponential, capped,
        jittered ±`jitter` fraction."""
        base = min(self.backoff_max_s,
                   self.backoff_base_s * (2.0 ** attempt))
        if self.jitter <= 0:
            return base
        return base * (1.0 - self.jitter + 2.0 * self.jitter * rng.random())


class RecoveryStats:
    """Failover counters + a bounded recovery-latency window (the numbers
    `benchmarks/bench_fault.py` publishes and `stats()["recovery"]`
    exposes; a fleet keeps one ledger PER WORKER).

    Thread-safe: every mutation goes through `bump`/`record_recovery`
    under an internal lock and `as_dict` snapshots under the same lock —
    fleet launcher threads and the fleet controller race the counters
    (PR 6 had a single launcher thread and mutated attributes directly).
    Counter reads stay plain attribute access (ints are consistent under
    the GIL; only read-modify-write needs the lock).
    """

    WINDOW = 256
    FIELDS = ("recoveries",            # failover rounds relaunched
              "chunks_replayed",       # requests re-equalized by failover
              "engine_rebuilds",       # pool entries dropped + rebuilt
              "deadline_timeouts",     # watchdog expirations
              "corrupt_detected",      # sentinel rejections
              "rollbacks",             # corrupt → prev_spec reinstalls
              "sessions_poisoned",     # streams lost despite recovery
              "device_losses",         # this worker's device declared lost
              "sessions_migrated_out",  # sessions this worker lost to peers
              "sessions_migrated_in")   # sessions adopted from dead peers

    def __init__(self):
        self._lock = threading.Lock()
        for f in self.FIELDS:
            setattr(self, f, 0)
        self.recovery_s: Deque[float] = deque(maxlen=self.WINDOW)

    def bump(self, field: str, n: int = 1) -> None:
        """Atomically increment one counter (must be a FIELDS name)."""
        if field not in self.FIELDS:
            raise AttributeError(f"unknown recovery counter {field!r}")
        with self._lock:
            setattr(self, field, getattr(self, field) + n)

    def record_recovery(self, dt: float) -> None:
        with self._lock:
            self.recovery_s.append(dt)

    def as_dict(self) -> Dict:
        with self._lock:
            lat = sorted(self.recovery_s)
            out = {f: getattr(self, f) for f in self.FIELDS}
        q = lambda f: lat[int(f * (len(lat) - 1))] if lat else 0.0
        out["p50_recovery_s"] = q(0.5)
        out["max_recovery_s"] = q(1.0)
        return out


# ---------------------------------------------------------------------------
# graceful degradation
# ---------------------------------------------------------------------------

class DegradationController:
    """Shrink-and-shed under persistent launch slowness, restore when
    healthy.

    Feeds every launch latency to a `StragglerMonitor`; when the
    monitor's `degraded` latch turns on (persistent slowness: `patience`
    consecutive flagged launches), the controller halves
    `BatchPolicy.max_batch` (floor 1) and sheds the `shed_count`
    lowest-priority open sessions (ties broken by tenant_id, so the shed
    set is deterministic) — their submits raise `TenantShedError`. When
    the latch decays (`patience` consecutive clean launches) the original
    policy is restored and shed tenants are readmitted.

    `mitigate=False` keeps the monitor observing (health visible in
    `stats()`) without ever mutating policy or shedding — the default for
    `AsyncServeRuntime`, which makes load shedding an explicit opt-in
    (`degrade_on_slow=True`): silently rejecting tenant traffic is a
    policy decision, not a default.

    Thread-safety: `observe` must be called under the runtime lock (it
    may mutate the batcher policy and session flags).
    """

    def __init__(self, batcher, sessions,
                 cfg: Optional[StragglerConfig] = None,
                 shed_count: int = 1, mitigate: bool = True):
        self.batcher = batcher
        self.sessions = sessions
        self.shed_count = shed_count
        self.mitigate = mitigate
        self.monitor = StragglerMonitor(cfg or StragglerConfig(),
                                        on_straggler=self._degrade,
                                        on_recovered=self._restore)
        self._orig_policy = None
        self.shed_ids: List[str] = []
        self.events: Deque[tuple] = deque(maxlen=64)

    def observe(self, launch_idx: int, dt: float) -> bool:
        """Record one launch latency (seconds); returns True if flagged.
        Caller holds the runtime lock."""
        return self.monitor.observe(launch_idx, dt)

    @property
    def degraded(self) -> bool:
        return self.monitor.degraded

    # -- mitigation edges (fired by the monitor, under observe's lock) -----

    def _degrade(self, step: int, dt: float) -> None:
        if not self.mitigate:
            self.events.append(("degrade_advisory", step))
            return
        pol = self.batcher.policy
        if self._orig_policy is None:
            self._orig_policy = pol
        self.batcher.policy = dataclasses.replace(
            pol, max_batch=max(1, pol.max_batch // 2))
        for s in sorted(self.sessions.sessions.values(),
                        key=lambda s: (s.spec.priority, s.spec.tenant_id)):
            if len(self.shed_ids) >= self.shed_count:
                break
            if s.spec.tenant_id not in self.shed_ids:
                s.shed = True
                self.shed_ids.append(s.spec.tenant_id)
        self.events.append(("degrade", step, self.batcher.policy.max_batch,
                            tuple(self.shed_ids)))

    def _restore(self, step: int) -> None:
        if not self.mitigate:
            self.events.append(("restore_advisory", step))
            return
        if self._orig_policy is not None:
            self.batcher.policy = self._orig_policy
            self._orig_policy = None
        for tid in self.shed_ids:
            if tid in self.sessions:
                self.sessions.get(tid).shed = False
        self.shed_ids.clear()
        self.events.append(("restore", step))

    def state(self) -> Dict:
        return {"degraded": self.degraded,
                "mitigate": self.mitigate,
                "max_batch": self.batcher.policy.max_batch,
                "shed": list(self.shed_ids),
                "straggler": self.monitor.summary()}
