"""Fault-tolerant training loop: checkpoint/restart with failure injection.

The 1000-node contract: a training job is a PURE FUNCTION of (checkpoint,
data stream); any node loss reduces to "restart from the last durable step".
This module implements the controller side of that contract:

  * periodic async-ish checkpointing via checkpoint.CheckpointManager
    (atomic rename publish, keep-k GC);
  * a restart loop that catches worker failures (real exceptions, or
    `FailureInjector` for tests), restores the latest checkpoint, rebuilds
    the data iterator at the right step, and continues;
  * bounded retries (`max_restarts`) with failure bookkeeping;
  * hooks for the straggler monitor (runtime/straggler.py) so a persistent
    straggler can trigger a controlled restart instead of stalling the job.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax

from ..checkpoint.manager import CheckpointManager

log = logging.getLogger(__name__)


class WorkerFailure(RuntimeError):
    """A (simulated or real) worker fault during a step."""


@dataclasses.dataclass
class FailureInjector:
    """Deterministic failure schedule for tests/examples: fail at steps."""
    fail_at: tuple = ()
    _fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            raise WorkerFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int
    checkpoint_every: int = 50
    max_restarts: int = 10
    log_every: int = 10


def run_with_restarts(
    loop_cfg: TrainLoopConfig,
    ckpt: CheckpointManager,
    init_state: Callable[[], Any],          # () → (params, opt_state)
    train_step: Callable[..., Any],         # (params, opt, batch) → (p,o,metrics)
    batches: Callable[[int], Iterator],     # start_step → batch iterator
    injector: Optional[FailureInjector] = None,
    on_step: Optional[Callable[[int, Dict], None]] = None,
) -> Dict[str, Any]:
    """Run to total_steps, surviving failures. Returns summary stats."""
    restarts = 0
    history: list = []

    while True:
        # ---- (re)start: restore or init --------------------------------
        start = ckpt.latest_step()
        if start is not None:
            params, opt_state = ckpt.restore(init_state())
            step = start
            log.info("restored checkpoint at step %d", step)
        else:
            params, opt_state = init_state()
            step = 0
        it = batches(step)

        try:
            while step < loop_cfg.total_steps:
                batch = next(it)
                if injector is not None:
                    injector.check(step)
                params, opt_state, metrics = train_step(params, opt_state,
                                                        batch)
                step += 1
                if on_step is not None:
                    on_step(step, metrics)
                if step % loop_cfg.log_every == 0:
                    loss = float(metrics["loss"])
                    history.append((step, loss))
                    log.info("step %d loss %.4f", step, loss)
                if step % loop_cfg.checkpoint_every == 0 \
                        or step == loop_cfg.total_steps:
                    ckpt.save(step, (params, opt_state),
                              extra={"step": step})
            return {"steps": step, "restarts": restarts,
                    "history": history,
                    "final": (params, opt_state)}
        except WorkerFailure as e:
            restarts += 1
            log.warning("worker failure (%s); restart %d/%d", e, restarts,
                        loop_cfg.max_restarts)
            if restarts > loop_cfg.max_restarts:
                raise
            # fall through: restore from the last durable checkpoint
            del params, opt_state
            continue
