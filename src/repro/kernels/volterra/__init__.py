from .ops import equalize
from .ref import volterra as volterra_ref
from .volterra import volterra as volterra_pallas

__all__ = ["equalize", "volterra_ref", "volterra_pallas"]
