"""Load generation for the serving runtime — reproducible tenant traffic.

Builds per-tenant waveform chunk schedules (optionally through the paper's
channel simulators) and replays them against a `ServeRuntime` round-robin,
which is the worst case for a batcher: every tenant's chunks arrive
interleaved, so coalescing only happens if the scheduler actually does its
job. Used by `benchmarks/bench_serve.py` and `examples/serve_equalizer.py`.

Drift mode: `drift_streams` walks a time-varying channel
(`repro.channels.drift`) through a `DriftSchedule`, advancing the channel
state once per BURST, and returns both the waveform chunks and the true tx
symbols per chunk — the pilot labels the adaptation loop trains against.
`replay_adaptive` replays such traffic while feeding pilots and running
`OnlineAdapter` cycles between rounds, so `benchmarks/bench_adapt.py`,
`tests/test_adapt.py` and `examples/adaptive_serving.py` all share one
traffic path.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from .runtime import AsyncServeRuntime, ServeRuntime


def chop(waveform: np.ndarray, chunk_samples: int, seed: int = 0,
         jitter: float = 0.5) -> List[np.ndarray]:
    """Split one stream into chunks of ~chunk_samples (±jitter fraction),
    modelling bursty arrivals. jitter=0 → fixed-size chunks."""
    rng = np.random.default_rng(seed)
    out: List[np.ndarray] = []
    pos = 0
    total = int(waveform.shape[0])
    while pos < total:
        c = chunk_samples
        if jitter > 0:
            c = int(round(c * rng.uniform(1.0 - jitter, 1.0 + jitter)))
        c = max(1, min(c, total - pos))
        out.append(np.asarray(waveform[pos:pos + c], np.float32))
        pos += c
    return out


def random_waveforms(n_tenants: int, n_syms: int, n_os: int = 2,
                     seed: int = 0) -> List[np.ndarray]:
    """Unit-power random waveforms, one per tenant (throughput benches
    don't need channel realism; examples use the channel sims instead)."""
    rng = np.random.default_rng(seed)
    return [rng.standard_normal(n_syms * n_os).astype(np.float32)
            for _ in range(n_tenants)]


def drift_streams(channel, schedule, tenant_ids: Sequence[str],
                  n_bursts: int, syms_per_burst: int, seed: int = 0
                  ) -> Tuple[Dict[str, List[np.ndarray]],
                             Dict[str, List[np.ndarray]]]:
    """Piecewise-stationary tenant traffic over a drifting channel.

    channel:   a `repro.channels.drift` wrapper (`DriftingProakis` /
               `DriftingIMDD`) — anything with `.at(t) → channel_fn`.
    schedule:  a `DriftSchedule` mapping burst index → drift coordinate.
    Each tenant gets its own PRNG stream (same channel STATE, independent
    noise/data), and the channel state advances once per burst for all
    tenants — the physical picture of links sharing a drifting medium.

    Returns (streams, pilots): per tenant, the list of waveform chunks and
    the matching list of true tx symbol arrays (the labels a pilot-driven
    adaptation loop uses; ignore them to model blind operation).
    """
    streams: Dict[str, List[np.ndarray]] = {t: [] for t in tenant_ids}
    pilots: Dict[str, List[np.ndarray]] = {t: [] for t in tenant_ids}
    base = jax.random.PRNGKey(seed)
    for burst in range(n_bursts):
        fn = channel.at(schedule.t_at(burst))
        for i, tid in enumerate(tenant_ids):
            key = jax.random.fold_in(jax.random.fold_in(base, burst), i)
            rx, syms = fn(key, syms_per_burst)
            streams[tid].append(np.asarray(rx, np.float32))
            pilots[tid].append(np.asarray(syms, np.int32))
    return streams, pilots


def replay_adaptive(runtime: Union[ServeRuntime, AsyncServeRuntime],
                    streams: Dict[str, Sequence[np.ndarray]],
                    pilots: Optional[Dict[str, Sequence[np.ndarray]]] = None,
                    adapter=None, step_every: int = 1,
                    pump_between: bool = True) -> Dict[str, float]:
    """Round-robin replay with pilot feeding + adaptation cycles.

    Like `replay`, but: tenants present in `pilots` AND attached to
    `adapter` get their true tx symbols fed as labels right before each
    chunk is submitted (stream-order lockstep — see
    `repro.adapt.collector` `add_pilots`), and every `step_every` rounds
    the adapter runs one synchronous adaptation cycle over its tenants.
    Pass adapter=None to replay the same traffic with adaptation off (the
    frozen-tenant control arm benches compare against).
    """
    ids = list(streams)
    iters = {t: iter(streams[t]) for t in ids}
    piter = {t: iter(pilots[t]) for t in pilots or {}}
    adapted = set() if adapter is None else set(adapter.tenants)
    live = set(ids)
    rounds = 0
    t0 = time.perf_counter()
    while live:
        for t in list(live):
            chunk = next(iters[t], None)
            labels = next(piter[t], None) if t in piter else None
            if chunk is None:
                live.discard(t)
                runtime.finish(t)
                continue
            if adapter is not None and t in adapted and labels is not None:
                adapter.feed_pilots(t, labels)
            runtime.submit(t, chunk)
        if pump_between:
            runtime.pump()
        rounds += 1
        if adapter is not None and step_every > 0 \
                and rounds % step_every == 0:
            adapter.step()
    runtime.drain()
    if adapter is not None:
        adapter.step()                 # final cycle over the full buffer
    elapsed = time.perf_counter() - t0
    total_syms = sum(runtime.sessions.get(t).syms_emitted for t in ids
                     if t in runtime.sessions)
    return {"elapsed_s": elapsed, "total_syms": total_syms,
            "agg_syms_per_s": total_syms / elapsed if elapsed else 0.0,
            "rounds": rounds}


def replay(runtime: Union[ServeRuntime, AsyncServeRuntime],
           streams: Dict[str, Sequence[np.ndarray]],
           pump_between: bool = True) -> Dict[str, float]:
    """Round-robin replay: submit one chunk per tenant per round until all
    streams are exhausted, then flush tails and drain. Returns wall-clock
    accounting. Tenants must already be open on `runtime`. Works unchanged
    against both drivers — the async runtime's `drain()` blocks until every
    launch has landed, so `total_syms` is complete either way."""
    ids = list(streams)
    iters = {t: iter(streams[t]) for t in ids}
    live = set(ids)
    t0 = time.perf_counter()
    while live:
        for t in list(live):
            chunk = next(iters[t], None)
            if chunk is None:
                live.discard(t)
                runtime.finish(t)
                continue
            runtime.submit(t, chunk)
        if pump_between:
            runtime.pump()
    runtime.drain()
    elapsed = time.perf_counter() - t0
    total_syms = sum(runtime.sessions.get(t).syms_emitted for t in ids
                     if t in runtime.sessions)
    return {"elapsed_s": elapsed, "total_syms": total_syms,
            "agg_syms_per_s": total_syms / elapsed if elapsed else 0.0}


def replay_wire(gateway, client, streams: Dict[str, Sequence[np.ndarray]],
                burst: int = 1, max_rounds: int = 100_000
                ) -> Dict[str, object]:
    """Round-robin replay THROUGH THE WIRE (the frame-emitting mode).

    Like `replay`, but every chunk crosses a transport as a DATA frame:
    `client` is a `repro.net.NetClient` whose tenants are attached (or
    wire-opened), `gateway` the `repro.net.NetGateway` serving the
    runtime on the other end. Single-threaded cooperative driving —
    client sends ride the credit window, the gateway polls/pumps/emits,
    and a stalled round (client credit-blocked while launches wait on
    policy) forces a `settle()` so progress is deadlock-free. Tenants
    whose wire errors (NACK / ingress `stream_gap`) surface stop being
    waited on — the error is in the returned `errors` map, never a hang.

    `burst` chunks per tenant go out between polls (burst>1 keeps several
    datagrams in flight so an impaired wire actually gets to reorder).

    Returns wall-clock accounting plus per-tenant received symbol counts
    and surfaced wire errors."""
    ids = list(streams)
    iters = {t: iter(streams[t]) for t in ids}
    live = list(ids)          # ordered: send order must be deterministic
    waiting = list(ids)       # (impairment schedules index datagrams)
    errors: Dict[str, str] = {}
    t0 = time.perf_counter()
    rounds = 0
    while waiting:
        rounds += 1
        if rounds > max_rounds:
            raise RuntimeError(f"replay_wire stalled: {sorted(waiting)} "
                               f"never finished")
        activity = 0
        for t in list(live):
            for _ in range(max(1, burst)):   # burst>1: frames actually
                chunk = next(iters[t], None)  # share the wire and reorder
                if chunk is None:
                    live.remove(t)
                    client.finish(t)
                    break
                client.send_samples(t, chunk)
            activity += 1
        activity += gateway.step(max_datagrams=256)
        activity += client.poll(max_datagrams=256)
        for t in list(waiting):
            err = (client.errors(t) or [None])[0] or gateway.ingress.error(t)
            if err:
                errors[t] = str(err)
                waiting.remove(t)
            elif client.done(t):
                waiting.remove(t)
        if not activity and waiting:
            gateway.settle()
            if not client.poll(max_datagrams=256):
                gateway.ingress.flush_gaps()
    elapsed = time.perf_counter() - t0
    received = {t: int(client.symbols(t).shape[0]) for t in ids
                if t in client.streams}
    total_syms = sum(received.values())
    return {"elapsed_s": elapsed, "total_syms": total_syms,
            "agg_syms_per_s": total_syms / elapsed if elapsed else 0.0,
            "rounds": rounds, "received": received, "errors": errors}
