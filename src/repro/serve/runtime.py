"""ServeRuntime — the multi-tenant streaming equalizer serving facade.

    rt = ServeRuntime(BatchPolicy(max_batch=8, max_wait_s=2e-3))
    rt.open(TenantSpec("link-a", cfg, params=params_a))
    rt.open(TenantSpec("link-b", cfg, params=params_b))
    ...
    rt.submit("link-a", samples)        # arbitrary chunk sizes
    rt.submit("link-b", samples)        # coalesced into one fused launch
    ...
    rt.pump()                           # honour max_wait while idle
    syms = rt.close("link-a")           # flush tail, return the stream

Single-threaded and synchronous by design: launches happen inside
`submit`/`pump`/`drain` on the caller's thread, which keeps results
deterministic (bitwise-reproducible vs the offline engine — the tier-1
test surface) while still modelling the real coalescing policy with
timestamps. An async front-end would merely move WHERE pump() is called.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional

import numpy as np

from .pool import EnginePool
from .scheduler import BatchPolicy, MicroBatcher, Request
from .session import Session, SessionManager, TenantSpec


class ServeRuntime:
    def __init__(self, policy: Optional[BatchPolicy] = None,
                 max_engines: int = 32,
                 clock: Callable[[], float] = time.perf_counter):
        self.sessions = SessionManager(max_engines=max_engines)
        self.batcher = MicroBatcher(policy, clock=clock)

    # -- tenant lifecycle --------------------------------------------------

    def open(self, spec: TenantSpec) -> Session:
        """Admit a tenant: build (or pool-hit) its engine, start a stream."""
        return self.sessions.open(spec)

    def close(self, tenant_id: str) -> np.ndarray:
        """End a tenant's stream: flush the receptive-field tail, launch
        ONLY this tenant's pending requests (other tenants' partial
        batches keep waiting for their policy), release the session;
        returns the full symbol stream (identical to the offline engine
        on the whole waveform)."""
        self.finish(tenant_id)
        self.batcher.flush_session(self.sessions.get(tenant_id))
        return self.sessions.close(tenant_id).output()

    # -- streaming ---------------------------------------------------------

    def submit(self, tenant_id: str, samples) -> Optional[Request]:
        """Feed a chunk of waveform samples; may trigger batched launches
        (max_batch reached, or another group's max_wait expired)."""
        s = self.sessions.get(tenant_id)
        s.chunker.push(np.asarray(samples))
        req = self.batcher.enqueue(s)
        self.batcher.pump()
        return req

    def finish(self, tenant_id: str) -> Optional[Request]:
        """End-of-stream marker: queue the zero-padded tail flush."""
        s = self.sessions.get(tenant_id)
        if not s.chunker.finished:
            s.chunker.finish()
        return self.batcher.enqueue(s)

    def pump(self) -> int:
        """Time-based flush (call while idle to honour max_wait_s)."""
        return self.batcher.pump()

    def drain(self) -> int:
        """Launch every pending request now."""
        return self.batcher.drain()

    def output(self, tenant_id: str) -> np.ndarray:
        return self.sessions.get(tenant_id).output()

    # -- accounting --------------------------------------------------------

    @property
    def pool(self) -> EnginePool:
        return self.sessions.pool

    def stats(self) -> Dict:
        st = {"tenants": len(self.sessions),
              "pending": self.batcher.pending(),
              "pool": self.pool.stats()}
        st.update(self.batcher.latency_stats())
        return st
