"""zamba2-1.2b — hybrid Mamba2 + shared attention block [arXiv:2411.15242].

38 Mamba2 layers · d_model 2048 · ssm_state 64 · expand 2 (d_inner 4096,
64 SSD heads of 64) · shared attention block (32 heads, MHA) applied every
6 layers · d_ff 8192 (shared block MLP) · vocab 32000.

long_500k policy: Mamba2 state is O(1); the shared attention block decodes
the 500k cell with a 4096-token sliding-window ring cache set by the
launcher (`decode_window`) — DESIGN.md §9.
"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32000,
    ssm_state=64, d_conv=4, expand=2, ssm_head=64, attn_every=6,
    tp=16, train_accum=8, ssd_chunk=64,   # accum 8: fits 16 GiB HBM (§Perf it. 8)
)

REDUCED = ModelConfig(
    name="zamba2-reduced", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=512,
    ssm_state=16, d_conv=4, expand=2, ssm_head=16, attn_every=2,
    ssd_chunk=16, dtype="float32",
)
