"""Serving throughput/latency — micro-batched multi-tenant vs sequential.

The axis the paper's GPU baseline lost on: per-link calls too small to fill
the device. `repro.serve` answers with dynamic micro-batching — pending
chunks from every tenant sharing a topology+backend coalesce into ONE
stacked fused-kernel launch with per-row tenant weights. This bench drives
both DOP operating points (`equalizer_ht` → int8 QAT formats,
`equalizer_lp` → 12-bit formats deploying bf16) with the round-robin load
generator and records, per tenant count:

  * serve:       aggregate syms/s + p50/p99 request latency + mean batch
                 occupancy through the micro-batcher (max_batch = N),
  * serve_async: the SAME workload through `AsyncServeRuntime` — host
                 chunk bookkeeping + stacked-input assembly overlap the
                 device phase via the launcher thread (double buffering),
  * sequential:  the SAME streaming workload with batching disabled
                 (max_batch = 1 → one engine launch per tenant chunk),
  * offline_oneshot_syms_per_s: each tenant's full stream in one
                 engine call (non-streaming upper reference),
  * speedup_async_vs_sync: the overlap win. CAVEAT (interpret-mode hosts):
                 on CPU the "device" phase runs on host cores, so the
                 async overlap competes with assembly for the same
                 silicon and the ratio understates what a real
                 TPU-attached host would see; it is recorded for its
                 TRAJECTORY, and `--check` does not gate on it.

Writes machine-readable `BENCH_serve.json` at the repo root — the committed
baseline `benchmarks/run.py --check` regresses against. Absolute rates are
host-dependent (CPU hosts run the kernels in interpret mode); the tracked
signals are the serve-vs-sequential ratio and its trajectory over PRs.
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import equalizer_ht as HT
from repro.configs import equalizer_lp as LP
from repro.core import equalizer as eq
from repro.serve import (AsyncServeRuntime, BatchPolicy, ServeRuntime,
                         TenantSpec, chop, replay)
from repro.serve.loadgen import random_waveforms

from .common import Bench

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT_PATH = REPO_ROOT / "BENCH_serve.json"

# learned-format stand-ins (paper Fig. 6): ht lands int8, lp mid-curve bf16
FORMATS = {
    "equalizer_ht": {"w_int": 2, "w_frac": 5, "a_int": 3, "a_frac": 4},
    "equalizer_lp": {"w_int": 3, "w_frac": 8, "a_int": 3, "a_frac": 8},
}
TILE_M = 16          # serving tile: chunks are short; big tiles waste skip


def _tenant_spec(op_name, cfg, tenant_idx) -> TenantSpec:
    params = eq.init(jax.random.PRNGKey(1000 + tenant_idx), cfg)
    params["qat"] = {
        f"layer{i}": {k: jnp.asarray(float(v))
                      for k, v in FORMATS[op_name].items()}
        for i in range(cfg.layers)}
    return TenantSpec(f"{op_name}-t{tenant_idx}", cfg, params=params,
                      bn_state=eq.init_bn_state(cfg), backend="auto",
                      tile_m=TILE_M)


def _run_streaming(specs, waves, chunk_samples, max_batch,
                   driver: str = "sync") -> Dict:
    def one_pass():
        policy = BatchPolicy(max_batch=max_batch, max_wait_s=1e9)
        if driver == "async":
            with AsyncServeRuntime(policy, max_engines=64) as rt:
                for s in specs:
                    rt.open(s)
                streams = {s.tenant_id: chop(w, chunk_samples, seed=i,
                                             jitter=0.0)
                           for i, (s, w) in enumerate(zip(specs, waves))}
                rep = replay(rt, streams)      # drain() waits for landings
                return rt, rep
        rt = ServeRuntime(policy, max_engines=64)
        for s in specs:
            rt.open(s)
        streams = {s.tenant_id: chop(w, chunk_samples, seed=i, jitter=0.0)
                   for i, (s, w) in enumerate(zip(specs, waves))}
        return rt, replay(rt, streams)

    one_pass()                 # warm-up: compile every (B, W) launch shape
    # best-of-3 (compile excluded): interpret-mode hosts are noisy and the
    # --check regression gate needs a stable statistic
    rt, rep = max((one_pass() for _ in range(3)),
                  key=lambda p: p[1]["agg_syms_per_s"])
    stats = rt.stats()
    return {
        "agg_syms_per_s": rep["agg_syms_per_s"],
        "total_syms": rep["total_syms"],
        "elapsed_s": rep["elapsed_s"],
        "mean_batch": stats.get("mean_batch", 1.0),
        "launches": stats.get("launches", 0),
        "p50_latency_ms": stats.get("p50_latency_ms", 0.0),
        "p99_latency_ms": stats.get("p99_latency_ms", 0.0),
    }


def _offline_oneshot(specs, waves) -> float:
    engines = [s.build_engine() for s in specs]
    xs = [jnp.asarray(w[None]) for w in waves]
    for e, x in zip(engines, xs):                  # warm-up compile
        jax.block_until_ready(e(x))
    t0 = time.perf_counter()
    n = 0
    for e, x in zip(engines, xs):
        n += jax.block_until_ready(e(x)).shape[1]
    return n / (time.perf_counter() - t0)


def run(n_syms: int = 4096, chunk_syms: int = 512,
        tenant_counts=(1, 2, 4, 8),
        out_path: Optional[pathlib.Path] = OUT_PATH) -> dict:
    bench = Bench("serve_multitenant", "§5.3 DOP-parallel datapath, served")
    report = {"n_syms": n_syms, "chunk_syms": chunk_syms, "tile_m": TILE_M,
              "backend_default": jax.default_backend(),
              "async_note": (
                  "speedup_async_vs_sync measures host/device overlap "
                  "(double-buffered launches). On interpret-mode CPU hosts "
                  "the device phase runs on the same cores as assembly, so "
                  "the ratio understates real accelerator hosts and is "
                  "tracked for trajectory only (not gated by --check)."),
              "configs": {}}
    ops = {"equalizer_ht": HT.CNN, "equalizer_lp": LP.CNN}

    for op_idx, (op_name, cfg) in enumerate(ops.items()):
        chunk_samples = chunk_syms * cfg.n_os
        entry = {"formats": FORMATS[op_name], "tenants": {},
                 "backend": _tenant_spec(op_name, cfg, 0)
                 .build_engine().backend}
        for n_t in tenant_counts:
            specs = [_tenant_spec(op_name, cfg, i) for i in range(n_t)]
            # fixed per-op seed: str hash() is randomized per process and
            # would feed --check different waveforms than the baseline saw
            waves = random_waveforms(n_t, n_syms, cfg.n_os, seed=op_idx)
            serve = _run_streaming(specs, waves, chunk_samples,
                                   max_batch=max(n_t, 1))
            asyn = _run_streaming(specs, waves, chunk_samples,
                                  max_batch=max(n_t, 1), driver="async")
            seq = _run_streaming(specs, waves, chunk_samples, max_batch=1)
            entry["tenants"][str(n_t)] = {
                "serve": serve,
                "serve_async": asyn,
                "sequential": seq,
                "offline_oneshot_syms_per_s": _offline_oneshot(specs, waves),
                "speedup_serve_vs_sequential":
                    serve["agg_syms_per_s"] / seq["agg_syms_per_s"],
                "speedup_async_vs_sync":
                    asyn["agg_syms_per_s"] / serve["agg_syms_per_s"],
            }
            print(f"[bench_serve] {op_name} N={n_t} "
                  f"({entry['backend']}): serve "
                  f"{serve['agg_syms_per_s']:,.0f} sym/s "
                  f"(batch {serve['mean_batch']:.1f}, "
                  f"p99 {serve['p99_latency_ms']:.1f} ms) vs sequential "
                  f"{seq['agg_syms_per_s']:,.0f} sym/s → "
                  f"{serve['agg_syms_per_s'] / seq['agg_syms_per_s']:.2f}×; "
                  f"async {asyn['agg_syms_per_s']:,.0f} sym/s → "
                  f"{asyn['agg_syms_per_s'] / serve['agg_syms_per_s']:.2f}× "
                  f"vs sync")
        report["configs"][op_name] = entry

    if out_path is not None:
        out_path.write_text(json.dumps(report, indent=2))
        print(f"[bench_serve] wrote {out_path}")
    bench.record("report", report)
    return bench.finish()


if __name__ == "__main__":
    run()
