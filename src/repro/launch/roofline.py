"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s per ICI link.

XLA's `cost_analysis()` visits while-loop bodies ONCE, so a scan-over-layers
model under-counts by L× (and grad accumulation by accum×). This module
therefore carries its own small HLO analyzer:

  * parses the per-partition post-optimization HLO text into computations /
    instructions (a symbol table resolves operand shapes — post-fusion HLO
    prints operands as bare names);
  * extracts `known_trip_count` from every `while` and composes NESTED loop
    multipliers (accum loop × layer scan);
  * FLOPs: 2·numel(result)·K for every dot (K = lhs contracting dims), ×mult;
  * HBM bytes: Σ (operand + result bytes) over top-level instructions of
    reachable computations (entry + while bodies) — fusion-internal traffic
    excluded, which is exactly the fusion memory model;
  * collective traffic: operand sizes per op kind ×mult, plus a ring-model
    per-chip bytes-moved estimate.

Terms (seconds, per step, per chip):
  compute    = flops / PEAK_FLOPS
  memory     = hbm_bytes / HBM_BW
  collective = ring_bytes / ICI_BW
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(pred|s4|u4|s8|u8|s16|u16|f16|bf16|s32|u32|f32"
                       r"|s64|u64|f64|c64|c128)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w\.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"^(\(?.*?\)?)\s([\w\-]+)\(")
# computation headers sit at column 0 and end with "{":
#   %region_2.2_spmd (param: (s32[], …)) -> (…) {
#   ENTRY %main.1234 (…) -> (…) {
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w\.\-]+)\s*\(")
_OPERAND_RE = re.compile(r"%[\w\.\-]+")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_BRACES_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
# ops that move no HBM data (views / metadata / control)
_NO_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "while", "conditional", "call", "after-all",
               "partition-id", "replica-id", "iota", "get-dimension-size"}


def _numel(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _shape_bytes(dtype: str, dims: str) -> int:
    return _numel(dims) * _DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)          # replica_groups=[G,S]<=[...]
    if m:
        return max(int(m.group(2)), 2)
    m = _GROUPS_BRACES_RE.search(line)        # replica_groups={{0,1,…},…}
    if m:
        return max(len(m.group(1).split(",")), 2)
    return 2


@dataclasses.dataclass
class _Instr:
    name: str
    opcode: str
    result_bytes: int
    result_dims: Optional[List[int]]
    operands: List[str]
    line: str
    comp: str


@dataclasses.dataclass
class CollectiveStats:
    op_bytes: Dict[str, int]          # op kind → Σ operand bytes (per chip)
    ring_bytes: Dict[str, float]      # op kind → ring-model bytes moved/chip
    count: Dict[str, int]

    @property
    def total_operand_bytes(self) -> int:
        return sum(self.op_bytes.values())

    @property
    def total_ring_bytes(self) -> float:
        return sum(self.ring_bytes.values())


@dataclasses.dataclass
class HloAnalysis:
    flops: float                      # per-chip dot flops (loop-scaled)
    hbm_bytes: float                  # per-chip fusion-level traffic
    coll: CollectiveStats
    xla_flops: float = 0.0            # cost_analysis (loops counted once)
    xla_bytes: float = 0.0
    top_traffic: Optional[list] = None    # [(bytes, opcode, op_name), …]
    top_collectives: Optional[list] = None


_METADATA_RE = re.compile(r'op_name="([^"]*)"')


def analyze_hlo(text: str, top_k: int = 0) -> HloAnalysis:
    comp = ""
    instrs: List[_Instr] = []
    sym_bytes: Dict[str, int] = {}
    sym_dims: Dict[str, Optional[List[int]]] = {}
    whiles: List[Tuple[str, str, str, int]] = []   # (comp, body, cond, trip)

    for line in text.splitlines():
        if line and not line[0].isspace() and line.rstrip().endswith("{"):
            cm = _COMP_RE.match(line)
            if cm and " = " not in line.split("->")[0]:
                comp = cm.group(1).lstrip("%")
                continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1).lstrip("%"), m.group(2)
        om = _OP_RE.match(rest)
        if not om:
            continue
        result_str, opcode = om.group(1), om.group(2)
        shapes = _SHAPE_RE.findall(result_str)
        rbytes = sum(_shape_bytes(d, s) for d, s in shapes)
        rdims = ([int(x) for x in shapes[0][1].split(",") if x]
                 if len(shapes) == 1 else None)
        sym_bytes[name] = rbytes
        sym_dims[name] = rdims
        paren = rest[om.end() - 1:]
        operand_str = paren[1:paren.find(")")] if ")" in paren else ""
        operands = [o.lstrip("%") for o in _OPERAND_RE.findall(operand_str)]
        instrs.append(_Instr(name, opcode, rbytes, rdims, operands, line,
                             comp))
        if opcode == "while":
            b = _BODY_RE.search(line)
            c = _COND_RE.search(line)
            t = _TRIP_RE.search(line)
            whiles.append((comp, b.group(1) if b else "",
                           c.group(1) if c else "",
                           int(t.group(1)) if t else 1))

    # loop multipliers (compose nested loops via fixpoint)
    mult: Dict[str, float] = {}
    entry_comps = {i.comp for i in instrs}
    bodies = {b for _, b, _, _ in whiles} | {c for _, _, c, _ in whiles}
    for c in entry_comps - bodies:
        mult[c] = 1.0
    for _ in range(12):
        changed = False
        for parent, body, cond, trip in whiles:
            if parent in mult:
                for target, t in ((body, trip), (cond, trip + 1)):
                    val = mult[parent] * max(t, 1)
                    if target and mult.get(target) != val:
                        mult[target] = val
                        changed = True
        if not changed:
            break
    reachable = set(mult)

    flops = 0.0
    hbm = 0.0
    op_bytes: Dict[str, int] = {}
    ring: Dict[str, float] = {}
    count: Dict[str, int] = {}
    contributors: list = []
    coll_contrib: list = []

    for ins in instrs:
        if ins.comp not in reachable:
            continue                     # fusion bodies / reducers
        m = mult.get(ins.comp, 1.0)
        base = ins.opcode.replace("-start", "").replace("-done", "")
        operand_bytes = sum(sym_bytes.get(o, 0) for o in ins.operands)

        if ins.opcode == "dot" and ins.result_dims is not None:
            lc = _LHS_CONTRACT_RE.search(ins.line)
            k = 1
            lhs_dims = sym_dims.get(ins.operands[0]) if ins.operands else None
            if lc and lhs_dims:
                for idx in lc.group(1).split(","):
                    if idx:
                        k *= lhs_dims[int(idx)]
            flops += 2.0 * _numel(",".join(map(str, ins.result_dims))) \
                * k * m
        elif ins.opcode == "convolution" and ins.result_dims is not None:
            # 2 · numel(out) · (K_spatial · C_in): operand1 = kernel
            kdims = sym_dims.get(ins.operands[1]) if len(ins.operands) > 1 \
                else None
            kprod = 1
            if kdims:
                for d in kdims[:-1]:     # all but output-feature dim
                    kprod *= d
            n_out = 1
            for d in ins.result_dims:
                n_out *= d
            flops += 2.0 * n_out * kprod * m

        if base in _COLL_OPS and not ins.opcode.endswith("-done"):
            n = _group_size(ins.line)
            op_bytes[base] = op_bytes.get(base, 0) + int(operand_bytes * m)
            count[base] = count.get(base, 0) + int(m)
            if base == "all-gather":
                moved = operand_bytes * (n - 1)
            elif base == "all-reduce":
                moved = 2.0 * operand_bytes * (n - 1) / n
            elif base in ("reduce-scatter", "all-to-all"):
                moved = operand_bytes * (n - 1) / n
            else:                        # collective-permute
                moved = operand_bytes
            ring[base] = ring.get(base, 0.0) + moved * m

        if base in _COLL_OPS and top_k and not ins.opcode.endswith("-done"):
            meta = _METADATA_RE.search(ins.line)
            coll_contrib.append((operand_bytes * m, base,
                                 meta.group(1)[-90:] if meta else ins.name))

        if ins.opcode in _NO_TRAFFIC or ins.opcode.endswith("-done"):
            continue
        traffic = (operand_bytes + ins.result_bytes) * m
        hbm += traffic
        if top_k:
            meta = _METADATA_RE.search(ins.line)
            contributors.append((traffic, ins.opcode,
                                 meta.group(1)[-90:] if meta else ins.name))

    contributors.sort(reverse=True)
    coll_contrib.sort(reverse=True)
    return HloAnalysis(flops=flops, hbm_bytes=hbm,
                       coll=CollectiveStats(op_bytes, ring, count),
                       top_traffic=contributors[:top_k] or None,
                       top_collectives=coll_contrib[:top_k] or None)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    return analyze_hlo(hlo_text).coll


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-chip flops (loop-scaled dot flops)
    hbm_bytes: float             # per-chip bytes accessed
    coll: CollectiveStats
    n_chips: int
    model_flops: float = 0.0     # 6·N·D (global, useful work)
    xla_flops: float = 0.0
    xla_bytes: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll.total_ring_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_step(self) -> float:
        """Perfect-overlap lower bound: max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (per-chip HLO flops × chips) — remat/pad waste."""
        total = self.flops * self.n_chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs utilization at the roofline step time."""
        total = self.n_chips * PEAK_FLOPS * self.t_step
        return self.model_flops / total if total else 0.0

    def to_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "xla_flops_per_chip_loops_once": self.xla_flops,
            "xla_bytes_per_chip_loops_once": self.xla_bytes,
            "collective_operand_bytes": self.coll.total_operand_bytes,
            "collective_ring_bytes": self.coll.total_ring_bytes,
            "collective_ops": self.coll.count,
            "collective_ring_bytes_by_op": self.coll.ring_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "t_step_s": self.t_step,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_at_roofline": self.mfu,
        }


def model_flops(n_active_params: int, tokens: int, kind: str) -> float:
    """6·N·D for training, 2·N·D for inference forward passes."""
    factor = 6.0 if kind == "train" else 2.0
    return factor * n_active_params * tokens


def from_compiled(compiled, n_chips: int, model_fl: float = 0.0,
                  hlo_text: Optional[str] = None) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):           # older jax returns [dict]
        cost = cost[0]
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    an = analyze_hlo(text)
    return Roofline(flops=an.flops, hbm_bytes=an.hbm_bytes, coll=an.coll,
                    n_chips=n_chips, model_flops=model_fl,
                    xla_flops=xla_flops, xla_bytes=xla_bytes)
