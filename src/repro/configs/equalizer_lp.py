"""Low-power equalizer operating point (paper §5.2 / Fig. 8).

Same CNN topology on the Proakis-B magnetic-recording channel, low-cost
target (FPGA: XC7S25). The flexible DOP set {1, 5, 10, 25, 225} maps on TPU
to the kernel tile-shape / lane-utilization sweep in benchmarks/bench_dop.py.
"""
from ..channels.proakis import ProakisConfig
from ..core.equalizer import CNNEqConfig

CNN = CNNEqConfig(layers=3, kernel=9, channels=5, v_parallel=8, n_os=2,
                  levels=2)
CHANNEL = ProakisConfig(snr_db=20.0)
N_INSTANCES = 1
DOPS = (1, 5, 10, 25, 225)    # paper's feasible DOP set for K=9, C=5
F_CLK = 100e6
