"""Benchmark orchestrator: `PYTHONPATH=src python -m benchmarks.run`.

One benchmark per paper table/figure (see DESIGN.md §6):

    bench_dse       Fig. 2   DSE: CNN vs FIR vs Volterra on IM/DD
    bench_proakis   Fig. 4   the same on the magnetic-recording channel
    bench_quant     Fig. 5/6 3-phase QAT bit-width/BER curves per QLF
    bench_dop       Fig. 8   flexible-DOP study (TPU tile-utilization axis)
    bench_stream    Fig. 9/§7.2  64-instance stream partitioning
    bench_engine    §7       engine backend throughput → BENCH_engine.json
    bench_serve     §5.3     multi-tenant serving → BENCH_serve.json
    bench_adapt     companion papers: online adaptation under drift
                             → BENCH_adapt.json
    bench_fault     robustness: chaos-gated failover → BENCH_fault.json
    bench_fleet     robustness: device-loss migration on a 2-worker fleet
                             → BENCH_fleet.json
    bench_obs       observability: tracing tax + span integrity
                             → BENCH_obs.json
    bench_net       wire parity: packetized data+control plane
                             → BENCH_net.json
    bench_link      signal health: link estimators + SLO closed loop
                             → BENCH_link.json
    bench_timing    Fig. 12  timing model vs simulated measurement
    bench_platform  Fig. 13-15  CPU measured / TPU roofline-projected
    bench_roofline  Table 1 / §Roofline  aggregate the dry-run artifacts

`--full` runs paper-scale sweeps (hours); the default is a reduced pass
whose orderings (not absolute BERs) carry the claims.

`--check` is the perf-regression gate: it verifies the docs references
(tools/check_docs.py), then re-measures bench_engine, bench_serve and
bench_adapt (without overwriting the committed baselines) and exits
non-zero if any tracked throughput fell more than `--tol` below the
`BENCH_engine.json` / `BENCH_serve.json` / `BENCH_adapt.json` committed at
the repo root — after normalizing out the
uniform host-speed drift per gate group (geomean over shared keys), so
only RELATIVE per-path regressions fire the gate (default tol: 10% on
accelerators, 35% on interpret-mode CPU hosts — see `_default_tol`). The
adapt, fault, fleet and obs gates additionally enforce HARD,
host-independent criteria: the drift-recovery claim
(`criteria.recovery_ok` in `BENCH_adapt.json`), the chaos-recovery claim
(`criteria.recovery_ok` in `BENCH_fault.json` — bitwise zero-loss
failover under injected faults), the device-loss-migration claim
(`criteria.fleet_recovery_ok` in `BENCH_fleet.json` — a worker killed
mid-stream, every stream migrated bitwise with zero loss and zero
poisoning), and the observability claim (`criteria.overhead_ok` in
`BENCH_obs.json` — tracing ON keeps the ON/OFF throughput ratio above
its floor, stays bitwise, and seals exactly one complete span per
emitted chunk), and the wire-parity claim (`criteria.net_ok` in
`BENCH_net.json` — symbols served through the packetized
NetIngress→runtime→NetEgress path over a reordering+duplicating
loopback wire stay bitwise vs offline, exactly-once, with the control
plane acking), and the signal-health claim (`criteria.link_ok` in
`BENCH_link.json` — the decision-directed SNR estimate tracks a true
channel SNR ramp, an SLO breach latches during quality degradation and
triggers an event-driven fine-tune whose promotion retires the alert,
and serving with link estimation + SLOs + tracing ON stays bitwise vs
offline on every fused backend) are deterministic under their fixed
seeds, so their failure is never noise. The fault, fleet, obs, net and
link gates carry no throughput rates at all — they are purely the hard
criteria.
Compare like with like: the committed baseline must come from the same
host class AND be recorded in the gate's in-process order
(`--only engine serve adapt fault fleet obs net link`); CPU hosts run
the kernels in interpret mode.
"""
from __future__ import annotations

import argparse
import json
import math
import pathlib
import sys
import time
import traceback

from . import (bench_adapt, bench_dop, bench_dse, bench_engine,
               bench_fault, bench_fleet, bench_link, bench_net,
               bench_obs, bench_platform, bench_proakis, bench_quant,
               bench_roofline, bench_serve, bench_stream, bench_timing)
from .common import REPORT_DIR

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
from tools import check_docs  # noqa: E402  (repo-root import, no package)

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _engine_rates(rep: dict) -> dict:
    return {f"engine/{c}/{b}": r
            for c, e in rep.get("configs", {}).items()
            for b, r in e.get("syms_per_s", {}).items()}


def _serve_rates(rep: dict) -> dict:
    return {f"serve/{c}/N{n}": t["serve"]["agg_syms_per_s"]
            for c, e in rep.get("configs", {}).items()
            for n, t in e.get("tenants", {}).items()}


def _adapt_rates(rep: dict) -> dict:
    ov = rep.get("overhead", {})
    return {f"adapt/{k}": ov[k]
            for k in ("serve_syms_per_s_frozen", "serve_syms_per_s_adapting")
            if k in ov}


def _adapt_criteria(rep: dict):
    """Hard (host-independent) gate on the fresh adapt report: the BER
    drift-recovery criterion is deterministic under its fixed seeds, so a
    failure is a code regression, never noise."""
    crit = rep.get("criteria", {})
    if crit.get("recovery_ok", False):
        return []
    return [f"adapt: drift-recovery criterion failed "
            f"(frozen degradation {crit.get('frozen_degradation_x', 0):.1f}x"
            f" must be >= 4, adaptive-vs-fresh "
            f"{crit.get('adaptive_vs_fresh_x', 99):.2f}x must be <= 2)"]


def _fault_rates(rep: dict) -> dict:
    """The fault gate tracks NO throughput rates — recovery latencies are
    host-speed dependent; the whole gate is the hard criterion below."""
    return {}


def _fault_criteria(rep: dict):
    """Hard (host-independent) gate on the fresh fault report: under the
    injected faults every chunk must be emitted exactly once, bitwise-equal
    to offline, with no sessions poisoned and every fault fired.
    Deterministic under its fixed seeds — a failure is a code regression,
    never noise."""
    crit = rep.get("criteria", {})
    if crit.get("recovery_ok", False):
        return []
    return [f"fault: chaos-recovery criterion failed "
            f"(zero_loss={crit.get('zero_loss')} "
            f"bitwise={crit.get('bitwise')} "
            f"sessions_poisoned={crit.get('sessions_poisoned')} "
            f"faults_fired={crit.get('faults_fired')})"]


def _fleet_rates(rep: dict) -> dict:
    """The fleet gate tracks NO throughput rates — migration latencies are
    host-speed dependent; the whole gate is the hard criterion below."""
    return {}


def _fleet_criteria(rep: dict):
    """Hard (host-independent) gate on the fresh fleet report: a worker
    killed mid-stream, and still every chunk emitted exactly once,
    bitwise-equal to offline (contract #10, placement invariance), zero
    sessions poisoned, both device faults fired. Deterministic under its
    fixed seeds — a failure is a code regression, never noise."""
    crit = rep.get("criteria", {})
    if crit.get("fleet_recovery_ok", False):
        return []
    return [f"fleet: device-loss-migration criterion failed "
            f"(zero_loss={crit.get('zero_loss')} "
            f"bitwise={crit.get('bitwise')} "
            f"sessions_poisoned={crit.get('sessions_poisoned')} "
            f"device_faults_fired={crit.get('device_faults_fired')})"]


def _obs_rates(rep: dict) -> dict:
    """The obs gate tracks NO absolute rates — the tracing tax is the
    ON/OFF ratio inside the hard criterion below."""
    return {}


def _obs_criteria(rep: dict):
    """Hard (host-independent) gate on the fresh obs report: tracing must
    stay nearly free (ON/OFF throughput ratio above the floor), must not
    change a single output bit, and every emitted chunk must carry exactly
    one complete span. The ratio self-normalizes host speed; the bitwise
    and span checks are deterministic under the fixed seeds."""
    crit = rep.get("criteria", {})
    if crit.get("overhead_ok", False):
        return []
    return [f"obs: observability criterion failed "
            f"(overhead {crit.get('overhead_x', 0.0):.2f}x must be >= "
            f"{crit.get('overhead_floor', 0.5)}, "
            f"bitwise={crit.get('bitwise')} "
            f"trace_complete={crit.get('trace_complete')})"]


def _net_rates(rep: dict) -> dict:
    """The net gate tracks NO throughput rates — framed syms/s is
    host-speed dependent; the whole gate is the hard criterion below."""
    return {}


def _net_criteria(rep: dict):
    """Hard (host-independent) gate on the fresh net report: symbols
    served through the packetized wire (control-plane open, DATA frames
    in, symbol frames out) over a seeded reordering+duplicating loopback
    must stay bitwise vs offline and exactly-once, with the impairments
    verifiably fired and every control command acked. Deterministic
    under its fixed seeds — a failure is a code regression, never
    noise."""
    crit = rep.get("criteria", {})
    if crit.get("net_ok", False):
        return []
    return [f"net: wire-parity criterion failed "
            f"(bitwise={crit.get('bitwise')} "
            f"exactly_once={crit.get('exactly_once')} "
            f"impairments_fired={crit.get('impairments_fired')} "
            f"control_ok={crit.get('control_ok')})"]


def _link_rates(rep: dict) -> dict:
    """The link gate tracks NO throughput rates — estimation is host-side
    numpy; the whole gate is the hard criterion below."""
    return {}


def _link_criteria(rep: dict):
    """Hard (host-independent) gate on the fresh link report: the
    decision-directed SNR estimate must track the true channel SNR ramp,
    the SLO breach must latch during the degradation and trigger the
    event-driven fine-tune, the promotion must retire the alert, and
    serving with link + SLO + tracing ON must stay bitwise vs offline on
    every fused backend. Deterministic under its fixed seeds — a failure
    is a code regression, never noise."""
    crit = rep.get("criteria", {})
    if crit.get("link_ok", False):
        return []
    return [f"link: signal-health criterion failed "
            f"(snr_corr={crit.get('snr_corr', 0.0):.2f} "
            f"drop={crit.get('snr_est_drop_db', 0.0):.2f}dB "
            f"breach_fired={crit.get('breach_fired')} "
            f"promoted={crit.get('promoted')} "
            f"resolved={crit.get('resolved')} "
            f"final_clear={crit.get('final_clear')} "
            f"bitwise={crit.get('bitwise')})"]


def _default_tol() -> float:
    """Host-class-aware gate width. Real accelerators get the tight 10%
    gate; interpret-mode CPU hosts run the kernels ~50× slower with
    ±25–40% per-key noise even after drift normalization (see
    docs/ARCHITECTURE.md), where a 10% gate fires on noise in most clean
    runs — the honest per-key bound there is 35%, and serve-vs-sequential
    RATIOS carry the fine-grained regression signal instead."""
    import jax
    return 0.10 if jax.default_backend() != "cpu" else 0.35


def _geomean(vals) -> float:
    vals = [v for v in vals if v > 0]
    if not vals:
        return 1.0
    return math.exp(sum(math.log(v) for v in vals) / len(vals))


def check(tol: float | None = None) -> int:
    """Regress fresh engine/serve throughput against committed baselines.

    Rates are compared DRIFT-NORMALIZED: within each gate group (engine,
    serve) both the fresh and the baseline rates are divided by their
    geometric mean over the shared keys, so a uniform host-speed change
    (this host drifts up to 2× over minutes; a TPU pool may simply be a
    different machine) cancels and the gate fires only when one path
    regressed RELATIVE to the others. The raw drift factor is printed so a
    genuinely slower build still leaves a visible trace. The gate is
    >`tol` below baseline on any normalized rate (default: 10% on
    accelerator hosts, 35% on interpret-mode CPU hosts — see
    `_default_tol`), and a regression must REPRODUCE: suspect groups are
    re-measured once and only keys regressed in both passes fail (noise
    spikes don't repeat; real regressions do). Methodology and
    interpret-mode caveats in docs/ARCHITECTURE.md "Benchmarks and the
    regression gate".
    Also runs the docs reference check (tools/check_docs.py) first — stale
    docs fail the same gate as stale baselines. On failure, every
    regressed key is listed with its fresh rate, baseline rate, and the
    normalized drop.
    """
    if tol is None:
        tol = _default_tol()
        print(f"[check] tolerance {tol:.0%} (host-class default; "
              f"override with --tol)")
    doc_rc = check_docs.main([])
    if doc_rc != 0:
        print("[check] FAIL: docs reference check (see above); "
              "fix docs/*.md before measuring perf")
        return doc_rc
    gates = (
        ("engine", REPO_ROOT / "BENCH_engine.json",
         lambda: bench_engine.run(out_path=None), _engine_rates, None),
        ("serve", REPO_ROOT / "BENCH_serve.json",
         lambda: bench_serve.run(out_path=None), _serve_rates, None),
        ("adapt", REPO_ROOT / "BENCH_adapt.json",
         lambda: bench_adapt.run(out_path=None), _adapt_rates,
         _adapt_criteria),
        ("fault", REPO_ROOT / "BENCH_fault.json",
         lambda: bench_fault.run(out_path=None), _fault_rates,
         _fault_criteria),
        ("fleet", REPO_ROOT / "BENCH_fleet.json",
         lambda: bench_fleet.run(out_path=None), _fleet_rates,
         _fleet_criteria),
        ("obs", REPO_ROOT / "BENCH_obs.json",
         lambda: bench_obs.run(out_path=None), _obs_rates,
         _obs_criteria),
        ("net", REPO_ROOT / "BENCH_net.json",
         lambda: bench_net.run(out_path=None), _net_rates,
         _net_criteria),
        ("link", REPO_ROOT / "BENCH_link.json",
         lambda: bench_link.run(out_path=None), _link_rates,
         _link_criteria))
    # validate the configuration before burning minutes of re-measurement
    missing = [p.name for _, p, _, _, _ in gates if not p.exists()]
    if missing:
        print(f"[check] FAIL: no committed baseline(s): {', '.join(missing)}")
        return 2
    def _normalized_ratios(baseline, fresh, label):
        """Per-key fresh/baseline ratios with the group's uniform
        host-speed drift (geomean over shared keys) divided out."""
        shared = [k for k in sorted(baseline) if k in fresh]
        if not shared:
            return {}
        drift = (_geomean(fresh[k] for k in shared)
                 / _geomean(baseline[k] for k in shared))
        print(f"[check] {label}: host-speed drift vs baseline {drift:.2f}x "
              f"(normalized out of the per-key gate)")
        return {k: fresh[k] / baseline[k] / drift for k in shared}

    failures = []          # (key, fresh, baseline, normalized ratio)
    hard_failures = []     # host-independent criteria (e.g. BER recovery)
    compared = 0
    for name, path, bench_fn, extract, criteria_fn in gates:
        baseline = extract(json.loads(path.read_text()))
        fresh_report = bench_fn()["results"]["report"]
        fresh = extract(fresh_report)
        if criteria_fn is not None:
            for msg in criteria_fn(fresh_report):
                print(f"[check] CRITERION FAILED: {msg}")
                hard_failures.append(msg)
        for key in sorted(baseline):
            if key not in fresh:
                print(f"[check] warn: {key} in baseline but not re-measured")
        ratios = _normalized_ratios(baseline, fresh, name)
        suspects = {k: r for k, r in ratios.items() if r < 1.0 - tol}
        if suspects:
            # a real regression reproduces; a noise spike (this host's CPU
            # allocation varies over seconds) almost never does twice — so
            # fail only keys that regress in BOTH of two measurements
            print(f"[check] {name}: {len(suspects)} suspect(s) "
                  f"{sorted(suspects)} — re-measuring to confirm")
            fresh2 = extract(bench_fn()["results"]["report"])
            ratios2 = _normalized_ratios(baseline, fresh2, f"{name}#2")
            for k in list(suspects):
                if ratios2.get(k, 0.0) >= 1.0 - tol:
                    print(f"[check] {name}: {k} recovered on re-measure "
                          f"({ratios2.get(k, 0.0):.2f}x) — noise, not gated")
                    ratios[k] = ratios2[k]
                else:
                    ratios[k] = max(suspects[k], ratios2.get(k, 0.0))
        for key, ratio in ratios.items():
            compared += 1
            status = "ok" if ratio >= 1.0 - tol else "REGRESSION"
            print(f"[check] {status}: {key} {fresh[key]:,.0f} vs baseline "
                  f"{baseline[key]:,.0f} sym/s ({ratio:.2f}x normalized)")
            if ratio < 1.0 - tol:
                failures.append((key, fresh[key], baseline[key], ratio))
    print(f"[check] {compared} rates compared, {len(failures)} regressions, "
          f"{len(hard_failures)} hard-criterion failure(s)")
    if failures:
        print(f"[check] FAIL — rates more than {tol:.0%} below baseline "
              f"after drift normalization:")
        for key, f, b, r in failures:
            print(f"[check]   {key}: {f:,.0f} sym/s vs baseline {b:,.0f} "
                  f"sym/s — {(1.0 - r):.1%} relative drop "
                  f"(allowed {tol:.0%})")
        print("[check] interpret-mode CPU hosts are noisy (±25–40% per "
              "key); if this host class matches the baseline, re-run or "
              "raise --tol (see docs/ARCHITECTURE.md)")
    if hard_failures:
        print("[check] FAIL — host-independent criteria (deterministic, "
              "not noise-gated):")
        for msg in hard_failures:
            print(f"[check]   {msg}")
    return 1 if (failures or hard_failures) else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sweeps (hours)")
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument("--check", action="store_true",
                    help="re-measure engine/serve throughput and fail on "
                         ">tol regression vs the committed BENCH_*.json")
    ap.add_argument("--tol", type=float, default=None,
                    help="--check regression tolerance (fraction; default "
                         "0.10 on accelerators, 0.35 on interpret-mode CPU "
                         "hosts; raise on noisy shared hosts)")
    args = ap.parse_args(argv)

    if args.check:
        return check(tol=args.tol)

    steps = 700 if not args.full else 10_000
    jobs = [
        ("timing", lambda: bench_timing.run()),
        ("engine", lambda: bench_engine.run()),
        ("serve", lambda: bench_serve.run()),
        ("adapt", lambda: bench_adapt.run()),
        ("fault", lambda: bench_fault.run()),
        ("fleet", lambda: bench_fleet.run()),
        ("obs", lambda: bench_obs.run()),
        ("net", lambda: bench_net.run()),
        ("link", lambda: bench_link.run()),
        ("stream", lambda: bench_stream.run()),
        ("dop", lambda: bench_dop.run()),
        ("roofline", lambda: bench_roofline.run()),
        ("platform", lambda: bench_platform.run()),
        ("proakis", lambda: bench_proakis.run(steps=min(steps, 800))),
        ("quant", lambda: bench_quant.run(steps=min(steps, 600))),
        ("dse", lambda: bench_dse.run(full=args.full, steps=steps)),
    ]
    if args.only:
        jobs = [(n, f) for n, f in jobs if n in args.only]

    t0 = time.time()
    failures = []
    summary = {}
    for name, fn in jobs:
        print(f"\n=== bench:{name} " + "=" * 50)
        try:
            out = fn()
            summary[name] = {"status": "ok",
                             "elapsed_s": out.get("elapsed_s")}
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failures.append(name)
            summary[name] = {"status": f"failed: {e}"}
    summary["total_elapsed_s"] = round(time.time() - t0, 1)
    REPORT_DIR.mkdir(parents=True, exist_ok=True)
    (REPORT_DIR / "benchmarks_summary.json").write_text(
        json.dumps(summary, indent=2))
    print("\n=== benchmark summary ===")
    for k, v in summary.items():
        print(f"  {k}: {v}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
