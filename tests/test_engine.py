"""EqualizerEngine — the production inference path (core/engine.py).

Covers the ISSUE-1 acceptance surface:
  * fused_fp32 backend vs the pure-jnp oracle (`ref.cnn_eq`) across the two
    DOP operating points (equalizer_ht / equalizer_lp) and extra topologies,
    odd stream lengths, and tile-boundary cases — ≤2-ULP agreement (the
    kernels share `conv_valid_taps`, so only XLA FMA contraction differs);
  * fused_int8 backend vs the QAT fake-quant reference — within one
    accumulation LSB (observed: exact, integer arithmetic);
  * backend equivalence through `partitioned_apply` — the merged stream is
    identical across backends on the kept (interior) symbols;
  * backend selection (auto → int8 only when the learned formats deploy),
    and the tile_m autotune cache.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import equalizer_ht as HT
from repro.configs import equalizer_lp as LP
from repro.core import autotune
from repro.core import equalizer as eq
from repro.core import qat as qat_lib
from repro.core import stream_partition as sp
from repro.core.engine import BACKENDS, EqualizerEngine
from repro.kernels.cnn_eq import ref as cnn_ref

KEY = jax.random.PRNGKey(0)
ULP_TOL = 5e-6      # ~2 ULP of fp32 at the equalizer's output magnitudes

INT8_FMT = (2, 5, 3, 4)      # Q2.5 weights / Q3.4 activations — 8 bits each


def _engine(cfg, backend, tile_m=64, key=KEY, formats=None):
    params = eq.init(key, cfg)
    bn = {"bn": [{"mean": 0.1 * jax.random.normal(key, s["mean"].shape),
                  "var": 1.0 + 0.5 * jax.random.uniform(key, s["var"].shape)}
                 for s in eq.init_bn_state(cfg)["bn"]]}
    folded = eq.fold_bn(params, bn, cfg)
    engine = EqualizerEngine.from_folded(folded, cfg, backend=backend,
                                         tile_m=tile_m, formats=formats)
    return engine, folded


# ---------------------------------------------------------------------------
# fused_fp32 vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg", [
    HT.CNN,                                                  # §7.2 point
    eq.CNNEqConfig(layers=4, kernel=15, channels=4, v_parallel=4),
    eq.CNNEqConfig(layers=5, kernel=9, channels=5, v_parallel=16),
])
@pytest.mark.parametrize("n_syms", [1024, 1021, 257])        # odd lengths
def test_fused_fp32_matches_ref(cfg, n_syms):
    engine, folded = _engine(cfg, "fused_fp32", tile_m=16)
    weights = tuple((l["w"], l["b"]) for l in folded["conv"])
    strides = tuple(s for _, _, s in cfg.layer_specs())
    x = jax.random.normal(KEY, (2, n_syms * cfg.n_os))
    got = engine(x)
    want = cnn_ref.cnn_eq(x, weights, strides)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=ULP_TOL)


@pytest.mark.parametrize("tile_m", [8, 17, 64, 1024])        # boundary cases:
# partial last tile, non-power-of-two, single tile covering the stream
def test_fused_fp32_tile_boundaries(tile_m):
    cfg = LP.CNN
    engine, folded = _engine(cfg, "fused_fp32", tile_m=tile_m)
    weights = tuple((l["w"], l["b"]) for l in folded["conv"])
    strides = tuple(s for _, _, s in cfg.layer_specs())
    x = jax.random.normal(KEY, (1, 999 * cfg.n_os))          # odd stream
    np.testing.assert_allclose(np.asarray(engine(x)),
                               np.asarray(cnn_ref.cnn_eq(x, weights, strides)),
                               rtol=0, atol=ULP_TOL)


def test_engine_handles_unbatched_input():
    engine, _ = _engine(eq.CNNEqConfig(), "fused_fp32")
    x = jax.random.normal(KEY, (512 * 2,))
    y = engine(x)
    assert y.shape == (512,)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(engine(x[None])[0]))


# ---------------------------------------------------------------------------
# fused_int8 vs QAT fake-quant reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg,name", [(HT.CNN, "ht"), (LP.CNN, "lp")])
def test_fused_int8_matches_fake_quant(cfg, name):
    formats = tuple(INT8_FMT for _ in range(cfg.layers))
    engine, folded = _engine(cfg, "fused_int8", tile_m=32, formats=formats)
    weights = tuple((l["w"], l["b"]) for l in folded["conv"])
    strides = tuple(s for _, _, s in cfg.layer_specs())
    x = jax.random.normal(KEY, (2, 1024 * cfg.n_os))
    got = engine(x)
    want = cnn_ref.cnn_eq_quant(x, weights, strides, formats)
    lsb = 2.0 ** -(INT8_FMT[1] + INT8_FMT[3])    # accumulation grid LSB
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=lsb)


def test_fused_int8_quant_error_is_bounded():
    """int8 output differs from fp32 only by quantization noise, not junk."""
    cfg = eq.CNNEqConfig()
    formats = tuple(INT8_FMT for _ in range(cfg.layers))
    e8, folded = _engine(cfg, "fused_int8", formats=formats)
    e32 = EqualizerEngine.from_folded(folded, cfg, backend="fused_fp32",
                                      tile_m=64)
    x = jax.random.normal(KEY, (1, 2048))
    err = float(jnp.max(jnp.abs(e8(x) - e32(x))))
    assert 0 < err < 1.0         # quantized but sane (Q3.4 activation grid)


def test_int8_rejects_wide_formats():
    cfg = eq.CNNEqConfig()
    wide = tuple((4, 9, 3, 4) for _ in range(cfg.layers))    # 14-bit weights
    with pytest.raises(ValueError, match="int8"):
        _engine(cfg, "fused_int8", formats=wide)


def test_int8_kernel_rejects_wide_activation_formats():
    """Direct kernel API: 9-bit activations would WRAP in the int8 requant
    cast — must raise, not corrupt silently."""
    from repro.kernels.cnn_eq.cnn_eq import (cnn_eq_fused_int8,
                                             quantize_weights_int8)
    cfg = eq.CNNEqConfig()
    _, folded = _engine(cfg, "ref")
    weights = tuple((l["w"], l["b"]) for l in folded["conv"])
    strides = tuple(s for _, _, s in cfg.layer_specs())
    bad = tuple((2, 5, 4, 4) for _ in range(cfg.layers))     # 9-bit acts
    qw = quantize_weights_int8(weights, bad)                 # weights OK
    x = jax.random.normal(KEY, (1, 256))
    with pytest.raises(ValueError, match="wrap"):
        cnn_eq_fused_int8(x, qw, strides, bad, tile_m=16, interpret=True)


# ---------------------------------------------------------------------------
# fused_bf16 vs bf16 oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg", [
    HT.CNN,
    eq.CNNEqConfig(layers=4, kernel=15, channels=4, v_parallel=4),
])
def test_fused_bf16_matches_oracle(cfg):
    """bf16 kernel and oracle share conv_valid_taps_bf16 → bitwise."""
    engine, folded = _engine(cfg, "fused_bf16", tile_m=16)
    weights = tuple((l["w"], l["b"]) for l in folded["conv"])
    strides = tuple(s for _, _, s in cfg.layer_specs())
    x = jax.random.normal(KEY, (2, 1021 * cfg.n_os))         # odd length
    got = engine(x)
    want = cnn_ref.cnn_eq_bf16(x, weights, strides)
    assert got.shape == want.shape
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fused_bf16_error_is_bounded():
    """bf16 differs from fp32 only by mantissa-rounding noise, not junk."""
    cfg = eq.CNNEqConfig()
    eb, folded = _engine(cfg, "fused_bf16")
    e32 = EqualizerEngine.from_folded(folded, cfg, backend="fused_fp32",
                                      tile_m=64)
    x = jax.random.normal(KEY, (1, 2048))
    err = float(jnp.max(jnp.abs(eb(x) - e32(x))))
    assert 0 < err < 0.2         # ~2^-8 relative at O(1) activations


# ---------------------------------------------------------------------------
# stacked multi-tenant launch (serving path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_stacked_engine_fn_matches_individual(backend):
    """One batched launch with per-row weights ≡ each engine run alone —
    the bitwise contract the serve micro-batcher relies on."""
    from repro.core.engine import stacked_engine_fn
    cfg = eq.CNNEqConfig()
    formats = (tuple(INT8_FMT for _ in range(cfg.layers))
               if backend == "fused_int8" else None)
    engines = [_engine(cfg, backend, tile_m=32, key=jax.random.PRNGKey(i),
                       formats=formats)[0]
               for i in range(3)]
    fn = stacked_engine_fn(engines)
    x = jax.random.normal(KEY, (3, 512 * cfg.n_os))
    y = np.asarray(fn(x))
    for i, e in enumerate(engines):
        np.testing.assert_array_equal(y[i:i + 1],
                                      np.asarray(e(x[i:i + 1])))


def test_stacked_engine_fn_rejects_mixed_groups():
    from repro.core.engine import stacked_engine_fn
    cfg = eq.CNNEqConfig()
    e_a, _ = _engine(cfg, "fused_fp32", tile_m=32)
    e_b, _ = _engine(cfg, "fused_fp32", tile_m=64)          # different tile
    with pytest.raises(ValueError, match="not batch-compatible"):
        stacked_engine_fn([e_a, e_b])


# ---------------------------------------------------------------------------
# backend selection / deployment
# ---------------------------------------------------------------------------

def _qat_params(cfg, wi, wf, ai, af):
    params = eq.init(KEY, cfg)
    params["qat"] = {
        f"layer{i}": {"w_int": jnp.asarray(float(wi)),
                      "w_frac": jnp.asarray(float(wf)),
                      "a_int": jnp.asarray(float(ai)),
                      "a_frac": jnp.asarray(float(af))}
        for i in range(cfg.layers)}
    return params


def test_auto_backend_selection():
    cfg = eq.CNNEqConfig()
    bn = eq.init_bn_state(cfg)
    # no QAT → fp32
    plain = eq.init(KEY, cfg)
    assert EqualizerEngine.from_params(plain, bn, cfg).backend == "fused_fp32"
    # learned 8-bit formats → int8
    p8 = _qat_params(cfg, 2, 5, 3, 4)
    assert EqualizerEngine.from_params(p8, bn, cfg).backend == "fused_int8"
    # 9–16-bit learned formats → native bf16 deployment
    p16 = _qat_params(cfg, 4, 9, 4, 9)
    assert EqualizerEngine.from_params(p16, bn, cfg).backend == "fused_bf16"
    # wider than 16 bits → fp32
    p32 = _qat_params(cfg, 8, 12, 8, 12)
    assert EqualizerEngine.from_params(p32, bn, cfg).backend == "fused_fp32"
    # explicit request still honoured
    assert EqualizerEngine.from_params(p8, bn, cfg,
                                       backend="ref").backend == "ref"
    with pytest.raises(ValueError, match="unknown backend"):
        EqualizerEngine.from_params(plain, bn, cfg, backend="fused_int4")


def test_auto_backend_falls_back_when_folding_overflows_grid():
    """QAT learns Q(w_int) on UNfolded weights; trained BN stats with tiny
    running variance scale the folded weights past the learned grid. The
    engine must refuse silent int8 saturation — it deploys bf16 instead
    (the exponent covers the overflowed range, no clipping)."""
    cfg = eq.CNNEqConfig()
    params = _qat_params(cfg, 2, 5, 3, 4)
    bn = eq.init_bn_state(cfg)
    # var = 1e-4 → fold gain g ≈ 100× → |w·g| ≫ 2^2
    bn = {"bn": [{"mean": s["mean"], "var": 1e-4 * jnp.ones_like(s["var"])}
                 for s in bn["bn"]]}
    engine = EqualizerEngine.from_params(params, bn, cfg)
    assert engine.backend == "fused_bf16"
    # benign BN stats keep the int8 deployment
    assert EqualizerEngine.from_params(params, eq.init_bn_state(cfg),
                                       cfg).backend == "fused_int8"
    # EXPLICIT int8 under the same overflow must refuse, not saturate
    with pytest.raises(ValueError, match="saturate"):
        EqualizerEngine.from_params(params, bn, cfg, backend="fused_int8")


def test_from_params_int8_matches_fake_quant_apply():
    """End-to-end deployment: trained-style params with frozen QAT widths →
    auto int8 engine ≡ the training-graph fake-quant forward (interior)."""
    cfg = eq.CNNEqConfig()
    bn = eq.init_bn_state(cfg)
    params = _qat_params(cfg, 2, 5, 3, 4)
    engine = EqualizerEngine.from_params(params, bn, cfg, tile_m=64)
    assert engine.backend == "fused_int8"
    x = jax.random.normal(KEY, (1, 1024 * cfg.n_os))
    got = engine(x)
    want, _ = eq.apply(params, x, cfg, train=False, bn_state=bn,
                       qat_enabled=True)
    o = cfg.receptive_field_syms
    # stream vs SAME padding differ only inside the overlap region. The
    # BN-fold ε (w → w/√(1+1e-5)) can flip individual rounding decisions
    # between Q(w)·g (training graph) and Q(w·g) (deployment), so allow 2
    # activation LSBs (observed max ≈ 1.1 LSB).
    np.testing.assert_allclose(np.asarray(got)[:, o:-o],
                               np.asarray(want)[:, o:-o], rtol=0,
                               atol=2.0 * 2.0 ** -4)


# ---------------------------------------------------------------------------
# backend equivalence through the partitioned stream path
# ---------------------------------------------------------------------------

def test_backend_equivalence_through_partitioned_apply():
    cfg = HT.CNN
    n_inst = 8
    formats = tuple(INT8_FMT for _ in range(cfg.layers))
    engines = {}
    _, folded = _engine(cfg, "ref")
    for backend in BACKENDS:
        engines[backend] = EqualizerEngine.from_folded(
            folded, cfg, backend=backend, tile_m=32,
            formats=formats if backend == "fused_int8" else None)
    x = jax.random.normal(KEY, (1024 * n_inst * cfg.n_os,))
    merged = {b: np.asarray(sp.partitioned_apply(e, x, n_inst, cfg))
              for b, e in engines.items()}
    # fp32 backends agree everywhere on the merged stream
    np.testing.assert_allclose(merged["ref"], merged["fused_fp32"],
                               rtol=0, atol=ULP_TOL)
    # every backend: partitioned == unsplit (the §6.1 overlap guarantee) —
    # int8 exactly (integer datapath), fp32 to fusion noise
    for b, e in engines.items():
        whole = np.asarray(e(x))
        tol = 0.0 if b == "fused_int8" else ULP_TOL
        np.testing.assert_allclose(merged[b], whole, rtol=0, atol=tol)


# ---------------------------------------------------------------------------
# autotune
# ---------------------------------------------------------------------------

def test_autotune_cache_roundtrip(tmp_path, monkeypatch):
    monkeypatch.setattr(autotune, "CACHE_PATH",
                        tmp_path / "autotune_tile_m.json")
    autotune.clear_cache()
    cfg = eq.CNNEqConfig()
    calls = []

    def make_fn(tile_m):
        engine, _ = _engine(cfg, "fused_fp32", tile_m=tile_m)
        calls.append(tile_m)
        return engine

    best = autotune.best_tile_m(cfg, "fused_fp32", make_fn,
                                candidates=(16, 64), probe_syms=512)
    assert best in (16, 64) and sorted(set(calls)) == [16, 64]
    # second query: memory cache, no new sweeps
    n = len(calls)
    assert autotune.best_tile_m(cfg, "fused_fp32", make_fn) == best
    assert len(calls) == n
    # cold process simulation: memory cleared, disk hit survives
    autotune.clear_cache()
    assert autotune.best_tile_m(cfg, "fused_fp32", make_fn) == best
    assert len(calls) == n
    # different backend → different cache slot
    assert autotune.cache_key(cfg, "fused_int8") != autotune.cache_key(
        cfg, "fused_fp32")


def test_engine_auto_tile_resolves(tmp_path, monkeypatch):
    monkeypatch.setattr(autotune, "CACHE_PATH",
                        tmp_path / "autotune_tile_m.json")
    autotune.clear_cache()
    monkeypatch.setattr(autotune, "DEFAULT_TILES", (16, 64))
    engine, _ = _engine(eq.CNNEqConfig(), "fused_fp32", tile_m="auto")
    t = engine.resolved_tile_m()
    assert t in (16, 64)
    assert engine.tile_m == t            # sticky after first resolution
    y = engine(jax.random.normal(KEY, (1, 1024)))
    assert y.shape == (1, 512)
