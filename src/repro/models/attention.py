"""GQA attention with qk-norm, RoPE, sliding windows, KV caching.

TP policy (parallel/sharding.resolve_heads): Q heads padded to the TP degree;
KV heads either shard directly or are EXPANDED to per-Q-head replicas at
compute/cache time (the logical GQA weights stay at n_kv heads, so parameter
counts match the assigned architecture).

Memory policy: full-causal attention materializes scores per Q-CHUNK
(`q_chunk`), bounding live memory to (B, H, q_chunk, S) — the TPU analogue of
flash attention's tiling, expressed at the XLA level so GSPMD still shards
it. Sliding-window attention slices the K/V band per chunk, making long
sequences (mixtral long_500k) linear in S.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..parallel import sharding
from .common import ModelConfig, dense_init, rms_norm, rope

NEG_INF = -1e30


def init(key: jax.Array, cfg: ModelConfig, d_out: Optional[int] = None
         ) -> Dict[str, Any]:
    """Attention parameters. Logical KV heads = cfg.n_kv_heads."""
    d = cfg.d_model
    dh = cfg.head_dim
    hq_pad, _ = sharding.resolve_heads(cfg.n_heads, cfg.n_kv_heads, cfg.tp)
    dt = cfg.param_dtype()
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, (d, hq_pad, dh), dt),
        "wk": dense_init(k2, (d, cfg.n_kv_heads, dh), dt),
        "wv": dense_init(k3, (d, cfg.n_kv_heads, dh), dt),
        "wo": dense_init(k4, (hq_pad, dh, d_out or d), dt,
                         scale=1.0 / np.sqrt(hq_pad * dh)),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dt)
        p["k_norm"] = jnp.ones((dh,), dt)
    return p


def _expand_kv(k: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """(B, S, n_kv, D) → (B, S, kv_eff, D) per resolve_heads policy."""
    hq, kv_eff = sharding.resolve_heads(cfg.n_heads, cfg.n_kv_heads, cfg.tp)
    if kv_eff == cfg.n_kv_heads:
        return k
    idx = jnp.asarray(sharding.kv_head_map(cfg.n_heads, cfg.n_kv_heads, hq,
                                           kv_eff))
    return jnp.take(k, idx, axis=2)


def qkv(params, x: jnp.ndarray, cfg: ModelConfig,
        positions: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) → q (B,S,Hq,D), k/v (B,S,KVeff,D) — rope'd, normed."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if cfg.rope_theta > 0:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    k = _expand_kv(k, cfg)
    v = _expand_kv(v, cfg)
    q = sharding.logical(q, ("batch", None, "heads", None))
    k = sharding.logical(k, ("batch", None, "heads", None))
    v = sharding.logical(v, ("batch", None, "heads", None))
    return q, k, v


def _attend_dense(q, k, v, mask, scale):
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def _gqa_repeat(q, n_kv_eff):
    """Group Q heads for GQA score computation when kv not expanded."""
    b, s, hq, d = q.shape
    return q.reshape(b, s, n_kv_eff, hq // n_kv_eff, d)


def _flash_sharded(q, k, v, window: int, q_offset: int):
    """Flash-attention kernel, manually partitioned.

    A pallas custom-call is opaque to GSPMD (it would replicate + gather),
    so the kernel runs under shard_map with (batch→data/pod, heads→model)
    specs — each device runs the kernel on its local shard, which is the
    whole point of head/batch parallelism. Falls back to a direct call
    without a mesh (single-device tests).

    DRY-RUN MODE (REPRO_STUB_FLASH=1, set by launch/dryrun.py): interpret-
    mode pallas lowers to per-grid-step loops whose HLO traffic massively
    misrepresents the mosaic custom-call (measured 10× phantom bytes), and
    mosaic itself cannot lower on the CPU dry-run host. The stub below has
    the kernel's EXACT HBM profile — reads q/k/v once, writes o once —
    and its MXU flops are added analytically (dryrun._kernel_flops)."""
    import os
    from jax.sharding import PartitionSpec as P
    from ..kernels.flash_attn import flash_attention

    if os.environ.get("REPRO_STUB_FLASH") == "1":
        alpha = (jnp.mean(k.astype(jnp.float32))
                 + jnp.mean(v.astype(jnp.float32))).astype(q.dtype)
        return q + alpha * 0  # traffic-equivalent stand-in (never executed)

    mesh = sharding.get_mesh()

    def call(q_, k_, v_):
        return flash_attention(q_, k_, v_, causal=True, window=window,
                               q_offset=q_offset)

    if mesh is None:
        return call(q, k, v)
    b_axes = sharding.batch_axes(mesh)
    bsz = 1
    for a in b_axes:
        bsz *= mesh.shape[a]
    b_spec = b_axes if (b_axes and q.shape[0] % bsz == 0) else None
    h_ax = "model" if "model" in mesh.axis_names \
        and q.shape[2] % mesh.shape["model"] == 0 \
        and k.shape[2] % mesh.shape["model"] == 0 else None
    spec = P(b_spec, None, h_ax, None)
    return jax.shard_map(call, mesh=mesh, in_specs=(spec, spec, spec),
                         out_specs=spec, check_vma=False)(q, k, v)


def _flash_bwd_sharded(q, k, v, o, lse, g, window: int, q_offset: int):
    """Backward kernels under the same manual partitioning as the forward.

    In dry-run stub mode the gradients are traffic-equivalent stand-ins
    (read what the kernels read, write what they write); the MXU flops are
    added analytically (launch/dryrun._kernel_flops)."""
    import os
    from jax.sharding import PartitionSpec as P
    from ..kernels.flash_attn.flash_attn import flash_attention_bwd

    if os.environ.get("REPRO_STUB_FLASH") == "1":
        alpha = (jnp.mean(o.astype(jnp.float32))
                 + jnp.mean(lse)).astype(q.dtype) * 0
        dq = g.astype(q.dtype) + alpha
        dk = jnp.zeros_like(k) + alpha
        dv = jnp.zeros_like(v) + alpha
        return dq, dk, dv

    mesh = sharding.get_mesh()

    def call(q_, k_, v_, o_, lse_, g_):
        return flash_attention_bwd(q_, k_, v_, o_, lse_, g_, causal=True,
                                   window=window, q_offset=q_offset)

    if mesh is None:
        return call(q, k, v, o, lse, g)
    b_axes = sharding.batch_axes(mesh)
    bsz = 1
    for a in b_axes:
        bsz *= mesh.shape[a]
    b_spec = b_axes if (b_axes and q.shape[0] % bsz == 0) else None
    h_ax = "model" if "model" in mesh.axis_names \
        and q.shape[2] % mesh.shape["model"] == 0 \
        and k.shape[2] % mesh.shape["model"] == 0 else None
    s4 = P(b_spec, None, h_ax, None)
    s3 = P(b_spec, None, h_ax)
    return jax.shard_map(call, mesh=mesh,
                         in_specs=(s4, s4, s4, s4, s3, s4),
                         out_specs=(s4, s4, s4), check_vma=False)(
        q, k, v, o, lse, g)


def _flash_fwd_lse_sharded(q, k, v, window: int, q_offset: int):
    import os
    from jax.sharding import PartitionSpec as P
    from ..kernels.flash_attn.flash_attn import flash_attention_fwd

    if os.environ.get("REPRO_STUB_FLASH") == "1":
        alpha = (jnp.mean(k.astype(jnp.float32))
                 + jnp.mean(v.astype(jnp.float32))).astype(q.dtype) * 0
        lse = jnp.zeros(q.shape[:2] + (q.shape[2],), jnp.float32) \
            + alpha.astype(jnp.float32)
        return q + alpha, lse

    mesh = sharding.get_mesh()

    def call(q_, k_, v_):
        return flash_attention_fwd(q_, k_, v_, causal=True, window=window,
                                   q_offset=q_offset)

    if mesh is None:
        return call(q, k, v)
    b_axes = sharding.batch_axes(mesh)
    bsz = 1
    for a in b_axes:
        bsz *= mesh.shape[a]
    b_spec = b_axes if (b_axes and q.shape[0] % bsz == 0) else None
    h_ax = "model" if "model" in mesh.axis_names \
        and q.shape[2] % mesh.shape["model"] == 0 \
        and k.shape[2] % mesh.shape["model"] == 0 else None
    s4 = P(b_spec, None, h_ax, None)
    s3 = P(b_spec, None, h_ax)
    return jax.shard_map(call, mesh=mesh, in_specs=(s4, s4, s4),
                         out_specs=(s4, s3), check_vma=False)(q, k, v)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _fused_causal(q, k, v, q_offset: int, window: int, q_chunk: int):
    """Flash attention, forward AND backward in Pallas (§Perf it. 3/6):
    HBM traffic is O(S·D) in both directions — no (S×S) score tensor ever
    reaches HBM."""
    return _flash_sharded(q, k, v, window, q_offset)


def _fused_fwd(q, k, v, q_offset, window, q_chunk):
    o, lse = _flash_fwd_lse_sharded(q, k, v, window, q_offset)
    return o, (q, k, v, o, lse)


def _fused_bwd(q_offset, window, q_chunk, res, g):
    q, k, v, o, lse = res
    dq, dk, dv = _flash_bwd_sharded(q, k, v, o, lse, g, window, q_offset)
    return dq, dk, dv


_fused_causal.defvjp(_fused_fwd, _fused_bwd)


def attend_causal(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  q_offset: jnp.ndarray | int = 0, window: int = 0,
                  q_chunk: int = 1024, fused: bool = False) -> jnp.ndarray:
    if fused and isinstance(q_offset, int) and q.shape[1] > 1:
        return _fused_causal(q, k, v, q_offset, window, q_chunk)
    return _attend_causal_xla(q, k, v, q_offset, window, q_chunk)


def _attend_causal_xla(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                       q_offset: jnp.ndarray | int = 0, window: int = 0,
                       q_chunk: int = 1024) -> jnp.ndarray:
    """Causal (optionally sliding-window) attention, chunked over queries.

    q: (B, Sq, Hq, D); k/v: (B, Sk, Hkv, D) with Hq % Hkv == 0.
    q_offset: absolute position of q[0] relative to k[0] (prefill: 0).
    """
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    scale = 1.0 / np.sqrt(d)
    rep = hq // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)

    if sq <= q_chunk:
        qpos = q_offset + jnp.arange(sq)[:, None]
        kpos = jnp.arange(sk)[None, :]
        mask = kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        return _attend_dense(q, k, v, mask[None, None], scale)

    n_chunks = sq // q_chunk
    assert sq % q_chunk == 0, "q_chunk must divide the sequence"

    def chunk_fn(i):
        qs = q_offset + i * q_chunk
        qc = jax.lax.dynamic_slice_in_dim(q, i * q_chunk, q_chunk, axis=1)
        qpos = qs + jnp.arange(q_chunk)[:, None]
        if 0 < window < sk:
            # only the K/V band [qs - window + 1, qs + q_chunk) can attend
            band = min(q_chunk + window, sk)
            start = jnp.clip(qs - window + 1, 0, sk - band)
            kc = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
            kpos = start + jnp.arange(band)[None, :]
            mask = (kpos <= qpos) & (kpos > qpos - window)
            return _attend_dense(qc, kc, vc, mask[None, None], scale)
        kpos = jnp.arange(sk)[None, :]
        mask = kpos <= qpos
        if window > 0:
            mask = mask & (kpos > qpos - window)
        return _attend_dense(qc, k, v, mask[None, None], scale)

    out = jax.lax.map(chunk_fn, jnp.arange(n_chunks))   # (n, B, qc, H, D)
    return jnp.moveaxis(out, 0, 1).reshape(b, sq, hq, d)


def attend_full(q, k, v):
    """Bidirectional attention (encoder / cross)."""
    scale = 1.0 / np.sqrt(q.shape[-1])
    rep = q.shape[2] // k.shape[2]
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    mask = jnp.ones((1, 1, q.shape[1], k.shape[1]), bool)
    return _attend_dense(q, k, v, mask, scale)


def out_proj(params, o: jnp.ndarray) -> jnp.ndarray:
    y = jnp.einsum("bshk,hkd->bsd", o, params["wo"])
    return sharding.logical(y, ("batch", None, None))


# ---------------------------------------------------------------------------
# Full layers (self / cross) with cache plumbing
# ---------------------------------------------------------------------------

def self_attention(params, x, cfg: ModelConfig, positions,
                   cache: Optional[Dict[str, jnp.ndarray]] = None,
                   cache_pos: Optional[jnp.ndarray] = None,
                   causal: bool = True, q_chunk: int = 1024):
    """Returns (out, new_cache).

    Modes:
      train/eval: cache=None → full pass.
      prefill:    cache=zeros, cache_pos=0 → fills cache[0:S].
      decode:     x is (B,1,d), cache_pos = current length → one step.
    """
    q, k, v = qkv(params, x, cfg, positions)
    if cache is None:
        o = (attend_causal(q, k, v, 0, cfg.window, q_chunk,
                           fused=cfg.fused_attention) if causal
             else attend_full(q, k, v))
        return out_proj(params, o), None

    new_k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), cache_pos, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), cache_pos, axis=1)
    new_cache = {"k": new_k, "v": new_v}
    sq = x.shape[1]
    if sq == 1:
        # decode: attend to cache[0:cache_pos+1] via position masking
        kk, vv = new_k, new_v
        sk = kk.shape[1]
        rep = q.shape[2] // kk.shape[2]
        if rep > 1:
            kk = jnp.repeat(kk, rep, axis=2)
            vv = jnp.repeat(vv, rep, axis=2)
        kpos = jnp.arange(sk)[None, :]
        mask = kpos <= cache_pos
        if cfg.window > 0:
            mask &= kpos > cache_pos - cfg.window
        o = _attend_dense(q, kk, vv, mask[None, None],
                          1.0 / np.sqrt(q.shape[-1]))
    else:
        o = attend_causal(q, k, v, cache_pos, cfg.window, q_chunk)
    return out_proj(params, o), new_cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> Dict[str, jnp.ndarray]:
    _, kv_eff = sharding.resolve_heads(cfg.n_heads, cfg.n_kv_heads, cfg.tp)
    cache_len = min(max_len, cfg.window) if cfg.window > 0 else max_len
    # sliding-window caches could be ring buffers of length `window`;
    # kept at max_len here for positional simplicity, window-sliced at use.
    shape = (batch, max_len, kv_eff, cfg.head_dim)
    dt = dtype or cfg.param_dtype()
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def cross_attention(params, x, enc_out, cfg: ModelConfig,
                    cached_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None):
    """Decoder→encoder attention; enc K/V can be precomputed at prefill."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
    if cached_kv is None:
        k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"])
        if cfg.qk_norm:
            k = rms_norm(k, params["k_norm"])
        k = _expand_kv(k, cfg)
        v = _expand_kv(v, cfg)
    else:
        k, v = cached_kv
    o = attend_full(q, k, v)
    return out_proj(params, o), (k, v)
