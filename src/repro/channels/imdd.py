"""Simulated 40 GBd IM/DD optical fiber channel (paper §2.1).

The paper captures data from an experimental link; we reproduce the link in
simulation with the same parameters:

    * 40 GBd PAM-2 (OOK), Mersenne-Twister pseudo-random pattern
    * RRC pulse shaping, N_os = 2 samples/symbol
    * MZM biased at quadrature → field amplitude modulation
    * 31.5 km SSMF, CD coefficient 16 ps/(nm km) @ 1550 nm
    * square-law photodetection (|E|²) — the CD × direct-detection interplay
      is what makes the effective channel NONLINEAR
    * receiver AWGN (transceiver noise)

Chromatic dispersion is applied in the frequency domain on the optical field:
    H(f) = exp(+j · (π λ² D L / c) · f²)
Square-law detection afterwards yields nonlinear ISI that a linear FIR cannot
invert — the motivation for the CNN equalizer.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .common import awgn, bits_to_pam, fir_same, rrc_taps, upsample

C_LIGHT = 299_792_458.0  # m/s


@dataclasses.dataclass(frozen=True)
class IMDDConfig:
    baud_rate: float = 40e9          # 40 GBd
    n_os: int = 2                    # oversampling at the equalizer input
    sim_os: int = 4                  # internal simulation oversampling
    fiber_km: float = 31.5
    cd_ps_nm_km: float = 16.0
    wavelength_nm: float = 1550.0
    rrc_beta: float = 0.2
    rrc_taps: int = 129
    snr_db: float = 20.0             # electrical (post-PD) SNR
    osnr_db: float = 28.0            # optical SNR (ASE before the PD):
    #   signal×ASE beat noise after |·|² is SIGNAL-DEPENDENT — the level-
    #   dependent decision statistics a nonlinear equalizer exploits
    mzm_vpi_frac: float = 1.0        # drive swing as fraction of Vpi (OOK)
    pd_bw_hz: float = 40e9           # photodetector bandwidth (paper: 40 GHz)
    levels: int = 2                  # PAM2


def _cd_phase(n_fft: int, fs: float, cfg: IMDDConfig) -> np.ndarray:
    """Frequency-domain chromatic-dispersion all-pass phase response."""
    lam = cfg.wavelength_nm * 1e-9
    d = cfg.cd_ps_nm_km * 1e-12 / 1e-9 / 1e3          # s/m/m
    length = cfg.fiber_km * 1e3
    f = np.fft.fftfreq(n_fft, d=1.0 / fs)
    phase = np.pi * lam**2 * d * length / C_LIGHT * f**2
    return phase.astype(np.float64)


@functools.partial(jax.jit, static_argnames=("cfg", "n_syms"))
def simulate(key: jax.Array, cfg: IMDDConfig, n_syms: int):
    """Simulate one frame.

    Returns:
      rx:   received electrical waveform at N_os samples/symbol, length
            n_syms * n_os, normalized to zero mean / unit variance.
      syms: transmitted symbol indices (n_syms,), aligned with rx (timing
            recovery is exact in simulation).
    """
    kbits, knoise = jax.random.split(key)
    syms = jax.random.randint(kbits, (n_syms,), 0, cfg.levels)
    amps = bits_to_pam(syms, cfg.levels)

    # --- transmitter: upsample + RRC shape (at simulation oversampling) ---
    taps = jnp.asarray(rrc_taps(cfg.rrc_taps, cfg.rrc_beta, cfg.sim_os))
    x = upsample(amps, cfg.sim_os)
    x = fir_same(x, taps) * jnp.sqrt(float(cfg.sim_os))

    # --- MZM at quadrature: field E ∝ cos(π/4 + drive) ------------------
    # (intensity is then sin-shaped; small-signal ≈ linear intensity mod)
    drive = cfg.mzm_vpi_frac * (np.pi / 2.0) * x
    field = jnp.cos(np.pi / 4.0 - drive / 2.0)  # complex envelope, real here

    # --- fiber: chromatic dispersion on the optical field ---------------
    fs = cfg.baud_rate * cfg.sim_os
    phase = jnp.asarray(_cd_phase(int(field.shape[0]), fs, cfg))
    spec = jnp.fft.fft(field.astype(jnp.complex64))
    field_out = jnp.fft.ifft(spec * jnp.exp(1j * phase))

    # --- amplifier ASE: complex AWGN on the FIELD (pre-detection) -------
    knoise, kase = jax.random.split(knoise)
    p_sig = jnp.mean(jnp.abs(field_out) ** 2)
    p_ase = p_sig / (10.0 ** (cfg.osnr_db / 10.0))
    ase = jnp.sqrt(p_ase / 2.0) * (
        jax.random.normal(kase, field_out.shape)
        + 1j * jax.random.normal(jax.random.fold_in(kase, 1),
                                 field_out.shape))
    field_out = field_out + ase.astype(field_out.dtype)

    # --- receiver: square-law photodetector + AWGN ----------------------
    # |E|² doubles the signal bandwidth; the photodetector's finite analog
    # bandwidth (paper: 40 GHz PD) low-passes it BEFORE sampling — without
    # this the later 2× decimation aliases the nonlinear mixing products
    # into band, turning deterministic (equalizable) ISI into noise.
    current = jnp.abs(field_out) ** 2
    f = np.fft.fftfreq(int(current.shape[0]), d=1.0 / fs)
    pd_lpf = jnp.asarray(1.0 / np.sqrt(1.0 + (f / cfg.pd_bw_hz) ** 8))
    current = jnp.real(jnp.fft.ifft(jnp.fft.fft(current.astype(jnp.complex64))
                                    * pd_lpf))
    current = awgn(knoise, current.astype(jnp.float32), cfg.snr_db)

    # --- resample to N_os samples/symbol + normalize --------------------
    step = cfg.sim_os // cfg.n_os
    rx = current[::step]
    rx = (rx - jnp.mean(rx)) / (jnp.std(rx) + 1e-9)
    return rx, syms
