"""Fig. 4 — the DSE-selected CNN vs FIR/Volterra on the LINEAR magnetic-
recording channel (Proakis-B @ 20 dB): the gap between CNN and FIR closes
on a linear channel (paper: CNN 8.4e-3 vs FIR 9.6e-3 — a few percent, not
the 4× of the nonlinear channel)."""
from __future__ import annotations

import jax

from repro.channels import proakis
from repro.core.equalizer import CNNEqConfig
from repro.core.fir import FIRConfig
from repro.core.train_eq import EqTrainConfig, train_equalizer
from repro.core.volterra import VolterraConfig
from repro.data.equalizer_data import channel_fn

from .common import Bench


def run(steps: int = 800) -> dict:
    bench = Bench("proakis_b", "Fig. 4 / §3.6")
    fn = channel_fn("proakis", proakis.ProakisConfig(snr_db=20.0))
    tcfg = EqTrainConfig(steps=steps, batch=8, seq_syms=256, lr=3e-3,
                         eval_syms=1 << 15)
    key = jax.random.PRNGKey(0)

    rows = {}
    for name, kind, cfg in [
        ("cnn_selected", "cnn", CNNEqConfig()),
        ("fir_57", "fir", FIRConfig(taps=57)),
        ("volterra", "volterra", VolterraConfig(m1=25, m2=9, m3=0)),
    ]:
        _, _, info = train_equalizer(key, kind, cfg, fn, tcfg)
        rows[name] = {"ber": info["ber"],
                      "mac_per_sym": cfg.mac_per_symbol()}
        print(f"[bench_proakis] {name}: BER {info['ber']:.3e} "
              f"({cfg.mac_per_symbol():.1f} MAC/sym)")
    bench.record("rows", rows)
    # Fig-4 claim: on the linear channel the CNN/FIR gap is SMALL
    gap = rows["fir_57"]["ber"] / max(rows["cnn_selected"]["ber"], 1e-9)
    bench.record("fir_over_cnn_ratio", gap)
    bench.record("claim_gap_small", bool(0.3 <= gap <= 3.5))
    return bench.finish()


if __name__ == "__main__":
    run()
