"""Jitted public wrapper for the fused sLSTM kernel."""
from .ref import slstm as slstm_ref
from .slstm import slstm_fused

__all__ = ["slstm_fused", "slstm_ref"]
