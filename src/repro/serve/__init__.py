"""Multi-tenant streaming equalizer serving runtime (see runtime.py and
docs/ARCHITECTURE.md).

Layers:
  chunker    — stateful overlap-save: arbitrary chunk sizes, offline-exact
               (carry snapshot/restore is the failover primitive)
  pool       — LRU-bounded engine pool (session-manager memory bound)
  session    — TenantSpec / Session / SessionManager
  scheduler  — BatchPolicy / MicroBatcher: dynamic micro-batching into
               stacked fused-kernel launches with per-row tenant weights,
               split into assemble/execute/descatter phases; TrafficStats
               feed the serve-aware autotune
  recovery   — fault taxonomy, deterministic FaultPlan chaos injection,
               RecoveryPolicy failover bounds, output sentinel, and the
               straggler-driven DegradationController
  runtime    — ServeRuntime (sync) / AsyncServeRuntime (threaded
               front-end: timer-driven pump, double-buffered launches,
               per-chunk futures, deadline/backoff launch discipline,
               bounded session failover)
  loadgen    — reproducible tenant traffic for benches/examples
"""
from .chunker import CarrySnapshot, ChunkPlan, StreamChunker
from .loadgen import (chop, drift_streams, random_waveforms, replay,
                      replay_adaptive)
from .pool import EnginePool
from .recovery import (CorruptOutput, DegradationController, Fault,
                       FaultPlan, InjectedFault, LaunchTimeout,
                       RecoveryPolicy, RecoveryStats, TenantShedError)
from .runtime import AsyncServeRuntime, ServeRuntime
from .scheduler import (BatchPolicy, LaunchBatch, MicroBatcher, Request,
                        TrafficStats)
from .session import Session, SessionManager, TenantSpec

__all__ = ["AsyncServeRuntime", "BatchPolicy", "CarrySnapshot", "ChunkPlan",
           "CorruptOutput", "DegradationController", "EnginePool", "Fault",
           "FaultPlan", "InjectedFault", "LaunchBatch", "LaunchTimeout",
           "MicroBatcher", "RecoveryPolicy", "RecoveryStats", "Request",
           "ServeRuntime", "Session", "SessionManager", "StreamChunker",
           "TenantShedError", "TenantSpec", "TrafficStats", "chop",
           "drift_streams", "random_waveforms", "replay", "replay_adaptive"]
