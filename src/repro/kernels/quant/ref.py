"""Pure-jnp oracle for the fixed-point quantization kernel."""
from __future__ import annotations

import jax.numpy as jnp


def fixed_point_quantize(x: jnp.ndarray, int_bits: float,
                         frac_bits: float) -> jnp.ndarray:
    """Signed Q(int_bits).(frac_bits) fixed-point rounding + saturation."""
    scale = 2.0 ** frac_bits
    hi = 2.0 ** int_bits - 1.0 / scale
    lo = -(2.0 ** int_bits)
    xq = jnp.round(x.astype(jnp.float32) * scale) / scale
    return jnp.clip(xq, lo, hi).astype(x.dtype)
