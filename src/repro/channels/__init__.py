from . import common, drift, imdd, proakis
from .common import awgn, ber, ber_from_soft, bits_to_pam, pam_decision
from .drift import (DriftingIMDD, DriftingProakis, DriftSchedule)
from .imdd import IMDDConfig
from .proakis import ProakisConfig

__all__ = [
    "common", "drift", "imdd", "proakis", "awgn", "ber", "ber_from_soft",
    "bits_to_pam", "pam_decision", "DriftingIMDD", "DriftingProakis",
    "DriftSchedule", "IMDDConfig", "ProakisConfig",
]
