"""Declarative per-tenant SLOs over the metrics registry, with hysteresis.

A deployment states its service-level objectives as `SloRule`s — "tenant
snr_db must stay above 14 dB", "p99 launch latency must stay under 5 ms" —
and the `SloEngine` evaluates them against live `MetricsRegistry`
instruments, latching breach/clear EDGES with the same patience discipline
as `repro.runtime.straggler.StragglerMonitor`: a rule must breach (or
recover) for `patience` CONSECUTIVE evaluations before its state flips, so
an oscillating metric near the threshold never thrashes alerts.

Rules are declarative and tenant-generic: a metric path may contain the
literal placeholder ``{tenant}``, which is substituted (metric-name
sanitized) for every tenant registered via `watch()` — one rule covers the
whole fleet of streams. Paths without the placeholder evaluate once,
globally.

Edges are loud in three places, and bounded in all of them:

  * a tracer instant (``slo_breach`` / ``slo_clear`` / ``slo_resolved``)
    when tracing is on — breaches land in the same Chrome export as the
    chunk spans they explain;
  * the ALERT LEDGER — a bounded deque of edge records surfaced in
    ``snapshot()`` under ``slo.alerts`` (plus latch states under
    ``slo.state``), so an exported snapshot carries the alert history;
  * the `on_breach` / `on_clear` callbacks — the closed-loop seam:
    `repro.adapt.OnlineAdapter.request_adapt` hangs off `on_breach` to
    fine-tune ON DEMAND instead of on a fixed cadence, and its promotion
    path calls `resolve()` so a successful adaptation retires the alert.

Evaluation (`step()`) is read-only over the registry and runs wherever the
caller wants — typically from `LinkMonitor` after each served segment, or
from a test/bench loop. It never throws on missing metrics (a rule over a
tenant that has not emitted yet simply waits) and honours each rule's
`min_samples` guard so cold streams are not judged on noise.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

from .hub import Observability
from .metrics import (DEFAULT_WINDOW, Counter, Gauge, Histogram,
                      safe_segment)

# edge callback signature: (tenant or None, rule, observed value)
EdgeHook = Callable[[Optional[str], "SloRule", float], None]


@dataclasses.dataclass(frozen=True)
class SloRule:
    """One service-level objective.

    name:        rule identifier (metric-name-safe; keys alerts and state).
    metric:      dotted registry path to evaluate; may contain ``{tenant}``
                 (substituted, sanitized, for every watched tenant).
    threshold:   the objective boundary.
    direction:   "below" (default) breaches when value < threshold — the
                 shape for quality floors like SNR; "above" breaches when
                 value > threshold — for ceilings like EVM or latency.
    window:      the observation window (samples) the metric is expected
                 to be computed over; purely declarative for gauges (the
                 estimator owns its window) but histogram-valued metrics
                 are evaluated over their windowed mean, and the rule
                 documents that width.
    min_samples: evaluation guard — the rule is SKIPPED (streaks frozen)
                 until this many samples back the metric. Samples come
                 from the `samples` path when given, else from a
                 histogram metric's lifetime count; a gauge metric with
                 no `samples` path is assumed always warm.
    samples:     optional dotted path (``{tenant}`` allowed) of a Counter/
                 Gauge holding the metric's sample count.
    patience:    consecutive breaching (resp. clean) evaluations required
                 to latch (resp. clear) — the hysteresis width.
    """
    name: str
    metric: str
    threshold: float
    direction: str = "below"
    window: int = DEFAULT_WINDOW
    min_samples: int = 1
    samples: Optional[str] = None
    patience: int = 3

    def __post_init__(self) -> None:
        if self.direction not in ("below", "above"):
            raise ValueError(f"SloRule.direction must be 'below' or "
                             f"'above', got {self.direction!r}")
        if self.patience < 1:
            raise ValueError("SloRule.patience must be >= 1")
        if self.min_samples < 0:
            raise ValueError("SloRule.min_samples must be >= 0")
        if self.window < 1:
            raise ValueError("SloRule.window must be >= 1")

    def breaches(self, value: float) -> bool:
        return (value < self.threshold if self.direction == "below"
                else value > self.threshold)


@dataclasses.dataclass
class _Latch:
    """Per-(rule, tenant) hysteresis state — the StragglerMonitor latch."""
    breached: bool = False
    breach_streak: int = 0
    clear_streak: int = 0
    value: float = float("nan")
    evaluations: int = 0


class SloEngine:
    """Evaluates `SloRule`s against an `Observability` hub's registry.

    Construction wires the ``slo.*`` snapshot surface (breached/watched
    gauges, the alert ledger and latch states as callbacks); `watch()`
    registers tenants; `step()` evaluates. `on_breach`/`on_clear` are
    plain mutable attributes so closed loops with construction cycles
    (engine ↔ adapter) can late-bind them.
    """

    def __init__(self, obs: Observability,
                 rules: Tuple[SloRule, ...] = (),
                 on_breach: Optional[EdgeHook] = None,
                 on_clear: Optional[EdgeHook] = None,
                 ledger_max: Optional[int] = None) -> None:
        self.obs = obs
        self.rules: List[SloRule] = []
        self.on_breach = on_breach
        self.on_clear = on_clear
        self._lock = threading.Lock()
        self._tenants: List[str] = []
        self._latches: Dict[Tuple[str, Optional[str]], _Latch] = {}
        self.alerts: Deque[Dict[str, Any]] = deque(
            maxlen=ledger_max if ledger_max is not None
            else obs.retention.errors)
        self.alerts_total = 0
        scope = obs.scope("slo")
        self._g_rules = scope.gauge("rules")
        self._g_watched = scope.gauge("watched")
        self._g_breached = scope.gauge("breached")
        scope.callback("alerts", self._alerts_view)
        scope.callback("state", self._state_view)
        for r in rules:
            self.add_rule(r)

    # -- configuration -------------------------------------------------------

    def add_rule(self, rule: SloRule) -> SloRule:
        with self._lock:
            if any(r.name == rule.name for r in self.rules):
                raise ValueError(f"SLO rule {rule.name!r} already added")
            self.rules.append(rule)
            self._g_rules.set(len(self.rules))
        return rule

    def watch(self, tenant_id: str) -> None:
        """Register a tenant for ``{tenant}`` rule substitution (idempotent)."""
        with self._lock:
            if tenant_id not in self._tenants:
                self._tenants.append(tenant_id)
                self._g_watched.set(len(self._tenants))

    # -- evaluation ----------------------------------------------------------

    def step(self, tenant_id: Optional[str] = None) -> List[Dict[str, Any]]:
        """Evaluate every rule (for one tenant, or all watched tenants plus
        the global rules). Returns the edge records produced by THIS call.
        Read-only over the registry; never raises on missing metrics."""
        edges: List[Dict[str, Any]] = []
        with self._lock:
            rules = list(self.rules)
            tenants = list(self._tenants)
        for rule in rules:
            if "{tenant}" in rule.metric:
                targets = ([tenant_id] if tenant_id is not None
                           else tenants)
                targets = [t for t in targets if t in tenants]
            else:
                targets = [None] if tenant_id is None else []
            for t in targets:
                edge = self._evaluate(rule, t)
                if edge is not None:
                    edges.append(edge)
        for edge in edges:          # callbacks OUTSIDE the latch lock
            hook = (self.on_breach if edge["state"] == "breach"
                    else self.on_clear)
            if hook is not None:
                hook(edge["tenant"], edge["rule_obj"], edge["value"])
        return [
            {k: v for k, v in e.items() if k != "rule_obj"} for e in edges]

    def _paths(self, rule: SloRule, tenant: Optional[str]):
        seg = safe_segment(tenant) if tenant is not None else ""
        metric = rule.metric.replace("{tenant}", seg)
        samples = (rule.samples.replace("{tenant}", seg)
                   if rule.samples else None)
        return metric, samples

    def _read(self, path: str):
        inst = self.obs.registry.instrument(path)
        if isinstance(inst, (Counter, Gauge)):
            return float(inst.value), None
        if isinstance(inst, Histogram):
            return inst.window_mean(), inst.count
        return None, None

    def _evaluate(self, rule: SloRule,
                  tenant: Optional[str]) -> Optional[Dict[str, Any]]:
        metric, samples_path = self._paths(rule, tenant)
        value, hist_count = self._read(metric)
        if value is None or value != value:            # missing or NaN
            return None
        n = hist_count
        if samples_path is not None:
            sv, _ = self._read(samples_path)
            n = None if sv is None else int(sv)
            if n is None:                              # guard path missing:
                return None                           # not warm yet
        if n is not None and n < rule.min_samples:
            return None                               # min-samples guard
        breach_now = rule.breaches(value)
        with self._lock:
            st = self._latches.setdefault((rule.name, tenant), _Latch())
            st.value = value
            st.evaluations += 1
            edge: Optional[str] = None
            if breach_now:
                st.clear_streak = 0
                st.breach_streak += 1
                if not st.breached and st.breach_streak >= rule.patience:
                    st.breached = True
                    st.breach_streak = 0
                    edge = "breach"
            else:
                st.breach_streak = 0
                st.clear_streak += 1
                if st.breached and st.clear_streak >= rule.patience:
                    st.breached = False
                    st.clear_streak = 0
                    edge = "clear"
            if edge is None:
                return None
            record = self._record_edge_locked(rule, tenant, metric, value,
                                              edge)
        self.obs.tracer.instant(f"slo_{edge}", rule=rule.name,
                                tenant=tenant or "", metric=metric,
                                value=value, threshold=rule.threshold)
        record = dict(record)
        record["rule_obj"] = rule
        return record

    def _record_edge_locked(self, rule: SloRule, tenant: Optional[str],
                            metric: str, value: float,
                            state: str) -> Dict[str, Any]:
        record = {"rule": rule.name, "tenant": tenant, "metric": metric,
                  "value": float(value), "threshold": rule.threshold,
                  "state": state, "t": self.obs.clock()}
        self.alerts.append(record)
        self.alerts_total += 1
        self._g_breached.set(sum(1 for s in self._latches.values()
                                 if s.breached))
        return record

    # -- closed-loop resolution ----------------------------------------------

    def resolve(self, tenant_id: str, reason: str = "promoted") -> int:
        """Clear every latched breach for `tenant_id` NOW — the promotion
        path: a successful adaptation retires the alert without waiting
        for `patience` clean evaluations. Returns the number of latches
        cleared; ledger records carry state "resolved" and the reason."""
        cleared: List[Tuple[SloRule, str, float]] = []
        with self._lock:
            rules = {r.name: r for r in self.rules}
            for (rname, tenant), st in self._latches.items():
                if tenant == tenant_id and st.breached:
                    st.breached = False
                    st.breach_streak = 0
                    st.clear_streak = 0
                    rule = rules.get(rname)
                    if rule is None:
                        continue
                    metric, _ = self._paths(rule, tenant)
                    rec = self._record_edge_locked(rule, tenant, metric,
                                                   st.value, "resolved")
                    rec["reason"] = reason
                    cleared.append((rule, metric, st.value))
        for rule, metric, value in cleared:
            self.obs.tracer.instant("slo_resolved", rule=rule.name,
                                    tenant=tenant_id, metric=metric,
                                    reason=reason)
            if self.on_clear is not None:
                self.on_clear(tenant_id, rule, value)
        return len(cleared)

    # -- introspection ---------------------------------------------------------

    def breached(self, tenant_id: Optional[str] = None) -> List[str]:
        """Names of currently latched rules (optionally for one tenant)."""
        with self._lock:
            return sorted(rname for (rname, t), st in self._latches.items()
                          if st.breached
                          and (tenant_id is None or t == tenant_id))

    def breached_tenants(self) -> List[str]:
        """Tenants with at least one latched breach (fleet health input)."""
        with self._lock:
            return sorted({t for (_, t), st in self._latches.items()
                           if st.breached and t is not None})

    def _alerts_view(self) -> List[Dict[str, Any]]:
        with self._lock:
            return [dict(a) for a in self.alerts]

    def _state_view(self) -> Dict[str, Any]:
        with self._lock:
            states = {}
            for (rname, tenant), st in self._latches.items():
                key = f"{rname}[{tenant}]" if tenant is not None else rname
                states[key] = {"breached": st.breached,
                               "value": st.value,
                               "evaluations": st.evaluations}
            return {"alerts_total": self.alerts_total,
                    "alerts_dropped": self.alerts_total - len(self.alerts),
                    "latches": states}
