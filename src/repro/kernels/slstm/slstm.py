"""Pallas TPU kernel: fused sLSTM recurrence (§Perf enumerated lever).

The sLSTM scan is the dominant residual of the xlstm train cell after the
XLA-level iterations (EXPERIMENTS.md §Perf cell 1): 4096 sequential steps
of tiny (B, 4d) elementwise ops + an (nh·dh×dh) recurrent matmul, each
round-tripping the carry through HBM at arithmetic intensity ≈ 0.5
flop/byte. The xLSTM authors hit the same wall on GPU and shipped a fused
recurrent kernel; this is the TPU analogue:

  * grid = (B-tiles,); the ENTIRE time loop runs inside one kernel
    invocation with the carry (c, n, h, m) resident in VMEM scratch;
  * the input stream xg is blocked over time via a fori_loop reading
    VMEM-resident slices (the (S, 4d)-tile per batch-block is streamed by
    the BlockSpec), outputs written to the h-sequence tile;
  * per step: one (B_t, d)×(d, d) block-diag recurrent matmul on the MXU
    + the gate elementwise ops on the VPU — no HBM traffic besides the
    input/output streams.

Napkin (xlstm-125m train cell): xs stream once instead of ~6 carry
round-trips per step ⇒ sLSTM traffic (B·S·4d·(in+carry·k)) drops ~6×;
predicted cell t_mem 18.1 s → ~2.5 s. Validated for numerics against
ref.slstm in interpret mode (tests/test_slstm_kernel.py); the dry-run
accounting treats it like the other kernels (traffic-equivalent stub +
analytical flops) once wired into the model path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _slstm_kernel(xg_ref, r_ref, c0_ref, n0_ref, h0_ref, m0_ref,
                  hs_ref, c_ref, n_ref, h_ref, m_ref,
                  c_scr, n_scr, h_scr, m_scr, *, seq: int, nh: int, dh: int):
    d = nh * dh
    c_scr[...] = c0_ref[0].astype(jnp.float32)
    n_scr[...] = n0_ref[0].astype(jnp.float32)
    h_scr[...] = h0_ref[0].astype(jnp.float32)
    m_scr[...] = m0_ref[0].astype(jnp.float32)
    r = r_ref[...].astype(jnp.float32)              # (4, nh, dh, dh)
    # block-diagonal recurrence as one (d, 4d) matrix in VMEM
    rmat = jnp.zeros((d, 4 * d), jnp.float32)
    for g in range(4):
        for hidx in range(nh):
            rmat = jax.lax.dynamic_update_slice(
                rmat, r[g, hidx], (hidx * dh, g * d + hidx * dh))

    def step(t, _):
        c, n, h, m = c_scr[...], n_scr[...], h_scr[...], m_scr[...]
        x_t = xg_ref[0, pl.ds(t, 1), :][0].astype(jnp.float32)  # (4d,)
        rec = jax.lax.dot(h[None, :], rmat,
                          preferred_element_type=jnp.float32)[0]
        pre = x_t + rec                                  # (4d,)
        z = jnp.tanh(pre[0 * d:1 * d])
        i_pre = pre[1 * d:2 * d]
        f_pre = pre[2 * d:3 * d]
        o = jax.nn.sigmoid(pre[3 * d:4 * d])
        m_new = jnp.maximum(f_pre + m, i_pre)
        i_s = jnp.exp(i_pre - m_new)
        f_s = jnp.exp(f_pre + m - m_new)
        c_new = f_s * c + i_s * z
        n_new = f_s * n + i_s
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        c_scr[...] = c_new
        n_scr[...] = n_new
        h_scr[...] = h_new
        m_scr[...] = m_new
        hs_ref[0, pl.ds(t, 1), :] = h_new[None].astype(hs_ref.dtype)
        return 0

    jax.lax.fori_loop(0, seq, step, 0)
    c_ref[0] = c_scr[...]
    n_ref[0] = n_scr[...]
    h_ref[0] = h_scr[...]
    m_ref[0] = m_scr[...]


@functools.partial(jax.jit, static_argnames=("nh", "interpret"))
def slstm_fused(xg: jnp.ndarray, r: jnp.ndarray, state, nh: int,
                interpret: bool | None = None):
    """xg: (B, S, 4·d) pre-activations; r: (4, nh, dh, dh);
    state: (c, n, h, m) each (B, d) f32.  Returns (hs (B,S,d) f32, state').

    Grid over batch; the whole time recurrence lives in one kernel
    invocation per batch row with the carry in VMEM.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, s, d4 = xg.shape
    d = d4 // 4
    dh = d // nh
    c0, n0, h0, m0 = state

    kernel = functools.partial(_slstm_kernel, seq=s, nh=nh, dh=dh)
    row = lambda i: (i, 0, 0)
    vec = lambda i: (i, 0)
    hs, c, n, h, m = pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, s, d4), row),
            pl.BlockSpec((4, nh, dh, dh), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((1, d), vec),
            pl.BlockSpec((1, d), vec),
            pl.BlockSpec((1, d), vec),
            pl.BlockSpec((1, d), vec),
        ],
        out_specs=[
            pl.BlockSpec((1, s, d), row),
            pl.BlockSpec((1, d), vec),
            pl.BlockSpec((1, d), vec),
            pl.BlockSpec((1, d), vec),
            pl.BlockSpec((1, d), vec),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, d), jnp.float32),
            jax.ShapeDtypeStruct((b, d), jnp.float32),
            jax.ShapeDtypeStruct((b, d), jnp.float32),
            jax.ShapeDtypeStruct((b, d), jnp.float32),
            jax.ShapeDtypeStruct((b, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d,), jnp.float32)] * 4,
        interpret=interpret,
    )(xg, r, c0, n0, h0, m0)
    return hs, (c, n, h, m)
