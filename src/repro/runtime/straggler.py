"""Straggler detection & mitigation.

At 1000+ nodes the slowest worker sets the step time (synchronous SGD), so
the controller needs (a) detection — a robust running estimate of the step
time distribution — and (b) mitigation hooks. This module implements the
detection machinery and three mitigations, exercised in tests with injected
delays:

  * `deadline-skip`: if a step exceeds μ + k·σ (or an absolute deadline),
    flag it; after `patience` consecutive flags, fire the mitigation
    callback (production: preempt + reschedule the slow host; here: the
    callback is pluggable — the fault loop uses a controlled restart);
  * `microbatch rebalance`: shrink the accum factor for flagged workers
    (returned as a recommendation — the data pipeline consumes it);
  * bookkeeping for EXPERIMENTS.md (flag counts, step-time quantiles).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional


@dataclasses.dataclass
class StragglerConfig:
    ema_alpha: float = 0.1
    sigma_factor: float = 3.0        # flag threshold: μ + k·σ
    abs_deadline_s: Optional[float] = None
    patience: int = 3                # consecutive flags before mitigation
    warmup_steps: int = 5            # ignore compile/first-touch steps


class StragglerMonitor:
    """`degraded` is the mitigation latch: it turns on after `patience`
    CONSECUTIVE flagged steps (when `on_straggler` also fires, and — new —
    `on_recovered` fires on the way back) and decays after `patience`
    consecutive clean steps, so a transient slow phase stops costing
    anything once it has passed. `recommend_accum` keys off the latch,
    not off the cumulative flag count (which could never recover)."""

    def __init__(self, cfg: StragglerConfig = StragglerConfig(),
                 on_straggler: Optional[Callable[[int, float], None]] = None,
                 on_recovered: Optional[Callable[[int], None]] = None):
        self.cfg = cfg
        self.on_straggler = on_straggler
        self.on_recovered = on_recovered
        self.mean = 0.0
        self.var = 0.0
        self.n = 0
        self.consecutive = 0
        self.clean_streak = 0
        self.degraded = False
        self.flags: List[int] = []
        self.times: List[float] = []
        self._t0: Optional[float] = None

    # -- timing interface ---------------------------------------------------

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> bool:
        assert self._t0 is not None, "stop() without start()"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        return self.observe(step, dt)

    def observe(self, step: int, dt: float) -> bool:
        """Record a step time; returns True if the step is flagged."""
        self.times.append(dt)
        self.n += 1
        if self.n <= self.cfg.warmup_steps:
            # prime the estimate but never flag during warmup
            a = 0.5
            self.mean = (1 - a) * self.mean + a * dt if self.n > 1 else dt
            return False
        flagged = False
        sd = self.var ** 0.5
        thresh = self.mean + self.cfg.sigma_factor * max(sd, 1e-9)
        if self.cfg.abs_deadline_s is not None:
            thresh = min(thresh, self.cfg.abs_deadline_s)
        if dt > thresh:
            flagged = True
            self.flags.append(step)
            self.consecutive += 1
            self.clean_streak = 0
            if self.consecutive >= self.cfg.patience:
                if not self.degraded and self.on_straggler is not None:
                    self.on_straggler(step, dt)
                self.degraded = True
                self.consecutive = 0
        else:
            self.consecutive = 0
            self.clean_streak += 1
            if self.degraded and self.clean_streak >= self.cfg.patience:
                # transient slow phase has passed: lift the mitigation
                self.degraded = False
                if self.on_recovered is not None:
                    self.on_recovered(step)
            # update stats from non-straggler steps only (robustness)
            a = self.cfg.ema_alpha
            delta = dt - self.mean
            self.mean += a * delta
            self.var = (1 - a) * (self.var + a * delta * delta)
        return flagged

    # -- mitigation recommendations ------------------------------------------

    def recommend_accum(self, base_accum: int) -> int:
        """Shrink per-worker accumulation while persistently slow (the
        microbatch-rebalance mitigation): slow worker does less local work,
        the optimizer sees the same global batch via gradient reweighting.
        Keys off the `degraded` latch — NOT the cumulative flag count — so
        the recommendation returns to `base_accum` after `patience`
        consecutive clean steps."""
        if self.degraded:
            return max(1, base_accum // 2)
        return base_accum

    def summary(self) -> dict:
        # warmup steps carry compile/first-touch time, not steady-state
        # step time — including them would skew every quantile of a short
        # run, so they are excluded (flag bookkeeping never saw them either)
        ts = sorted(self.times[self.cfg.warmup_steps:])
        q = lambda f: ts[int(f * (len(ts) - 1))] if ts else 0.0
        return {"steps": self.n, "flagged": len(self.flags),
                "degraded": self.degraded,
                "p50_s": q(0.5), "p95_s": q(0.95), "p99_s": q(0.99),
                "mean_s": self.mean}
