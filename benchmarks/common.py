"""Shared benchmark machinery: result registry + JSON/markdown emission."""
from __future__ import annotations

import json
import pathlib
import time
from typing import Any, Callable, Dict, List

REPORT_DIR = pathlib.Path(__file__).resolve().parent.parent / "reports"


class Bench:
    """One benchmark = one paper table/figure."""

    def __init__(self, name: str, paper_ref: str):
        self.name = name
        self.paper_ref = paper_ref
        self.results: Dict[str, Any] = {}
        self.t0 = time.time()

    def record(self, key: str, value: Any) -> None:
        self.results[key] = value

    def finish(self) -> Dict[str, Any]:
        out = {
            "bench": self.name,
            "paper_ref": self.paper_ref,
            "elapsed_s": round(time.time() - self.t0, 1),
            "results": self.results,
        }
        d = REPORT_DIR / "benchmarks"
        d.mkdir(parents=True, exist_ok=True)
        (d / f"{self.name}.json").write_text(json.dumps(out, indent=2,
                                                        default=str))
        return out


def fmt_ber(b: float) -> str:
    return f"{b:.2e}"
