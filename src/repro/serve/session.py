"""Tenant sessions — channel config + trained params + QAT formats → engine.

A TENANT is one equalized link (an optical channel, a magnetic-recording
head, …) with its own trained parameters and learned fixed-point formats.
A SESSION is a tenant's live streaming state: the overlap-save chunker
carry, output accumulator, and latency counters. Engines themselves live in
the LRU `EnginePool` (pool.py) and are rebuilt on demand after eviction —
sessions never pin one.

Serve-aware autotune hook: `Session` accepts a `tile_tuner` callback
(provided by the runtime, see `runtime._serve_tile`). For a spec with
tile_m="auto" it may return a tile width tuned against LIVE traffic
histograms instead of the engine's single-stream autotune default. The
chosen tile is frozen into the session's spec copy at open time, so engine
rebuilds after LRU eviction reproduce it deterministically and the chunker's
tile-alignment (bitwise-vs-offline) invariant holds for the stream's whole
lifetime.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from ..core.engine import EqualizerEngine
from ..core.equalizer import (CNNEqConfig, fold_bn, folded_weights,
                              init_bn_state)
from .chunker import StreamChunker
from .pool import EnginePool

# a tile_tuner maps a freshly built engine to a tile width (or None to keep
# the engine's own single-stream autotune choice)
TileTuner = Callable[[EqualizerEngine], Optional[int]]


class TapChain:
    """Fan-out for the `Session.tap` seam: several consumers (adaptation
    collector, link-quality monitor, tests) observe the SAME descatter
    callback, in registration order. A plain callable, so every existing
    `session.tap(...)` call site works unchanged; exceptions propagate
    (a broken tap must be loud, exactly like a broken single tap)."""

    __slots__ = ("taps",)

    def __init__(self, taps: Optional[List[Callable]] = None) -> None:
        self.taps: List[Callable] = list(taps or [])

    def __call__(self, rx: np.ndarray, soft_syms: np.ndarray) -> None:
        for fn in self.taps:
            fn(rx, soft_syms)

    def __len__(self) -> int:
        return len(self.taps)


@dataclasses.dataclass
class TenantSpec:
    """Everything needed to (re)build a tenant's engine deterministically.

    tenant_id: unique key (string) — engine-pool identity; opening the same
               id twice on one runtime raises ValueError.
    cfg:       the CNN topology (`CNNEqConfig`).
    params:    trained (unfolded) parameters; BN is folded and QAT formats
               are picked up automatically at engine build
               (`EqualizerEngine.from_params`). Exactly one of
               params/weights must be given, else build_engine raises
               ValueError.
    bn_state:  running BN statistics to fold (default None → init stats).
    weights:   pre-folded fp32 weights (alternative to params).
    formats:   per-layer (w_int, w_frac, a_int, a_frac) fixed-point
               formats — required for backend="fused_int8" with explicit
               weights. When given TOGETHER with params they PIN the
               deployment formats: BN is folded but the formats are taken
               as-is instead of being re-derived from the params' QAT
               subtree. This is the weight hot-swap form
               (`repro.serve.runtime` `swap_weights`): new weights, frozen
               static kernel config, so the group key cannot move.
    backend:   "auto" (default; deploys the QAT ladder int8→bf16→fp32),
               or an explicit backend name. Explicit "fused_int8" raises at
               build if the formats don't fit int8 or the BN-folded weights
               overflow the learned grid (see docs/QUANTIZATION.md).
    tile_m:    kernel sequence-tile width. "auto" (default) → autotune
               sweep, possibly serve-aware (live-traffic histograms) when
               opened through a runtime with warm stats; an explicit int is
               NEVER re-tuned. Fixed for the life of the stream.
    per_channel: refine learned per-layer weight formats to per-output-
               channel scales at deployment (`repro.core.qat`
               `per_channel_formats`; params path only). Deterministic
               given the params, so rebuilds after eviction agree.
    weight_epoch: monotone counter of weight hot-swaps (0 = the weights
               the stream opened with). Bumped by `swap_weights`/
               `rollback_weights`; NOT part of the engine's group key —
               epochs ride in the per-row stacked weight operands, so
               tenants on different epochs still share launches.
    priority:  load-shedding rank (int; default 0, higher = more
               important). Under persistent launch slowness the
               degradation controller (`repro.serve.recovery`) sheds the
               LOWEST-priority tenants first (ties broken by tenant_id).
               Not part of the engine identity — purely a serving-policy
               attribute.
    """
    tenant_id: str
    cfg: CNNEqConfig
    params: Optional[Dict[str, Any]] = None
    bn_state: Optional[Dict[str, Any]] = None
    weights: Optional[tuple] = None
    formats: Optional[tuple] = None
    backend: str = "auto"
    tile_m: int | str = "auto"
    per_channel: bool = False
    weight_epoch: int = 0
    priority: int = 0

    def build_engine(self) -> EqualizerEngine:
        if (self.params is None) == (self.weights is None):
            raise ValueError(
                f"tenant {self.tenant_id!r}: exactly one of params/weights")
        if self.params is not None:
            if self.formats is not None:
                # pinned-formats deployment (hot-swap spec): fold BN, keep
                # the frozen static kernel config exactly as served
                folded = fold_bn(self.params,
                                 self.bn_state or init_bn_state(self.cfg),
                                 self.cfg)
                return EqualizerEngine(cfg=self.cfg,
                                       weights=folded_weights(folded),
                                       backend=self.backend,
                                       tile_m=self.tile_m,
                                       formats=self.formats)
            return EqualizerEngine.from_params(
                self.params, self.bn_state, self.cfg,
                backend=self.backend, tile_m=self.tile_m,
                per_channel=self.per_channel)
        return EqualizerEngine(cfg=self.cfg, weights=self.weights,
                               backend=self.backend, tile_m=self.tile_m,
                               formats=self.formats)


class Session:
    """One tenant's live stream state (engine NOT held — see pool).

    `failed` is None on the happy path; the async runtime sets it to the
    terminal exception when a launch for this stream exhausted its retries,
    after which `output()` raises instead of returning a stream with a
    silent hole (a lost chunk would otherwise just shorten the output).

    Online-adaptation hooks (`repro.adapt`):

    `tap` — optional callback `(rx_segment, soft_symbols) → None` invoked
    by the micro-batcher's descatter for every emitted chunk, with the REAL
    input samples behind the emitted positions and the symbols they
    produced, both in stream order. This is how the sample collector sees
    served traffic without a second pass over the stream. Must be cheap
    (it runs on the descatter path, under the async runtime's lock) and
    must copy what it keeps (the rx view aliases the launch input buffer).

    `swap_log` — [(weight_epoch, first_position)] history: positions ≥
    first_position were equalized with that epoch's weights. Epoch 0 is the
    weights the stream opened with. `install_spec` appends on every
    successful hot-swap/rollback; `prev_spec` holds the previous spec so a
    bad promotion can be rolled back bit-identically (specs rebuild their
    engines deterministically). The log stays a plain list (callers slice
    it) but is BOUNDED: `swap_log_max` (from `repro.obs.Retention.swap_log`
    when opened through a runtime) trims the oldest entries, so a
    long-running adaptive stream holds steady memory.
    """

    SWAP_LOG_MAX = 256                 # default bound (Retention.swap_log)

    def __init__(self, spec: TenantSpec, pool: EnginePool,
                 tile_tuner: Optional[TileTuner] = None,
                 swap_log_max: Optional[int] = None):
        self._pool = pool
        # a NEW stream must never inherit a pool entry built (or tile-
        # mutated) for an earlier session under the same tenant_id — the
        # chunker below must be sized off an engine that this session's
        # spec rebuilds identically after LRU eviction
        pool.drop(spec.tenant_id)
        engine = pool.get(spec.tenant_id, spec.build_engine)
        if tile_tuner is not None and spec.tile_m == "auto":
            tuned = tile_tuner(engine)
            if tuned is not None:
                # freeze the serve-aware tile into the session's spec copy:
                # rebuilds after LRU eviction must reproduce it, and the
                # caller's spec object stays untouched
                spec = dataclasses.replace(spec, tile_m=int(tuned))
                engine.tile_m = int(tuned)
        self.spec = spec
        self.chunker = StreamChunker(            # sized off the built engine
            halo=engine.halo_samples,
            total_stride=engine.total_stride,
            tile_m=engine.resolved_tile_m())
        self.v_parallel = engine.cfg.v_parallel
        self._out: List[np.ndarray] = []
        self.syms_emitted = 0
        self.failed: Optional[BaseException] = None
        # requests taken for launch but not yet descattered/failed —
        # maintained (under its lock) by AsyncServeRuntime so close() can
        # wait for a tenant's in-flight work; always 0 on the sync path
        self.inflight = 0
        # fault-tolerance bookkeeping (serve/recovery.py, async runtime):
        # `recoveries` counts failover rounds this stream has consumed
        # (bounded by RecoveryPolicy.max_session_recoveries before the
        # stream is poisoned the old way); `shed` marks the tenant as
        # load-shed by the degradation controller — submits raise
        # TenantShedError until health returns; `rolled_back` latches
        # after a corrupt-output rollback so a session never ping-pongs
        # between spec and prev_spec
        self.recoveries = 0
        self.shed = False
        self.rolled_back = False
        # online-adaptation hooks (see class docstring)
        self.tap: Optional[Callable[[np.ndarray, np.ndarray], None]] = None
        # cross-wire trace context: (trace_id, t_client, t_ingress) tuples
        # pushed by the net ingress when a DATA frame carried the v2 trace
        # extension, drained into the next chunk span at enqueue. Bounded:
        # with tracing off nothing drains, so a rude flood must not grow
        # host memory (oldest context drops — ids are best-effort hints)
        self.trace_ctx: Deque[Tuple[int, float, float]] = deque(maxlen=256)
        self.prev_spec: Optional[TenantSpec] = None
        self.swap_log: List[tuple] = [(spec.weight_epoch, 0)]
        self.swap_log_max = (self.SWAP_LOG_MAX if swap_log_max is None
                             else max(1, int(swap_log_max)))

    @property
    def engine(self) -> EqualizerEngine:
        """Fetch (or rebuild after LRU eviction) this tenant's engine."""
        return self._pool.get(self.spec.tenant_id, self.spec.build_engine)

    def rebuild_on(self, pool: EnginePool) -> "Session":
        """Fleet migration primitive: reincarnate this mid-stream session
        against ANOTHER worker's engine pool (`repro.serve.fleet`).

        The replacement builds a fresh engine from the (frozen) spec —
        deterministic, so it serves bitwise-identically — then reinstalls
        the complete stream state: the chunker carry via
        `snapshot()`/`restore()` (deep copies; the dead session is not
        aliased) plus the output accumulator, recovery/adaptation
        bookkeeping, and in-flight accounting. No `tile_tuner` is passed:
        the spec's tile is already frozen (or "auto" resolves through the
        deterministic autotune cache), and a re-tune mid-stream would
        change the chunker geometry and void the bitwise contract. A
        geometry mismatch between old and new engines means the spec does
        NOT rebuild deterministically — that is corruption, so it raises
        instead of silently emitting misaligned symbols."""
        s = Session(self.spec, pool, swap_log_max=self.swap_log_max)
        old_c, new_c = self.chunker, s.chunker
        if ((new_c.halo, new_c.ts, new_c.tile_m)
                != (old_c.halo, old_c.ts, old_c.tile_m)):
            raise RuntimeError(
                f"tenant {self.spec.tenant_id!r}: rebuilt engine changed "
                f"chunker geometry "
                f"{(old_c.halo, old_c.ts, old_c.tile_m)} -> "
                f"{(new_c.halo, new_c.ts, new_c.tile_m)}; spec is not "
                f"deterministic, refusing to migrate")
        new_c.restore(old_c.snapshot())
        s._out = list(self._out)
        s.syms_emitted = self.syms_emitted
        s.failed = self.failed
        s.inflight = self.inflight
        s.recoveries = self.recoveries
        s.shed = self.shed
        s.rolled_back = self.rolled_back
        s.tap = self.tap
        s.trace_ctx = deque(self.trace_ctx, maxlen=self.trace_ctx.maxlen)
        s.prev_spec = self.prev_spec
        s.swap_log = list(self.swap_log)
        return s

    def add_tap(self, fn: Callable[[np.ndarray, np.ndarray], None]) -> None:
        """Register an additional descatter tap, composing with whatever is
        already installed (the adaptation collector claims the slot first
        when both are wired; taps run in registration order)."""
        if self.tap is None:
            self.tap = fn
        elif isinstance(self.tap, TapChain):
            self.tap.taps.append(fn)
        else:
            self.tap = TapChain([self.tap, fn])

    @property
    def weight_epoch(self) -> int:
        return self.spec.weight_epoch

    def install_spec(self, new_spec: TenantSpec,
                     prebuilt: Optional[EqualizerEngine] = None) -> int:
        """Install a hot-swap spec as the stream's active identity.

        The CALLER must have landed all of this session's planned work
        first (sync: `flush_session`; async: take_session + in-flight
        wait) — the swap boundary is `chunker.emitted_positions` at install
        time, and positions planned-but-not-landed would otherwise execute
        with the wrong epoch's weights.

        The candidate engine (built here, or passed as `prebuilt` when the
        caller already constructed it OUTSIDE its locks — engine builds
        fold BN and quantize weights, hundreds of ms on interpret-mode
        hosts) must share the active engine's `group_key()` — same
        topology, backend, static kernel config (formats), and tile. A
        weight swap that would change any of those is NOT a weight swap
        (it would re-tile the chunker or move the stream between batch
        groups mid-flight) and raises ValueError, leaving the active
        weights untouched. On success the previous spec is kept in
        `prev_spec` for bit-identical rollback, the engine pool entry is
        replaced, and the (epoch, first_position) pair is appended to
        `swap_log`. Returns the new weight epoch.
        """
        candidate = prebuilt if prebuilt is not None \
            else new_spec.build_engine()
        active_key = self.engine.group_key()
        if candidate.group_key() != active_key:
            raise ValueError(
                f"tenant {new_spec.tenant_id!r}: hot-swap would change the "
                f"serving identity {active_key} -> {candidate.group_key()} "
                f"(backend/formats/tile must stay fixed mid-stream)")
        self.prev_spec = self.spec
        self.spec = new_spec
        self._pool.drop(new_spec.tenant_id)
        self._pool.get(new_spec.tenant_id, lambda: candidate)
        self.swap_log.append((new_spec.weight_epoch,
                              self.chunker.emitted_positions))
        if len(self.swap_log) > self.swap_log_max:   # retention bound —
            del self.swap_log[:len(self.swap_log)    # oldest epochs out,
                              - self.swap_log_max]   # list semantics kept
        return new_spec.weight_epoch

    def append_output(self, syms: np.ndarray) -> None:
        self._out.append(syms)
        self.syms_emitted += int(syms.shape[0])

    def output(self) -> np.ndarray:
        """All symbols emitted so far, in stream order. Raises the stream's
        terminal launch error (if any) rather than returning a stream with
        missing chunks."""
        if self.failed is not None:
            raise RuntimeError(
                f"stream {self.spec.tenant_id!r} lost a chunk to a failed "
                f"launch") from self.failed
        if not self._out:
            return np.zeros((0,), np.float32)
        return np.concatenate(self._out)


class SessionManager:
    """tenant_id → Session registry over a shared LRU engine pool."""

    def __init__(self, pool: Optional[EnginePool] = None,
                 max_engines: int = 32,
                 swap_log_max: Optional[int] = None):
        self.pool = pool if pool is not None else EnginePool(max_engines)
        self.swap_log_max = swap_log_max
        self._sessions: Dict[str, Session] = {}

    def open(self, spec: TenantSpec,
             tile_tuner: Optional[TileTuner] = None) -> Session:
        if spec.tenant_id in self._sessions:
            raise ValueError(f"tenant {spec.tenant_id!r} already open")
        s = Session(spec, self.pool, tile_tuner=tile_tuner,
                    swap_log_max=self.swap_log_max)
        self._sessions[spec.tenant_id] = s
        return s

    def get(self, tenant_id: str) -> Session:
        return self._sessions[tenant_id]

    def close(self, tenant_id: str) -> Session:
        s = self._sessions.pop(tenant_id)
        self.pool.drop(tenant_id)
        return s

    def __contains__(self, tenant_id: str) -> bool:
        return tenant_id in self._sessions

    def __len__(self) -> int:
        return len(self._sessions)

    @property
    def sessions(self) -> Dict[str, Session]:
        return dict(self._sessions)
