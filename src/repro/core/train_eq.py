"""Supervised equalizer training (MSE + Adam, paper §3.4) with optional
3-phase quantization-aware training (paper §4).

Works for all three equalizer families (CNN / FIR / Volterra) through a small
adapter. Data comes from a channel simulator `channel_fn(key, n_syms)`.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..channels.common import ber_from_soft, bits_to_pam
from ..optim import AdamW
from . import equalizer as cnn_eq
from . import fir as fir_eq
from . import qat as qat_lib
from . import volterra as vol_eq


@dataclasses.dataclass(frozen=True)
class EqTrainConfig:
    steps: int = 1500
    batch: int = 8
    seq_syms: int = 512          # symbols per training sequence
    lr: float = 3e-3             # paper: 1e-3 × 10k iters; we use fewer steps
    eval_syms: int = 1 << 15
    # QAT phases (fractions of `steps`); active only when qat_cfg given
    qat_phase1: float = 0.2      # full precision
    qat_phase2: float = 0.6      # bit-width-aware
    qat_lr_bits: float = 0.05    # lr for the width parameters


def _build(kind: str, model_cfg) -> Tuple[Callable, Callable]:
    if kind == "cnn":
        def init_fn(key, qat_cfg=None):
            return cnn_eq.init(key, model_cfg, qat_cfg), cnn_eq.init_bn_state(model_cfg)

        def apply_fn(params, x, *, train, state, quant):
            return cnn_eq.apply(params, x, model_cfg, train=train,
                                bn_state=state, qat_enabled=quant)
        return init_fn, apply_fn
    if kind == "fir":
        return (lambda key, qat_cfg=None: (fir_eq.init(key, model_cfg), None),
                lambda p, x, *, train, state, quant:
                    (fir_eq.apply(p, x, model_cfg), state))
    if kind == "volterra":
        return (lambda key, qat_cfg=None: (vol_eq.init(key, model_cfg), None),
                lambda p, x, *, train, state, quant:
                    (vol_eq.apply(p, x, model_cfg), state))
    raise ValueError(f"unknown equalizer kind {kind!r}")


def train_equalizer(key: jax.Array, kind: str, model_cfg,
                    channel_fn: Callable, cfg: EqTrainConfig,
                    qat_cfg: Optional[qat_lib.QATConfig] = None,
                    record_every: int = 0):
    """Returns (params, bn_state, info dict with 'ber', optional 'history')."""
    init_fn, apply_fn = _build(kind, model_cfg)
    kinit, kdata, keval = jax.random.split(key, 3)
    params, bn_state = init_fn(kinit, qat_cfg)
    levels = model_cfg.levels

    opt = AdamW(lr=cfg.lr)
    opt_state = opt.init(params)

    p1_end = int(cfg.steps * cfg.qat_phase1) if qat_cfg else cfg.steps + 1
    p2_end = int(cfg.steps * (cfg.qat_phase1 + cfg.qat_phase2)) \
        if qat_cfg else cfg.steps + 1

    def loss_fn(params, batch_x, batch_amps, state, quant: bool):
        y, new_state = apply_fn(params, batch_x, train=True, state=state,
                                quant=quant)
        loss = jnp.mean((y - batch_amps) ** 2)
        if quant and qat_cfg is not None and "qat" in params:
            loss = loss + qat_lib.quant_loss_term(params["qat"], qat_cfg)
        return loss, new_state

    @functools.partial(jax.jit, static_argnames=("quant", "train_bits"))
    def step_fn(params, opt_state, state, key, quant: bool, train_bits: bool):
        ks = jax.random.split(key, cfg.batch)
        xs, syms = jax.vmap(lambda k: channel_fn(k, cfg.seq_syms))(ks)
        amps = bits_to_pam(syms, levels)
        (loss, new_state), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, xs, amps, state, quant)
        if "qat" in params:
            # widths never go through Adam: phase 2 uses dedicated sign-SGD
            # at qat_lr_bits (the paper's near-linear width descent, Fig. 5,
            # saturating where the MSE gradient pushes back); phases 1/3
            # hold them exactly.
            qat_grads = grads["qat"]
            grads = dict(grads)
            grads["qat"] = jax.tree.map(jnp.zeros_like, grads["qat"])
        new_params, new_opt = opt.update(grads, opt_state, params)
        if "qat" in new_params and qat_cfg is not None:
            new_params = dict(new_params)
            if train_bits:
                stepped = jax.tree.map(
                    lambda b, g: b - cfg.qat_lr_bits * jnp.sign(g),
                    params["qat"], qat_grads)
                new_params["qat"] = qat_lib.clip_qparams(stepped, qat_cfg)
            else:
                new_params["qat"] = params["qat"]
        return new_params, new_opt, new_state, loss

    history = []
    for step in range(cfg.steps):
        kdata, kstep = jax.random.split(kdata)
        quant = qat_cfg is not None and step >= p1_end
        train_bits = qat_cfg is not None and p1_end <= step < p2_end
        if qat_cfg is not None and step == p2_end and "qat" in params:
            params = dict(params)
            params["qat"] = qat_lib.freeze_qparams(params["qat"])
        params, opt_state, bn_state, loss = step_fn(
            params, opt_state, bn_state, kstep, quant, train_bits)
        if record_every and step % record_every == 0:
            rec = {"step": step, "loss": float(loss)}
            if "qat" in params:
                bp, ba = qat_lib.average_bits(params["qat"])
                rec["bits_params"] = float(bp)
                rec["bits_acts"] = float(ba)
            history.append(rec)

    # ---- evaluation --------------------------------------------------------
    quant = qat_cfg is not None
    rx, syms = channel_fn(keval, cfg.eval_syms)
    y, _ = apply_fn(params, rx, train=False, state=bn_state, quant=quant)
    b = float(ber_from_soft(y, syms, levels))
    info: Dict[str, Any] = {"ber": b, "history": history}
    if "qat" in params:
        bp, ba = qat_lib.average_bits(params["qat"])
        info["bits_params"], info["bits_acts"] = float(bp), float(ba)
    return params, bn_state, info


def fine_tune_equalizer(key: jax.Array, params: Dict[str, Any],
                        bn_state: Optional[Dict[str, Any]], model_cfg,
                        sample_fn: Callable, *, steps: int = 60,
                        lr: float = 1e-3, kind: str = "cnn"):
    """Resume the QAT loop from deployed params — WEIGHT-ONLY fine-tuning.

    This is the in-the-field retraining step (Ney & Wehn's trainable-FPGA
    deployment story, driven here by `repro.adapt`): the channel drifted,
    the learned fixed-point FORMATS must not move (they are baked into the
    deployed int8/bf16 kernel and into the serving group key — changing
    them would change the backend mid-flight), so only the weights train.
    Equivalent to phase 3 of `train_equalizer`'s schedule (quantized
    forward at the frozen widths, widths held exactly), except the data
    comes from SERVED traffic instead of a channel simulator:

    sample_fn(key) → (xs (batch, S·N_os), amps (batch, S)) — waveform
    windows and their target PAM amplitudes, typically sampled from an
    `repro.adapt.collector.SampleCollector` buffer (decision-directed or
    pilot-labelled).

    Fake-quantization is enabled iff the params carry a "qat" subtree, so
    the fine-tune optimizes the same quantized forward the deployed kernel
    computes. Returns (params, bn_state, info) — the caller decides whether
    the candidate is promoted (`repro.adapt.shadow`).
    """
    quant = "qat" in params
    opt, step_fn = _fine_tune_step(kind, model_cfg, quant, lr)
    opt_state = opt.init(params)
    first = last = float("nan")
    for step in range(steps):
        key, kstep = jax.random.split(key)
        xs, amps = sample_fn(kstep)
        params, opt_state, bn_state, loss = step_fn(
            params, opt_state, bn_state, jnp.asarray(xs), jnp.asarray(amps))
        last = float(loss)
        if step == 0:
            first = last
    return params, bn_state, {"steps": steps, "loss_first": first,
                              "loss_last": last}


@functools.lru_cache(maxsize=8)
def _fine_tune_step(kind: str, model_cfg, quant: bool, lr: float):
    """Memoized (optimizer, jitted step) for `fine_tune_equalizer`.

    Background adaptation calls fine_tune_equalizer once per cycle; a
    fresh jit closure per call would retrace/recompile every cycle (the
    jit cache is keyed on function identity). The cache key is the full
    static configuration of the step; model_cfg is a frozen dataclass.
    """
    _, apply_fn = _build(kind, model_cfg)
    opt = AdamW(lr=lr)

    def loss_fn(p, batch_x, batch_amps, state):
        y, new_state = apply_fn(p, batch_x, train=True, state=state,
                                quant=quant)
        return jnp.mean((y - batch_amps) ** 2), new_state

    @jax.jit
    def step_fn(p, opt_state, state, batch_x, batch_amps):
        (loss, new_state), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(p, batch_x, batch_amps, state)
        if "qat" in p:
            grads = dict(grads)
            grads["qat"] = jax.tree.map(jnp.zeros_like, grads["qat"])
        new_p, new_opt = opt.update(grads, opt_state, p)
        if "qat" in new_p:
            new_p = dict(new_p)
            new_p["qat"] = p["qat"]          # widths FROZEN, bit-identical
        return new_p, new_opt, new_state, loss

    return opt, step_fn
