"""Fused sLSTM recurrence kernel vs the pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.slstm import slstm_fused, slstm_ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("b,s,nh,dh", [
    (2, 40, 4, 16),
    (1, 65, 2, 8),        # odd sequence length
    (3, 17, 1, 32),       # single head
])
def test_slstm_fused_vs_ref(b, s, nh, dh):
    d = nh * dh
    xg4 = 0.5 * jax.random.normal(KEY, (b, s, 4, d))
    r = 0.3 * jax.random.normal(jax.random.fold_in(KEY, 1), (4, nh, dh, dh))
    state = tuple(jnp.zeros((b, d)) for _ in range(3)) \
        + (jnp.full((b, d), -1e30),)
    want, st_want = slstm_ref(xg4, r, state)
    got, st_got = slstm_fused(xg4.reshape(b, s, 4 * d), r, state, nh=nh,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)
    for a, w in zip(st_got, st_want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(w), atol=1e-4)


def test_slstm_state_carry_composes():
    """Running [0:s1] then [s1:s] equals one pass — the streaming contract
    (the paper's bounded-state stream split, §5.3, for the recurrent cell)."""
    b, s, nh, dh = 2, 48, 4, 8
    d = nh * dh
    xg = 0.4 * jax.random.normal(KEY, (b, s, 4 * d))
    r = 0.3 * jax.random.normal(jax.random.fold_in(KEY, 2), (4, nh, dh, dh))
    state = tuple(jnp.zeros((b, d)) for _ in range(3)) \
        + (jnp.full((b, d), -1e30),)
    full, _ = slstm_fused(xg, r, state, nh=nh, interpret=True)
    h1, st = slstm_fused(xg[:, :20], r, state, nh=nh, interpret=True)
    h2, _ = slstm_fused(xg[:, 20:], r, st, nh=nh, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], 1)),
                               np.asarray(full), atol=1e-4)
