"""Link-quality estimation + SLO closed loop — the signal-health gate.

The system metrics see launches and latencies; `repro.obs.link` watches
the SIGNAL. This bench runs the whole quality-degradation story on the
serving runtime and records, in `BENCH_link.json` at the repo root, one
HARD host-independent criterion (`criteria.link_ok`) with three parts:

  * tracking — a TRACK tenant serves through an AWGN-only channel
    (identity taps, noise-dominated operating point — see
    `_track_channel`) whose SNR ramps down 4 dB: the decision-directed
    `LinkMonitor` SNR estimate must follow the true channel ramp
    (Pearson correlation ≥ `CORR_FLOOR` over the burst trajectory, and
    the estimate must fall by ≥ `DROP_FLOOR_DB`). This is the "estimator
    sees the channel, not the host" check.
  * closed loop — an ADAPT tenant serves through the tap-rotation drift
    (SNR held constant, so recovery is possible): an `SloEngine` rule on
    `link.{tenant}.snr_db` must LATCH a breach during the degradation,
    the breach edge must trigger `OnlineAdapter.request_adapt` (the
    fine-tune cadence is set effectively infinite — adaptation here is
    PURELY event-driven), the promotion must call back into
    `SloEngine.resolve`, and the alert must stay clear to the end of the
    run (the recovered estimate sits back above the threshold).
  * bitwise — serving with link estimation AND tracing AND the SLO
    engine all ON must equal offline equalization bit-for-bit on every
    fused backend (fp32 / bf16 / int8) — contract #11 extended:
    observation of the signal plane never changes the signal.

All three parts are deterministic under the fixed seeds — `--check`
fails hard if any breaks. No throughput rates are tracked (estimation
is host-side numpy; its cost is covered by bench_obs's tracing-tax
ratio).
"""
from __future__ import annotations

import json
import pathlib
from typing import Optional

import jax
import numpy as np

from repro.adapt import (AdaptPolicy, FineTuneConfig, OnlineAdapter,
                         PromotionPolicy)
from repro.channels.drift import DriftingProakis, DriftSchedule
from repro.channels.proakis import ProakisConfig
from repro.core import equalizer as eq
from repro.core.train_eq import EqTrainConfig, train_equalizer
from repro.obs import LinkMonitor, Observability, SloEngine, SloRule
from repro.serve import BatchPolicy, ServeRuntime, TenantSpec, chop

from .common import Bench

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT_PATH = REPO_ROOT / "BENCH_link.json"

CFG = eq.CNNEqConfig()
TILE_M = 16
SYMS_PER_BURST = 2048
SCHEDULE = DriftSchedule(hold_bursts=4, ramp_bursts=6)
N_BURSTS = 20
FT = FineTuneConfig(steps=200, batch=8, seq_syms=256, lr=3e-3)

CORR_FLOOR = 0.8           # est-vs-true SNR Pearson corr over the bursts
DROP_FLOOR_DB = 2.0        # the 4 dB true ramp must show as >= this
SLO_MARGIN_DB = 2.0        # breach threshold below the pre-drift estimate

# bitwise-parity workload (mirrors bench_obs)
INT8_FMT = tuple((2, 5, 3, 4) for _ in range(CFG.layers))
PAR_SYMS = 480
PAR_CHUNK = 120


def _adapt_policy() -> AdaptPolicy:
    # adapt_every_syms effectively infinite: fine-tuning fires ONLY via
    # request_adapt (the SLO breach hook) — the event-driven claim
    return AdaptPolicy(
        min_train_syms=3072, adapt_every_syms=1 << 30, eval_capacity=8192,
        promotion=PromotionPolicy(min_eval_syms=1024, eval_bucket_syms=512))


def _track_channel() -> DriftingProakis:
    """AWGN-only Proakis (identity taps) at a noise-dominated operating
    point: the equalizer's residual is mostly channel noise, so the true
    SNR ramp must show through in the decision-directed estimate. (On the
    full Proakis-B ISI channel the CNN's residual is ISI-dominated and a
    4 dB noise ramp moves the output SNR by well under 1 dB — a tracking
    gate there would test the equalizer, not the estimator.)"""
    return DriftingProakis(cfg=ProakisConfig(snr_db=14.0),
                           taps_from=(1.0, 0.0, 0.0),
                           taps_to=(1.0, 0.0, 0.0),
                           snr_delta_db=-4.0)


def _drift_phase(track_pb, adapt_pb):
    """The two-tenant drift scenario on one observed runtime."""
    ch_snr = _track_channel()                        # SNR ramp only
    ch_rot = DriftingProakis(snr_delta_db=0.0)       # tap rotation only

    obs = Observability(tracing=True)
    slo = SloEngine(obs)
    link = LinkMonitor(obs, slo=slo)
    rt = ServeRuntime(BatchPolicy(max_batch=2, max_wait_s=1e9),
                      obs=obs, link=link)
    adapter = OnlineAdapter(rt, _adapt_policy(), FT)

    # breach edge → event-driven fine-tune; promotion → alert retired
    def on_breach(tenant, rule, value):
        if tenant in adapter.tenants:
            adapter.request_adapt(tenant)

    slo.on_breach = on_breach
    adapter.on_promoted = lambda tid: slo.resolve(tid)

    rt.open(TenantSpec("track", CFG, params=track_pb[0],
                       bn_state=track_pb[1],
                       backend="fused_fp32", tile_m=TILE_M))
    adapter.attach(TenantSpec("adapt", CFG, params=adapt_pb[0],
                              bn_state=adapt_pb[1],
                              backend="fused_fp32", tile_m=TILE_M))

    key = jax.random.PRNGKey(3)
    est_track, est_adapt, true_snr = [], [], []
    for b in range(N_BURSTS):
        t = SCHEDULE.t_at(b)
        for i, (tid, ch) in enumerate((("track", ch_snr),
                                       ("adapt", ch_rot))):
            rx, syms = ch.at(t)(jax.random.fold_in(key, 2 * b + i),
                                SYMS_PER_BURST)
            if tid == "adapt":
                adapter.feed_pilots(tid, np.asarray(syms))
            rt.submit(tid, np.asarray(rx))
        rt.drain()
        est_track.append(link.estimate("track").snr_db)
        est_adapt.append(link.estimate("adapt").snr_db)
        true_snr.append(ch_snr.snr_at(t))
        if b == SCHEDULE.hold_bursts - 1:
            # threshold pinned to the MEASURED pre-drift estimate, so the
            # rule is host-independent and survives retraining drift
            thresh = min(est_track[-1], est_adapt[-1]) - SLO_MARGIN_DB
            slo.add_rule(SloRule(
                "snr_floor", "link.{tenant}.snr_db", threshold=thresh,
                direction="below", min_samples=SYMS_PER_BURST,
                samples="link.{tenant}.syms", patience=2))
        if slo.breached("adapt"):
            adapter.request_adapt("adapt")   # keep asking until promoted
        adapter.step("adapt")
    rt.close("track")
    rt.close("adapt")
    return {
        "true_snr_db": true_snr, "est_track_db": est_track,
        "est_adapt_db": est_adapt,
        "threshold_db": next((r.threshold for r in slo.rules), None),
        "alerts": [dict(a) for a in slo.alerts],
        "actions": [r.action for r in adapter.history
                    if r.action != "idle"],
        "breached_final": slo.breached("adapt"),
        "promotions": sum(r.action == "promoted" for r in adapter.history),
    }


def _weights(seed: int):
    params = eq.init(jax.random.PRNGKey(seed), CFG)
    folded = eq.fold_bn(params, eq.init_bn_state(CFG), CFG)
    return eq.folded_weights(folded)


def _parity_phase() -> dict:
    """Serve all three fused backends with link + SLO + tracing ON and
    demand bitwise equality with offline (contract #11 extended)."""
    import jax.numpy as jnp

    specs = []
    for i, backend in enumerate(("fused_fp32", "fused_bf16", "fused_int8")):
        specs.append(TenantSpec(
            f"p{i}", CFG, weights=_weights(600 + i),
            formats=INT8_FMT if backend == "fused_int8" else None,
            backend=backend, tile_m=32))
    rng = np.random.default_rng(11)
    waves = {s.tenant_id: rng.standard_normal(
        (PAR_SYMS + 16 * i) * CFG.n_os).astype(np.float32)
        for i, s in enumerate(specs)}
    offline = {s.tenant_id: np.asarray(
        s.build_engine()(jnp.asarray(waves[s.tenant_id][None])))[0]
        for s in specs}

    obs = Observability(tracing=True)
    slo = SloEngine(obs, rules=(SloRule(
        "snr_floor", "link.{tenant}.snr_db", threshold=5.0),))
    link = LinkMonitor(obs, slo=slo)
    rt = ServeRuntime(BatchPolicy(max_batch=3, max_wait_s=1e9),
                      obs=obs, link=link)
    for s in specs:
        rt.open(s)
    streams = {t: iter(chop(w, PAR_CHUNK * CFG.n_os, seed=i, jitter=0.5))
               for i, (t, w) in enumerate(sorted(waves.items()))}
    live = set(streams)
    while live:
        for t in sorted(live):
            c = next(streams[t], None)
            if c is None:
                live.discard(t)
                rt.finish(t)
            else:
                rt.submit(t, c)
    rt.drain()
    per_backend = {
        s.backend: bool(np.array_equal(rt.output(s.tenant_id),
                                       offline[s.tenant_id]))
        for s in specs}
    return {"per_backend": per_backend,
            "syms_estimated": int(sum(
                link.estimate(s.tenant_id).syms for s in specs)),
            "bitwise": all(per_backend.values())}


def run(train_steps: int = 500,
        out_path: Optional[pathlib.Path] = OUT_PATH) -> dict:
    bench = Bench("link_slo", "signal health: link estimators + SLO loop")

    tcfg = EqTrainConfig(steps=train_steps, eval_syms=1 << 14)
    params_a, bn_a, info_a = train_equalizer(
        jax.random.PRNGKey(0), "cnn",
        CFG, DriftingProakis().at(0.0), tcfg)
    params_t, bn_t, info_t = train_equalizer(
        jax.random.PRNGKey(0), "cnn",
        CFG, _track_channel().at(0.0), tcfg)
    print(f"[bench_link] trained: adapt tenant pre-drift BER "
          f"{float(info_a['ber']):.3e}, track tenant "
          f"{float(info_t['ber']):.3e}")

    drift = _drift_phase((params_t, bn_t), (params_a, bn_a))
    est_t = np.asarray(drift["est_track_db"])
    true_t = np.asarray(drift["true_snr_db"])
    corr = float(np.corrcoef(est_t, true_t)[0, 1])
    pre = float(np.mean(est_t[:SCHEDULE.hold_bursts]))
    drop = pre - float(est_t[-1])
    states = [a["state"] for a in drift["alerts"]
              if a["tenant"] == "adapt"]
    breach_fired = "breach" in states
    resolved = "resolved" in states
    promoted = drift["promotions"] >= 1
    final_clear = not drift["breached_final"]
    print(f"[bench_link] tracking: corr {corr:.3f} (floor {CORR_FLOOR}), "
          f"est drop {drop:.2f} dB (floor {DROP_FLOOR_DB}, true 4.00)")
    print(f"[bench_link] closed loop: breach_fired={breach_fired} "
          f"promoted={promoted} resolved={resolved} "
          f"final_clear={final_clear} "
          f"(actions {drift['actions']})")

    parity = _parity_phase()
    print(f"[bench_link] parity with link+slo+tracing ON: "
          f"{parity['per_backend']}")

    criteria = {
        "snr_corr": corr,
        "snr_est_drop_db": drop,
        "tracking_ok": bool(corr >= CORR_FLOOR and drop >= DROP_FLOOR_DB),
        "breach_fired": bool(breach_fired),
        "promoted": bool(promoted),
        "resolved": bool(resolved),
        "final_clear": bool(final_clear),
        "bitwise": bool(parity["bitwise"]),
        "link_ok": bool(corr >= CORR_FLOOR and drop >= DROP_FLOOR_DB
                        and breach_fired and promoted and resolved
                        and final_clear and parity["bitwise"]),
    }
    print(f"[bench_link] link_ok={criteria['link_ok']}")

    report = {
        "backend_default": jax.default_backend(),
        "scenario": {
            "n_bursts": N_BURSTS, "syms_per_burst": SYMS_PER_BURST,
            "hold_bursts": SCHEDULE.hold_bursts,
            "ramp_bursts": SCHEDULE.ramp_bursts,
            "train_steps": train_steps,
            "snr_ramp_db": -4.0,
            "slo_margin_db": SLO_MARGIN_DB,
            "fine_tune": {"steps": FT.steps, "lr": FT.lr,
                          "seq_syms": FT.seq_syms},
        },
        "drift": drift,
        "parity": parity,
        "criteria": criteria,
    }
    if out_path is not None:
        out_path.write_text(json.dumps(report, indent=2))
        print(f"[bench_link] wrote {out_path}")
    bench.record("report", report)
    return bench.finish()


if __name__ == "__main__":
    run()
