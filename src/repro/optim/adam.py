"""Minimal, sharding-friendly AdamW implemented on raw pytrees.

No optax dependency: the optimizer state is a pytree with the same structure
(and therefore the same PartitionSpecs) as the parameters, so pjit shards the
moments exactly like the weights (ZeRO-style when params are FSDP-sharded).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jnp.ndarray      # scalar int32
    mu: Any                # first moment, like params
    nu: Any                # second moment, like params


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float | Callable[[jnp.ndarray], jnp.ndarray] = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip_norm: Optional[float] = None
    # dtype for the moments; f32 master moments are standard
    state_dtype: Any = jnp.float32

    def init(self, params: Any) -> AdamState:
        zeros = lambda p: jnp.zeros(p.shape, self.state_dtype)
        return AdamState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def update(self, grads: Any, state: AdamState, params: Any):
        """Returns (new_params, new_state)."""
        step = state.step + 1
        if self.grad_clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.grad_clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)

        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(m.dtype),
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(v.dtype)),
                          state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p.astype(delta.dtype)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamState(step=step, mu=mu, nu=nu)


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))
