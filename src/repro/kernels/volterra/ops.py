"""Jitted wrapper: run the Volterra Pallas kernel from core params."""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from ...core.volterra import VolterraConfig
from .ref import volterra as volterra_ref
from .volterra import volterra as volterra_pallas


def equalize(params: Dict[str, jnp.ndarray], x: jnp.ndarray,
             cfg: VolterraConfig, use_pallas: bool = True,
             tile: int = 128) -> jnp.ndarray:
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None]
    w2 = params.get("w2") if cfg.m2 > 0 else None
    w3 = params.get("w3") if cfg.m3 > 0 else None
    if use_pallas:
        y = volterra_pallas(x, params["w0"], params["w1"], w2, w3,
                            stride=cfg.n_os, tile=tile)
    else:
        y = volterra_ref(x, params["w0"], params["w1"], w2, w3,
                         stride=cfg.n_os)
    return y[0] if squeeze else y


__all__ = ["volterra_pallas", "volterra_ref", "equalize"]
