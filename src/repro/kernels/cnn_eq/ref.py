"""Pure-jnp oracle for the fused CNN-equalizer kernel (fp32 + int8 paths).

STREAM semantics (matching the FPGA and the Pallas kernel): the input is
padded ONCE with half a receptive field of zeros per side and the layer stack
runs VALID convolutions — there is no per-layer zero padding, because on the
streaming hardware the layers see a continuous activation stream.

This differs from `repro.core.equalizer.apply_folded` (per-layer SAME
padding, the training-time definition) ONLY within o_sym symbols of the
stream edges — exactly the region the paper's overlap machinery discards.
tests/test_kernels.py asserts: kernel == ref everywhere, and
kernel == core-module on the interior.

The convolutions here are TAP-UNROLLED (`conv_valid_taps`): each tap k
contributes one (C_out, C_in) · (C_in, W) dot, accumulated k = 0 … K-1.
The Pallas kernel reuses this exact helper on its VMEM tiles — same dots,
same accumulation order; only the tiling differs, and the contraction is
over C_in and taps only (never the width axis), so tiling cannot change
the math. The fused fp32 kernel therefore agrees with this oracle to
within ~2 ULP (XLA may contract mul+add chains into FMAs differently for
different program shapes; tests assert atol=5e-6, observed ≤1e-6). The
int8 path is integer arithmetic and reproduces its oracle EXACTLY.

`cnn_eq_quant` is the QAT fake-quant oracle for the int8 datapath: weights
and per-layer input activations are snapped to their learned fixed-point
grids (core/qat.quantize_fixed) and the convs run in fp32. The int8 Pallas
kernel computes the same values with integer arithmetic + power-of-two
rescaling; tests assert agreement within one accumulation LSB.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def receptive_halo(kernels: Sequence[int], strides: Sequence[int]) -> int:
    r, jump = 0, 1
    for k, s in zip(kernels, strides):
        r += (k // 2) * jump
        jump *= s
    return r


def conv_valid_taps(h: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                    stride: int, n_out: int) -> jnp.ndarray:
    """(C_in, W) ⊛ (C_out, C_in, K) → (C_out, n_out): tap-unrolled dots.

    The shared definition of one equalizer conv layer — used by this oracle
    AND inside the Pallas kernel, so both accumulate in the same order.
    """
    k = w.shape[-1]
    acc = jnp.zeros((w.shape[0], n_out), jnp.float32)
    for kk in range(k):
        xk = jax.lax.slice(h, (0, kk),
                           (h.shape[0], kk + (n_out - 1) * stride + 1),
                           (1, stride))
        acc = acc + jax.lax.dot(w[:, :, kk].astype(jnp.float32), xk,
                                preferred_element_type=jnp.float32)
    return acc + b.astype(jnp.float32)[:, None]


def conv_valid_taps_bf16(h: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                         stride: int, n_out: int) -> jnp.ndarray:
    """bf16 variant of `conv_valid_taps`: bf16 MXU dots, fp32 accumulation.

    Inputs and weights are cast to bfloat16 immediately before each tap dot
    (weights may already be bf16 — the cast is then a no-op); the accumulator,
    bias add, and the activations BETWEEN layers stay fp32. This is the
    deployment datapath for QAT formats in the 9–16-bit range
    (`qat.deployment_dtype() == "bfloat16"`): bf16's 8-bit mantissa covers the
    learned fraction widths and its exponent covers any integer width, so no
    clipping/saturation logic is needed. Shared by the pure-jnp oracle
    (`cnn_eq_bf16`) and the fused Pallas kernel — same dots, same order.
    """
    k = w.shape[-1]
    hb = h.astype(jnp.bfloat16)
    wb = w.astype(jnp.bfloat16)
    acc = jnp.zeros((w.shape[0], n_out), jnp.float32)
    for kk in range(k):
        xk = jax.lax.slice(hb, (0, kk),
                           (hb.shape[0], kk + (n_out - 1) * stride + 1),
                           (1, stride))
        acc = acc + jax.lax.dot(wb[:, :, kk], xk,
                                preferred_element_type=jnp.float32)
    return acc + b.astype(jnp.float32)[:, None]


def _halo_pad(x: jnp.ndarray, kernels: Sequence[int],
              strides: Sequence[int]):
    """Stream-semantics padding shared by every oracle: ONE halo of zeros
    on the left, zeros on the right up to the last position's window."""
    halo = receptive_halo(kernels, strides)
    total_stride = 1
    for s in strides:
        total_stride *= s
    n_pos = x.shape[1] // total_stride
    need = (n_pos - 1) * total_stride + 2 * halo + 1
    xp = jnp.pad(x, ((0, 0), (halo, max(0, need - x.shape[1] - halo))))
    return xp, n_pos


def _stack_valid(x_row: jnp.ndarray,
                 weights: Sequence[Tuple[jnp.ndarray, jnp.ndarray]],
                 strides: Sequence[int], n_pos: int,
                 conv_fn=conv_valid_taps) -> jnp.ndarray:
    """Run the halo-padded layer stack on one stream: (W_pad,) → (n_syms,).

    conv_fn picks the datapath: `conv_valid_taps` (fp32, the default) or
    `conv_valid_taps_bf16` — the surrounding span/ReLU machinery is the
    single shared definition of stream semantics.
    """
    n_layers = len(weights)
    spans = [n_pos]
    for (w, _), s in zip(reversed(list(weights)), reversed(list(strides))):
        spans.append((spans[-1] - 1) * s + int(w.shape[-1]))
    spans = spans[::-1]
    h = x_row[None, :].astype(jnp.float32)          # (C_in=1, W_pad)
    for i, ((w, b), s) in enumerate(zip(weights, strides)):
        h = conv_fn(h, w, b, s, spans[i + 1])
        if i < n_layers - 1:
            h = jax.nn.relu(h)
    return jnp.swapaxes(h, 0, 1).reshape(-1)        # (n_pos · V_p,)


def cnn_eq(x: jnp.ndarray, weights: Sequence[Tuple[jnp.ndarray, jnp.ndarray]],
           strides: Sequence[int]) -> jnp.ndarray:
    """x: (B, W) waveform → (B, W//(∏strides)·V_p) symbols (stream semantics)."""
    kernels = [int(w.shape[-1]) for w, _ in weights]
    xp, n_pos = _halo_pad(x, kernels, strides)
    y = jax.vmap(lambda row: _stack_valid(row, weights, strides, n_pos))(xp)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# QAT fake-quant oracle (int8 datapath reference)
# ---------------------------------------------------------------------------

def _fake_quant(x: jnp.ndarray, int_bits, frac_bits) -> jnp.ndarray:
    """quantize_fixed without the STE (forward values are identical).

    int_bits/frac_bits are python ints, or arrays broadcastable against `x`
    (the per-output-channel weight-scale path: shape (C_out, 1, 1))."""
    scale = np.exp2(np.asarray(frac_bits, np.float32))
    hi = np.exp2(np.asarray(int_bits, np.float32)) - 1.0 / scale
    lo = -np.exp2(np.asarray(int_bits, np.float32))
    return jnp.clip(jnp.round(x * scale) / scale, lo, hi)


def cnn_eq_quant(x: jnp.ndarray,
                 weights: Sequence[Tuple[jnp.ndarray, jnp.ndarray]],
                 strides: Sequence[int],
                 formats: Sequence[Tuple[int, int, int, int]]) -> jnp.ndarray:
    """Fake-quantized stream-semantics forward — the int8 kernel's oracle.

    formats[l] = (w_int, w_frac, a_int, a_frac): the frozen per-layer
    fixed-point formats from QAT. Layer l snaps its input activations to
    Q(a_int).(a_frac) and its (BN-folded) weights to Q(w_int).(w_frac),
    exactly like `core.equalizer.apply` with qat_enabled, then convolves in
    fp32. Biases stay fp32 (the FPGA keeps full-width accumulators).
    """
    kernels = [int(w.shape[-1]) for w, _ in weights]
    xp, n_pos = _halo_pad(x, kernels, strides)

    spans = [n_pos]
    for k, s in zip(reversed(kernels), reversed(list(strides))):
        spans.append((spans[-1] - 1) * s + k)
    spans = spans[::-1]

    n_layers = len(weights)

    def one(row):
        h = row[None, :].astype(jnp.float32)
        for i, ((w, b), s) in enumerate(zip(weights, strides)):
            wi, wf, ai, af = formats[i]
            # scalar or per-output-channel weight formats: reshape to a
            # (C_out|1, 1, 1) column so both broadcast over (C_out, C_in, K)
            wi_col = np.asarray(wi, np.float32).reshape(-1, 1, 1)
            wf_col = np.asarray(wf, np.float32).reshape(-1, 1, 1)
            wq = _fake_quant(w.astype(jnp.float32), wi_col, wf_col)
            h = _fake_quant(h, ai, af)
            h = conv_valid_taps(h, wq, b, s, spans[i + 1])
            if i < n_layers - 1:
                h = jax.nn.relu(h)
        return jnp.swapaxes(h, 0, 1).reshape(-1)

    return jax.vmap(one)(xp).astype(x.dtype)


def cnn_eq_bf16(x: jnp.ndarray,
                weights: Sequence[Tuple[jnp.ndarray, jnp.ndarray]],
                strides: Sequence[int]) -> jnp.ndarray:
    """bf16-datapath stream-semantics forward — the fused_bf16 oracle.

    Same halo/VALID structure as `cnn_eq` (shared `_stack_valid`
    machinery), but every conv runs through `conv_valid_taps_bf16` (bf16
    dots, fp32 accum). Weights may be fp32 (cast there) or pre-cast bf16
    (the engine's deployment form) — both give identical results because
    the cast is idempotent.
    """
    kernels = [int(w.shape[-1]) for w, _ in weights]
    xp, n_pos = _halo_pad(x, kernels, strides)
    y = jax.vmap(lambda row: _stack_valid(row, weights, strides, n_pos,
                                          conv_fn=conv_valid_taps_bf16))(xp)
    return y.astype(x.dtype)
