"""Pallas TPU kernel: the FUSED L-layer CNN equalizer (paper §5.1 on TPU).

The FPGA architecture instantiates each conv layer as a pipeline stage with
activations streaming between stages through on-chip FIFOs. The TPU-native
equivalent keeps the whole layer stack inside ONE kernel so inter-layer
activations never leave VMEM:

  HBM ──DMA──▶ VMEM input tile (with receptive-field halo)
                 │ conv1 (stride V_p) + ReLU        ┐ all in VMEM /
                 │ conv2 … conv_{L-1} + ReLU        │ vector registers —
                 │ conv_L (stride N_os)             ┘ zero HBM round-trips
  HBM ◀──DMA── VMEM output tile (tile_m · V_p symbols)

Grid = (batch, sequence tiles): Mosaic overlaps the tile DMAs with compute,
which is exactly the paper's "each layer starts as soon as first inputs
arrive" streaming property, realized at tile granularity.

Each grid step takes its overlapping input window (half a receptive field of
halo per side, `receptive_halo`) with an in-kernel `pl.ds` dynamic slice of
the padded stream; the kernel computes VALID convolutions and the wrapper
pre-pads the stream so the result equals the SAME_LOWER-padded reference
(`ref.cnn_eq`) — including at stream edges. The fp32 kernel reuses
`ref.conv_valid_taps` for its layer math (same dots, same accumulation
order), matching the oracle to ~2 ULP; the int8 kernel matches its
fake-quant oracle exactly (integer arithmetic has no rounding freedom).

INT8 datapath (`cnn_eq_fused_int8`) — the deployment path when QAT's learned
per-layer fixed-point formats fit int8 (qat.deployment_dtype == "int8").
Weights are pre-quantized host-side to int8 at scale 2^w_frac; activations
are requantized INSIDE the kernel between layers, so the whole quantized
stack stays fused in VMEM:

      x (fp32 tile, VMEM)
        │ requant:  q = clip(round(x · 2^af₁))        → int8
        │ conv1:    int8 × int8 MXU dots              → int32 accum
        │ rescale:  acc · 2^-(wf₁+af₁) + b₁ (fp32)    → fp32
        │ ReLU ──▶ requant 2^af₂ → int8 ──▶ conv2 ──▶ … conv_L
        ▼
      y (fp32 symbols, VMEM)

The integer dot is exact (|w|·|a| ≤ 127², ΣC_in·K terms ≪ 2³¹) and the
rescale multiplies by a power of two, so the kernel reproduces the QAT
fake-quant reference (`ref.cnn_eq_quant`) to within one accumulation LSB —
quantization error comes ONLY from the learned formats, never the kernel.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
import numpy as np

from .ref import conv_valid_taps, conv_valid_taps_bf16, receptive_halo


def _wformat_cols(wi, wf):
    """Weight-format components as broadcastable fp32 columns.

    wi/wf are static ints (one scale per layer, the paper's scheme) or
    per-output-channel tuples of ints (`qat.per_channel_formats`). Either
    way the result is a numpy column — shape (1, 1) or (C_out, 1) — that
    broadcasts over a (C_out, …) accumulator, so the scalar and per-channel
    paths share every downstream expression.
    """
    return (np.asarray(wi, np.float32).reshape(-1, 1),
            np.asarray(wf, np.float32).reshape(-1, 1))


def _layer_spans(tile_m: int, kernels: Sequence[int],
                 strides: Sequence[int]) -> list[int]:
    """Positions needed at each level to produce tile_m final positions."""
    spans = [tile_m]
    for k, s in zip(reversed(kernels), reversed(strides)):
        spans.append((spans[-1] - 1) * s + k)
    return list(reversed(spans))  # spans[0] = input samples per tile


def _layer_wb(w_ref, b_ref):
    """Read one layer's (w, b) block, squeezing the per-row tenant dim.

    Weights arrive either SHARED across the batch (w: (C_out, C_in, K),
    b: (C_out,) — every grid row sees the same block) or STACKED per row
    (w: (1, C_out, C_in, K), b: (1, C_out) — the BlockSpec selected THIS
    row's tenant weights). The kernel math is identical after the squeeze;
    this is what lets one fused launch serve many tenants (repro.serve).
    """
    w = w_ref[...]
    b = b_ref[...]
    if w.ndim == 4:
        w = w[0]
    if b.ndim == 2:
        b = b[0]
    return w, b


def _cnn_eq_kernel(x_ref, *refs, tile_m: int, in_tile: int, kernels, strides,
                   v_parallel: int, conv_fn=conv_valid_taps):
    """Float kernel body; conv_fn picks the datapath — `conv_valid_taps`
    (fp32) or `conv_valid_taps_bf16` (bf16 dots, fp32 accum) — mirroring
    the conv_fn parameterization of the oracle (`ref._stack_valid`)."""
    n_layers = len(kernels)
    w_refs = refs[:-1][0::2]
    b_refs = refs[:-1][1::2]
    o_ref = refs[-1]
    spans = _layer_spans(tile_m, kernels, strides)
    total_stride = 1
    for s in strides:
        total_stride *= s

    start = pl.program_id(1) * (tile_m * total_stride)
    h = x_ref[:, pl.ds(start, in_tile)].astype(jnp.float32)  # (1, in_tile)
    for i in range(n_layers):
        w, b = _layer_wb(w_refs[i], b_refs[i])
        h = conv_fn(h, w, b, strides[i], spans[i + 1])
        if i < n_layers - 1:
            h = jax.nn.relu(h)
    # (V_p, tile_m) → interleave channels: symbol s = m·V_p + c
    y = jnp.swapaxes(h, 0, 1).reshape(1, tile_m * v_parallel)
    o_ref[...] = y.astype(o_ref.dtype)


def requant_int8(h: jnp.ndarray, a_int: int, a_frac: int) -> jnp.ndarray:
    """fp32 → int8 on the Q(a_int).(a_frac) grid (values are x·2^a_frac).

    Idempotent through `dequant_int8`: requant(dequant(q)) == q exactly
    (power-of-two scale, round of an on-grid value). The int8 kernel uses it
    between layers; `parallel.halo` uses it to ship int8 halo samples.
    """
    hi = float(2 ** (a_int + a_frac)) - 1.0
    lo = -float(2 ** (a_int + a_frac))
    q = jnp.clip(jnp.round(h * float(2.0 ** a_frac)), lo, hi)
    return q.astype(jnp.int8)


def dequant_int8(q: jnp.ndarray, a_frac: int) -> jnp.ndarray:
    """int8 grid values → fp32 real units (inverse scale of requant_int8)."""
    return q.astype(jnp.float32) * float(2.0 ** -a_frac)


_requant = requant_int8          # kernel-internal alias


def _cnn_eq_kernel_int8(x_ref, *refs, tile_m: int, in_tile: int, kernels,
                        strides, v_parallel: int, formats):
    n_layers = len(kernels)
    body = refs[:-1]             # per layer: (w int8, b fp32, rescale fp32)
    w_refs = body[0::3]          # int8 weights, pre-scaled by 2^w_frac
    b_refs = body[1::3]          # fp32 biases (full-width accumulators)
    s_refs = body[2::3]          # (C_out,) exact power-of-two rescale —
    #   2^-(w_frac + a_frac) per OUTPUT CHANNEL. A uniform vector for the
    #   paper's one-scale-per-layer scheme; genuinely per-channel for
    #   `qat.per_channel_formats` deployments. Either way the int8 dot
    #   below is identical — per-channel scales cost no MXU work, only
    #   this rescale column (Pallas cannot capture array constants, hence
    #   an operand rather than a baked-in value).
    o_ref = refs[-1]
    spans = _layer_spans(tile_m, kernels, strides)
    total_stride = 1
    for s in strides:
        total_stride *= s

    start = pl.program_id(1) * (tile_m * total_stride)
    h = x_ref[:, pl.ds(start, in_tile)].astype(jnp.float32)
    for i in range(n_layers):
        _, _, ai, af = formats[i]
        hq = _requant(h, ai, af)                     # fused requantization
        w, b = _layer_wb(w_refs[i], b_refs[i])
        n_out = spans[i + 1]
        k = w.shape[-1]
        acc = jnp.zeros((w.shape[0], n_out), jnp.int32)
        for kk in range(k):
            xk = jax.lax.slice(
                hq, (0, kk), (hq.shape[0], kk + (n_out - 1) * strides[i] + 1),
                (1, strides[i]))
            acc = acc + jax.lax.dot(w[:, :, kk], xk,
                                    preferred_element_type=jnp.int32)
        # exact power-of-two rescale back to real units, then fp32 bias
        h = acc.astype(jnp.float32) * s_refs[i][...][:, None] \
            + b.astype(jnp.float32)[:, None]
        if i < n_layers - 1:
            h = jax.nn.relu(h)
    y = jnp.swapaxes(h, 0, 1).reshape(1, tile_m * v_parallel)
    o_ref[...] = y.astype(o_ref.dtype)


def _fused_call(kernel_body, x, weights, strides, tile_m, interpret,
                **kernel_kwargs):
    """Shared grid/BlockSpec plumbing for all fused kernel bodies.

    Weights are either SHARED — w: (C_out, C_in, K) broadcast to every batch
    row — or STACKED per row — w: (B, C_out, C_in, K), b: (B, C_out), batch
    row i computed with weight set i. The stacked form is the multi-tenant
    serving path: one launch, per-tenant weights selected by the BlockSpec.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    batch, width = x.shape
    stacked = weights[0][0].ndim == 4
    if stacked and int(weights[0][0].shape[0]) != batch:
        raise ValueError(
            f"stacked weights carry {int(weights[0][0].shape[0])} rows but "
            f"x has batch {batch}")
    kernels = tuple(int(item[0].shape[-1]) for item in weights)
    v_parallel = int(weights[-1][0].shape[1 if stacked else 0])
    total_stride = 1
    for s in strides:
        total_stride *= s
    n_pos = width // total_stride                  # final-layer positions
    n_syms = n_pos * v_parallel

    # Always tile at the REQUESTED tile_m — even for a stream shorter than
    # one tile. Shrinking the tile to n_pos would change the conv dot shapes
    # (and with them the fp32 accumulation splits) relative to a streaming
    # launch that buckets at full tile_m, costing 1-2 ULP in end-padding
    # window positions and breaking chunked==offline bitwise equality
    # (contract #4). Short streams just compute a few extra padded positions
    # that the final n_syms slice drops.
    tile_m = max(1, tile_m)
    n_tiles = pl.cdiv(n_pos, tile_m)
    halo = receptive_halo(kernels, strides)
    in_tile = _layer_spans(tile_m, kernels, strides)[0]

    # pad: halo on the left; halo + tile rounding on the right
    needed = (n_tiles - 1) * tile_m * total_stride + in_tile
    xp = jnp.pad(x, ((0, 0), (halo, max(0, needed - width - halo))))

    flat: list[jnp.ndarray] = []
    in_specs = [pl.BlockSpec((1, xp.shape[1]), lambda ib, it: (ib, 0))]
    for item in weights:
        w, b = item[0], item[1]
        flat += [w, b]
        if stacked:
            in_specs += [pl.BlockSpec((1,) + w.shape[1:],
                                      lambda ib, it: (ib, 0, 0, 0)),
                         pl.BlockSpec((1, b.shape[1]),
                                      lambda ib, it: (ib, 0))]
        else:
            in_specs += [pl.BlockSpec(w.shape, lambda ib, it: (0, 0, 0)),
                         pl.BlockSpec(b.shape, lambda ib, it: (0,))]
        # trailing per-layer operands (e.g. the int8 rescale column) are
        # SHARED across batch rows even in stacked launches: they derive
        # from the static formats, which every engine in a group shares
        # (formats are part of group_key)
        for extra in item[2:]:
            flat.append(extra)
            in_specs.append(pl.BlockSpec(extra.shape, lambda ib, it: (0,)))

    out = pl.pallas_call(
        functools.partial(kernel_body, tile_m=tile_m, in_tile=in_tile,
                          kernels=kernels, strides=strides,
                          v_parallel=v_parallel, **kernel_kwargs),
        grid=(batch, n_tiles),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, tile_m * v_parallel),
                               lambda ib, it: (ib, it)),
        out_shape=jax.ShapeDtypeStruct(
            (batch, n_tiles * tile_m * v_parallel), x.dtype),
        interpret=interpret,
    )(xp, *flat)
    return out[:, :n_syms]


@functools.partial(jax.jit,
                   static_argnames=("strides", "tile_m", "interpret"))
def cnn_eq_fused(x: jnp.ndarray,
                 weights: Tuple[Tuple[jnp.ndarray, jnp.ndarray], ...],
                 strides: Tuple[int, ...], tile_m: int = 64,
                 interpret: bool | None = None) -> jnp.ndarray:
    """Fused fp32 equalizer forward. x: (B, W) → (B, W//N_os) symbols.

    weights: ((w_1, b_1), …, (w_L, b_L)) — BN pre-folded (equalizer.fold_bn).
    Shared (w: (C_out, C_in, K)) or per-row stacked (w: (B, C_out, C_in, K))
    — see `_fused_call`. strides: (V_p, 1, …, N_os).
    Output length = W // (V_p·N_os) · V_p.
    """
    return _fused_call(_cnn_eq_kernel, x, weights, strides, tile_m, interpret)


def cast_weights_bf16(
        weights: Tuple[Tuple[jnp.ndarray, jnp.ndarray], ...],
) -> Tuple[Tuple[jnp.ndarray, jnp.ndarray], ...]:
    """Host-side bf16 deployment cast: fp32 folded weights → bf16; biases
    stay fp32 (full-width accumulators, like the int8 path)."""
    return tuple((w.astype(jnp.bfloat16), b.astype(jnp.float32))
                 for w, b in weights)


@functools.partial(jax.jit,
                   static_argnames=("strides", "tile_m", "interpret"))
def cnn_eq_fused_bf16(x: jnp.ndarray,
                      bweights: Tuple[Tuple[jnp.ndarray, jnp.ndarray], ...],
                      strides: Tuple[int, ...], tile_m: int = 64,
                      interpret: bool | None = None) -> jnp.ndarray:
    """Fused bf16 equalizer forward: bf16 tap dots, fp32 accumulation.

    The deployment path for QAT formats in the 9–16-bit range
    (`qat.deployment_dtype() == "bfloat16"`). bweights from
    `cast_weights_bf16` (fp32 weights also accepted — cast in-kernel).
    Matches the pure-jnp oracle `ref.cnn_eq_bf16` bitwise (shared
    `conv_valid_taps_bf16` tap math). Shared or per-row stacked weights,
    like `cnn_eq_fused`.
    """
    return _fused_call(_cnn_eq_kernel, x, bweights, strides, tile_m,
                       interpret, conv_fn=conv_valid_taps_bf16)


def quantize_weights_int8(
        weights: Tuple[Tuple[jnp.ndarray, jnp.ndarray], ...],
        formats: Tuple[Tuple[int, int, int, int], ...],
) -> Tuple[Tuple[jnp.ndarray, jnp.ndarray], ...]:
    """Host-side weight quantization: fp32 folded weights → int8 at 2^w_frac.

    formats[l] = (w_int, w_frac, a_int, a_frac); requires w_int+w_frac+1 ≤ 8
    (qat.deployment_dtype == "int8"). Biases stay fp32. w_int/w_frac may be
    per-output-channel tuples (`qat.per_channel_formats`) — each channel is
    then quantized on its own 2^w_frac[c] grid; the kernel undoes the
    per-channel scale in its requantization column.
    """
    out = []
    for (w, b), (wi, wf, _, _) in zip(weights, formats):
        wi_col, wf_col = _wformat_cols(wi, wf)
        bits = int(np.max(wi_col + wf_col)) + 1
        if bits > 8:
            raise ValueError(
                f"format Q{wi}.{wf} needs {bits} bits > int8")
        shape = (-1, 1, 1)                     # broadcast over (C_out, C_in, K)
        hi = np.exp2(wi_col + wf_col).reshape(shape) - 1.0
        lo = -np.exp2(wi_col + wf_col).reshape(shape)
        scale = np.exp2(wf_col).reshape(shape)
        wq = jnp.clip(jnp.round(w.astype(jnp.float32) * scale),
                      lo, hi).astype(jnp.int8)
        out.append((wq, b.astype(jnp.float32)))
    return tuple(out)


@functools.partial(jax.jit,
                   static_argnames=("strides", "formats", "tile_m",
                                    "interpret"))
def cnn_eq_fused_int8(x: jnp.ndarray,
                      qweights: Tuple[Tuple[jnp.ndarray, jnp.ndarray], ...],
                      strides: Tuple[int, ...],
                      formats: Tuple[Tuple[int, int, int, int], ...],
                      tile_m: int = 64,
                      interpret: bool | None = None) -> jnp.ndarray:
    """Fused INT8 equalizer forward (see module docstring datapath diagram).

    qweights: ((w_q int8, b fp32), …) from `quantize_weights_int8`.
    formats:  per-layer (w_int, w_frac, a_int, a_frac) — static, baked into
              the kernel as requant scales/clip bounds; w_int/w_frac may be
              per-output-channel tuples. Every format must fit a signed
              8-bit grid: the in-kernel requant casts to int8, which would
              silently WRAP (not saturate) wider grids.
    """
    for i, (wi, wf, ai, af) in enumerate(formats):
        wi_col, wf_col = _wformat_cols(wi, wf)
        if int(np.max(wi_col + wf_col)) + 1 > 8 or ai + af + 1 > 8:
            raise ValueError(
                f"layer {i} format (Q{wi}.{wf} w / Q{ai}.{af} a) does not "
                f"fit int8; the int8 requant would wrap silently")
    # per-layer rescale column: 2^-(w_frac + a_frac), broadcast to (C_out,)
    # — Pallas kernels cannot capture array constants, so the (possibly
    # per-channel) scale travels as a third per-layer operand
    withscale = []
    for (w, b), (wi, wf, ai, af) in zip(qweights, formats):
        c_out = int(w.shape[-3])
        _, wf_col = _wformat_cols(wi, wf)
        scale = np.broadcast_to(np.exp2(-(wf_col + af)).reshape(-1),
                                (c_out,)).astype(np.float32)
        withscale.append((w, b, jnp.asarray(scale)))
    return _fused_call(_cnn_eq_kernel_int8, x, tuple(withscale), strides,
                       tile_m, interpret, formats=formats)
