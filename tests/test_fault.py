"""Fault-tolerant serving (repro.serve.recovery) — the ISSUE-6 acceptance
surface.

  * `FaultPlan` determinism: scheduled faults fire exactly once, in the
    right index space, and validate their kinds;
  * bounded session failover: an injected terminal launch failure rebuilds
    the affected engines from `TenantSpec` and replays the lost chunks —
    the finished streams stay BITWISE-equal to offline equalization;
  * output-sentinel quarantine: NaN/saturated launch output is rejected
    before emission and replayed clean (plus the PR 5 rollback path when
    the session recently hot-swapped weights);
  * launch discipline: the watchdog deadline abandons a hung device call;
    backoff between retries is exponential, capped, and jitter-seeded;
  * graceful degradation: persistent launch slowness halves
    `BatchPolicy.max_batch` and sheds the lowest-priority tenant
    (`TenantShedError` on submit), both restored when healthy;
  * the chaos acceptance sweep: 6 tenants across fused_fp32 + fused_int8
    under all four fault kinds — every submitted chunk emitted exactly
    once, bitwise-equal to offline.

All tests carry the `chaos` marker (deselect with -m "not chaos").
"""
import random
import time

import jax
import numpy as np
import pytest

from repro.core import equalizer as eq
from repro.core.engine import EqualizerEngine
from repro.runtime.straggler import StragglerConfig
from repro.serve import (AsyncServeRuntime, BatchPolicy, CorruptOutput,
                         DeviceLost, Fault, FaultPlan, InjectedFault,
                         MicroBatcher, RecoveryPolicy, ServeRuntime,
                         TenantShedError, TenantSpec, chop)
from repro.serve.recovery import output_ok

pytestmark = pytest.mark.chaos

CFG = eq.CNNEqConfig()
INT8_FMT = tuple((2, 5, 3, 4) for _ in range(CFG.layers))


def _weights(seed, cfg=CFG):
    params = eq.init(jax.random.PRNGKey(seed), cfg)
    folded = eq.fold_bn(params, eq.init_bn_state(cfg), cfg)
    return eq.folded_weights(folded)


def _spec(tid, backend, seed, cfg=CFG, tile_m=32, priority=0):
    return TenantSpec(
        tid, cfg, weights=_weights(seed, cfg),
        formats=INT8_FMT if backend == "fused_int8" else None,
        backend=backend, tile_m=tile_m, priority=priority)


def _offline(spec, wave):
    import jax.numpy as jnp
    return np.asarray(spec.build_engine()(jnp.asarray(wave[None])))[0]


def _wave(seed, n_syms):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(n_syms * CFG.n_os).astype(np.float32)


# ---------------------------------------------------------------------------
# FaultPlan / policy units
# ---------------------------------------------------------------------------

def test_fault_plan_validates_and_fires_once():
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault("meteor_strike", 0)
    with pytest.raises(ValueError, match="unknown corrupt mode"):
        Fault("corrupt", 0, mode="gremlins")
    with pytest.raises(ValueError, match="duplicate"):
        FaultPlan([Fault("launch_error", 1), Fault("launch_error", 1)])

    fp = FaultPlan([Fault("launch_error", 1), Fault("build_error", 0)])
    fp.on_execute(0)                               # not scheduled: no-op
    with pytest.raises(InjectedFault):
        fp.on_execute(1)
    fp.on_execute(1)                               # fires at most ONCE
    with pytest.raises(InjectedFault):
        fp.on_build(0)
    fp.on_build(0)
    assert fp.fired == [("launch_error", 1), ("build_error", 0)]
    assert fp.pending == 0
    assert fp.summary() == {"launch_error": 1, "build_error": 1}


def test_fault_plan_device_kinds_validate_and_fire_once():
    """`device_lost`/`device_slow` schedule per WORKER index: `at` names
    the worker, `after` the first per-worker execute index eligible to
    fire — and each fault still fires at most once."""
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault("device_on_fire", 0)
    with pytest.raises(ValueError, match="`after` only applies"):
        Fault("launch_error", 0, after=2)

    fp = FaultPlan([Fault("device_lost", at=0, after=2),
                    Fault("device_slow", at=1, after=0, delay_s=0.01)])
    assert fp.pending == 2
    fp.on_worker(0, 0)                             # below `after`: no-op
    fp.on_worker(0, 1)
    fp.on_worker(1, 5)                             # wrong worker for lost
    assert fp.fired == [("device_slow", 1)]        # slow fired above
    with pytest.raises(DeviceLost, match="worker 0 at execute 2"):
        fp.on_worker(0, 2)
    fp.on_worker(0, 3)                             # fires at most ONCE
    assert fp.fired == [("device_slow", 1), ("device_lost", 0)]
    assert fp.pending == 0
    assert fp.summary() == {"device_slow": 1, "device_lost": 1}


def test_fault_plan_device_slow_injects_measurable_delay():
    fp = FaultPlan([Fault("device_slow", at=3, after=1, delay_s=0.05)])
    t0 = time.perf_counter()
    fp.on_worker(3, 0)                             # below `after`
    assert time.perf_counter() - t0 < 0.04
    t0 = time.perf_counter()
    fp.on_worker(3, 4)                             # at/after: sleeps once
    assert time.perf_counter() - t0 >= 0.05
    assert fp.pending == 0


def test_fault_plan_corrupts_scheduled_rows_only():
    fp = FaultPlan([Fault("corrupt", 0, mode="nan", rows=(1,)),
                    Fault("corrupt", 1, mode="saturate")])
    y = np.ones((3, 4), np.float32)
    out = fp.on_output(0, y)
    assert np.isnan(out[1]).all() and np.isfinite(out[[0, 2]]).all()
    assert np.isfinite(y).all()                    # input untouched (copy)
    out2 = fp.on_output(1, y)
    assert (np.abs(out2) >= 1e9).all()
    assert fp.on_output(2, y) is y                 # unscheduled: passthrough


def test_output_sentinel():
    assert output_ok(np.ones((2, 3), np.float32), 1e4)
    assert output_ok(np.zeros((0,), np.float32), 1e4)      # empty is fine
    assert not output_ok(np.array([1.0, np.nan]), 1e4)
    assert not output_ok(np.array([1.0, np.inf]), 1e4)
    assert not output_ok(np.array([1.0, 2e4]), 1e4)


def test_backoff_is_exponential_capped_and_jitter_bounded():
    pol = RecoveryPolicy(backoff_base_s=0.01, backoff_max_s=0.05,
                         jitter=0.25)
    rng = random.Random(0)
    for attempt, nominal in enumerate([0.01, 0.02, 0.04, 0.05, 0.05]):
        for _ in range(20):
            d = pol.backoff_s(attempt, rng)
            assert 0.75 * nominal <= d <= 1.25 * nominal
    nojit = RecoveryPolicy(backoff_base_s=0.01, jitter=0.0)
    assert nojit.backoff_s(2, rng) == pytest.approx(0.04)


# ---------------------------------------------------------------------------
# sync driver: faults surface, requeue, and replay clean
# ---------------------------------------------------------------------------

def test_sync_runtime_fault_requeues_and_recovers_bitwise():
    fp = FaultPlan([Fault("launch_error", 0), Fault("corrupt", 1)])
    rt = ServeRuntime(BatchPolicy(max_batch=1, max_wait_s=0.0),
                      fault_plan=fp, sentinel_limit=1e4)
    spec = _spec("sync", "fused_fp32", seed=3)
    rt.open(spec)
    wave = _wave(5, 300)
    with pytest.raises(InjectedFault):             # exec 0: injected error
        rt.submit("sync", wave)
    with pytest.raises(CorruptOutput):             # exec 1: sentinel trips
        rt.pump()
    got = rt.close("sync")                         # exec 2+: clean replay
    np.testing.assert_array_equal(got, _offline(spec, wave))
    assert fp.pending == 0


# ---------------------------------------------------------------------------
# async failover: rebuild + replay, bitwise
# ---------------------------------------------------------------------------

def test_async_terminal_injected_failure_recovers_bitwise():
    """launch_retries=1 and back-to-back injected errors make the first
    launch fail TERMINALLY; failover rebuilds the engine and replays —
    the stream finishes bitwise-equal to offline, futures all resolve."""
    fp = FaultPlan([Fault("launch_error", 0), Fault("launch_error", 1)])
    with AsyncServeRuntime(BatchPolicy(max_batch=1, max_wait_s=1e9),
                           launch_retries=1, fault_plan=fp) as rt:
        spec = _spec("phoenix", "fused_fp32", seed=17)
        rt.open(spec)
        wave = _wave(23, 400)
        futs = [rt.submit("phoenix", c) for c in chop(wave, 350, seed=2)]
        futs.append(rt.finish("phoenix"))
        rt.drain()
        for f in futs:
            if f is not None:
                assert np.isfinite(f.result(timeout=30)).all()
        got = rt.output("phoenix")
        np.testing.assert_array_equal(got, _offline(spec, wave))
        st = rt.stats()
        assert st["recovery"]["recoveries"] >= 1
        assert st["recovery"]["chunks_replayed"] >= 1
        assert st["recovery"]["engine_rebuilds"] >= 1
        assert st["recovery"]["sessions_poisoned"] == 0
        assert rt.errors and rt.errors_total == len(rt.errors)


def test_async_build_failure_during_failover_is_retried():
    """The failover engine rebuild itself hits an injected build failure
    (build index 1 = the first rebuild; build 0 was the open) — the
    bounded build retry absorbs it and the stream still lands bitwise."""
    fp = FaultPlan([Fault("launch_error", 0), Fault("launch_error", 1),
                    Fault("build_error", 1)])
    with AsyncServeRuntime(BatchPolicy(max_batch=1, max_wait_s=1e9),
                           launch_retries=1, fault_plan=fp) as rt:
        spec = _spec("rebuilder", "fused_fp32", seed=31)
        rt.open(spec)
        wave = _wave(37, 300)
        rt.submit("rebuilder", wave)
        got = rt.close("rebuilder")
        np.testing.assert_array_equal(got, _offline(spec, wave))
        assert fp.pending == 0
        assert rt.recovery_stats.engine_rebuilds >= 1


def test_async_recovery_budget_exhaustion_still_poisons(monkeypatch):
    """A permanently dead device exhausts max_session_recoveries and the
    stream is poisoned the pre-recovery way — bounded, not infinite."""
    def dead_execute(self, batch):
        raise RuntimeError("dead device")

    monkeypatch.setattr(MicroBatcher, "execute", dead_execute)
    pol = RecoveryPolicy(max_session_recoveries=2, backoff_base_s=1e-4,
                         backoff_max_s=1e-3)
    with AsyncServeRuntime(BatchPolicy(max_batch=1, max_wait_s=1e9),
                           launch_retries=0, recovery=pol) as rt:
        rt.open(_spec("doomed", "fused_fp32", seed=41))
        fut = rt.submit("doomed", _wave(43, 250))
        rt.drain()
        with pytest.raises(RuntimeError, match="dead device"):
            fut.result(timeout=30)
        with pytest.raises(RuntimeError, match="lost a chunk"):
            rt.output("doomed")
        s = rt.sessions.get("doomed")
        assert s.recoveries == pol.max_session_recoveries + 1
        assert rt.recovery_stats.sessions_poisoned == 1


def test_async_corrupt_output_quarantined_and_replayed_bitwise():
    fp = FaultPlan([Fault("corrupt", 0, mode="nan"),
                    Fault("corrupt", 1, mode="saturate")])
    with AsyncServeRuntime(BatchPolicy(max_batch=1, max_wait_s=1e9),
                           fault_plan=fp) as rt:
        spec = _spec("glitchy", "fused_int8", seed=53)
        rt.open(spec)
        wave = _wave(59, 300)
        futs = [rt.submit("glitchy", c) for c in chop(wave, 280, seed=4)]
        futs.append(rt.finish("glitchy"))
        rt.drain()
        got = rt.output("glitchy")
        assert np.isfinite(got).all()
        np.testing.assert_array_equal(got, _offline(spec, wave))
        assert rt.recovery_stats.corrupt_detected >= 1
        assert rt.recovery_stats.sessions_poisoned == 0


def test_async_corrupt_after_swap_rolls_back_weights():
    """Corruption on a session that recently hot-swapped takes the PR 5
    quarantine: the weights roll back to prev_spec bit-identically (epoch
    bumps), the chunks replay, and the stream survives un-poisoned."""
    w0, w1 = _weights(61), _weights(67)
    # exec 0 = pre-swap launch; exec 1 = first post-swap launch → corrupt
    fp = FaultPlan([Fault("corrupt", 1, mode="nan")])
    with AsyncServeRuntime(BatchPolicy(max_batch=1, max_wait_s=1e9),
                           fault_plan=fp) as rt:
        spec = _spec("swapper", "fused_fp32", seed=61)
        rt.open(spec)
        f0 = rt.submit("swapper", _wave(71, 200))
        f0.result(timeout=30)
        assert rt.swap_weights("swapper", weights=w1) == 1
        f1 = rt.submit("swapper", _wave(73, 200))
        rt.drain()
        assert np.isfinite(f1.result(timeout=30)).all()
        s = rt.sessions.get("swapper")
        assert s.failed is None and s.rolled_back
        assert rt.recovery_stats.rollbacks == 1
        assert s.spec.weight_epoch == 2            # rollback bumps epoch
        # the active weights are bit-identical to the pre-swap ones
        np.testing.assert_array_equal(np.asarray(s.spec.weights[0][0]),
                                      np.asarray(spec.weights[0][0]))


def test_async_launch_deadline_abandons_hung_call():
    """An injected 3 s launch delay against a 1 s watchdog deadline: the
    hung attempt is abandoned (LaunchTimeout), the retry lands clean, and
    the stream stays bitwise. Exec 0 is a fault-free warm-up so the
    kernel compile never races the deadline."""
    fp = FaultPlan([Fault("launch_delay", 1, delay_s=3.0)])
    with AsyncServeRuntime(BatchPolicy(max_batch=1, max_wait_s=1e9),
                           launch_retries=1, launch_deadline_s=1.0,
                           fault_plan=fp) as rt:
        spec = _spec("sleeper", "fused_fp32", seed=79)
        rt.open(spec)
        wave = _wave(83, 400)
        chunks = list(chop(wave, 220, seed=6))
        rt.submit("sleeper", chunks[0]).result(timeout=60)   # warm-up
        for c in chunks[1:]:
            rt.submit("sleeper", c)
        got = rt.close("sleeper")
        np.testing.assert_array_equal(got, _offline(spec, wave))
        assert rt.recovery_stats.deadline_timeouts >= 1
        assert rt.recovery_stats.sessions_poisoned == 0


# ---------------------------------------------------------------------------
# graceful degradation
# ---------------------------------------------------------------------------

def test_degradation_shrinks_sheds_lowest_priority_and_restores():
    cfg = StragglerConfig(warmup_steps=2, patience=2, sigma_factor=3.0)
    with AsyncServeRuntime(BatchPolicy(max_batch=8, max_wait_s=1e9),
                           straggler=cfg, degrade_on_slow=True) as rt:
        rt.open(_spec("vip", "fused_fp32", seed=89, priority=5))
        rt.open(_spec("best-effort", "fused_fp32", seed=97, priority=0))
        ctl = rt.degradation
        step = 0
        with rt._lock:
            for _ in range(6):                     # warmup + baseline
                ctl.observe(step, 0.01)
                step += 1
            for _ in range(2):                     # persistent slowness
                ctl.observe(step, 1.0)
                step += 1
        assert ctl.degraded
        assert rt.batcher.policy.max_batch == 4
        assert ctl.shed_ids == ["best-effort"]     # lowest priority first
        with pytest.raises(TenantShedError):
            rt.submit("best-effort", np.zeros(300, np.float32))
        rt.submit("vip", _wave(101, 100))          # VIP keeps serving
        with rt._lock:
            for _ in range(2):                     # health returns
                ctl.observe(step, 0.01)
                step += 1
        assert not ctl.degraded
        assert rt.batcher.policy.max_batch == 8
        assert not rt.sessions.get("best-effort").shed
        rt.submit("best-effort", _wave(103, 80))   # readmitted
        rt.drain()


# ---------------------------------------------------------------------------
# the ISSUE-6 acceptance sweep: all four fault kinds, 6 tenants, bitwise
# ---------------------------------------------------------------------------

def test_chaos_sweep_six_tenants_all_fault_kinds_bitwise_zero_loss():
    """6 tenants across fused_fp32 + fused_int8 under a FaultPlan that
    injects launch errors (terminal pair), a launch delay, an engine-build
    failure, and output corruption. Every submitted chunk must be emitted
    exactly ONCE (stream lengths match offline) and bitwise-equal to
    offline equalization; no session may be poisoned."""
    fp = FaultPlan([
        Fault("launch_delay", 1, delay_s=0.05),
        Fault("launch_error", 2), Fault("launch_error", 3),  # terminal
        Fault("corrupt", 5, mode="saturate"),
        Fault("build_error", 6),     # builds 0-5 are the opens → 6 is the
    ])                               # first failover rebuild
    backends = ["fused_fp32", "fused_int8"]
    specs = [_spec(f"t{i}", backends[i % 2], seed=200 + i, priority=i)
             for i in range(6)]
    # streams must exceed one kernel tile (tile_m · v_parallel symbols) —
    # below that the offline reference legally shrinks its tile and the
    # contract is ~1 ULP, not bitwise (see chunker module docstring)
    waves = {s.tenant_id: _wave(300 + i, 280 + 16 * i)
             for i, s in enumerate(specs)}
    with AsyncServeRuntime(BatchPolicy(max_batch=3, max_wait_s=1e9),
                           launch_retries=1, fault_plan=fp) as rt:
        for s in specs:
            rt.open(s)
        streams = {t: iter(chop(w, 120 * CFG.n_os, seed=i, jitter=0.5))
                   for i, (t, w) in enumerate(sorted(waves.items()))}
        futs = []
        live = set(streams)
        while live:
            for t in sorted(live):
                c = next(streams[t], None)
                if c is None:
                    live.discard(t)
                    futs.append(rt.finish(t))
                else:
                    futs.append(rt.submit(t, c))
        rt.drain()
        for f in futs:
            if f is not None:
                assert np.isfinite(f.result(timeout=60)).all()
        for s in specs:
            got = rt.output(s.tenant_id)
            want = _offline(s, waves[s.tenant_id])
            assert got.shape == want.shape         # exactly-once emission
            np.testing.assert_array_equal(got, want)
        st = rt.stats()
        assert fp.pending == 0, f"unfired faults: {fp.summary()}"
        assert set(fp.summary()) == {"launch_error", "launch_delay",
                                     "corrupt", "build_error"}
        assert st["recovery"]["recoveries"] >= 1
        assert st["recovery"]["chunks_replayed"] >= 1
        assert st["recovery"]["sessions_poisoned"] == 0
