"""mixtral-8x22b — sparse MoE with sliding-window attention
[arXiv:2401.04088; hf].

56L · d_model 6144 · 48 heads (GQA kv=8) · d_ff 16384 · vocab 32768 ·
8 experts top-2 · SWA window 4096.
Sharding note: 8 experts do not divide the 16-way model axis — experts
replicate and d_ff is TP-sharded instead (sharding.py fallback; moonshot
takes the EP16 path). SWA ⇒ finite receptive field ⇒ long_500k RUNS with an
O(window) ring cache (the paper's bounded-receptive-field insight).
"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=32768,
    n_experts=8, top_k=2, window=4096,
    tp=16, train_accum=16, moe_group=2048,
    serve_fsdp=True,     # 280 GB bf16 params need 2-D sharding at serve time
)

REDUCED = ModelConfig(
    name="mixtral-reduced", family="moe",
    n_layers=3, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab=512, n_experts=4, top_k=2, window=32,
    moe_group=64, dtype="float32",
)
