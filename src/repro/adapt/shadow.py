"""Shadow evaluation + hysteresis-guarded promotion decisions.

A fine-tuned candidate must EARN its way into the live stream: the shadow
evaluator runs candidate and active engines over the collector's held-out
traffic (data the fine-tuner never saw) and compares BERs against the
buffered labels. Promotion requires a hysteresis-guarded win — a relative
AND absolute BER margin — so label noise and eval variance cannot cause
swap thrash; the same comparison, pointed at the pre-swap engine, decides
rollback when a promotion turns out to have been a mistake.

The engines evaluated here are the REAL deployed artifacts (the candidate
is built through the same pinned-formats `TenantSpec` path the hot-swap
installs), so the decision sees exactly the quantized datapath the stream
would get — including any int8 saturation the fine-tune introduced.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from .collector import hard_decide


@dataclasses.dataclass(frozen=True)
class PromotionPolicy:
    """Hysteresis knobs for the promote/rollback decisions.

    min_eval_syms:   refuse to decide on fewer held-out symbols (default
                     2048 — below this, a BER estimate at the interesting
                     1e-2..1e-1 range has too few error events).
    min_rel_gain:    candidate BER must undercut active by this fraction
                     (default 0.15 — the hysteresis band; within it the
                     active weights stay, preventing swap thrash on noise).
    min_abs_gain:    …and by this absolute BER (default 2e-3 — two engines
                     both at ~0 BER never swap).
    eval_bucket_syms: evaluation streams are trimmed to a multiple of this
                     (default 1024) so eval launches reuse a tiny set of
                     compiled shapes (each fresh shape costs ~175 ms of XLA
                     compile on interpret-mode hosts).
    max_eval_syms:   cap on evaluation length (default 8192) — bounds the
                     per-cycle eval cost as the buffer grows.
    """
    min_eval_syms: int = 2048
    min_rel_gain: float = 0.15
    min_abs_gain: float = 2e-3
    eval_bucket_syms: int = 1024
    max_eval_syms: int = 8192


@dataclasses.dataclass
class ShadowReport:
    """Outcome of one candidate-vs-active shadow evaluation."""
    ber_active: float
    ber_candidate: float
    eval_syms: int
    promote: bool
    reason: str


def engine_ber(engine, rx: np.ndarray, syms: np.ndarray) -> float:
    """BER of an `EqualizerEngine` over a labelled waveform.

    Trims to whole engine passes (total_stride samples each); labels are
    whatever the collector stored (pilot or decision-directed), so with
    decision labels this measures DISAGREEMENT with the labelling
    equalizer rather than true BER — still the right promotion signal,
    since both engines are scored against the same labels.
    """
    ts = engine.total_stride
    vp = engine.cfg.v_parallel
    n_pos = int(rx.shape[0]) // ts
    if n_pos == 0:
        return float("nan")
    rx = rx[: n_pos * ts]
    want = np.asarray(syms[: n_pos * vp])
    y = np.asarray(engine(jnp.asarray(rx[None], jnp.float32)))[0]
    got = hard_decide(y, engine.cfg.levels)
    return float(np.mean(got != want[: got.shape[0]]))


def _trim(rx: np.ndarray, syms: np.ndarray, n_os: int,
          policy: PromotionPolicy):
    """Apply the eval-length bucket + cap (compile-shape hygiene)."""
    n = min(int(syms.shape[0]), int(rx.shape[0]) // n_os,
            policy.max_eval_syms)
    n = (n // policy.eval_bucket_syms) * policy.eval_bucket_syms
    return rx[: n * n_os], syms[:n], n


def shadow_evaluate(active_engine, candidate_engine, rx: np.ndarray,
                    syms: np.ndarray,
                    policy: PromotionPolicy = PromotionPolicy()
                    ) -> ShadowReport:
    """Score candidate vs active on held-out traffic; decide promotion.

    Promotion fires only on a hysteresis-guarded win (see
    `PromotionPolicy`); everything else — insufficient data, a tie, a
    loss — keeps the active weights, with the reason recorded.
    """
    n_os = active_engine.cfg.n_os
    rx, syms, n = _trim(rx, syms, n_os, policy)
    if n < policy.min_eval_syms:
        return ShadowReport(float("nan"), float("nan"), n, False,
                            f"insufficient eval data ({n} syms < "
                            f"{policy.min_eval_syms})")
    ber_a = engine_ber(active_engine, rx, syms)
    ber_c = engine_ber(candidate_engine, rx, syms)
    margin = max(policy.min_rel_gain * ber_a, policy.min_abs_gain)
    if ber_c <= ber_a - margin:
        return ShadowReport(ber_a, ber_c, n, True,
                            f"candidate wins by {ber_a - ber_c:.2e} "
                            f"(margin {margin:.2e})")
    return ShadowReport(ber_a, ber_c, n, False,
                        f"within hysteresis band (active {ber_a:.2e}, "
                        f"candidate {ber_c:.2e}, margin {margin:.2e})")
