"""Jitted wrappers for the quantization kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .quant import fixed_point_quantize as quantize_pallas
from .ref import fixed_point_quantize as quantize_ref


def quantize_params(params, qparams, use_pallas: bool = True):
    """Quantize a whole equalizer parameter tree with its learned widths."""
    fn = quantize_pallas if use_pallas else quantize_ref
    out = {"conv": []}
    for i, layer in enumerate(params["conv"]):
        q = qparams[f"layer{i}"]
        out["conv"].append({
            "w": fn(layer["w"], q["w_int"], q["w_frac"]),
            "b": fn(layer["b"], q["w_int"], q["w_frac"]),
        })
    return out


__all__ = ["quantize_pallas", "quantize_ref", "quantize_params"]
