"""Table 1 analogue + §Roofline — aggregate the dry-run JSONs into the
roofline table (one row per architecture × shape at 256 chips), identify
each cell's bottleneck, and emit the markdown table EXPERIMENTS.md embeds."""
from __future__ import annotations

import json
import pathlib

from .common import REPORT_DIR, Bench

DRYRUN_DIR = REPORT_DIR / "dryrun"


def load_cells(tag: str = "sp"):
    cells = []
    for f in sorted(DRYRUN_DIR.glob(f"*_{tag}.json")):
        d = json.loads(f.read_text())
        cells.append(d)
    return cells


def markdown_table(cells) -> str:
    hdr = ("| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
           "bottleneck | useful/HLO | MFU@roof | fits HBM |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for c in cells:
        if c.get("status") == "skipped":
            rows.append(f"| {c['arch']} | {c['shape']} | — | — | — | "
                        f"skipped | — | — | — |")
            continue
        if c.get("status") != "ok":
            rows.append(f"| {c['arch']} | {c['shape']} | — | — | — | "
                        f"FAILED | — | — | — |")
            continue
        r = c["roofline"]
        mem = c.get("memory", {})
        rows.append(
            f"| {c['arch']} | {c['shape']} "
            f"| {r['t_compute_s']*1e3:.2f} | {r['t_memory_s']*1e3:.2f} "
            f"| {r['t_collective_s']*1e3:.2f} | {r['bottleneck']} "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {r['mfu_at_roofline']*100:.1f}% "
            f"| {mem.get('fits_hbm', '—')} |")
    return hdr + "\n".join(rows)


def run() -> dict:
    bench = Bench("roofline_table", "Table 1 / §Roofline")
    cells = load_cells("sp")
    if not cells:
        print("[bench_roofline] no dry-run artifacts under", DRYRUN_DIR,
              "— run `python -m repro.launch.dryrun --all` first")
        bench.record("cells", 0)
        return bench.finish()
    ok = [c for c in cells if c.get("status") == "ok"]
    bench.record("n_cells", len(cells))
    bench.record("n_ok", len(ok))
    bench.record("n_skipped",
                 len([c for c in cells if c.get("status") == "skipped"]))
    table = markdown_table(cells)
    (REPORT_DIR / "roofline_table.md").write_text(table)
    bench.record("table_path", str(REPORT_DIR / "roofline_table.md"))

    # bottleneck census + hillclimb candidates
    census = {}
    for c in ok:
        b = c["roofline"]["bottleneck"]
        census[b] = census.get(b, 0) + 1
    bench.record("bottleneck_census", census)
    worst = min(ok, key=lambda c: c["roofline"]["mfu_at_roofline"]
                if c["kind"] == "train" else 1.0)
    most_coll = max(ok, key=lambda c: c["roofline"]["t_collective_s"]
                    / max(c["roofline"]["t_step_s"], 1e-12))
    bench.record("hillclimb_candidates", {
        "worst_mfu_train": f"{worst['arch']}×{worst['shape']}",
        "most_collective_bound": f"{most_coll['arch']}×{most_coll['shape']}",
    })
    print(f"[bench_roofline] {len(ok)} cells ok; census {census}")
    return bench.finish()


if __name__ == "__main__":
    run()
