from .ops import quantize_params
from .quant import fixed_point_quantize as quantize_pallas
from .ref import fixed_point_quantize as quantize_ref

__all__ = ["quantize_params", "quantize_pallas", "quantize_ref"]
