from .pipeline import PipelineConfig, TokenSource, lm_batches
from . import equalizer_data
