"""Tenant sessions — channel config + trained params + QAT formats → engine.

A TENANT is one equalized link (an optical channel, a magnetic-recording
head, …) with its own trained parameters and learned fixed-point formats.
A SESSION is a tenant's live streaming state: the overlap-save chunker
carry, output accumulator, and latency counters. Engines themselves live in
the LRU `EnginePool` (pool.py) and are rebuilt on demand after eviction —
sessions never pin one.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np

from ..core.engine import EqualizerEngine
from ..core.equalizer import CNNEqConfig
from .chunker import StreamChunker
from .pool import EnginePool


@dataclasses.dataclass
class TenantSpec:
    """Everything needed to (re)build a tenant's engine deterministically.

    Either trained `params` (+ optional bn_state; QAT formats picked up
    automatically → the auto backend ladder) or pre-folded `weights`
    (+ explicit formats for int8).
    """
    tenant_id: str
    cfg: CNNEqConfig
    params: Optional[Dict[str, Any]] = None
    bn_state: Optional[Dict[str, Any]] = None
    weights: Optional[tuple] = None
    formats: Optional[tuple] = None
    backend: str = "auto"
    tile_m: int | str = "auto"

    def build_engine(self) -> EqualizerEngine:
        if (self.params is None) == (self.weights is None):
            raise ValueError(
                f"tenant {self.tenant_id!r}: exactly one of params/weights")
        if self.params is not None:
            return EqualizerEngine.from_params(
                self.params, self.bn_state, self.cfg,
                backend=self.backend, tile_m=self.tile_m)
        return EqualizerEngine(cfg=self.cfg, weights=self.weights,
                               backend=self.backend, tile_m=self.tile_m,
                               formats=self.formats)


class Session:
    """One tenant's live stream state (engine NOT held — see pool)."""

    def __init__(self, spec: TenantSpec, pool: EnginePool):
        self.spec = spec
        self._pool = pool
        engine = self.engine                     # build once up front …
        self.chunker = StreamChunker(            # … to size the chunker
            halo=engine.halo_samples,
            total_stride=engine.total_stride,
            tile_m=engine.resolved_tile_m())
        self.v_parallel = engine.cfg.v_parallel
        self._out: List[np.ndarray] = []
        self.syms_emitted = 0

    @property
    def engine(self) -> EqualizerEngine:
        """Fetch (or rebuild after LRU eviction) this tenant's engine."""
        return self._pool.get(self.spec.tenant_id, self.spec.build_engine)

    def append_output(self, syms: np.ndarray) -> None:
        self._out.append(syms)
        self.syms_emitted += int(syms.shape[0])

    def output(self) -> np.ndarray:
        """All symbols emitted so far, in stream order."""
        if not self._out:
            return np.zeros((0,), np.float32)
        return np.concatenate(self._out)


class SessionManager:
    """tenant_id → Session registry over a shared LRU engine pool."""

    def __init__(self, pool: Optional[EnginePool] = None,
                 max_engines: int = 32):
        self.pool = pool if pool is not None else EnginePool(max_engines)
        self._sessions: Dict[str, Session] = {}

    def open(self, spec: TenantSpec) -> Session:
        if spec.tenant_id in self._sessions:
            raise ValueError(f"tenant {spec.tenant_id!r} already open")
        s = Session(spec, self.pool)
        self._sessions[spec.tenant_id] = s
        return s

    def get(self, tenant_id: str) -> Session:
        return self._sessions[tenant_id]

    def close(self, tenant_id: str) -> Session:
        s = self._sessions.pop(tenant_id)
        self.pool.drop(tenant_id)
        return s

    def __contains__(self, tenant_id: str) -> bool:
        return tenant_id in self._sessions

    def __len__(self) -> int:
        return len(self._sessions)

    @property
    def sessions(self) -> Dict[str, Session]:
        return dict(self._sessions)
