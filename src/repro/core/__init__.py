from . import dse, equalizer, fir, qat, seqlen_opt, stream_partition, timing_model, train_eq, volterra
from .equalizer import CNNEqConfig
from .fir import FIRConfig
from .qat import QATConfig
from .volterra import VolterraConfig

__all__ = [
    "dse", "equalizer", "fir", "qat", "seqlen_opt", "stream_partition",
    "timing_model", "train_eq", "volterra",
    "CNNEqConfig", "FIRConfig", "QATConfig", "VolterraConfig",
]
