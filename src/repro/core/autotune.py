"""tile_m autotuning for the fused equalizer kernels.

The paper's DOP knob (how many MACs the FPGA instantiates per layer) maps on
TPU to the fused kernel's sequence-tile width `tile_m`: it sets how much of
the MXU's 128-lane axis each tap-matmul fills and how well the tile DMAs
overlap compute. The best value depends on the topology (receptive field →
halo overhead per tile) and on the backend (int8 tiles fit 4× more VMEM),
so DOP-style operating points (`equalizer_ht`, `equalizer_lp`) each get
their own sweep.

Results are cached twice:
  * in-process, keyed on (CNNEqConfig, backend, width-bucket), and
  * on disk (reports/autotune_tile_m.json), so benchmark runs and future
    sessions skip the sweep entirely.
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Callable, Dict, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp

from .equalizer import CNNEqConfig

DEFAULT_TILES: Tuple[int, ...] = (16, 32, 64, 128, 256)
CACHE_PATH = (pathlib.Path(__file__).resolve().parents[3]
              / "reports" / "autotune_tile_m.json")

_memory_cache: Dict[Tuple, int] = {}


def cache_key(cfg: CNNEqConfig, backend: str) -> Tuple:
    # platform is part of the key: an interpret-mode sweep on a CPU host
    # must not pin the tile choice for real TPU silicon (and vice versa)
    return (cfg.layers, cfg.kernel, cfg.channels, cfg.v_parallel, cfg.n_os,
            backend, jax.default_backend())


def _key_str(key: Tuple) -> str:
    l, k, c, vp, nos, backend, platform = key[:7]
    s = f"L{l}_K{k}_C{c}_Vp{vp}_Nos{nos}__{backend}__{platform}"
    if len(key) > 7:                   # batched-serving sweep (probe_batch>1)
        s += f"__B{key[7]}"
    if len(key) > 8:                   # serve-aware sweep: live-traffic width
        s += f"_S{key[8]}"
    return s


def _load_disk() -> Dict[str, int]:
    try:
        return json.loads(CACHE_PATH.read_text())
    except (OSError, ValueError):
        return {}


def _store_disk(key: Tuple, tile_m: int) -> None:
    data = _load_disk()
    data[_key_str(key)] = tile_m
    try:
        CACHE_PATH.parent.mkdir(parents=True, exist_ok=True)
        CACHE_PATH.write_text(json.dumps(data, indent=2, sort_keys=True))
    except OSError:
        pass                       # read-only checkout: in-memory cache only


def time_callable(fn: Callable[[jnp.ndarray], jnp.ndarray], x: jnp.ndarray,
                  iters: int = 3) -> float:
    """Mean seconds per call, compiling outside the timed region — the one
    timing methodology shared by the autotuner and the engine benchmarks."""
    y = fn(x)
    jax.block_until_ready(y)       # warm-up: compile outside the timed region
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(x))
    return (time.perf_counter() - t0) / iters


def best_tile_m(cfg: CNNEqConfig, backend: str,
                make_fn: Callable[[int], Callable[[jnp.ndarray], jnp.ndarray]],
                candidates: Optional[Iterable[int]] = None,
                probe_syms: int = 4096,
                use_disk: bool = True,
                probe_batch: int = 1) -> int:
    """Sweep tile_m candidates for (cfg, backend); return the fastest.

    make_fn(tile_m) must return a jit-able callable (B, W) → (B, S). The
    probe input is `probe_batch` rows of `probe_syms` symbols — long enough
    that every candidate runs multiple grid tiles. probe_batch > 1 models
    the multi-tenant serving shape (repro.serve stacks B tenant chunks per
    launch) and gets its own cache slot, keyed on BOTH the batch and the
    probe width — the best tile for one long stream is not necessarily best
    when B rows split VMEM, and the serve-aware re-tune
    (`repro.serve.runtime` `_serve_tile`) probes with the width observed in
    live traffic rather than the default.
    """
    if candidates is None:
        candidates = DEFAULT_TILES       # resolved at call time (testable)
    key = cache_key(cfg, backend)
    if probe_batch != 1:
        key = key + (probe_batch, probe_syms)
    if key in _memory_cache:
        return _memory_cache[key]
    if use_disk:
        hit = _load_disk().get(_key_str(key))
        if hit is not None:
            _memory_cache[key] = int(hit)
            return int(hit)

    x = jax.random.normal(jax.random.PRNGKey(0),
                          (probe_batch, probe_syms * cfg.n_os), jnp.float32)
    timings: Dict[int, float] = {}
    for tile_m in candidates:
        timings[int(tile_m)] = time_callable(make_fn(int(tile_m)), x)
    best = min(timings, key=timings.get)
    _memory_cache[key] = best
    if use_disk:
        _store_disk(key, best)
    return best


def clear_cache(disk: bool = False) -> None:
    _memory_cache.clear()
    if disk:
        try:
            CACHE_PATH.unlink()
        except OSError:
            pass
