"""Background fine-tuning from buffered traffic.

Wraps `repro.core.train_eq.fine_tune_equalizer` — the weight-only resume
of the QAT loop (frozen formats, quantized forward) — with the sampling
glue that turns a `SampleCollector` buffer into training batches: random
symbol-aligned windows over the buffered stream, labels mapped to PAM
amplitudes. The candidate parameters come back WITHOUT touching the live
stream; promotion is the shadow evaluator's call (`repro.adapt.shadow`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from ..core.train_eq import fine_tune_equalizer
from .collector import pam_amplitudes


@dataclasses.dataclass(frozen=True)
class FineTuneConfig:
    """Knobs for one background fine-tune round.

    steps:     optimizer steps per round (default 60 — rounds are meant to
               be cheap and frequent, not one big retrain).
    batch:     sequences per step.
    seq_syms:  symbols per training sequence; must be a multiple of the
               topology's V_p so the strided forward tiles cleanly
               (checked at sample time).
    lr:        AdamW learning rate — lower than from-scratch training
               (`EqTrainConfig.lr`): this is a warm start, and the labels
               may be decision-directed (label noise argues for small
               steps).
    """
    steps: int = 60
    batch: int = 8
    seq_syms: int = 256
    lr: float = 1e-3


def make_sample_fn(rx: np.ndarray, syms: np.ndarray, *, n_os: int,
                   levels: int, cfg: FineTuneConfig):
    """Batch sampler over a buffered stream: random symbol-aligned windows.

    rx:   (n·n_os,) buffered waveform, stream order.
    syms: (n,) label symbol indices aligned with rx.

    Returns sample_fn(key) → (xs (batch, seq·n_os), amps (batch, seq)) for
    `fine_tune_equalizer`. Window starts are arbitrary symbol offsets —
    the equalizer's forward is shift-equivariant at symbol granularity, so
    every offset is a valid training sequence.
    """
    n = int(min(syms.shape[0], rx.shape[0] // n_os))
    seq = cfg.seq_syms
    if n < seq + 1:
        raise ValueError(f"buffer too small: {n} syms < seq_syms={seq}+1")
    amps = pam_amplitudes(levels)[syms[:n]].astype(np.float32)

    def sample_fn(key: jax.Array) -> Tuple[np.ndarray, np.ndarray]:
        seed = int(jax.random.randint(key, (), 0, np.iinfo(np.int32).max))
        rng = np.random.default_rng(seed)
        offs = rng.integers(0, n - seq, size=cfg.batch)
        xs = np.stack([rx[o * n_os:(o + seq) * n_os] for o in offs])
        ys = np.stack([amps[o:o + seq] for o in offs])
        return xs, ys

    return sample_fn


def fine_tune_from_buffer(key: jax.Array, params: Dict[str, Any],
                          bn_state: Optional[Dict[str, Any]], model_cfg,
                          rx: np.ndarray, syms: np.ndarray,
                          cfg: FineTuneConfig = FineTuneConfig()):
    """One background fine-tune round over buffered traffic.

    Returns (candidate_params, candidate_bn_state, info). The inputs are
    never mutated — the caller's live params stay valid for rollback.
    """
    if cfg.seq_syms % model_cfg.v_parallel != 0:
        raise ValueError(
            f"seq_syms={cfg.seq_syms} must be a multiple of "
            f"V_p={model_cfg.v_parallel}")
    sample_fn = make_sample_fn(rx, syms, n_os=model_cfg.n_os,
                               levels=model_cfg.levels, cfg=cfg)
    return fine_tune_equalizer(key, params, bn_state, model_cfg, sample_fn,
                               steps=cfg.steps, lr=cfg.lr)
