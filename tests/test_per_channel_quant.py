"""Per-output-channel int8 weight scales (ROADMAP open item, ISSUE-5
satellite): `qat.per_channel_formats` + the per-channel rescale column in
the int8 kernel/oracle/engine.

Contracts:
  * refinement preserves each layer's learned TOTAL weight width and
    never widens the integer part past the learned grid;
  * the int8 Pallas kernel still matches the fake-quant oracle EXACTLY
    with per-channel formats (scalar formats stay exact too — same kernel
    body, the scale is just a uniform column);
  * per-channel grids strictly reduce weight-quantization error on layers
    whose channels have uneven ranges — the BER headroom the adaptation
    fine-tunes spend at aggressive QLFs;
  * engine deployment: per-channel formats deploy int8, group keys stay
    hashable, `_folded_fit_grid` checks each channel's own grid, and the
    wrap guard still fires when a channel's total width exceeds 8 bits.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import equalizer as eq
from repro.core import qat as qat_lib
from repro.core.engine import EqualizerEngine, _folded_fit_grid
from repro.kernels.cnn_eq import ref
from repro.kernels.cnn_eq.cnn_eq import (cnn_eq_fused_int8,
                                         quantize_weights_int8)

CFG = eq.CNNEqConfig()
STRIDES = eq.layer_strides(CFG)
SCALAR_FMT = tuple((2, 5, 3, 4) for _ in range(CFG.layers))


def _weights(seed=0):
    params = eq.init(jax.random.PRNGKey(seed), CFG)
    folded = eq.fold_bn(params, eq.init_bn_state(CFG), CFG)
    return eq.folded_weights(folded)


def test_per_channel_formats_preserve_learned_total_width():
    weights = _weights()
    pc = qat_lib.per_channel_formats(weights, SCALAR_FMT)
    assert len(pc) == CFG.layers
    for (wi, wf, ai, af), (swi, swf, sai, saf), (w, _) in zip(
            pc, SCALAR_FMT, weights):
        assert (ai, af) == (sai, saf)            # activations untouched
        wi_a, wf_a = np.asarray(wi), np.asarray(wf)
        # total magnitude bits preserved per channel; int part never wider
        np.testing.assert_array_equal(wi_a + wf_a, swi + swf)
        assert np.all(wi_a <= swi)
        if isinstance(wi, tuple):
            assert len(wi) == int(w.shape[0])
        assert qat_lib.format_max_bits(wi, wf) <= swi + swf + 1
    # refinement is deterministic (rebuild-after-evict contract)
    assert pc == qat_lib.per_channel_formats(weights, SCALAR_FMT)


@pytest.mark.parametrize("per_channel", [False, True])
def test_int8_kernel_matches_fake_quant_oracle_exactly(per_channel):
    weights = _weights()
    fmt = (qat_lib.per_channel_formats(weights, SCALAR_FMT)
           if per_channel else SCALAR_FMT)
    qw = quantize_weights_int8(weights, fmt)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 192 * CFG.n_os),
                          jnp.float32)
    y_kernel = cnn_eq_fused_int8(x, qw, STRIDES, fmt, tile_m=32)
    y_oracle = ref.cnn_eq_quant(x, weights, STRIDES, fmt)
    np.testing.assert_array_equal(np.asarray(y_kernel),
                                  np.asarray(y_oracle))


def test_per_channel_grids_reduce_weight_quant_error():
    """On a net whose folded channel ranges are uneven (BN-fold gains make
    them so), per-channel scales must strictly reduce the total weight
    quantization error — the whole point of the refinement."""
    weights = _weights(seed=3)
    pc = qat_lib.per_channel_formats(weights, SCALAR_FMT)
    assert any(isinstance(f[0], tuple) for f in pc), "nothing refined"

    def quant_err(fmt):
        err = 0.0
        for (w, _), (wi, wf, _, _) in zip(weights, fmt):
            wi_c = np.asarray(wi, np.float32).reshape(-1, 1, 1)
            wf_c = np.asarray(wf, np.float32).reshape(-1, 1, 1)
            scale = np.exp2(wf_c)
            hi = np.exp2(wi_c) - 1.0 / scale
            lo = -np.exp2(wi_c)
            wq = np.clip(np.round(np.asarray(w) * scale) / scale, lo, hi)
            err += float(np.sum((wq - np.asarray(w)) ** 2))
        return err
    assert quant_err(pc) < quant_err(SCALAR_FMT)


def test_engine_deploys_per_channel_formats():
    weights = _weights()
    pc = qat_lib.per_channel_formats(weights, SCALAR_FMT)
    e = EqualizerEngine(cfg=CFG, weights=weights, backend="fused_int8",
                        formats=pc, tile_m=32)
    assert isinstance(hash(e.group_key()), int)       # stays hashable
    assert _folded_fit_grid(weights, pc)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 128 * CFG.n_os),
                          jnp.float32)
    y = e(x)
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(ref.cnn_eq_quant(x, weights, STRIDES, pc)))


def test_from_params_per_channel_auto_deploys_int8():
    params = eq.init(jax.random.PRNGKey(5), CFG)
    params["qat"] = {f"layer{i}": {"w_int": jnp.asarray(2.0),
                                   "w_frac": jnp.asarray(5.0),
                                   "a_int": jnp.asarray(3.0),
                                   "a_frac": jnp.asarray(4.0)}
                     for i in range(CFG.layers)}
    e = EqualizerEngine.from_params(params, eq.init_bn_state(CFG), CFG,
                                    backend="auto", tile_m=32,
                                    per_channel=True)
    assert e.backend == "fused_int8"
    assert any(isinstance(f[0], tuple) for f in e.formats)


def test_per_channel_fit_grid_checks_each_channels_own_grid():
    weights = _weights()
    pc = qat_lib.per_channel_formats(weights, SCALAR_FMT)
    # inflate ONE channel past ITS narrowed grid (still inside the layer's
    # scalar grid): the per-channel check must catch it
    wi0 = np.asarray(pc[0][0]).reshape(-1)
    c = int(np.argmin(wi0))
    if wi0[c] < SCALAR_FMT[0][0]:                 # a genuinely narrowed ch
        w0, b0 = weights[0]
        bad = np.asarray(w0).copy()
        bad[c, 0, 0] = 2.0 ** int(wi0[c]) + 0.5   # > its channel grid
        bad_weights = ((jnp.asarray(bad), b0),) + tuple(weights[1:])
        assert _folded_fit_grid(bad_weights, SCALAR_FMT)
        assert not _folded_fit_grid(bad_weights, pc)


def test_int8_wrap_guard_fires_on_wide_per_channel_format():
    weights = _weights()
    c_out = int(weights[0][0].shape[0])
    wide = ((tuple([3] * c_out), tuple([5] * c_out), 3, 4),) \
        + SCALAR_FMT[1:]                          # 3+5+1 = 9 bits > int8
    with pytest.raises(ValueError, match="int8"):
        quantize_weights_int8(weights, wide)
    qw = quantize_weights_int8(weights, SCALAR_FMT)
    x = jnp.zeros((1, 64 * CFG.n_os), jnp.float32)
    with pytest.raises(ValueError, match="wrap"):
        cnn_eq_fused_int8(x, qw, STRIDES, wide, tile_m=16)
