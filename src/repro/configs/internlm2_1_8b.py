"""internlm2-1.8b — dense GQA transformer [arXiv:2403.17297; hf].

24L · d_model 2048 · 16 heads (GQA kv=8) · d_ff 8192 · vocab 92544.
"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b", family="dense",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab=92544,
    tp=16, train_accum=4,
)

REDUCED = ModelConfig(
    name="internlm2-reduced", family="dense",
    n_layers=3, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab=512, dtype="float32",
)
