"""Shared DSP building blocks for the simulated communication channels.

Everything is pure JAX so channel simulation can be jitted, vmapped and run
on-device as part of the data pipeline (`repro.data.equalizer_data`).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Symbol mapping
# ---------------------------------------------------------------------------

def pam_constellation(levels: int) -> jnp.ndarray:
    """Gray-free PAM-`levels` constellation, unit average power."""
    pts = jnp.arange(levels, dtype=jnp.float32)
    pts = 2.0 * pts - (levels - 1)
    pts = pts / jnp.sqrt(jnp.mean(pts**2))
    return pts


def bits_to_pam(bits: jnp.ndarray, levels: int = 2) -> jnp.ndarray:
    """Map integer symbols in [0, levels) to PAM amplitudes."""
    return pam_constellation(levels)[bits]


def pam_decision(y: jnp.ndarray, levels: int = 2) -> jnp.ndarray:
    """Hard decision: nearest constellation point, returns symbol indices."""
    const = pam_constellation(levels)
    d = jnp.abs(y[..., None] - const[None, :] if y.ndim == 1 else
                y[..., None] - const)
    return jnp.argmin(d, axis=-1)


# ---------------------------------------------------------------------------
# Pulse shaping
# ---------------------------------------------------------------------------

def rrc_taps(n_taps: int, beta: float, sps: int) -> np.ndarray:
    """Root-raised-cosine filter taps (numpy; built once at trace time)."""
    assert n_taps % 2 == 1, "use an odd number of taps"
    t = (np.arange(n_taps) - (n_taps - 1) / 2) / sps
    taps = np.zeros_like(t)
    for i, ti in enumerate(t):
        if abs(ti) < 1e-9:
            taps[i] = 1.0 - beta + 4 * beta / np.pi
        elif beta > 0 and abs(abs(ti) - 1 / (4 * beta)) < 1e-9:
            taps[i] = (beta / np.sqrt(2)) * (
                (1 + 2 / np.pi) * np.sin(np.pi / (4 * beta))
                + (1 - 2 / np.pi) * np.cos(np.pi / (4 * beta)))
        else:
            num = (np.sin(np.pi * ti * (1 - beta))
                   + 4 * beta * ti * np.cos(np.pi * ti * (1 + beta)))
            den = np.pi * ti * (1 - (4 * beta * ti) ** 2)
            taps[i] = num / den
    taps = taps / np.sqrt(np.sum(taps**2))
    return taps.astype(np.float32)


def rc_taps(n_taps: int, beta: float, sps: int) -> np.ndarray:
    """Raised-cosine filter taps."""
    assert n_taps % 2 == 1
    t = (np.arange(n_taps) - (n_taps - 1) / 2) / sps
    taps = np.sinc(t) * np.cos(np.pi * beta * t)
    den = 1.0 - (2.0 * beta * t) ** 2
    # limit at the singular points
    sing = np.abs(den) < 1e-8
    taps = np.where(sing, (np.pi / 4) * np.sinc(1 / (2 * beta)), taps / np.where(sing, 1.0, den))
    taps = taps / np.max(np.abs(taps))
    return taps.astype(np.float32)


def upsample(x: jnp.ndarray, sps: int) -> jnp.ndarray:
    """Insert sps-1 zeros between samples (expander)."""
    out = jnp.zeros((x.shape[0] * sps,), dtype=x.dtype)
    return out.at[::sps].set(x)


def fir_same(x: jnp.ndarray, taps: jnp.ndarray) -> jnp.ndarray:
    """'same'-mode FIR filtering of a 1-D sequence."""
    k = taps.shape[0]
    pad = k // 2
    xp = jnp.pad(x, (pad, k - 1 - pad))
    return jnp.convolve(xp, taps, mode="valid")


# ---------------------------------------------------------------------------
# Noise
# ---------------------------------------------------------------------------

def awgn(key: jax.Array, x: jnp.ndarray, snr_db: float,
         signal_power: float | None = None) -> jnp.ndarray:
    """Add white Gaussian noise at the given SNR (per-sample, real signal)."""
    p_sig = jnp.mean(x**2) if signal_power is None else signal_power
    p_noise = p_sig / (10.0 ** (snr_db / 10.0))
    return x + jnp.sqrt(p_noise) * jax.random.normal(key, x.shape, x.dtype)


# ---------------------------------------------------------------------------
# BER
# ---------------------------------------------------------------------------

def ber(pred_syms: jnp.ndarray, true_syms: jnp.ndarray,
        bits_per_sym: int = 1) -> jnp.ndarray:
    """Symbol-error-based BER (PAM2 ⇒ symbol errors == bit errors)."""
    errs = jnp.sum(pred_syms != true_syms)
    return errs / (pred_syms.size * bits_per_sym)


@functools.partial(jax.jit, static_argnames=("levels",))
def ber_from_soft(y: jnp.ndarray, true_syms: jnp.ndarray, levels: int = 2):
    return ber(pam_decision(y, levels), true_syms)
