"""Per-kernel validation: Pallas (interpret mode on CPU) vs pure-jnp oracle,
swept over shapes and dtypes, plus equivalence to the core (training-time)
modules on the stream interior."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import equalizer as eq
from repro.core import qat as qat_lib
from repro.core import volterra as vol_core
from repro.kernels.cnn_eq import ops as cnn_ops
from repro.kernels.cnn_eq import ref as cnn_ref
from repro.kernels.cnn_eq.cnn_eq import cnn_eq_fused
from repro.kernels.conv1d import ref as c1_ref
from repro.kernels.conv1d.conv1d import conv1d as conv1d_pallas
from repro.kernels.quant import ops as q_ops
from repro.kernels.volterra import ops as v_ops

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# conv1d
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("batch,c_in,c_out,width,kernel,stride", [
    (1, 1, 5, 128, 9, 8),          # equalizer layer 1
    (2, 5, 5, 256, 9, 1),          # mid layer
    (2, 5, 8, 254, 9, 2),          # output layer, non-tile-aligned width
    (1, 3, 7, 64, 15, 4),
    (4, 2, 2, 33, 3, 1),           # tiny odd width
    (1, 1, 1, 512, 21, 2),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_conv1d_vs_ref(batch, c_in, c_out, width, kernel, stride, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    x = jax.random.normal(k1, (batch, c_in, width), dtype)
    w = jax.random.normal(k2, (c_out, c_in, kernel), dtype) * 0.3
    b = jax.random.normal(k3, (c_out,), dtype)
    got = conv1d_pallas(x, w, b, stride, tile_w=64, interpret=True)
    want = c1_ref.conv1d(x, w, b, stride)
    assert got.shape == want.shape
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_conv1d_tile_sweep():
    """Result must be invariant to the BlockSpec tile choice (the DOP knob)."""
    x = jax.random.normal(KEY, (2, 5, 300), jnp.float32)
    w = jax.random.normal(KEY, (5, 5, 9), jnp.float32) * 0.2
    b = jnp.zeros((5,))
    ref = c1_ref.conv1d(x, w, b, 1)
    for tile in (8, 32, 128, 512):
        got = conv1d_pallas(x, w, b, 1, tile_w=tile, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# fused CNN equalizer
# ---------------------------------------------------------------------------

def _folded(cfg, key=KEY):
    params = eq.init(key, cfg)
    bn = eq.init_bn_state(cfg)
    # randomize BN state so folding is non-trivial
    bn = {"bn": [{"mean": 0.1 * jax.random.normal(key, s["mean"].shape),
                  "var": 1.0 + 0.5 * jax.random.uniform(key, s["var"].shape)}
                 for s in bn["bn"]]}
    return params, bn, eq.fold_bn(params, bn, cfg)


@pytest.mark.parametrize("cfg", [
    eq.CNNEqConfig(),                                       # paper operating pt
    eq.CNNEqConfig(layers=4, kernel=15, channels=4, v_parallel=4),
    eq.CNNEqConfig(layers=3, kernel=21, channels=3, v_parallel=2),
    eq.CNNEqConfig(layers=5, kernel=9, channels=5, v_parallel=16),
])
def test_cnn_eq_fused_vs_ref(cfg):
    _, _, folded = _folded(cfg)
    weights = cnn_ops.weights_of(folded)
    strides = cnn_ops.strides_of(cfg)
    x = jax.random.normal(KEY, (2, 64 * cfg.v_parallel * cfg.n_os))
    got = cnn_eq_fused(x, weights, strides, tile_m=16, interpret=True)
    want = cnn_ref.cnn_eq(x, weights, strides)
    assert got.shape == want.shape == (2, x.shape[1] // cfg.n_os)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_cnn_eq_fused_matches_core_on_interior():
    """Kernel (stream semantics) == core apply_folded (SAME padding) away
    from the edges — the overlap region the paper's OGM/ORM discards."""
    cfg = eq.CNNEqConfig()
    params, bn, folded = _folded(cfg)
    x = jax.random.normal(KEY, (1, 2048 * cfg.n_os))
    y_kernel = cnn_ops.equalize(params, bn, x, cfg, use_pallas=True,
                                tile_m=32)
    y_core = eq.apply_folded(folded, x, cfg)
    o = cfg.receptive_field_syms
    np.testing.assert_allclose(np.asarray(y_kernel)[:, o:-o],
                               np.asarray(y_core)[:, o:-o],
                               rtol=2e-4, atol=2e-4)


def test_cnn_eq_tile_invariance():
    cfg = eq.CNNEqConfig()
    _, _, folded = _folded(cfg)
    weights = cnn_ops.weights_of(folded)
    strides = cnn_ops.strides_of(cfg)
    x = jax.random.normal(KEY, (1, 4096))
    outs = [cnn_eq_fused(x, weights, strides, tile_m=t, interpret=True)
            for t in (8, 64, 256)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# quantization kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(128,), (5, 64), (3, 5, 33)])
@pytest.mark.parametrize("ib,fb", [(2.0, 6.0), (4.0, 9.0), (1.0, 1.0)])
def test_quant_vs_ref(shape, ib, fb):
    x = 8.0 * jax.random.normal(KEY, shape)
    got = q_ops.quantize_pallas(x, jnp.asarray(ib), jnp.asarray(fb),
                                interpret=True)
    want = q_ops.quantize_ref(x, jnp.asarray(ib), jnp.asarray(fb))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=0)
    core = qat_lib.quantize_fixed(x, jnp.asarray(ib), jnp.asarray(fb))
    np.testing.assert_allclose(np.asarray(got), np.asarray(core),
                               rtol=0, atol=1e-7)


# ---------------------------------------------------------------------------
# volterra kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m1,m2,m3", [(25, 9, 0), (9, 3, 3), (15, 0, 0),
                                      (41, 15, 9)])
def test_volterra_vs_ref(m1, m2, m3):
    cfg = vol_core.VolterraConfig(m1=m1, m2=m2, m3=m3)
    params = vol_core.init(KEY, cfg)
    # make the nonlinear kernels non-trivial
    if "w2" in params:
        params["w2"] = 0.1 * jax.random.normal(KEY, params["w2"].shape)
    if "w3" in params:
        params["w3"] = 0.05 * jax.random.normal(KEY, params["w3"].shape)
    x = jax.random.normal(KEY, (2, 256))
    got = v_ops.equalize(params, x, cfg, use_pallas=True, tile=32)
    want = v_ops.equalize(params, x, cfg, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_volterra_matches_core_on_interior():
    cfg = vol_core.VolterraConfig(m1=9, m2=5, m3=0)
    params = vol_core.init(KEY, cfg)
    params["w2"] = 0.1 * jax.random.normal(KEY, (5, 5))
    x = jax.random.normal(KEY, (1, 512))
    y_k = v_ops.equalize(params, x, cfg, use_pallas=True)
    y_c = vol_core.apply(params, x, cfg)
    o = max(cfg.m1, cfg.m2) // 2 + 1
    np.testing.assert_allclose(np.asarray(y_k)[:, o:-o],
                               np.asarray(y_c)[:, o:-o], rtol=1e-4,
                               atol=1e-4)
