"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see 1 device; multi-device tests spawn subprocesses with their own flags."""
import os
import subprocess
import sys
import textwrap

import pytest


@pytest.fixture(scope="session")
def repo_src():
    return os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")


@pytest.fixture
def loopback_wire():
    """Factory for deterministic impaired loopback transport pairs — the
    shared wire every packetized-subsystem test drives (tests/test_net.py
    today; multi-host fleet RPC is the ROADMAP follow-on).

    make(seed=0, reorder_window=0, dup_prob=0.0, drop_idx=(),
         impair_both=True) -> (client_end, server_end): the client→server
    direction runs the seeded `WireSchedule`; with impair_both the
    server→client direction runs it too under seed+1. Endpoints are
    closed at teardown."""
    from repro.net.transport import WireSchedule, loopback_pair
    made = []

    def make(seed: int = 0, reorder_window: int = 0, dup_prob: float = 0.0,
             drop_idx=(), drop_prob: float = 0.0, impair_both: bool = True):
        fwd = WireSchedule(seed=seed, reorder_window=reorder_window,
                           dup_prob=dup_prob, drop_idx=drop_idx,
                           drop_prob=drop_prob)
        back = (WireSchedule(seed=seed + 1, reorder_window=reorder_window,
                             dup_prob=dup_prob)
                if impair_both else None)
        client_end, server_end = loopback_pair(fwd, back)
        made.extend((client_end, server_end))
        return client_end, server_end

    yield make
    for t in made:
        t.close()


def run_subprocess_devices(code: str, n_devices: int, repo_src: str,
                           timeout: int = 600) -> str:
    """Run `code` in a fresh python with n_devices host CPU devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = repo_src
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         env=env, capture_output=True, text=True,
                         timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout
