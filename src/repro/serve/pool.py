"""Keyed, LRU-bounded engine pool — the session manager's memory bound.

Millions of tenants cannot all keep a live `EqualizerEngine` (folded fp32
weights + backend-specific quantized copies) resident. The pool holds at
most `max_engines` built engines, keyed by tenant identity; a hit refreshes
recency, a miss builds via the caller-supplied factory and evicts the least
recently used entry. Evicting an engine loses NO stream state — chunker
carries live in the `Session`, and the factory rebuilds the engine
deterministically from the tenant's spec (BN folding and weight
quantization are pure functions of the trained params).
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from typing import Any, Callable, Dict, Hashable, Optional


class EnginePool:
    """LRU cache of built engines: key → engine.

    max_engines: resident-engine bound (count; default 32; must be ≥ 1 or
                 __init__ raises ValueError). Sizing note: one engine holds
                 folded fp32 weights plus a backend-specific quantized copy
                 (int8/bf16), so the bound is effectively a host-memory
                 knob. A bound smaller than the number of concurrently
                 ACTIVE tenants still works — engines rebuild on demand —
                 but turns steady-state traffic into rebuild churn
                 (`stats()["evictions"]` is the tell).

    Thread-safety: every operation is atomic under an internal lock — the
    async serving threads touch the pool under the runtime lock, but the
    online-adaptation thread (`repro.adapt`) reads engines outside it, so
    the pool must not rely on its callers for consistency. `get` builds on
    a miss OUTSIDE the lock (engine construction is pure but slow —
    BN fold, weight quantization, possibly an autotune sweep); two racing
    misses may both build, and the second build wins the slot — benign,
    deterministic engines are interchangeable.
    """

    def __init__(self, max_engines: int = 32):
        if max_engines < 1:
            raise ValueError("max_engines must be ≥ 1")
        self.max_engines = max_engines
        self._lock = threading.Lock()
        self._entries: "OrderedDict[Hashable, Any]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        # optional chaos hook (serve/recovery.py FaultPlan): `build_error`
        # faults are scheduled against the miss/build counter, so they hit
        # both session opens AND failover rebuilds deterministically
        self.fault_plan = None
        # optional observability hook (repro.obs): called as
        # build_hook(key, build_seconds) after every successful miss-build,
        # outside the pool lock — runtimes use it to record engine
        # build/compile events as trace instants + a build-time histogram
        self.build_hook: Optional[Callable[[Hashable, float], None]] = None
        self.clock: Callable[[], float] = time.perf_counter

    def get(self, key: Hashable, build: Callable[[], Any]) -> Any:
        """Return the cached engine for `key`, building (and possibly
        evicting the LRU entry) on a miss. An installed `fault_plan` may
        fail the build at its scheduled build index — the exception
        propagates to the caller exactly like a real build failure."""
        with self._lock:
            if key in self._entries:
                self.hits += 1
                self._entries.move_to_end(key)
                return self._entries[key]
            idx = self.misses
            self.misses += 1
        if self.fault_plan is not None:
            self.fault_plan.on_build(idx)
        t0 = self.clock()
        engine = build()                   # slow: outside the lock
        if self.build_hook is not None:
            self.build_hook(key, self.clock() - t0)
        with self._lock:
            self._entries[key] = engine
            if len(self._entries) > self.max_engines:
                self._entries.popitem(last=False)      # evict LRU
                self.evictions += 1
        return engine

    def __contains__(self, key: Hashable) -> bool:     # no recency touch
        with self._lock:
            return key in self._entries

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def drop(self, key: Hashable) -> None:
        with self._lock:
            self._entries.pop(key, None)

    def clear(self) -> None:
        """Drop every entry (fleet worker death: the dead device's built
        engines are garbage; sessions rebuild on their new worker's pool).
        Hit/miss/eviction counters are preserved — they describe history,
        not contents."""
        with self._lock:
            self._entries.clear()

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"size": len(self._entries),
                    "max_engines": self.max_engines,
                    "hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions}
