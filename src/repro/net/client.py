"""NetClient — the sender/receiver half of the wire (tester, loadgen, or
a real application front-end).

Per tenant it keeps the transmit discipline the gateway's ingress
expects (monotone DATA seqs, EOS trailer, a credit-bounded in-flight
window fed by the server's cumulative CREDIT grants) and reassembles the
egress symbol stream through its own bounded `Reassembler` — the wire
back from the server crosses the same impaired transport, so symbol
frames can arrive reordered or duplicated too.

    client = NetClient(transport)
    client.attach("t0", wire_dtype=WireDtype.INT8, grid=(3, 4))
    client.send_samples("t0", wave_chunk)     # queues + flushes on credit
    client.finish("t0")
    while not client.done("t0"):
        client.poll(); gateway.step()
    syms = client.symbols("t0")               # bitwise vs offline

Control commands (`open`/`close`/`swap_weights`/... or raw `command`)
post a CTRL frame and poll until the matching ACK (the ack's seq echoes
the command's) — `ControlAckError` carries the server's typed error for
a rejected command.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Callable, Dict, List, Optional

import numpy as np

from .control import (Reg, pack_control, unpack_control, weights_to_arrays)
from .frame import (FrameError, FrameType, WireDtype, decode_frame,
                    encode_frame, encode_samples)
from .gateway import DEFAULT_REORDER_WINDOW, Reassembler


class ControlAckError(RuntimeError):
    """The server rejected a control command (the error ack's message)."""


class _ClientStream:
    def __init__(self, wire_dtype: WireDtype, grid):
        self.wire_dtype = wire_dtype
        self.grid = tuple(grid)
        self.tx_seq = 0
        self.sent = 0                   # DATA frames on the wire
        self.granted_total = 0          # max cumulative CREDIT seen
        self.backlog: deque = deque()   # encoded frames awaiting credit
        self.reasm = Reassembler(DEFAULT_REORDER_WINDOW)
        self.chunks: List[np.ndarray] = []
        self.eos_rx = False
        self.eos_tx = False
        self.nacks: List[str] = []


class NetClient:
    def __init__(self, transport, reorder_window: int =
                 DEFAULT_REORDER_WINDOW, tracing: bool = False,
                 clock: Callable[[], float] = time.perf_counter):
        self.transport = transport
        self.window = int(reorder_window)
        self.streams: Dict[str, _ClientStream] = {}
        self._acks: Dict[int, dict] = {}
        self._cmd_seq = 0
        self.decode_errors = 0
        # cross-wire trace propagation: with tracing on, every DATA frame
        # goes out as wire version 2 carrying (trace_id, clock()) so the
        # server's chunk spans can start at the CLIENT's send instant.
        # The clock must share the server tracer's base for the Chrome
        # lane to line up (both default to time.perf_counter in-process).
        self.tracing = bool(tracing)
        self.clock = clock
        self._trace_seq = 0

    # -- tenant attach / data path -------------------------------------------

    def attach(self, tenant: str, wire_dtype: WireDtype = WireDtype.FP32,
               grid=(0, 0), granted: int = 0) -> None:
        """Start a tenant's wire stream client-side (for tenants opened
        out-of-band; `open()` does this from the server's ack)."""
        if tenant not in self.streams:
            s = _ClientStream(wire_dtype, grid)
            s.reasm = Reassembler(self.window)
            s.granted_total = granted
            self.streams[tenant] = s

    def send_samples(self, tenant: str, samples: np.ndarray) -> None:
        """Queue one chunk as one DATA frame; flushes while credit lasts."""
        s = self.streams[tenant]
        if s.eos_tx:
            raise RuntimeError(f"tenant {tenant!r}: stream already finished")
        a_int, a_frac = s.grid
        payload = encode_samples(np.asarray(samples, np.float32),
                                 s.wire_dtype, a_int, a_frac)
        trace_id = None
        t_client = 0.0
        if self.tracing:
            self._trace_seq += 1
            trace_id = self._trace_seq
            t_client = self.clock()
        s.backlog.append(encode_frame(FrameType.DATA, tenant, s.tx_seq,
                                      payload, dtype=s.wire_dtype,
                                      a_int=a_int, a_frac=a_frac,
                                      trace_id=trace_id, t_client=t_client))
        s.tx_seq += 1
        self._flush(tenant, s)

    def finish(self, tenant: str) -> None:
        """Queue the EOS trailer (rides the data seq space, needs no
        credit — see the gateway's flow-control notes)."""
        s = self.streams[tenant]
        if not s.eos_tx:
            s.eos_tx = True
            s.backlog.append(encode_frame(FrameType.EOS, tenant, s.tx_seq))
            s.tx_seq += 1
            self._flush(tenant, s)

    def _flush(self, tenant: str, s: _ClientStream) -> None:
        while s.backlog:
            # The EOS frame is always the backlog tail (finish() is final)
            # and needs no credit: flush DATA while credit lasts, then the
            # trailing EOS unconditionally.
            if len(s.backlog) == 1 and s.eos_tx:
                self.transport.send(s.backlog.popleft())
                s.sent += 1
                continue
            if s.sent >= s.granted_total:
                break
            self.transport.send(s.backlog.popleft())
            s.sent += 1

    def credits(self, tenant: str) -> int:
        """DATA frames this tenant may still put on the wire right now."""
        s = self.streams[tenant]
        return max(0, s.granted_total - s.sent)

    def backlog(self, tenant: str) -> int:
        return len(self.streams[tenant].backlog)

    # -- receive path ---------------------------------------------------------

    def poll(self, max_datagrams: int = 64, timeout: float = 0.0) -> int:
        n = 0
        for _ in range(max_datagrams):
            data = self.transport.recv(timeout=timeout)
            if data is None:
                break
            n += 1
            try:
                f = decode_frame(data)
            except FrameError:
                self.decode_errors += 1
                continue
            s = self.streams.get(f.tenant)
            if f.ftype == FrameType.ACK:
                self._acks[f.seq] = unpack_control(f.payload)[0]
            elif s is None:
                continue
            elif f.ftype == FrameType.CREDIT:
                total = int.from_bytes(f.payload[:4], "little")
                s.granted_total = max(s.granted_total, total)
                self._flush(f.tenant, s)
            elif f.ftype == FrameType.NACK:
                s.nacks.append(f.payload.decode("utf-8", "replace"))
            elif f.ftype in (FrameType.DATA, FrameType.EOS):
                for g in s.reasm.offer(f.seq, f):
                    if g.ftype == FrameType.EOS:
                        s.eos_rx = True
                    else:
                        s.chunks.append(g.samples())
        return n

    def symbols(self, tenant: str) -> np.ndarray:
        """The reassembled egress symbol stream so far."""
        s = self.streams[tenant]
        if not s.chunks:
            return np.zeros((0,), np.float32)
        return np.concatenate(s.chunks)

    def done(self, tenant: str) -> bool:
        s = self.streams[tenant]
        return s.eos_rx and not s.backlog

    def errors(self, tenant: str) -> List[str]:
        return list(self.streams[tenant].nacks)

    # -- control commands -----------------------------------------------------

    def command(self, tenant: str, fields: dict, arrays=None, *,
                pump=None, max_rounds: int = 10_000) -> dict:
        """Post one CTRL frame and poll to its ACK. `pump` (optional
        callable) is invoked each round to advance an in-process server —
        pass `gateway.step` in single-threaded tests."""
        self._cmd_seq += 1
        cmd = self._cmd_seq
        self.transport.send(encode_frame(
            FrameType.CTRL, tenant, cmd, pack_control(fields, arrays)))
        for _ in range(max_rounds):
            if pump is not None:
                pump()
            self.poll(timeout=0.001)
            if cmd in self._acks:
                ack = self._acks.pop(cmd)
                if not ack.get("ok"):
                    raise ControlAckError(ack.get("error", "rejected"))
                return ack
        raise TimeoutError(f"no ack for control command {cmd}")

    def open(self, tenant: str, cfg, weights, *, formats=None,
             backend: str = "auto", tile_m="auto", per_channel: bool = False,
             priority: int = 0, credits: Optional[int] = None,
             wire_dtype: Optional[WireDtype] = None, pump=None) -> dict:
        """OPEN the tenant over the wire and attach its client stream on
        the granted credit window + int8 grid from the ack."""
        import dataclasses
        fields = {"reg": Reg.OPEN, "cfg": dataclasses.asdict(cfg),
                  "backend": backend, "tile_m": tile_m,
                  "per_channel": per_channel, "priority": priority}
        if formats is not None:
            fields["formats"] = [list(f) for f in formats]
        if credits is not None:
            fields["credits"] = credits
        ack = self.command(tenant, fields, weights_to_arrays(weights),
                           pump=pump)
        self.attach(tenant,
                    wire_dtype or WireDtype(ack["wire_dtype"]),
                    grid=(ack["a_int"], ack["a_frac"]),
                    granted=ack["granted"])
        return ack

    def close(self, tenant: str, pump=None) -> dict:
        ack = self.command(tenant, {"reg": Reg.CLOSE}, pump=pump)
        self.streams.pop(tenant, None)
        return ack

    def swap_weights(self, tenant: str, weights, pump=None) -> dict:
        return self.command(tenant, {"reg": Reg.SWAP_WEIGHTS},
                            weights_to_arrays(weights), pump=pump)

    def rollback_weights(self, tenant: str, pump=None) -> dict:
        return self.command(tenant, {"reg": Reg.ROLLBACK}, pump=pump)

    def set_policy(self, pump=None, **knobs) -> dict:
        return self.command("_", {"reg": Reg.SET_POLICY, **knobs}, pump=pump)

    def read_stats(self, pump=None) -> dict:
        return self.command("_", {"reg": Reg.READ_STATS}, pump=pump)
