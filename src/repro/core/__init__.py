from . import (autotune, dse, engine, equalizer, fir, qat, seqlen_opt,
               stream_partition, timing_model, train_eq, volterra)
from .engine import EqualizerEngine
from .equalizer import CNNEqConfig
from .fir import FIRConfig
from .qat import QATConfig
from .volterra import VolterraConfig

__all__ = [
    "autotune", "dse", "engine", "equalizer", "fir", "qat", "seqlen_opt",
    "stream_partition", "timing_model", "train_eq", "volterra",
    "CNNEqConfig", "EqualizerEngine", "FIRConfig", "QATConfig",
    "VolterraConfig",
]
