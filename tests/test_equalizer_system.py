"""System behaviour of the equalizer stack: topology, BN folding, stream
partitioning, timing model, sequence-length framework, channels."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.channels import imdd, proakis
from repro.channels.common import (ber_from_soft, bits_to_pam,
                                   pam_constellation, pam_decision)
from repro.core import equalizer as eq
from repro.core import seqlen_opt, stream_partition as sp, timing_model as tm

KEY = jax.random.PRNGKey(0)
PAPER_CFG = eq.CNNEqConfig()          # V_p=8, L=3, K=9, C=5, N_os=2


# ---------------------------------------------------------------------------
# topology / formulas (paper §3)
# ---------------------------------------------------------------------------

def test_paper_topology_shapes():
    params = eq.init(KEY, PAPER_CFG)
    assert params["conv"][0]["w"].shape == (5, 1, 9)
    assert params["conv"][1]["w"].shape == (5, 5, 9)
    assert params["conv"][2]["w"].shape == (8, 5, 9)
    x = jnp.zeros((4096 * 2,))
    y, _ = eq.apply(params, x, PAPER_CFG, train=True,
                    bn_state=eq.init_bn_state(PAPER_CFG))
    assert y.shape == (4096,)          # one estimate per symbol


def test_mac_per_symbol_formula():
    """MAC_sym = K·C/V_p + (L−2)·K·C²/V_p + K·C/N_os  (paper §3.5)."""
    c = PAPER_CFG
    want = 9 * 5 / 8 + 1 * 9 * 5 * 5 / 8 + 9 * 5 / 2
    assert c.mac_per_symbol() == pytest.approx(want)
    assert c.mac_per_symbol() == pytest.approx(56.25)


def test_receptive_field_formula():
    """o_sym = (K−1)(1+V_p(L−1))/2 (paper §6.1)."""
    assert sp.overlap_symbols(PAPER_CFG) == (9 - 1) * (1 + 8 * 2) // 2 == 68


def test_actual_overlap_paper():
    """o_act = nextEven(⌈o_sym/(V_p·N_i)⌉)·V_p·N_i."""
    o = sp.actual_overlap(PAPER_CFG, 64)
    assert o % (8 * 64) == 0 and o >= sp.overlap_symbols(PAPER_CFG)
    assert o == 2 * 8 * 64            # nextEven(1)=2 → 1024 symbols


def test_bn_fold_matches_eval():
    cfg = PAPER_CFG
    params = eq.init(KEY, cfg)
    bn = eq.init_bn_state(cfg)
    bn = {"bn": [{"mean": 0.3 * jnp.ones_like(s["mean"]),
                  "var": 1.7 * jnp.ones_like(s["var"])} for s in bn["bn"]]}
    x = jax.random.normal(KEY, (2, 512))
    y_eval, _ = eq.apply(params, x, cfg, train=False, bn_state=bn)
    y_fold = eq.apply_folded(eq.fold_bn(params, bn, cfg), x, cfg)
    np.testing.assert_allclose(np.asarray(y_eval), np.asarray(y_fold),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# stream partitioning (paper §5.3): N_i instances == 1 instance
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_inst", [2, 4, 8])
def test_partitioned_equals_unsplit_interior(n_inst):
    cfg = PAPER_CFG
    params = eq.init(KEY, cfg)
    folded = eq.fold_bn(params, eq.init_bn_state(cfg), cfg)
    apply_fn = lambda chunks: eq.apply_folded(folded, chunks, cfg)

    n_syms = 512 * n_inst
    x = jax.random.normal(KEY, (n_syms * cfg.n_os,))
    y_split = sp.partitioned_apply(apply_fn, x, n_inst, cfg)
    y_full = apply_fn(x[None])[0]
    assert y_split.shape == y_full.shape
    o = sp.overlap_symbols(cfg)
    # Interior: identical (the overlap covers the receptive field). The
    # outer o_sym symbols of the WHOLE stream differ by padding scheme
    # (per-layer SAME vs one-shot OGM zero-pad) — the FPGA pipeline's cold
    # start, outside the paper's equality claim.
    np.testing.assert_allclose(np.asarray(y_split)[o:-o],
                               np.asarray(y_full)[o:-o],
                               rtol=1e-4, atol=1e-4)
    # CHUNK BORDERS are interior symbols: verify the splices exactly
    # (this is the paper's "BER flat across the stream" property).
    l_inst = n_syms // n_inst
    for b in range(1, n_inst):
        lo, hi = b * l_inst - 100, b * l_inst + 100
        np.testing.assert_allclose(np.asarray(y_split)[lo:hi],
                                   np.asarray(y_full)[lo:hi],
                                   rtol=1e-4, atol=1e-4)


def test_partition_ber_flat_across_borders():
    """The paper's Fig-9 property: BER is not elevated at chunk borders."""
    cfg = PAPER_CFG
    ccfg = proakis.ProakisConfig(snr_db=25.0)
    rx, syms = proakis.simulate(KEY, ccfg, 4096)
    params = eq.init(KEY, cfg)
    folded = eq.fold_bn(params, eq.init_bn_state(cfg), cfg)
    apply_fn = lambda chunks: eq.apply_folded(folded, chunks, cfg)
    y = sp.partitioned_apply(apply_fn, rx, 4, cfg)
    # untrained CNN — we check only exactness vs the unsplit reference on
    # the interior (the stream's outer o_sym symbols differ by padding
    # scheme; see test_partitioned_equals_unsplit_interior)
    y_ref = apply_fn(rx[None])[0]
    o = sp.overlap_symbols(cfg)
    np.testing.assert_allclose(np.asarray(y)[o:-o],
                               np.asarray(y_ref)[o:-o], rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# timing model (paper §6.1, Fig. 12)
# ---------------------------------------------------------------------------

def test_timing_model_paper_numbers():
    cfg = PAPER_CFG
    hw = tm.fpga_profile(cfg, f_clk=200e6)
    # T_max = N_i·V_p·f_clk = 64·8·200MHz = 102.4 GSa/s ≈ 51.2 GBd
    assert tm.max_throughput(hw, 64) == pytest.approx(102.4e9)
    # the paper's framework picks ℓ_inst = 7320 for T_req = 80 GSym/s;
    # granularity differences allow ±1 grid step
    l_inst = seqlen_opt.optimal_l_inst(cfg, hw, 64, 80e9)
    assert abs(l_inst - 7320) <= 8
    # λ_sym at ℓ_inst: paper reports 17.5 µs
    lam = tm.symbol_latency(cfg, hw, 64, l_inst)
    assert lam == pytest.approx(17.5e-6, rel=0.05)
    # and the throughput constraint is met
    assert tm.net_throughput(cfg, hw, 64, l_inst) >= 80e9


def test_timing_monotonicity():
    cfg = PAPER_CFG
    hw = tm.fpga_profile(cfg)
    ls = [1024, 4096, 16384, 65536]
    tps = [tm.net_throughput(cfg, hw, 16, l) for l in ls]
    lats = [tm.symbol_latency(cfg, hw, 16, l) for l in ls]
    assert all(a < b for a, b in zip(tps, tps[1:]))        # T_net ↑ in ℓ
    assert all(a < b for a, b in zip(lats, lats[1:]))      # λ ↑ in ℓ
    assert tps[-1] < tm.max_throughput(hw, 16)             # saturates below T_max


def test_lut_generator():
    cfg = PAPER_CFG
    hw = tm.fpga_profile(cfg)
    lut = seqlen_opt.build_lut(cfg, hw, 64, [20e9, 40e9, 80e9])
    for t_req, choice in lut.items():
        assert choice.t_net >= t_req
        g = seqlen_opt.granularity(cfg, 64)
        assert choice.l_inst % g == 0
    # harder requirement ⇒ longer ℓ_inst ⇒ more latency
    assert lut[80e9].l_inst > lut[40e9].l_inst > lut[20e9].l_inst


def test_infeasible_t_req_raises():
    cfg = PAPER_CFG
    hw = tm.fpga_profile(cfg)
    with pytest.raises(ValueError):
        seqlen_opt.optimal_l_inst(cfg, hw, 4, 80e9)   # 4 instances can't


# ---------------------------------------------------------------------------
# channels (paper §2)
# ---------------------------------------------------------------------------

def test_imdd_is_nonlinear_channel():
    """CD + square-law ⇒ nonlinear ISI: the received samples at symbol
    instants are NOT an affine function of the transmitted amplitudes."""
    cfg = imdd.IMDDConfig(snr_db=60.0)          # noiseless, pure ISI
    rx, syms = imdd.simulate(KEY, cfg, 8192)
    assert rx.shape == (8192 * 2,)
    amps = np.asarray(bits_to_pam(syms, 2))
    samp = np.asarray(rx)[::2]
    # fit the best linear FIR (15 taps) from amps → samples; residual stays
    a = np.stack([np.roll(amps, s) for s in range(-7, 8)], 1)
    coef, *_ = np.linalg.lstsq(a[8:-8], samp[8:-8], rcond=None)
    resid = samp[8:-8] - a[8:-8] @ coef
    rel = np.var(resid) / np.var(samp)
    assert rel > 0.01, f"channel looks linear (rel resid {rel:.4f})"


def test_proakis_channel_shapes_and_stats():
    cfg = proakis.ProakisConfig()
    rx, syms = proakis.simulate(KEY, cfg, 4096)
    assert rx.shape == (8192,) and syms.shape == (4096,)
    assert abs(float(jnp.mean(rx))) < 1e-3
    assert float(jnp.std(rx)) == pytest.approx(1.0, abs=1e-3)


def test_pam_decision_roundtrip():
    for levels in (2, 4, 8):
        syms = jnp.arange(levels)
        amps = bits_to_pam(syms, levels)
        np.testing.assert_array_equal(np.asarray(pam_decision(amps, levels)),
                                      np.asarray(syms))
        c = pam_constellation(levels)
        assert float(jnp.mean(c ** 2)) == pytest.approx(1.0, rel=1e-5)


def test_ber_from_soft():
    y = jnp.asarray([1.0, -1.0, 1.0, -0.9])
    t = jnp.asarray([1, 0, 0, 0])
    assert float(ber_from_soft(y, t, 2)) == pytest.approx(0.25)
