from . import common, registry
