"""Dense MLPs (SwiGLU / GELU) and the GShard-style MoE layer."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..parallel import sharding
from .common import ModelConfig, dense_init


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------

def init(key: jax.Array, cfg: ModelConfig) -> Dict[str, Any]:
    d, f = cfg.d_model, cfg.d_ff
    dt = cfg.param_dtype()
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.mlp_act == "silu":
        return {"w_gate": dense_init(k1, (d, f), dt),
                "w_up": dense_init(k2, (d, f), dt),
                "w_down": dense_init(k3, (f, d), dt)}
    return {"w_in": dense_init(k1, (d, f), dt),
            "w_out": dense_init(k2, (f, d), dt)}


def apply(params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.mlp_act == "silu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
        h = sharding.logical(h, ("batch", None, "mlp"))
        y = h @ params["w_down"]
    else:
        h = jax.nn.gelu(x @ params["w_in"])
        h = sharding.logical(h, ("batch", None, "mlp"))
        y = h @ params["w_out"]
    return sharding.logical(y, ("batch", None, None))


# ---------------------------------------------------------------------------
# Mixture of Experts (GShard-style capacity dispatch)
# ---------------------------------------------------------------------------
# Dispatch uses per-batch-row groups: capacity C = cf · S · top_k / E tokens
# per expert per row. One-hot dispatch/combine einsums lower to all-to-all
# when experts are sharded over `model` — the collective shows up in the
# §Roofline tables. Overflow tokens are dropped (standard capacity dropping;
# the router's auxiliary loss keeps usage balanced).

def moe_init(key: jax.Array, cfg: ModelConfig) -> Dict[str, Any]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = cfg.param_dtype()
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": dense_init(k1, (d, e), jnp.float32),
        "moe_gate": dense_init(k2, (e, d, f), dt),
        "moe_up": dense_init(k3, (e, d, f), dt),
        "moe_down": dense_init(k4, (e, f, d), dt),
    }


def capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    c = int(cfg.capacity_factor * tokens_per_group * cfg.top_k
            / cfg.n_experts)
    return max(4, (c + 3) // 4 * 4)


def moe_apply(params, x: jnp.ndarray, cfg: ModelConfig):
    """x: (B, S, d) → (y, aux_loss).

    Tokens are dispatched in groups of ≤ cfg.moe_group: the dispatch/combine
    tensors are (B·G, g, E, C) with C = cf·g·k/E, so their footprint is
    B·S·g·k·cf — linear in S for fixed group size (a 32k-seq prefill would
    otherwise square it)."""
    bb, ss, d = x.shape
    g = min(cfg.moe_group, ss)
    n_groups = ss // g if ss % g == 0 else 1
    if ss % g != 0:
        g = ss
    x = x.reshape(bb * n_groups, g, d)
    b, s, _ = x.shape
    e, k = cfg.n_experts, cfg.top_k
    c = capacity(cfg, s)

    logits = (x.astype(jnp.float32) @ params["router"])        # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                        # (B,S,k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    # load-balancing auxiliary loss (Switch/GShard)
    density = jnp.mean(jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32),
                       axis=1)                                  # (B,E)
    density_proxy = jnp.mean(probs, axis=1)
    aux = jnp.mean(density * density_proxy) * e * e

    dispatch = jnp.zeros((b, s, e, c), x.dtype)
    combine = jnp.zeros((b, s, e, c), jnp.float32)
    counts = jnp.zeros((b, 1, e), jnp.int32)
    for r in range(k):                       # unrolled over choice rank
        mask_r = jax.nn.one_hot(idx[..., r], e, dtype=jnp.int32)   # (B,S,E)
        pos_r = jnp.cumsum(mask_r, axis=1) - 1 + counts            # (B,S,E)
        keep = (pos_r < c) & (mask_r > 0)
        pos_oh = jax.nn.one_hot(pos_r, c, dtype=x.dtype) \
            * keep[..., None].astype(x.dtype)                     # (B,S,E,C)
        dispatch = dispatch + pos_oh
        combine = combine + pos_oh.astype(jnp.float32) \
            * gates[..., r][..., None, None]
        counts = counts + jnp.sum(mask_r, axis=1, keepdims=True)

    # experts shard over `model` when E divides it (moonshot EP16) and the
    # dispatch einsum lowers to all-to-all; otherwise (mixtral 8e) experts
    # replicate and d_ff is TP-sharded — sharding.logical drops non-dividing
    # axes automatically, matching the param-rule fallback.
    xin = jnp.einsum("bsec,bsd->ebcd", dispatch, x)
    xin = sharding.logical(xin, ("experts", "batch", None, None))
    h = jax.nn.silu(jnp.einsum("ebcd,edf->ebcf", xin, params["moe_gate"])) \
        * jnp.einsum("ebcd,edf->ebcf", xin, params["moe_up"])
    # EP (moonshot): experts carry the model axis, f replicated;
    # d_ff TP (mixtral): experts replicated, f carries the model axis.
    ff_ax = None if sharding.experts_shardable(e) else "mlp"
    h = sharding.logical(h, ("experts", "batch", None, ff_ax))
    yout = jnp.einsum("ebcf,efd->ebcd", h, params["moe_down"])
    yout = sharding.logical(yout, ("experts", "batch", None, None))
    y = jnp.einsum("bsec,ebcd->bsd", combine.astype(x.dtype), yout)
    y = y.reshape(bb, ss, d)
    return sharding.logical(y, ("batch", None, None)), aux
