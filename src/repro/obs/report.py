"""Console summary over an observability snapshot.

`render(snapshot)` turns the nested registry tree (the dict returned by
`Observability.snapshot()` / `MetricsRegistry.snapshot()`, or the JSON
written by `Observability.write_snapshot`) into a compact human-readable
report: launch latency quantiles, throughput, per-tenant session state,
the fleet placement/recovery ledger, adaptation actions, and trace-ring
occupancy.

CLI:

    python -m repro.obs.report snapshot.json
    python -m repro.obs.report -          # read JSON from stdin

Every section is optional — the report renders whatever subtrees the
snapshot actually carries (a sync `ServeRuntime` has no fleet section, a
fleet has no single `serve` section), so the same tool serves every
runtime in the stack.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional


def _fmt(v: Any, nd: int = 4) -> str:
    if isinstance(v, bool):
        return str(v)
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1000 or abs(v) < 1e-3:
            return f"{v:.3g}"
        return f"{v:.{nd}g}"
    return str(v)


def _hist_line(label: str, h: Optional[Dict[str, Any]]) -> Optional[str]:
    """One line for a Histogram.summary() dict; None when absent/empty."""
    if not isinstance(h, dict) or not h.get("count"):
        return None
    parts = [f"n={h['count']}"]
    for k in ("mean", "p50", "p90", "p99", "max"):
        if k in h:
            parts.append(f"{k}={_fmt(h[k])}")
    return f"  {label:<22} {'  '.join(parts)}"


def _batcher_lines(node: Dict[str, Any]) -> List[str]:
    """Shared micro-batcher block (used by `serve` and every fleet
    worker): request/launch counters plus the launch histograms."""
    out: List[str] = []
    req = node.get("requests_total")
    lau = node.get("launches_total")
    if req is not None or lau is not None:
        pend = node.get("pending")
        out.append(f"  requests={_fmt(req or 0)}  launches={_fmt(lau or 0)}"
                   + (f"  pending={_fmt(pend)}" if pend is not None else ""))
    launch = node.get("launch")
    if isinstance(launch, dict):
        for key, label in (("latency_s", "latency_s"),
                           ("wait_s", "wait_s"),
                           ("device_s", "device_s"),
                           ("descatter_s", "descatter_s"),
                           ("occupancy", "occupancy"),
                           ("width_samples", "width_samples")):
            line = _hist_line(label, launch.get(key))
            if line:
                out.append(line)
    pool = node.get("pool")
    if isinstance(pool, dict) and "hits" in pool:
        out.append(f"  pool: size={pool.get('size')}/"
                   f"{pool.get('max_engines')}  hits={pool.get('hits')}  "
                   f"misses={pool.get('misses')}  "
                   f"evictions={pool.get('evictions')}")
    line = _hist_line("pool.build_s",
                      pool.get("build_s") if isinstance(pool, dict) else None)
    if line:
        out.append(line)
    return out


def _errors_line(node: Any) -> Optional[str]:
    if isinstance(node, dict) and "total" in node:
        return (f"  errors: total={node['total']}  window={node['window']}"
                f"  dropped={node['dropped']}")
    if isinstance(node, (int, float)):
        return f"  errors: total={_fmt(node)}"
    return None


def _recovery_line(node: Any) -> Optional[str]:
    if not isinstance(node, dict):
        return None
    interesting = [(k, v) for k, v in sorted(node.items())
                   if isinstance(v, (int, float)) and v]
    if not interesting:
        return "  recovery: clean"
    return "  recovery: " + "  ".join(f"{k}={_fmt(v)}"
                                      for k, v in interesting)


def _serve_section(serve: Dict[str, Any]) -> List[str]:
    out = ["[serve]"]
    out += _batcher_lines(serve)
    for key in ("tenants", "inflight"):
        if key in serve and not isinstance(serve[key], dict):
            out.append(f"  {key}={_fmt(serve[key])}")
    line = _errors_line(serve.get("errors"))
    if line:
        out.append(line)
    line = _recovery_line(serve.get("recovery"))
    if line:
        out.append(line)
    deg = serve.get("degradation")
    if isinstance(deg, dict) and deg:
        out.append("  degradation: " + "  ".join(
            f"{k}={_fmt(v)}" for k, v in sorted(deg.items())
            if not isinstance(v, dict)))
    sessions = serve.get("sessions")
    if isinstance(sessions, dict) and sessions:
        out.append("  sessions:")
        for tid, s in sorted(sessions.items()):
            if not isinstance(s, dict):
                continue
            out.append(f"    {tid:<12} syms={_fmt(s.get('syms_emitted', 0))}"
                       f"  epoch={s.get('weight_epoch', 0)}"
                       f"  recoveries={s.get('recoveries', 0)}"
                       f"  inflight={s.get('inflight', 0)}"
                       + ("  FAILED" if s.get("failed") else ""))
    return out


def _fleet_section(fleet: Dict[str, Any]) -> List[str]:
    out = ["[fleet]"]
    head = []
    for key in ("tenants", "inflight", "migrations"):
        if key in fleet and not isinstance(fleet[key], dict):
            head.append(f"{key}={_fmt(fleet[key])}")
    if head:
        out.append("  " + "  ".join(head))
    line = _errors_line(fleet.get("errors"))
    if line:
        out.append(line)
    line = _recovery_line(fleet.get("recovery"))
    if line:
        out.append(line)
    placement = fleet.get("placement")
    if isinstance(placement, dict) and placement:
        out.append("  placement: " + "  ".join(
            f"{tid}->w{w}" for tid, w in sorted(placement.items())))
    workers = sorted(k for k in fleet
                     if k.startswith("worker") and isinstance(fleet[k], dict))
    for wk in workers:
        w = fleet[wk]
        alive = w.get("alive")
        out.append(f"  [{wk}] alive={alive}")
        out += ["  " + ln for ln in _batcher_lines(w)]
        line = _recovery_line(w.get("recovery"))
        if line:
            out.append("  " + line)
    return out


def _adapt_section(adapt: Dict[str, Any]) -> List[str]:
    out = ["[adapt]"]
    head = []
    for key in ("tenants", "cycles"):
        if key in adapt and not isinstance(adapt[key], dict):
            head.append(f"{key}={_fmt(adapt[key])}")
    if head:
        out.append("  " + "  ".join(head))
    actions = adapt.get("actions")
    if isinstance(actions, dict) and actions:
        out.append("  actions: " + "  ".join(
            f"{k}={_fmt(v)}" for k, v in sorted(actions.items())))
    line = _errors_line(adapt.get("errors"))
    if line:
        out.append(line)
    for tid, node in sorted(adapt.items()):
        if tid in ("actions", "errors", "cycles", "tenants"):
            continue
        if not isinstance(node, dict):
            continue
        sh = node.get("shadow")
        parts = [f"epoch={_fmt(node.get('weight_epoch', 0))}"]
        if isinstance(sh, dict):
            for k in ("ber_active", "ber_candidate", "eval_syms"):
                if k in sh:
                    parts.append(f"{k}={_fmt(sh[k])}")
        out.append(f"    {tid:<12} " + "  ".join(parts))
    return out


def _net_section(net: Dict[str, Any]) -> List[str]:
    out = ["[net]"]
    frames = []
    for k in ("frames_in", "frames_out", "frames_dropped", "frames_parked"):
        if k in net:
            frames.append(f"{k.split('_', 1)[1]}={_fmt(net[k])}")
    if frames:
        out.append("  frames: " + "  ".join(frames))
    wire = []
    for k in ("crc_errors", "duplicates", "reordered", "gaps",
              "nacks_sent", "credits_granted"):
        if k in net:
            wire.append(f"{k}={_fmt(net[k])}")
    if wire:
        out.append("  wire:   " + "  ".join(wire))
    line = _hist_line("ingress_to_emit_s", net.get("ingress_to_emit_s"))
    if line:
        out.append(line)
    return out


def _link_section(link: Dict[str, Any]) -> List[str]:
    out = ["[link]"]
    for tid, node in sorted(link.items()):
        if not isinstance(node, dict):
            continue
        parts = []
        for k in ("snr_db", "evm", "ser_proxy"):
            if k in node:
                parts.append(f"{k}={_fmt(node[k])}")
        for k in ("syms", "segments"):
            if k in node:
                parts.append(f"{k}={_fmt(node[k])}")
        out.append(f"    {tid:<12} " + "  ".join(parts))
        life = node.get("lifetime")
        if isinstance(life, dict):
            out.append("    " + " " * 13 + "lifetime: " + "  ".join(
                f"{k}={_fmt(v)}" for k, v in sorted(life.items())))
        line = _hist_line("confidence", node.get("confidence"))
        if line:
            out.append("  " + line)
    return out


def _slo_section(slo: Dict[str, Any]) -> List[str]:
    out = ["[slo]"]
    head = []
    for k in ("rules", "watched", "breached"):
        if k in slo and not isinstance(slo[k], dict):
            head.append(f"{k}={_fmt(slo[k])}")
    if head:
        out.append("  " + "  ".join(head))
    state = slo.get("state")
    if isinstance(state, dict):
        out.append(f"  alerts: total={_fmt(state.get('alerts_total', 0))}"
                   f"  dropped={_fmt(state.get('alerts_dropped', 0))}")
        latches = state.get("latches")
        if isinstance(latches, dict):
            for name, l in sorted(latches.items()):
                if isinstance(l, dict) and l.get("breached"):
                    out.append(f"    BREACHED {name}  "
                               f"value={_fmt(l.get('value'))}")
    alerts = slo.get("alerts")
    if isinstance(alerts, list) and alerts:
        out.append("  ledger (recent):")
        for a in alerts[-5:]:
            if isinstance(a, dict):
                out.append(f"    {a.get('state', '?'):<9}"
                           f" {a.get('rule', '?')}"
                           f" [{a.get('tenant') or '-'}]"
                           f"  {a.get('metric', '')}"
                           f"  value={_fmt(a.get('value'))}"
                           f" vs {_fmt(a.get('threshold'))}")
    return out


def _trace_section(trace: Dict[str, Any]) -> List[str]:
    out = ["[trace]"]
    out.append("  " + "  ".join(
        f"{k}={_fmt(v)}" for k, v in sorted(trace.items())
        if not isinstance(v, dict)))
    return out


def render(snapshot: Dict[str, Any]) -> str:
    """Render a snapshot tree into the console report (a newline-joined
    string; always ends without a trailing newline)."""
    lines: List[str] = []
    meta = snapshot.get("meta")
    if isinstance(meta, dict):
        lines.append(f"observability snapshot — uptime "
                     f"{_fmt(meta.get('uptime_s', 0.0))}s, "
                     f"{meta.get('metric_names', 0)} metrics, "
                     f"{meta.get('callback_names', 0)} callbacks")
    if isinstance(snapshot.get("serve"), dict):
        lines += _serve_section(snapshot["serve"])
    fleets = [k for k in sorted(snapshot)
              if k.startswith("fleet") and isinstance(snapshot[k], dict)]
    for k in fleets:
        lines += _fleet_section(snapshot[k])
    if isinstance(snapshot.get("adapt"), dict):
        lines += _adapt_section(snapshot["adapt"])
    if isinstance(snapshot.get("net"), dict):
        lines += _net_section(snapshot["net"])
    if isinstance(snapshot.get("link"), dict):
        lines += _link_section(snapshot["link"])
    if isinstance(snapshot.get("slo"), dict):
        lines += _slo_section(snapshot["slo"])
    if isinstance(snapshot.get("trace"), dict):
        lines += _trace_section(snapshot["trace"])
    if not lines:
        lines.append("observability snapshot — empty")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render an observability snapshot (JSON) as a console "
                    "summary.")
    p.add_argument("path", help="snapshot JSON file, or '-' for stdin")
    args = p.parse_args(argv)
    if args.path == "-":
        snap = json.load(sys.stdin)
    else:
        with open(args.path) as f:
            snap = json.load(f)
    print(render(snap))
    return 0


if __name__ == "__main__":
    sys.exit(main())
