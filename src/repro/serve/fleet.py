"""Fleet serving over a device mesh — device-loss failover with bitwise
stream migration.

The paper's flexibility story ends in deployment: the same equalizer spans
40 GBd optical links and low-power magnetic-recording heads, running as a
long-lived field receiver where a component loss must not kill the stream
(the real-time FPGA demonstrator line, arXiv 2402.15288). PR 6 made one
device survivable (contract #9: failover is bitwise-invisible); this module
extends that contract FLEET-wide — `AsyncServeRuntime`'s blast radius is
one device, a `FleetRuntime`'s is none, as long as one worker survives.

Architecture
------------
One `FleetWorker` per device: an unbounded launch queue, a dedicated
launcher thread, its own `EnginePool` + `MicroBatcher` (so stacked-group
state never crosses devices), a `RecoveryStats` ledger, and a
`StragglerMonitor` heartbeat fed by launch latencies. The `FleetRuntime`
controller owns placement, routing, health, and migration:

  * PLACEMENT — new tenants shard onto the least-loaded healthy worker
    (tenant count, then `TrafficStats` launch counts), with group-key
    affinity as the tie-break so tenants that can share a stacked launch
    land together. `worker_devices` picks the device set (cycling real
    devices as interpret-mode stand-ins when the host has fewer devices
    than workers), and `best_mesh` — folded in from `runtime/elastic.py`,
    which now delegates here — remains the single source of mesh/device-set
    truth for elastic training restores.
  * HEALTH — every launch attempt's latency feeds the worker's
    `StragglerMonitor` (slow workers latch `degraded`, visible in
    `stats()`); a `launch_deadline_s` watchdog turns hangs into failed
    attempts; `RecoveryPolicy.device_lost_after` consecutive TERMINAL
    failures — or an injected/real `DeviceLost` — declare the device gone.
  * MIGRATION — on worker death every resident session is rebuilt on a
    surviving worker from its `TenantSpec` + `StreamChunker.CarrySnapshot`
    (`Session.rebuild_on`), and every un-landed request — stranded
    launches, queued batches, never-assembled pending requests — replays
    there in per-session FIFO order. A `ChunkPlan` is a self-contained
    input snapshot committed at enqueue, engine rebuilds are
    deterministic, and a landed request's plan is consumed atomically
    (under `_state`) — so every chunk is emitted exactly once and the
    migrated stream is BITWISE-equal to offline (contract #10, placement
    invariance: #4 bitwise chunking × #5 batch-composition invariance ⇒
    the output cannot depend on which worker served which chunk). Only a
    session that exhausts `RecoveryPolicy.max_session_recoveries` is
    poisoned — the serving analogue of `repro.runtime.fault`'s bounded
    restart budget (`run_with_restarts`), with migrations and same-worker
    failover rounds drawing from one budget.

Chaos testing is deterministic on CPU: `FaultPlan`'s `device_lost` /
`device_slow` kinds schedule per WORKER index (`Fault.at` = worker,
`Fault.after` = that worker's execute index), each firing at most once —
`tests/test_fleet.py` and `benchmarks/bench_fleet.py` kill a worker
mid-stream and assert the bitwise/exactly-once contract.

Locking (two levels, strictly ordered):
  * `_mutex` (RLock) — the control plane: serializes public API calls,
    the heartbeat tick, and migration. Never taken by launcher threads,
    so holding it while waiting on `_done` cannot deadlock a landing.
  * `_state` (Lock)  — the data plane, shared with launchers: batcher
    mutations, in-flight accounting, stranding, ledgers. `_done` is a
    Condition on it. Always acquired AFTER `_mutex`, never the reverse.

Worker queues are UNBOUNDED on purpose: a bounded queue whose launcher
died would block dispatch while the controller holds `_mutex` — a
deadlock. Memory stays bounded by the upstream producers (one chunk per
submit) and the heartbeat's migration sweep. A dead worker's launcher
stays alive as a STRANDER: anything still routed to it is moved to
`stranded` for the next migration sweep, so no request is ever orphaned.
"""
from __future__ import annotations

import concurrent.futures
import queue
import random
import threading
import time
from collections import Counter, deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from ..obs import Observability
from ..runtime.straggler import StragglerConfig, StragglerMonitor
from .pool import EnginePool
from .recovery import (CorruptOutput, DeviceLost, FaultPlan, LaunchTimeout,
                       RecoveryPolicy, RecoveryStats)
from .runtime import _serve_tile
from .scheduler import BatchPolicy, LaunchBatch, MicroBatcher, Request
from .session import Session, TenantSpec

# sentinel telling a worker's launcher thread to exit
_SHUTDOWN = object()


# ---------------------------------------------------------------------------
# device-set / mesh selection (single source of truth; elastic.py delegates)
# ---------------------------------------------------------------------------

def worker_devices(n_workers: Optional[int] = None,
                   devices: Optional[list] = None) -> list:
    """The device set for an `n_workers`-worker fleet.

    Uses the host's `jax.devices()` (or an explicit list); when the fleet
    is wider than the host — the CPU chaos-test case — real devices are
    CYCLED as stand-ins, so every worker still owns a valid device handle
    and the threading/failover topology is exercised faithfully even on a
    single-device interpret-mode host."""
    devs = list(devices) if devices is not None else jax.devices()
    if not devs:
        raise RuntimeError("no jax devices available")
    if n_workers is None:
        n_workers = len(devs)
    if n_workers < 1:
        raise ValueError("n_workers must be ≥ 1")
    return [devs[i % len(devs)] for i in range(n_workers)]


def best_mesh(n_devices: Optional[int] = None, model_parallel: int = 0,
              devices: Optional[list] = None) -> Mesh:
    """Largest (data, model) mesh for the surviving device set.

    Shared by elastic training restores (`repro.runtime.elastic`, which
    re-exports this) and documented here with the fleet's other device-set
    logic so there is ONE notion of "which devices do we have". Model
    parallelism is pinned by the checkpointed config (weights must still
    divide), halving until it divides the device count; the data axis
    absorbs the elasticity."""
    devs = list(devices) if devices is not None else jax.devices()
    if not devs:
        raise RuntimeError("no jax devices available")
    n = n_devices or len(devs)
    if not 1 <= n <= len(devs):
        raise ValueError(f"n_devices={n} outside [1, {len(devs)}]")
    mp = model_parallel or 1
    while mp > 1 and n % mp:
        mp //= 2
    dp = n // mp
    return Mesh(np.asarray(devs[:dp * mp]).reshape(dp, mp),
                ("data", "model"))


# ---------------------------------------------------------------------------
# one worker = one device, one launcher, one pool, one batcher
# ---------------------------------------------------------------------------

class FleetWorker:
    """One device's serving executor (data plane only — placement, health
    verdicts, and migration live in `FleetRuntime`).

    The launcher thread pops assembled `LaunchBatch`es from the unbounded
    queue and drives each to a terminal state: landed (descattered under
    the fleet's `_state`), poisoned, or — on `DeviceLost` / too many
    consecutive terminal failures — STRANDED for migration. After death
    the thread keeps running as a strander so late-routed batches are
    never lost; `FleetRuntime._absorb_dead_workers` collects them.
    """

    def __init__(self, idx: int, device, fleet: "FleetRuntime"):
        self.idx = idx
        self.device = device
        self._fleet = fleet
        self.pool = EnginePool(fleet.max_engines)
        self.pool.fault_plan = fleet.fault_plan
        self.pool.clock = fleet.clock
        # per-worker metrics scope: instruments land under
        # fleet.worker<idx>.* in the shared registry (one hub fleet-wide,
        # so chunk spans survive migration between workers)
        self.batcher = MicroBatcher(fleet.policy, clock=fleet.clock,
                                    obs=fleet.obs,
                                    obs_scope=f"fleet.worker{idx}")
        self.batcher.fault_plan = fleet.fault_plan
        self.batcher.sentinel_limit = fleet.recovery.sentinel_limit
        self.batcher.worker_index = idx
        self.stats = RecoveryStats()           # per-worker failover ledger
        self.monitor = StragglerMonitor(fleet.straggler
                                        or StragglerConfig())
        scope = fleet.obs.scope(f"fleet.worker{idx}")
        h_build = scope.histogram("pool.build_s")

        def _on_build(key, dt: float) -> None:
            h_build.observe(dt)
            fleet.obs.tracer.instant("engine_build", worker=idx,
                                     tenant=str(key), build_s=dt)

        self.pool.build_hook = _on_build
        scope.callback("pool", self.pool.stats)
        scope.callback("alive", lambda: self.device_lost is None)
        scope.callback("recovery", self.stats.as_dict)
        scope.callback("health", self.monitor.summary)
        self.tenants: set = set()
        self.groups: Counter = Counter()       # placement-key → residents
        self.q: "queue.Queue" = queue.Queue()  # unbounded (see module doc)
        self.stranded: List[LaunchBatch] = []  # un-landed work of a dead
        self.device_lost: Optional[BaseException] = None
        self.absorbed = False                  # migration sweep ran
        self.died_at = 0.0
        self.consecutive_failures = 0
        self.launch_seq = 0                    # monitor step counter
        self._rng = random.Random(1000 + idx)  # per-worker backoff jitter
        self._thread = threading.Thread(
            target=self._loop, name=f"fleet-worker-{idx}", daemon=True)
        self._thread.start()

    # -- launcher thread ---------------------------------------------------

    def _loop(self) -> None:
        fleet = self._fleet
        while True:
            batch = self.q.get()
            if batch is _SHUTDOWN:
                return
            try:
                self._run_batch(batch)
            except Exception as e:  # noqa: BLE001 — launcher must survive
                with fleet._state:
                    fleet._record_error_locked(e)

    def _run_batch(self, batch: LaunchBatch) -> None:
        """Drive one batch to a terminal state (mirrors
        `AsyncServeRuntime._run_batch`, plus the device-death verdicts)."""
        fleet = self._fleet
        if self.device_lost is not None:
            self._strand(batch)
            return
        t_fail: Optional[float] = None
        round_idx = 0
        while True:
            y, err = self._try_execute(batch)
            if err is None:
                with fleet._state:
                    try:
                        self.batcher.descatter(batch, y)
                        self.consecutive_failures = 0
                        fleet._land_locked(batch)
                        if t_fail is not None:
                            self.stats.record_recovery(
                                self.batcher.clock() - t_fail)
                        return
                    except CorruptOutput as e:
                        # sentinel rejected BEFORE anything was emitted:
                        # batch intact → quarantine + failover replay
                        self.stats.bump("corrupt_detected")
                        err = e
                    except Exception as e:  # noqa: BLE001
                        # descatter failed MIDWAY: emission ambiguous,
                        # replay could double-emit — poison, as in PR 6
                        fleet._record_error_locked(e)
                        self.batcher.fail(batch, e)
                        fleet._land_locked(batch)
                        return
            if isinstance(err, DeviceLost):
                self._die(err, batch)
                return
            if t_fail is None:
                t_fail = self.batcher.clock()
            with fleet._state:
                self.consecutive_failures += 1
                after = self._fleet.recovery.device_lost_after
                lost = (after is not None
                        and self.consecutive_failures >= after)
            if lost:
                self._die(DeviceLost(
                    f"worker {self.idx}: {self.consecutive_failures} "
                    f"consecutive terminal launch failures "
                    f"(last: {err!r})"), batch)
                return
            batch = self._failover(batch, err)
            if batch is None:
                return                 # everything poisoned and landed
            time.sleep(fleet.recovery.backoff_s(round_idx, self._rng))
            round_idx += 1

    def _try_execute(self, batch: LaunchBatch):
        """In-place launch attempts with backoff + watchdog; every
        attempt's latency feeds this worker's health monitor. Returns
        (y, None) on success, (None, last error) when exhausted —
        `DeviceLost` short-circuits (retrying a dead device is pointless
        and would delay migration). Latencies come from the fleet's
        injectable `clock` (NOT wall time), so fleet latency tests can
        freeze or script the timeline; failed attempts append a "retry"
        child event to each affected chunk's span."""
        fleet = self._fleet
        clk = fleet.clock
        err: Optional[BaseException] = None
        for attempt in range(fleet.launch_retries + 1):
            if attempt:
                time.sleep(fleet.recovery.backoff_s(attempt - 1, self._rng))
            t0 = clk()
            try:
                y = self._execute_deadline(batch)
            except DeviceLost as e:
                self._observe(clk() - t0)
                return None, e
            except Exception as e:  # noqa: BLE001 — retried/reported
                err = e
                dt = (fleet.launch_deadline_s
                      if isinstance(e, LaunchTimeout)
                      else clk() - t0)
                self._observe(dt)
                if self.batcher.tracer.enabled:
                    t = clk()
                    for r in batch.reqs:
                        if r.plan.span is not None:
                            r.plan.span.event("retry", t, worker=self.idx,
                                              attempt=attempt,
                                              error=repr(e))
                continue
            self._observe(clk() - t0)
            return y, None
        return None, err

    def _execute(self, batch: LaunchBatch) -> np.ndarray:
        if self.device is not None and jax.device_count() > 1:
            with jax.default_device(self.device):
                return self.batcher.execute(batch)
        return self.batcher.execute(batch)

    def _execute_deadline(self, batch: LaunchBatch) -> np.ndarray:
        """One device attempt, watchdog-bounded when the fleet sets
        `launch_deadline_s` (same abandon-the-hung-call semantics as
        `AsyncServeRuntime._execute_deadline`)."""
        deadline = self._fleet.launch_deadline_s
        if deadline is None:
            return self._execute(batch)
        result: Dict[str, object] = {}
        done = threading.Event()

        def _worker() -> None:
            try:
                result["y"] = self._execute(batch)
            except BaseException as e:  # noqa: BLE001 — re-raised below
                result["e"] = e
            finally:
                done.set()

        t = threading.Thread(target=_worker,
                             name=f"fleet-watchdog-{self.idx}", daemon=True)
        t.start()
        if not done.wait(deadline):
            self.stats.bump("deadline_timeouts")
            raise LaunchTimeout(
                f"worker {self.idx}: launch exceeded deadline "
                f"{deadline:g}s; hung device call abandoned")
        if "e" in result:
            raise result["e"]          # type: ignore[misc]
        return result["y"]             # type: ignore[return-value]

    def _observe(self, dt: float) -> None:
        """Feed one launch-attempt latency to this worker's heartbeat
        monitor (under `_state`: `stats()` reads the summary there)."""
        with self._fleet._state:
            self.monitor.observe(self.launch_seq, dt)
            self.launch_seq += 1

    def _failover(self, batch: LaunchBatch,
                  err: BaseException) -> Optional[LaunchBatch]:
        """Same-worker failover round (the device still answers, one
        launch keeps failing): budget-partition the batch, rebuild the
        surviving sessions' engines in THIS worker's pool, re-assemble a
        replay. Port of `AsyncServeRuntime._failover` against the fleet's
        locks and per-worker ledger (no corrupt-rollback here — weight
        hot-swap is an `AsyncServeRuntime` feature)."""
        fleet = self._fleet
        with fleet._state:
            fleet._record_error_locked(err)
            for s in {id(r.session): r.session for r in batch.reqs}.values():
                s.recoveries += 1
            keep: List[Request] = []
            doomed: List[Request] = []
            for r in batch.reqs:
                over = (r.session.recoveries
                        > fleet.recovery.max_session_recoveries)
                (doomed if over or r.session.failed is not None
                 else keep).append(r)
            fleet._poison_locked(self, doomed, err)
        if not keep:
            return None
        alive: Dict[int, bool] = {}
        build_err: Optional[BaseException] = None
        for s in {id(r.session): r.session for r in keep}.values():
            e = self._rebuild_engine(s)
            alive[id(s)] = e is None
            build_err = e or build_err
        good = [r for r in keep if alive[id(r.session)]]
        dead = [r for r in keep if not alive[id(r.session)]]
        with fleet._state:
            if dead:
                fleet._poison_locked(self, dead, build_err or err)
            if not good:
                return None
            if self.batcher.tracer.enabled:
                t = fleet.clock()
                for r in good:
                    if r.plan.span is not None:
                        r.plan.span.event("replay", t, worker=self.idx,
                                          error=type(err).__name__)
            replay = self.batcher.assemble(batch.key, good)
            self.stats.bump("recoveries")
            self.stats.bump("chunks_replayed", len(good))
        return replay

    def _rebuild_engine(self, s: Session) -> Optional[BaseException]:
        """Drop + rebuild one session's engine in this worker's pool
        (bounded by `RecoveryPolicy.build_retries`, no locks held)."""
        err: Optional[BaseException] = None
        self.pool.drop(s.spec.tenant_id)
        for attempt in range(self._fleet.recovery.build_retries + 1):
            if attempt:
                time.sleep(self._fleet.recovery.backoff_s(attempt - 1,
                                                          self._rng))
            try:
                s.engine               # pool miss → spec.build_engine()
                self.stats.bump("engine_rebuilds")
                return None
            except Exception as e:  # noqa: BLE001 — bounded retries
                err = e
        return err

    # -- death -------------------------------------------------------------

    def _die(self, err: BaseException,
             batch: Optional[LaunchBatch]) -> None:
        """Mark this worker's device lost and strand the failing batch.
        The launcher stays alive as a strander; the controller's next
        sweep (`_absorb_dead_workers`) migrates everything."""
        fleet = self._fleet
        with fleet._state:
            if self.device_lost is None:
                self.device_lost = err
                self.died_at = self.batcher.clock()
                self.stats.bump("device_losses")
                fleet._record_error_locked(err)
                fleet.obs.tracer.instant("device_lost", worker=self.idx,
                                         error=repr(err))
            if batch is not None:
                self.stranded.append(batch)
            fleet._done.notify_all()

    def _strand(self, batch: LaunchBatch) -> None:
        with self._fleet._state:
            self.stranded.append(batch)
            self._fleet._done.notify_all()


# ---------------------------------------------------------------------------
# the controller
# ---------------------------------------------------------------------------

class FleetRuntime:
    """Multi-device serving controller: N `FleetWorker`s, shard-by-tenant
    placement, health monitoring, and bitwise device-loss failover (see
    module docstring for the architecture and locking discipline).

    n_workers:      fleet width (count; default 2). Devices come from
                    `worker_devices(n_workers, devices)` — real devices
                    are cycled as stand-ins when the host is narrower.
    policy:         `BatchPolicy` coalescing knobs, applied PER WORKER
                    (each worker owns a `MicroBatcher`).
    max_engines:    LRU engine-pool bound PER WORKER (count; default 32).
    clock:          timestamp source (seconds; default perf_counter).
    launch_retries: in-place retries per failed launch before a terminal
                    verdict (count; default 2).
    launch_deadline_s: per-launch watchdog (seconds; default None =
                    disabled — leave None on interpret-mode hosts, where
                    first-touch compiles legitimately take seconds).
    recovery:       `RecoveryPolicy` budgets. Default: the stock policy
                    with `device_lost_after=2` — two consecutive terminal
                    failures on one worker declare its device lost.
                    Migration rounds and same-worker failover rounds draw
                    from the same `max_session_recoveries` budget.
    fault_plan:     optional `FaultPlan` — launch/build kinds hit
                    whichever worker's batcher/pool reaches the scheduled
                    index; `device_lost`/`device_slow` target a worker by
                    index. Testing/benching hook; None in production.
    straggler:      `StragglerConfig` for the per-worker launch-latency
                    heartbeat monitors (default: stock config).
    devices:        explicit device list (default: `jax.devices()`).
    obs:            optional `repro.obs.Observability` hub shared fleet-
                    wide (per-worker metrics under `fleet.worker<i>.*`;
                    chunk spans survive migration because every worker
                    stamps into the same tracer). Default None = private
                    hub, tracing off.
    link:           optional `repro.obs.LinkMonitor` — every tenant opened
                    on the fleet is auto-attached for streaming EVM/SNR/SER
                    estimation; pair with `attach_slo` to fold quality
                    breaches into worker health.

    Thread-safety: public methods may be called from any thread; per-
    tenant calls must not race each other (one producer per stream).
    Always `shutdown()` (or use as a context manager).
    """

    ERRORS_MAX = 256        # legacy default; Retention.errors governs now

    def __init__(self, n_workers: int = 2,
                 policy: Optional[BatchPolicy] = None,
                 max_engines: int = 32,
                 clock: Callable[[], float] = time.perf_counter,
                 launch_retries: int = 2,
                 launch_deadline_s: Optional[float] = None,
                 recovery: Optional[RecoveryPolicy] = None,
                 fault_plan: Optional[FaultPlan] = None,
                 straggler: Optional[StragglerConfig] = None,
                 devices: Optional[list] = None,
                 obs: Optional[Observability] = None,
                 link=None):
        self.policy = policy or BatchPolicy()
        self.max_engines = max_engines
        self.clock = clock
        self.obs = obs if obs is not None else Observability(clock=clock)
        self.link = link
        self._slo = None               # SloEngine, via attach_slo()
        self.launch_retries = launch_retries
        self.launch_deadline_s = launch_deadline_s
        self.recovery = (recovery if recovery is not None
                         else RecoveryPolicy(device_lost_after=2))
        self.fault_plan = fault_plan
        self.straggler = straggler
        self._mutex = threading.RLock()        # control plane (see module)
        self._state = threading.Lock()         # data plane, launcher-shared
        self._done = threading.Condition(self._state)
        self._sessions: Dict[str, Session] = {}
        self._homes: Dict[str, FleetWorker] = {}
        self._placekeys: Dict[str, Tuple] = {}  # tid → key used at open
        self._inflight = 0
        self._migrations = 0                   # dead workers absorbed
        self.errors: "Deque[BaseException]" = deque(
            maxlen=self.obs.retention.errors)
        self.errors_total = 0
        self._stop = threading.Event()
        self.workers = [FleetWorker(i, d, self)
                        for i, d in enumerate(
                            worker_devices(n_workers, devices))]
        scope = self.obs.scope("fleet")
        scope.callback("tenants", lambda: len(self._sessions))
        scope.callback("inflight", lambda: self._inflight)
        scope.callback("migrations", lambda: self._migrations)
        scope.callback("placement", lambda: {
            tid: w.idx for tid, w in self._homes.items()})
        scope.callback("errors", lambda: {
            "total": self.errors_total,
            "window": len(self.errors),
            "dropped": self.errors_total - len(self.errors)})
        scope.callback("recovery", lambda: {
            f: sum(getattr(w.stats, f) for w in self.workers)
            for f in RecoveryStats.FIELDS})
        self._hb = threading.Thread(target=self._heartbeat_loop,
                                    name="fleet-heartbeat", daemon=True)
        self._hb.start()

    # -- lifecycle ---------------------------------------------------------

    def shutdown(self) -> None:
        """Stop the heartbeat and every worker launcher (idempotent).
        Queued batches still execute; call `drain()` first for a clean
        flush."""
        if self._stop.is_set():
            return
        self._stop.set()
        self._hb.join()
        for w in self.workers:
            w.q.put(_SHUTDOWN)
        for w in self.workers:
            w._thread.join()

    def __enter__(self) -> "FleetRuntime":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    def _check_running(self) -> None:
        if self._stop.is_set():
            raise RuntimeError("fleet is shut down")

    # -- tenant lifecycle --------------------------------------------------

    def open(self, spec: TenantSpec) -> Session:
        """Admit a tenant: place it on the least-loaded healthy worker
        (group-key affinity as tie-break), build its engine in that
        worker's pool. Raises ValueError on a duplicate tenant_id,
        RuntimeError when no healthy worker remains."""
        with self._mutex:
            self._check_running()
            self._absorb_dead_workers()
            if spec.tenant_id in self._sessions:
                raise ValueError(f"tenant {spec.tenant_id!r} already open")
            key = self._spec_key(spec)
            w = self._place(key)
            s = Session(spec, w.pool,
                        tile_tuner=lambda e: _serve_tile(w.batcher, e))
            with self._state:
                self._sessions[spec.tenant_id] = s
                self._homes[spec.tenant_id] = w
                self._placekeys[spec.tenant_id] = key
                w.tenants.add(spec.tenant_id)
                w.groups[key] += 1
            if self.link is not None:
                self.link.attach(s)
            return s

    def close(self, tenant_id: str) -> np.ndarray:
        """End a tenant's stream: flush the tail, wait for its in-flight
        work (surviving any migration mid-wait — the session object may
        be REPLACED by a rebuild), release it, return the full stream.
        Raises RuntimeError if the stream was poisoned."""
        with self._mutex:
            self._check_running()
            self._absorb_dead_workers()
            if tenant_id not in self._sessions:
                raise KeyError(f"tenant {tenant_id!r} not open")
            with self._state:
                s = self._sessions[tenant_id]
                w = self._homes[tenant_id]
                if not s.chunker.finished:
                    s.chunker.finish()
                req = self.batcher_enqueue(w, s)
                self._dispatch_locked(w, w.batcher.take_session(s))
            while True:
                self._absorb_dead_workers()
                s = self._sessions[tenant_id]   # migration may replace it
                with self._done:
                    if s.failed is not None or s.inflight == 0:
                        break
                    self._done.wait(0.05)
            with self._state:
                s = self._sessions.pop(tenant_id)
                w = self._homes.pop(tenant_id)
                key = self._placekeys.pop(tenant_id)
                w.tenants.discard(tenant_id)
                w.groups[key] -= 1
            w.pool.drop(tenant_id)
            return s.output()

    # -- streaming ---------------------------------------------------------

    def submit(self, tenant_id: str,
               samples) -> Optional[concurrent.futures.Future]:
        """Feed a chunk of waveform samples; routed to the tenant's home
        worker. Returns a per-chunk future (None while buffering below an
        emittable position). Never blocks on a worker — queues are
        unbounded and a dead worker's traffic strands for migration."""
        with self._mutex:
            self._check_running()
            self._absorb_dead_workers()
            if tenant_id not in self._sessions:
                raise KeyError(f"tenant {tenant_id!r} not open")
            with self._state:
                s = self._sessions[tenant_id]
                w = self._homes[tenant_id]
                s.chunker.push(np.asarray(samples))
                req = self.batcher_enqueue(w, s)
                self._dispatch_locked(w, w.batcher.take_ready())
        return req.future if req is not None else None

    def finish(self, tenant_id: str) -> Optional[concurrent.futures.Future]:
        """End-of-stream marker: queue the zero-padded tail flush."""
        with self._mutex:
            self._check_running()
            self._absorb_dead_workers()
            if tenant_id not in self._sessions:
                raise KeyError(f"tenant {tenant_id!r} not open")
            with self._state:
                s = self._sessions[tenant_id]
                w = self._homes[tenant_id]
                if not s.chunker.finished:
                    s.chunker.finish()
                req = self.batcher_enqueue(w, s)
                self._dispatch_locked(w, w.batcher.take_ready())
        return req.future if req is not None else None

    def pump(self) -> int:
        """Manual scheduling pass over every healthy worker (normally the
        heartbeat's job). Returns launches scheduled."""
        with self._mutex:
            self._check_running()
            self._absorb_dead_workers()
            n = 0
            for w in self._healthy():
                with self._state:
                    batches = w.batcher.take_ready()
                    self._dispatch_locked(w, batches)
                n += len(batches)
            return n

    def drain(self) -> int:
        """Schedule every pending request and block until the fleet is
        empty — all launches landed, terminally failed, or migrated and
        landed elsewhere. Returns launches scheduled by this call."""
        n = 0
        while True:
            with self._mutex:
                self._check_running()
                self._absorb_dead_workers()
                sched = 0
                for w in self._healthy():
                    with self._state:
                        batches = w.batcher.take_ready(force=True)
                        self._dispatch_locked(w, batches)
                    sched += len(batches)
                n += sched
                if sched:
                    continue
                with self._done:
                    if (self._inflight == 0
                            and all(w.batcher.pending() == 0
                                    for w in self.workers)
                            and not any(w.device_lost is not None
                                        and not w.absorbed
                                        for w in self.workers)):
                        return n
                    self._done.wait(0.05)

    def output(self, tenant_id: str) -> np.ndarray:
        """Symbols emitted so far (stream order). NOT a barrier — use
        futures, `drain()`, or `close()`. Raises if the stream was
        poisoned."""
        with self._state:
            return self._sessions[tenant_id].output()

    @property
    def sessions(self) -> Dict[str, Session]:
        """Live sessions by tenant id (snapshot) — the same lookup shape
        `ServeRuntime.sessions` offers, so layers that need a session
        (the net ingress trace push, adapters) work against a fleet too."""
        with self._state:
            return dict(self._sessions)

    def attach_slo(self, slo) -> None:
        """Fold an `SloEngine`'s per-tenant quality verdicts into fleet
        health: `stats()` workers gain a `slo_breached` tenant list (next
        to the launch-latency straggler verdict) and the registry a
        `fleet.slo_breached` placement callback, so a worker serving
        quality-degraded tenants is visible fleet-wide."""
        self._slo = slo
        self.obs.scope("fleet").callback(
            "slo_breached", lambda: {
                tid: w.idx for tid, w in self._homes.items()
                if tid in set(self._slo.breached_tenants())})

    # -- accounting --------------------------------------------------------

    def stats(self) -> Dict:
        """Fleet snapshot: a per-worker block (aliveness, tenants, the
        `RecoveryStats` migration/failover ledger, straggler health,
        traffic, pool) plus fleet-wide placement and aggregate ledger.

        Legacy wrapper — the registry snapshot (`self.obs.snapshot()`)
        is the normalized superset; see docs/OBSERVABILITY.md for the
        key map. `errors` counts every error ever recorded (lifetime
        total, NOT the bounded deque length); `errors_total` is the
        schema-normalized alias shared with `AsyncServeRuntime`.
        With an `attach_slo`'d engine, each worker also lists its
        resident tenants holding a latched SLO breach (`slo_breached`) —
        quality degradation sits next to the straggler verdict."""
        breached = (set(self._slo.breached_tenants())
                    if self._slo is not None else set())
        with self._state:
            workers = []
            for w in self.workers:
                workers.append({
                    "worker": w.idx,
                    "device": str(w.device),
                    "alive": w.device_lost is None,
                    "reason": (repr(w.device_lost)
                               if w.device_lost is not None else None),
                    "tenants": sorted(w.tenants),
                    "consecutive_failures": w.consecutive_failures,
                    "recovery": w.stats.as_dict(),
                    "health": w.monitor.summary(),
                    "slo_breached": sorted(w.tenants & breached),
                    "traffic": w.batcher.traffic_stats(),
                    "pool": w.pool.stats(),
                    "pending": w.batcher.pending(),
                })
            agg = {f: sum(getattr(w.stats, f) for w in self.workers)
                   for f in RecoveryStats.FIELDS}
            return {"workers": workers,
                    "recovery": agg,
                    "tenants": len(self._sessions),
                    "placement": {tid: w.idx
                                  for tid, w in self._homes.items()},
                    "inflight": self._inflight,
                    "migrations": self._migrations,
                    "errors": self.errors_total,
                    "errors_total": self.errors_total}

    # -- internals: dispatch -----------------------------------------------

    @staticmethod
    def batcher_enqueue(w: FleetWorker,
                        s: Session) -> Optional[Request]:
        """Enqueue a session's next plan on its home worker, future
        attached (`_state` held by the caller)."""
        req = w.batcher.enqueue(s)
        if req is not None:
            req.future = concurrent.futures.Future()
        return req

    def _dispatch_locked(self, w: FleetWorker,
                         batches: List[LaunchBatch]) -> None:
        """Account batches in-flight and hand them to the worker's
        launcher (`_state` held; unbounded put never blocks)."""
        for b in batches:
            for r in b.reqs:
                r.session.inflight += 1
            self._inflight += len(b.reqs)
            w.q.put(b)

    def _record_error_locked(self, e: BaseException) -> None:
        self.errors.append(e)
        self.errors_total += 1

    def _land_locked(self, batch: LaunchBatch) -> None:
        for r in batch.reqs:
            r.session.inflight -= 1
        self._inflight -= len(batch.reqs)
        self._done.notify_all()

    def _poison_locked(self, w: FleetWorker, reqs: List[Request],
                       err: BaseException) -> None:
        """Terminal path for over-budget requests: fail futures, poison
        sessions, land, ledger on the verdict-issuing worker (`_state`
        held)."""
        if not reqs:
            return
        newly = {id(r.session) for r in reqs if r.session.failed is None}
        w.batcher.fail_requests(reqs, err)
        w.stats.bump("sessions_poisoned", len(newly))
        for r in reqs:
            r.session.inflight -= 1
        self._inflight -= len(reqs)
        self._done.notify_all()

    # -- internals: placement ----------------------------------------------

    @staticmethod
    def _spec_key(spec: TenantSpec) -> Tuple:
        """Spec-derivable placement shard key — the group-key fields known
        BEFORE an engine is built (the true `group_key()` needs the built
        engine's resolved tile). Specs that would share a stacked launch
        share this key, so affinity placement keeps them co-resident."""
        return (spec.cfg, spec.backend, spec.tile_m, spec.formats)

    def _healthy(self) -> List[FleetWorker]:
        return [w for w in self.workers if w.device_lost is None]

    def _place(self, key: Tuple) -> FleetWorker:
        """Least-loaded healthy worker (tenant count, then recorded
        launches — the `TrafficStats`-driven rebalance), preferring a
        worker already hosting this placement key among equals."""
        healthy = self._healthy()
        if not healthy:
            raise RuntimeError("fleet has no healthy workers left")
        with self._state:
            loads = {w.idx: (len(w.tenants),
                             0 if w.groups.get(key, 0) > 0 else 1,
                             sum(ts.launches
                                 for ts in w.batcher.traffic.values()),
                             w.idx)
                     for w in healthy}
        return min(healthy, key=lambda w: loads[w.idx])

    # -- internals: heartbeat + migration ----------------------------------

    def _heartbeat_loop(self) -> None:
        """The fleet's clock: pump time-based flushes on every healthy
        worker and sweep for dead workers needing migration."""
        while not self._stop.is_set():
            wait = self.policy.max_wait_s
            self._stop.wait(min(max(wait / 4.0, 1e-3), 0.05))
            if self._stop.is_set():
                return
            try:
                with self._mutex:
                    if self._stop.is_set():
                        return
                    self._absorb_dead_workers()
                    for w in self._healthy():
                        with self._state:
                            self._dispatch_locked(
                                w, w.batcher.take_ready())
            except Exception as e:  # noqa: BLE001 — keep the clock alive
                with self._state:
                    self._record_error_locked(e)

    def _absorb_dead_workers(self) -> None:
        """Migrate every dead, not-yet-absorbed worker (`_mutex` held)."""
        for w in self.workers:
            if w.device_lost is not None and not w.absorbed:
                self._migrate_worker(w)

    def _migrate_worker(self, dead: FleetWorker) -> None:
        """Rehome a dead worker's sessions and replay its un-landed work.

        Collection (under `_state`) gathers, in per-session FIFO order:
        stranded batches (the failing launch first, then anything the
        strander caught), still-queued batches, and never-assembled
        pending requests. Each session is rebuilt on a surviving worker
        from spec + carry snapshot (`Session.rebuild_on`), its requests
        re-pointed and adopted into the target's batcher, and re-launched
        via `take_session` — same plans, deterministic rebuild, identical
        width buckets, so the migrated stream is bitwise-equal to offline
        (contract #10) and every chunk lands exactly once. Sessions over
        their `RecoveryPolicy` budget (or unrebuildable, or with no
        healthy worker left) are poisoned."""
        err = dead.device_lost
        with self._state:
            batches = list(dead.stranded)
            dead.stranded.clear()
            while True:
                try:
                    b = dead.q.get_nowait()
                except queue.Empty:
                    break
                batches.append(b)
            stranded_by: Dict[str, List[Request]] = {}
            for b in batches:
                for r in b.reqs:
                    stranded_by.setdefault(
                        r.session.spec.tenant_id, []).append(r)
            pending_by: Dict[str, List[Request]] = {}
            for r in dead.batcher.evict_all():
                pending_by.setdefault(
                    r.session.spec.tenant_id, []).append(r)
            tids = sorted(set(dead.tenants)
                          | set(stranded_by) | set(pending_by))
            dead.absorbed = True
            self._migrations += 1
        dead.pool.clear()              # the dead device's engines are junk
        for tid in tids:
            stranded = stranded_by.get(tid, [])
            pending = pending_by.get(tid, [])
            old = self._sessions[tid]
            if old.failed is not None:
                self._drop_migrating(dead, old, stranded, pending,
                                     old.failed)
                continue
            old.recoveries += 1
            if old.recoveries > self.recovery.max_session_recoveries:
                self._drop_migrating(dead, old, stranded, pending, err)
                continue
            try:
                target = self._place(self._placekeys[tid])
            except RuntimeError as e:   # no healthy workers left
                self._drop_migrating(dead, old, stranded, pending, e)
                continue
            new_s, berr = self._rebuild_on(old, target)
            if new_s is None:
                self._drop_migrating(dead, old, stranded, pending,
                                     berr or err)
                continue
            with self._state:
                key = self._placekeys[tid]
                self._sessions[tid] = new_s
                self._homes[tid] = target
                dead.tenants.discard(tid)
                dead.groups[key] -= 1
                target.tenants.add(tid)
                target.groups[key] += 1
                replay = stranded + pending
                for r in replay:
                    r.session = new_s
                if self.obs.tracer.enabled:
                    t = self.clock()
                    for r in replay:
                        span = getattr(r.plan, "span", None)
                        if span is not None:
                            span.event("migrate", t,
                                       src=dead.idx, dst=target.idx)
                    self.obs.tracer.instant("migrate_session", tenant=tid,
                                            src=dead.idx, dst=target.idx)
                if replay:
                    target.batcher.adopt_requests(replay)
                    # stranded requests kept their in-flight accounting
                    # through the strand (never landed); pending ones were
                    # never accounted — account them now so one landing
                    # discipline covers the whole replay
                    new_s.inflight += len(pending)
                    self._inflight += len(pending)
                    for b in target.batcher.take_session(new_s):
                        target.q.put(b)
                    target.stats.bump("chunks_replayed", len(replay))
                target.stats.bump("recoveries")
                target.stats.bump("sessions_migrated_in")
                target.stats.record_recovery(self.clock() - dead.died_at)
                dead.stats.bump("sessions_migrated_out")
                self._done.notify_all()

    def _drop_migrating(self, dead: FleetWorker, s: Session,
                        stranded: List[Request], pending: List[Request],
                        err: BaseException) -> None:
        """Poison one session during migration (budget exhausted, rebuild
        failed, or nowhere left to go). Only the stranded requests carry
        in-flight accounting; pending ones never did."""
        with self._state:
            reqs = stranded + pending
            if reqs:
                dead.batcher.fail_requests(reqs, err)
            if s.failed is None:
                s.failed = err
            dead.stats.bump("sessions_poisoned")
            s.inflight -= len(stranded)
            self._inflight -= len(stranded)
            self._done.notify_all()

    def _rebuild_on(self, old: Session, target: FleetWorker):
        """Rebuild a session on `target` (bounded build retries; no locks
        held — engine builds are slow). Returns (session, None) or
        (None, last error)."""
        err: Optional[BaseException] = None
        rng = random.Random(7)          # migration is controller-driven
        for attempt in range(self.recovery.build_retries + 1):
            if attempt:
                time.sleep(self.recovery.backoff_s(attempt - 1, rng))
            try:
                s = old.rebuild_on(target.pool)
                target.stats.bump("engine_rebuilds")
                return s, None
            except Exception as e:  # noqa: BLE001 — bounded retries
                err = e
        return None, err
