"""Wire parity — the packetized data/control plane bench and hard gate.

Serves an fp32 tenant and an int8 tenant through the FULL network path —
control-plane OPEN over the wire, sample DATA frames through `NetIngress`
→ `ServeRuntime` → `NetEgress`, symbol frames reassembled client-side —
over a deterministic seeded loopback transport that reorders AND
duplicates datagrams in both directions, then records in
`BENCH_net.json` at the repo root:

  * throughput — end-to-end framed syms/s and frames/s (host-speed
    dependent, trend-watching only; `--check` does NOT gate on rates).
  * criteria.net_ok — the HARD host-independent gate, four parts:
      - bitwise: every tenant's wire-delivered symbol stream equals
        offline full-stream equalization bit-for-bit (the int8 tenant
        rides an int8 wire on its layer-0 requant grid — requantization
        idempotence makes the lossy wire bitwise-transparent);
      - exactly_once: received symbol counts match offline exactly (no
        loss, no duplication) and no tenant surfaced a wire error;
      - impairments_fired: the wire really reordered and duplicated
        datagrams this run (a vacuous pass on a clean wire proves
        nothing);
      - control_ok: both tenants were opened AND closed via control
        frames with success acks, and a deliberately malformed command
        drew an error ack.
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Optional

import jax
import numpy as np

from repro.core import equalizer as eq
from repro.net import (ControlAckError, NetClient, NetGateway, WireSchedule,
                       loopback_pair)
from repro.serve import BatchPolicy, ServeRuntime, chop, replay_wire
from repro.serve.session import TenantSpec

from .common import Bench

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT_PATH = REPO_ROOT / "BENCH_net.json"

CFG = eq.CNNEqConfig()
TILE_M = 32
INT8_FMT = tuple((2, 5, 3, 4) for _ in range(CFG.layers))
N_SYMS = 480
CHUNK_SYMS = 60
REORDER_WINDOW = 6
DUP_PROB = 0.2
BURST = 4


def _weights(seed: int):
    params = eq.init(jax.random.PRNGKey(seed), CFG)
    folded = eq.fold_bn(params, eq.init_bn_state(CFG), CFG)
    return eq.folded_weights(folded)


def _offline(spec: TenantSpec, wave: np.ndarray) -> np.ndarray:
    import jax.numpy as jnp
    return np.asarray(spec.build_engine()(jnp.asarray(wave[None])))[0]


def run(out_path: Optional[pathlib.Path] = OUT_PATH) -> dict:
    bench = Bench("net_wire", "packetized data+control plane: wire parity")
    tenants = {"t0": ("fused_fp32", None), "t1": ("fused_int8", INT8_FMT)}
    w = {t: _weights(600 + i) for i, t in enumerate(sorted(tenants))}
    rng = np.random.default_rng(42)
    waves = {t: rng.standard_normal(N_SYMS * CFG.n_os).astype(np.float32)
             for t in sorted(tenants)}
    offline = {t: _offline(TenantSpec(t, CFG, weights=w[t],
                                      formats=tenants[t][1],
                                      backend=tenants[t][0], tile_m=TILE_M),
                           waves[t])
               for t in tenants}

    cli_t, srv_t = loopback_pair(
        WireSchedule(seed=11, reorder_window=REORDER_WINDOW,
                     dup_prob=DUP_PROB),
        WireSchedule(seed=12, reorder_window=REORDER_WINDOW,
                     dup_prob=DUP_PROB))
    rt = ServeRuntime(BatchPolicy(max_batch=len(tenants), max_wait_s=1e9))
    gw = NetGateway(rt, srv_t)
    client = NetClient(cli_t)

    # control plane: OPEN both tenants over the wire
    opened = {}
    for t in sorted(tenants):
        backend, formats = tenants[t]
        opened[t] = client.open(t, CFG, w[t], formats=formats,
                                backend=backend, tile_m=TILE_M,
                                pump=gw.step)
    # a malformed command must draw an error ack, not damage the server
    try:
        client.command("t0", {"reg": 999}, pump=gw.step)
        bad_cmd_rejected = False
    except ControlAckError:
        bad_cmd_rejected = True

    streams = {t: chop(waves[t], CHUNK_SYMS * CFG.n_os, seed=i, jitter=0.5)
               for i, t in enumerate(sorted(waves))}
    t0 = time.perf_counter()
    acct = replay_wire(gw, client, streams, burst=BURST)
    elapsed = time.perf_counter() - t0

    received = {t: client.symbols(t) for t in tenants}
    bitwise = all(bool(np.array_equal(received[t], offline[t]))
                  for t in tenants)
    exactly_once = (not acct["errors"]
                    and all(received[t].shape == offline[t].shape
                            for t in tenants))
    closed_ok = True
    for t in sorted(tenants):
        try:
            client.close(t, pump=gw.step)
        except (ControlAckError, TimeoutError):
            closed_ok = False

    net = rt.obs.snapshot()["net"]
    saw_reorder = net["reordered"] > 0
    saw_dup = net["duplicates"] > 0
    wire_stats = {"client_tx": cli_t.stats, "server_tx": srv_t.stats}
    impairments_fired = bool(
        saw_reorder and saw_dup
        and wire_stats["client_tx"]["duplicated"] > 0
        and wire_stats["server_tx"]["duplicated"] > 0)
    control_ok = bool(all(a.get("ok") for a in opened.values())
                      and bad_cmd_rejected and closed_ok)
    criteria = {
        "bitwise": bool(bitwise),
        "exactly_once": bool(exactly_once),
        "impairments_fired": impairments_fired,
        "control_ok": control_ok,
        "net_ok": bool(bitwise and exactly_once and impairments_fired
                       and control_ok),
    }

    total_syms = int(sum(o.shape[0] for o in offline.values()))
    frames = int(net["frames_in"] + net["frames_out"])
    print(f"[bench_net] {total_syms} syms over {frames} frames in "
          f"{elapsed:.2f}s ({total_syms / elapsed:,.0f} sym/s)")
    print(f"[bench_net] wire: reordered={net['reordered']} "
          f"duplicates={net['duplicates']} gaps={net['gaps']} "
          f"crc_errors={net['crc_errors']}")
    print(f"[bench_net] bitwise={bitwise} exactly_once={exactly_once} "
          f"impairments_fired={impairments_fired} control_ok={control_ok}")
    print(f"[bench_net] net_ok={criteria['net_ok']}")

    report = {
        "backend_default": jax.default_backend(),
        "scenario": {
            "tenants": {t: tenants[t][0] for t in sorted(tenants)},
            "tile_m": TILE_M, "n_syms": N_SYMS, "chunk_syms": CHUNK_SYMS,
            "reorder_window": REORDER_WINDOW, "dup_prob": DUP_PROB,
            "burst": BURST,
        },
        "throughput": {
            "syms_per_s": total_syms / elapsed if elapsed else 0.0,
            "frames_per_s": frames / elapsed if elapsed else 0.0,
            "note": ("host-speed dependent; --check gates only on "
                     "criteria.net_ok"),
        },
        "wire": {**wire_stats, "net_counters": {
            k: v for k, v in net.items() if isinstance(v, (int, float))}},
        "criteria": criteria,
    }
    if out_path is not None:
        out_path.write_text(json.dumps(report, indent=2))
        print(f"[bench_net] wrote {out_path}")
    bench.record("report", report)
    return bench.finish()


if __name__ == "__main__":
    run()
