"""EqualizerEngine — the single production inference path.

Everything downstream of training funnels through this object: stream
partitioning (`core.stream_partition.partitioned_apply`), halo-exchange
sharding (`parallel.halo.halo_apply`), the examples, and the equalizer
benchmarks all consume an engine instead of hand-rolled `apply_folded`
lambdas. The engine owns:

  * BN folding (done once, at construction — the FPGA deployment step),
  * backend selection:
      - "ref"        pure-jnp stream-semantics oracle (kernels.cnn_eq.ref),
      - "fused_fp32" the fused Pallas kernel — same math as "ref",
      - "fused_int8" the quantized fused Pallas kernel: int8 weights at
        QAT's learned per-layer scales, int8×int8 MXU dots with int32
        accumulation and fused requantization between layers,
      - "auto"       fused_int8 when trained QAT formats deploy to int8
        (qat.deployment_plan), else fused_fp32,
  * tile_m selection: an explicit int, or "auto" → the cached autotune
    sweep (core.autotune) keyed on (topology, backend).

An engine is a plain callable `(W,) | (B, W) waveform → symbols`, so it
drops into every site that previously took an `apply_fn`.

All backends share STREAM semantics (one halo pad, VALID convs — see
kernels/cnn_eq/ref.py), so swapping backends never changes results beyond
floating-point fusion noise; the property tests in tests/test_engine.py
assert ≤2-ULP fp32 agreement with the oracle everywhere and ≤1-LSB int8
agreement with the QAT fake-quant reference (observed: exact).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax.numpy as jnp

from . import autotune as autotune_lib
from . import qat as qat_lib
from .equalizer import (CNNEqConfig, fold_bn, folded_weights, init_bn_state,
                        layer_strides)

BACKENDS = ("ref", "fused_fp32", "fused_int8")

Format = Tuple[int, int, int, int]          # (w_int, w_frac, a_int, a_frac)


def _folded_fit_grid(weights, formats) -> bool:
    """True iff every BN-folded weight is representable on its layer's
    learned Q(w_int).(w_frac) grid without saturating."""
    for (w, _), (wi, wf, _, _) in zip(weights, formats):
        hi = 2.0 ** wi - 2.0 ** -wf
        lo = -(2.0 ** wi)
        if float(jnp.max(w)) > hi or float(jnp.min(w)) < lo:
            return False
    return True


@dataclasses.dataclass
class EqualizerEngine:
    """Callable quantized/fused inference engine for the CNN equalizer.

    Build with `EqualizerEngine.from_params` (trained params + BN state,
    QAT formats picked up automatically) or directly from folded weights.
    """
    cfg: CNNEqConfig
    weights: Tuple[Tuple[jnp.ndarray, jnp.ndarray], ...]  # BN-folded, fp32
    backend: str = "fused_fp32"
    tile_m: int | str = "auto"
    formats: Optional[Tuple[Format, ...]] = None          # int8 backend only
    interpret: Optional[bool] = None

    def __post_init__(self):
        if self.backend == "auto":
            self.backend = ("fused_int8" if self._int8_deployable()
                            else "fused_fp32")
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"expected one of {BACKENDS + ('auto',)}")
        if self.backend == "fused_int8":
            if not self._int8_deployable():
                raise ValueError(
                    "fused_int8 needs per-layer formats that fit int8 "
                    "(qat.deployment_plan(...)['all_int8']); got "
                    f"{self.formats}")
            from ..kernels.cnn_eq.cnn_eq import quantize_weights_int8
            self._qweights = quantize_weights_int8(self.weights, self.formats)
        self._strides = layer_strides(self.cfg)

    # -- construction ------------------------------------------------------

    @classmethod
    def from_params(cls, params: Dict[str, Any], bn_state: Optional[Dict],
                    cfg: CNNEqConfig, backend: str = "auto",
                    tile_m: int | str = "auto",
                    interpret: Optional[bool] = None) -> "EqualizerEngine":
        """Deployment step: fold BN, derive int8 scales from learned QAT
        formats (`qat.deployment_plan`), pick the backend.

        QAT learns Q(w_int) on the UNfolded weights; folding multiplies by
        g = scale/√(var+ε), which can push weights past the learned grid.
        Silently saturating them would break the train→deploy accuracy
        contract, so auto-deployment only goes int8 when the FOLDED weights
        still fit each layer's grid; otherwise it falls back to fused_fp32.
        """
        folded = fold_bn(params, bn_state or init_bn_state(cfg), cfg)
        weights = folded_weights(folded)
        formats = None
        if "qat" in params:
            plan = qat_lib.deployment_plan(params["qat"])
            if plan["all_int8"] and _folded_fit_grid(weights,
                                                    plan["formats"]):
                formats = plan["formats"]
        return cls(cfg=cfg, weights=weights, backend=backend,
                   tile_m=tile_m, formats=formats, interpret=interpret)

    @classmethod
    def from_folded(cls, folded: Dict[str, Any], cfg: CNNEqConfig,
                    **kw) -> "EqualizerEngine":
        return cls(cfg=cfg, weights=folded_weights(folded), **kw)

    # -- backend plumbing --------------------------------------------------

    def _int8_deployable(self) -> bool:
        return (self.formats is not None
                and all(wi + wf + 1 <= 8 and ai + af + 1 <= 8
                        for wi, wf, ai, af in self.formats))

    def resolved_tile_m(self) -> int:
        """The tile width actually used (runs the autotune sweep if 'auto')."""
        if isinstance(self.tile_m, int):
            return self.tile_m
        if self.backend == "ref":
            return 64                              # ref has no tiling knob
        best = autotune_lib.best_tile_m(
            self.cfg, self.backend,
            lambda t: self._make_fn(t))
        self.tile_m = best
        return best

    def _make_fn(self, tile_m: int) -> Callable[[jnp.ndarray], jnp.ndarray]:
        if self.backend == "ref":
            from ..kernels.cnn_eq.ref import cnn_eq as ref_fn
            return functools.partial(ref_fn, weights=self.weights,
                                     strides=self._strides)
        if self.backend == "fused_fp32":
            from ..kernels.cnn_eq.cnn_eq import cnn_eq_fused
            return lambda x: cnn_eq_fused(x, self.weights, self._strides,
                                          tile_m=tile_m,
                                          interpret=self.interpret)
        from ..kernels.cnn_eq.cnn_eq import cnn_eq_fused_int8
        return lambda x: cnn_eq_fused_int8(x, self._qweights, self._strides,
                                           self.formats, tile_m=tile_m,
                                           interpret=self.interpret)

    # -- the production path -----------------------------------------------

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        """(S·N_os,) or (B, S·N_os) waveform → (S,) or (B, S) soft symbols."""
        squeeze = x.ndim == 1
        if squeeze:
            x = x[None]
        y = self._make_fn(self.resolved_tile_m())(x)
        return y[0] if squeeze else y

    def describe(self) -> Dict[str, Any]:
        """Deployment summary (for logs / benchmark records)."""
        return {
            "backend": self.backend,
            "tile_m": self.tile_m if isinstance(self.tile_m, int) else "auto",
            "layers": self.cfg.layers,
            "formats": self.formats,
        }
