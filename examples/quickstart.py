"""Quickstart: train the paper's CNN equalizer on the simulated 40 GBd
IM/DD optical channel and compare it with a linear FIR at the SAME
complexity (paper Fig. 2's headline comparison), then run the deployment
path (BN folded, fused Pallas kernel in interpret mode).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.channels import imdd
from repro.core.equalizer import CNNEqConfig
from repro.core.fir import FIRConfig
from repro.core.train_eq import EqTrainConfig, train_equalizer
from repro.data.equalizer_data import channel_fn
from repro.kernels.cnn_eq import ops as cnn_ops


def main():
    key = jax.random.PRNGKey(0)
    fn = channel_fn("imdd", imdd.IMDDConfig())
    tcfg = EqTrainConfig(steps=600, batch=8, seq_syms=256, lr=3e-3,
                         eval_syms=1 << 14)

    print("training the paper's CNN (V_p=8, L=3, K=9, C=5) …")
    cnn_cfg = CNNEqConfig()
    params, bn, cnn = train_equalizer(key, "cnn", cnn_cfg, fn, tcfg)
    print(f"  CNN  ({cnn_cfg.mac_per_symbol():.1f} MAC/sym): "
          f"BER {cnn['ber']:.3e}")

    print("training a same-complexity linear FIR …")
    _, _, fir = train_equalizer(key, "fir", FIRConfig(taps=57), fn, tcfg)
    print(f"  FIR  (57.0 MAC/sym): BER {fir['ber']:.3e}")

    # deployment path: fold BN and run the fused Pallas kernel
    rx, syms = imdd.simulate(key, imdd.IMDDConfig(), 4096)
    y = cnn_ops.equalize(params, bn, rx, cnn_cfg, use_pallas=True)
    from repro.channels.common import ber_from_soft
    print(f"fused-kernel deployment BER on a fresh frame: "
          f"{float(ber_from_soft(y, syms, 2)):.3e}")
    print("done — see benchmarks/ for the full paper-figure reproductions.")


if __name__ == "__main__":
    main()
