"""Packetized network front-end for the serving stack (`repro.net`).

The wire the paper's FPGA receiver implies: a framed sample data plane
(`frame.py` codec, `gateway.py` ingress/egress with bounded-reorder
reassembly and credit-based backpressure), a register-style control
plane (`control.py`), a driving client (`client.py`), and pluggable
transports (`transport.py`: deterministic impaired loopback + real UDP).
"""
from .client import ControlAckError, NetClient
from .control import (ControlError, ControlPlane, Reg, arrays_to_weights,
                      pack_control, unpack_control, weights_to_arrays)
from .frame import (Frame, FrameError, FrameType, WireDtype, BadCRC,
                    BadField, BadLength, BadMagic, BadVersion, decode_frame,
                    decode_samples, encode_frame, encode_samples,
                    samples_per_frame, wire_grid)
from .gateway import (NetEgress, NetGateway, NetIngress, Reassembler,
                      handle_done, handle_result)
from .transport import (LoopbackTransport, UdpTransport, WireSchedule,
                        loopback_pair)

__all__ = [
    "BadCRC", "BadField", "BadLength", "BadMagic", "BadVersion",
    "ControlAckError", "ControlError", "ControlPlane", "Frame",
    "FrameError", "FrameType", "LoopbackTransport", "NetClient",
    "NetEgress", "NetGateway", "NetIngress", "Reassembler", "Reg",
    "UdpTransport", "WireDtype", "WireSchedule", "arrays_to_weights",
    "decode_frame", "decode_samples", "encode_frame", "encode_samples",
    "handle_done", "handle_result", "loopback_pair", "pack_control",
    "samples_per_frame", "unpack_control", "weights_to_arrays",
    "wire_grid",
]
