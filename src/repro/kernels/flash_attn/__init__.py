from .flash_attn import attention_costs, flash_attention
from .ref import mha as mha_ref
