"""Doc-reference checker — keeps docs/*.md from rotting silently.

Every code reference in the documentation must resolve against the source
tree, so a rename/refactor that orphans a doc reference fails the same
gate as a perf regression (`benchmarks/run.py --check` runs this first;
it is also a standalone tier-2 check):

    PYTHONPATH=src python -m tools.check_docs [files...]

Checked reference forms (inline ``code`` spans, plus path-like tokens
inside fenced blocks):

  R1  repo paths        `src/repro/serve/runtime.py`, `docs/QUANTIZATION.md`
                        — token contains "/" and a known extension; must
                        exist relative to the repo root.
  R2  anchored refs     `src/repro/serve/chunker.py::StreamChunker.commit`
                        — file must exist AND every dot-separated symbol
                        component must appear as a word in the file.
  R3  module paths      `repro.core.autotune`, `benchmarks.bench_serve`,
                        optionally with a trailing symbol
                        (`repro.core.autotune.best_tile_m`) — the module
                        must resolve under src/ (or the repo root for
                        benchmarks/tools/tests), and the symbol, if any,
                        must appear in the module file.
  R4  callables         `best_tile_m()` — a `def`/`class` of that name
                        must exist somewhere in the python tree.
  R5  backend names     `fused_int8`, … — must be members of
                        `BACKENDS` in src/repro/core/engine.py.
  R6  rootless files    `BENCH_serve.json`, `README.md` — extension but no
                        slash; must exist at the repo root or in docs/.

Unrecognized tokens are ignored (the checker is a tripwire for the forms
the docs promise to keep resolvable, not a general linter).
"""
from __future__ import annotations

import pathlib
import re
import subprocess
import sys
from typing import Dict, List, Tuple

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
DEFAULT_DOCS = sorted(REPO_ROOT.glob("docs/*.md")) + [REPO_ROOT / "README.md"]

_EXTS = (".py", ".md", ".json", ".txt", ".ini")
_PATHY = re.compile(
    r"\b(?:src|docs|tools|tests|benchmarks|examples|reports)/[\w./-]+")
_FENCE = re.compile(r"```.*?```", re.S)
_INLINE = re.compile(r"`([^`\n]+)`")
_MODULE = re.compile(r"^(repro|benchmarks|tools|tests)(\.\w+)+$")
_CALLABLE = re.compile(r"^(\w+)\(\)$")
_BACKEND = re.compile(r"^(ref|fused_\w+)$")
_ROOTLESS = re.compile(r"^[\w.-]+\.(json|md|ini)$")

_file_cache: Dict[pathlib.Path, str] = {}


def _read(path: pathlib.Path) -> str:
    if path not in _file_cache:
        _file_cache[path] = path.read_text(errors="replace")
    return _file_cache[path]


def _backends() -> List[str]:
    src = _read(REPO_ROOT / "src" / "repro" / "core" / "engine.py")
    m = re.search(r"^BACKENDS\s*=\s*\(([^)]*)\)", src, re.M)
    names = re.findall(r"\"(\w+)\"", m.group(1)) if m else []
    return names + ["auto"]


def _gitignored(token: str) -> bool:
    """True if git ignores the path — i.e. it names a generated artifact
    whose absence on a fresh clone is expected, not doc rot."""
    try:
        rc = subprocess.run(["git", "-C", str(REPO_ROOT), "check-ignore",
                             "-q", token], timeout=10,
                            stdout=subprocess.DEVNULL,
                            stderr=subprocess.DEVNULL).returncode
    except (OSError, subprocess.TimeoutExpired):
        return False
    return rc == 0


def _symbol_in(path: pathlib.Path, symbol: str) -> bool:
    text = _read(path)
    return all(re.search(rf"\b{re.escape(part)}\b", text)
               for part in symbol.split("."))


def _module_path(dotted: str) -> Tuple[pathlib.Path | None, str | None]:
    """Resolve `pkg.mod[.Symbol…]` → (file, trailing symbol or None)."""
    parts = dotted.split(".")
    root = REPO_ROOT / "src" if parts[0] == "repro" else REPO_ROOT
    for cut in range(len(parts), 0, -1):
        base = root.joinpath(*parts[:cut])
        candidate = None
        if base.with_suffix(".py").is_file():
            candidate = base.with_suffix(".py")
        elif (base / "__init__.py").is_file():
            candidate = base / "__init__.py"
        if candidate is not None:
            rest = ".".join(parts[cut:]) or None
            return candidate, rest
    return None, None


def _defined_somewhere(name: str) -> bool:
    for sub in ("src", "benchmarks", "tools", "examples", "tests"):
        for path in (REPO_ROOT / sub).rglob("*.py"):
            if re.search(rf"^\s*(?:def|class)\s+{re.escape(name)}\b",
                         _read(path), re.M):
                return True
    return False


def _check_token(token: str, backends: List[str]) -> str | None:
    """Return an error message for a resolvable-form token, else None."""
    token = token.strip()
    if "::" in token:                                            # R2
        path_s, _, symbol = token.partition("::")
        if not path_s or not symbol:         # bare `::Name` prose, not a ref
            return None
        path = REPO_ROOT / path_s
        if not path.is_file():
            return f"anchored ref: no such file {path_s!r}"
        if not _symbol_in(path, symbol):
            return f"anchored ref: {symbol!r} not found in {path_s!r}"
        return None
    if "/" in token and token.endswith(_EXTS):                   # R1
        if "*" in token:                     # glob ref, e.g. docs/*.md
            if not any(REPO_ROOT.glob(token)):
                return f"glob matches nothing: {token!r}"
        elif not ((REPO_ROOT / token).exists() or _gitignored(token)):
            # gitignored paths are GENERATED artifacts (e.g. the autotune
            # disk cache): legitimate references even on a fresh clone
            return f"path does not exist: {token!r}"
        return None
    if _MODULE.match(token):                                     # R3
        path, symbol = _module_path(token)
        if path is None:
            return f"module does not resolve: {token!r}"
        if symbol and not _symbol_in(path, symbol):
            return f"symbol {symbol!r} not found in module file {path.name}"
        return None
    m = _CALLABLE.match(token)                                   # R4
    if m:
        if not _defined_somewhere(m.group(1)):
            return f"no def/class named {m.group(1)!r} in the tree"
        return None
    if _BACKEND.match(token):                                    # R5
        if token not in backends:
            return (f"backend {token!r} not in engine BACKENDS "
                    f"{tuple(backends)}")
        return None
    if _ROOTLESS.match(token):                                   # R6
        if not ((REPO_ROOT / token).exists()
                or (REPO_ROOT / "docs" / token).exists()):
            return f"file {token!r} not at repo root or docs/"
        return None
    return None                                  # unrecognized form: ignore


def check_file(path: pathlib.Path) -> List[str]:
    text = path.read_text()
    tokens = set(_INLINE.findall(_FENCE.sub("", text)))
    for fence in _FENCE.findall(text):           # paths inside code blocks
        tokens.update(_PATHY.findall(fence))
    errors = []
    backends = _backends()
    try:
        label = str(path.relative_to(REPO_ROOT))
    except ValueError:                       # doc outside the repo (tests)
        label = path.name
    for token in sorted(tokens):
        err = _check_token(token, backends)
        if err:
            errors.append(f"{label}: {err}")
    return errors


def main(argv=None) -> int:
    files = ([pathlib.Path(a).resolve() for a in argv] if argv
             else DEFAULT_DOCS)
    files = [f for f in files if f.exists()]
    if not files:
        print("[check_docs] no doc files found")
        return 2
    all_errors = []
    checked = 0
    for f in files:
        errs = check_file(f)
        all_errors.extend(errs)
        checked += 1
    for e in all_errors:
        print(f"[check_docs] STALE: {e}")
    print(f"[check_docs] {checked} file(s) checked, "
          f"{len(all_errors)} stale reference(s)")
    return 1 if all_errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
