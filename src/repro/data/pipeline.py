"""Sharded token data pipeline.

Production shape: each host materializes only ITS shard of the global batch
(`jax.make_array_from_callback`), so no host ever holds the full batch —
the same code path works at 1 host (this container) and at pod scale.

The source here is a deterministic synthetic LM stream (seeded per (step,
shard) so restarts are reproducible and elastic resharding yields identical
global batches); a real deployment swaps `TokenSource` for a tokenized
corpus reader with identical framing.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    seq_len: int
    global_batch: int
    accum: int = 1               # leading grad-accumulation axis
    seed: int = 0


class TokenSource:
    """Deterministic synthetic token stream: shard-addressable, stateless.

    `block(step, row)` returns the row's tokens — a function of (seed, step,
    row) only, so any host can materialize any row (elastic restarts change
    WHICH rows a host holds, never their contents).
    """

    def __init__(self, cfg: PipelineConfig, vocab: int):
        self.cfg = cfg
        self.vocab = vocab

    def block(self, step: int, row: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, row]))
        # Markov-ish stream: runs of repeated tokens → learnable structure
        n = self.cfg.seq_len
        changes = rng.random(n) < 0.3
        fresh = rng.integers(0, self.vocab, size=n)
        out = np.empty(n, np.int64)
        cur = fresh[0]
        for i in range(n):
            if changes[i]:
                cur = fresh[i]
            out[i] = cur
        return out.astype(np.int32)


def _global_batch_array(source: TokenSource, step: int, shape, mesh: Mesh,
                        spec: P) -> jax.Array:
    """Materialize per-device shards only (production data loading)."""
    sharding_ = NamedSharding(mesh, spec)

    def cb(index) -> np.ndarray:
        # index: tuple of slices into the global array for one device
        rows = range(*index[-2].indices(shape[-2])) \
            if len(shape) >= 2 else [0]
        accs = range(*index[0].indices(shape[0])) if len(shape) == 3 \
            else [None]
        out = []
        for a in accs:
            block_rows = []
            for r in rows:
                row_id = r if a is None else a * shape[-2] + r
                block_rows.append(source.block(step, row_id))
            out.append(np.stack(block_rows))
        arr = np.stack(out) if len(shape) == 3 else out[0]
        # slice the seq dim if the device holds a partial column
        return arr[..., index[-1]]

    return jax.make_array_from_callback(shape, sharding_, cb)


def lm_batches(cfg: PipelineConfig, model_cfg: ModelConfig, mesh: Mesh,
               batch_spec: Dict[str, P], start_step: int = 0
               ) -> Iterator[Dict[str, jax.Array]]:
    """Yields {tokens, labels[, enc_embed | embed_prefix]} global arrays."""
    source = TokenSource(cfg, model_cfg.vocab)
    mb = cfg.global_batch // cfg.accum
    step = start_step
    while True:
        shape = (cfg.accum, mb, cfg.seq_len)
        toks = _global_batch_array(source, step, shape, mesh,
                                   batch_spec["tokens"])
        batch = {"tokens": toks, "labels": toks}
        if model_cfg.family == "encdec":
            e = jnp.zeros((cfg.accum, mb, model_cfg.enc_len,
                           model_cfg.d_model), model_cfg.param_dtype())
            batch["enc_embed"] = jax.device_put(
                e, NamedSharding(mesh, batch_spec["enc_embed"]))
        if model_cfg.family == "vlm":
            e = jnp.zeros((cfg.accum, mb, model_cfg.img_tokens,
                           model_cfg.d_model), model_cfg.param_dtype())
            batch["embed_prefix"] = jax.device_put(
                e, NamedSharding(mesh, batch_spec["embed_prefix"]))
        yield batch
        step += 1
