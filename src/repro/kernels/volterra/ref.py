"""Pure-jnp oracle for the Volterra equalizer kernel (orders 0–3).

Stream semantics like cnn_eq: input padded once by the max memory half-length,
windows gathered per output symbol.
"""
from __future__ import annotations

import jax.numpy as jnp


def _windows(xp: jnp.ndarray, m: int, stride: int, n_out: int, off: int
             ) -> jnp.ndarray:
    idx = jnp.arange(n_out)[:, None] * stride + jnp.arange(m)[None, :] + off
    return xp[:, idx]


def volterra(x: jnp.ndarray, w0: jnp.ndarray, w1: jnp.ndarray,
             w2: jnp.ndarray | None, w3: jnp.ndarray | None,
             stride: int) -> jnp.ndarray:
    """x: (B, W) → (B, W//stride).  w1: (M1,), w2: (M2, M2), w3: (M3,M3,M3)."""
    m1 = w1.shape[0]
    m2 = w2.shape[0] if w2 is not None else 0
    m3 = w3.shape[0] if w3 is not None else 0
    halo = max(m1 // 2, m2 // 2, m3 // 2)
    n_out = x.shape[1] // stride
    xp = jnp.pad(x, ((0, 0), (halo, halo))).astype(jnp.float32)

    y = jnp.broadcast_to(w0.astype(jnp.float32), (x.shape[0], n_out))
    win1 = _windows(xp, m1, stride, n_out, halo - m1 // 2)
    y = y + jnp.einsum("bnm,m->bn", win1, w1.astype(jnp.float32))
    if w2 is not None and m2 > 0:
        win2 = _windows(xp, m2, stride, n_out, halo - m2 // 2)
        y = y + jnp.einsum("bni,bnj,ij->bn", win2, win2,
                           w2.astype(jnp.float32))
    if w3 is not None and m3 > 0:
        win3 = _windows(xp, m3, stride, n_out, halo - m3 // 2)
        y = y + jnp.einsum("bni,bnj,bnk,ijk->bn", win3, win3, win3,
                           w3.astype(jnp.float32))
    return y.astype(x.dtype)
